"""End-to-end training driver (deliverable b): train a ~100M-parameter
llama-family model for a few hundred steps on the synthetic bigram
stream and checkpoint it.

    PYTHONPATH=src python examples/train_lm.py --steps 300
(defaults to a short smoke run; --steps 300 is the full run)
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.models import build_model
from repro.training.data import DataConfig, TokenStream
from repro.training.optimizer import AdamWConfig
from repro.training.trainer import TrainConfig, train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=60)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=256)
ap.add_argument("--ckpt", default="/tmp/repro_lm_100m.npz")
ap.add_argument("--full-size", action="store_true",
                help="~100M params (slow on CPU); default is a small proxy")
args = ap.parse_args()

base = get_config("smollm-360m")
if args.full_size:
    # ~100M-class: 12L x 768d, GQA 12/4, ff 3072, 16k vocab
    cfg = dataclasses.replace(base, num_layers=12, d_model=768,
                              num_heads=12, num_kv_heads=4, head_dim=64,
                              d_ff=3072, vocab_size=16384,
                              name="smollm-100m")
else:
    cfg = dataclasses.replace(base.reduced(), num_layers=4, d_model=256,
                              vocab_size=2048, name="smollm-tiny")
model = build_model(cfg)
n_params = sum(p.size for p in jax.tree.leaves(
    jax.eval_shape(model.init, jax.random.PRNGKey(0))))
print(f"model {cfg.name}: {n_params / 1e6:.1f}M params")
params = model.init(jax.random.PRNGKey(0))
stream = TokenStream(cfg, DataConfig(batch_size=args.batch,
                                     seq_len=args.seq))
hist = train(model, params, stream,
             TrainConfig(steps=args.steps, log_every=max(args.steps // 15, 1),
                         ckpt_path=args.ckpt,
                         opt=AdamWConfig(lr=6e-4,
                                         warmup_steps=args.steps // 10,
                                         total_steps=args.steps)))
print(f"loss {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f}; "
      f"checkpoint at {args.ckpt}")
assert hist["loss"][-1] < hist["loss"][0]
