"""Live KV migration demo: decode a request on engine A, migrate its KV
slice mid-generation to engine B, finish there — and verify the output
is bit-identical to an unmigrated run.

    PYTHONPATH=src python examples/migrate_demo.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving.engine import Engine
from repro.serving.request import ServeRequest, State

cfg = get_config("smollm-360m").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
prompt = rng.integers(0, cfg.vocab_size, 24).astype(np.int32)

# reference: full decode on one engine
ref_eng = Engine(0, model, params, max_slots=2, max_seq=96)
ref = ServeRequest(0, prompt.copy(), 30)
ref_eng.submit(ref)
while ref.state != State.FINISHED:
    ref_eng.step()

# migrated: 10 steps on A, then move to B
a = Engine(1, model, params, max_slots=2, max_seq=96)
b = Engine(2, model, params, max_slots=2, max_seq=96)
req = ServeRequest(1, prompt.copy(), 30)
a.submit(req)
for _ in range(10):
    a.step()
print(f"generated {len(req.generated)} tokens on engine A "
      f"(length {req.length})")
_, piece, nbytes = a.export_slot(req.slot)
a.evict_slot(req.slot)
assert b.import_request(req, piece)
print(f"migrated {nbytes / 1024:.1f} KiB of KV to engine B")
while req.state != State.FINISHED:
    b.step()
print("tokens by engine:", req.tokens_by_engine)
assert req.generated == ref.generated, "migration must not change decode"
print("OK: migrated generation is bit-identical to the unmigrated run")
