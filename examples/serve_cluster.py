"""Serve a small model with batched requests across a CascadeInfer
multi-engine cluster (end-to-end driver, deliverable b).

Real JAX compute: paged-slot KV caches, continuous batching, and the
shared control plane (`repro.control`) doing length routing, growth-
triggered live migration with bid-ask negotiation, and adaptive
boundaries — the identical policy code the simulator runs. Arrivals are
open-loop (`submit_at`) and every generated token streams through a
callback.

    PYTHONPATH=src python examples/serve_cluster.py [--requests 24]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core.partition import PipelinePlan, Stage
from repro.core.qoe import QoEModel
from repro.models import build_model
from repro.serving.request import ServeRequest
from repro.serving.server import MILSServer, ServerConfig

ap = argparse.ArgumentParser()
ap.add_argument("--requests", type=int, default=24)
ap.add_argument("--engines", type=int, default=4)
ap.add_argument("--policy", default="cascade")
ap.add_argument("--refinement", default="adaptive",
                choices=["adaptive", "quantity", "memory", "none"])
ap.add_argument("--balancing", default="full",
                choices=["full", "inter-stage", "rr"])
args = ap.parse_args()

cfg = get_config("smollm-360m").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
E = args.engines
plan = PipelinePlan([Stage(0.0, 48.0, E - E // 2),
                     Stage(48.0, float("inf"), E // 2)], 0.0)
qoe = QoEModel(np.array([1e-3, 1e-4, 1e-6, 0.0, 1e-6]))

streamed = [0]
srv = MILSServer(model, params, plan, qoe,
                 ServerConfig(policy=args.policy,
                              refinement=args.refinement,
                              balancing=args.balancing, refine_every=16),
                 max_slots=3, max_seq=128,
                 on_token=lambda req, tok: streamed.__setitem__(
                     0, streamed[0] + 1))

rng = np.random.default_rng(1)
for i in range(args.requests):
    req = ServeRequest(i,
                       rng.integers(0, cfg.vocab_size,
                                    int(rng.integers(8, 40))
                                    ).astype(np.int32),
                       int(rng.integers(8, 70)))
    srv.submit_at(req, step=2 * i)        # open-loop Poisson-ish arrivals
fin = srv.run(max_steps=60 * args.requests)
s = srv.summary()
print(f"policy={args.policy} finished={s['finished']} "
      f"steps={s['steps']} migrations={s['migrations']} "
      f"streamed-tokens={streamed[0]} "
      f"TTFT mean/p95={s['ttft_steps_mean']:.1f}/{s['ttft_steps_p95']:.1f} "
      f"E2E mean/p99={s['e2e_steps_mean']:.1f}/{s['e2e_steps_p99']:.1f}")
print("per-stage migrations:",
      {k: v for k, v in s.items() if k.startswith("migrations_s")})
print("final stage bounds:", [(round(a), "inf" if b == float("inf")
                               else round(b)) for a, b in srv.stage_bounds])
per_engine = {e.id: e.tokens_out for e in srv.engines}
print("tokens per engine:", per_engine)
assert streamed[0] == s["tokens_out"], "streaming missed tokens"
