"""Quickstart: the CascadeInfer pipeline in five minutes on CPU.

1. profile a (simulated) instance and fit the QoE model (§4.1)
2. plan the length-specialized pipeline with the DP (§4.2)
3. run the 16-instance cluster sim: round-robin vs CascadeInfer
4. run a REAL tiny model through the multi-engine server with live
   KV migration.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.sim.experiment import (compare_policies, fitted_qoe,
                                  plan_pipeline)

print("== 1. profile + fit QoE model (paper §4.1)")
qoe = fitted_qoe("llama3.2-3b")
print("   D =", np.array2string(qoe.D, precision=3))

print("== 2. length-aware stage partition (paper §4.2)")
plan = plan_pipeline("llama3.2-3b", qoe, E=16)
for i, s in enumerate(plan.stages):
    hi = "inf" if s.hi == float("inf") else f"{s.hi:.0f}"
    print(f"   stage {i}: lengths [{s.lo:.0f}, {hi})  "
          f"x{s.num_instances} instances")

print("== 3. simulate 16 instances under load (paper §6.2/6.3)")
res = compare_policies("llama3.2-3b", rate=40.0, duration=20.0, E=16)
for kind, r in res.items():
    s = r.summary()
    print(f"   {kind:12s} TTFT {s['ttft_mean']:.3f}s  "
          f"TPOT {s['tpot_mean'] * 1e3:.1f}ms  "
          f"throughput {s['throughput_tok_s']:.0f} tok/s")

print("== 4. real JAX engines + live KV migration")
from repro.core.partition import PipelinePlan, Stage
from repro.core.qoe import QoEModel
from repro.serving.request import ServeRequest
from repro.serving.server import MILSServer, ServerConfig

cfg = get_config("smollm-360m").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
srv = MILSServer(model, params,
                 PipelinePlan([Stage(0.0, 48.0, 2),
                               Stage(48.0, float("inf"), 2)], 0.0),
                 QoEModel(np.array([1e-3, 1e-4, 1e-6, 0.0, 1e-6])),
                 ServerConfig(policy="cascade"), max_slots=3, max_seq=96)
rng = np.random.default_rng(0)
reqs = [ServeRequest(i, rng.integers(0, cfg.vocab_size, 20)
                     .astype(np.int32), int(rng.integers(10, 50)))
        for i in range(8)]
srv.run(reqs, max_steps=400)
print("  ", srv.summary())
print("done.")
