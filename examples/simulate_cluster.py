"""Reproduce the paper's cluster experiment (Figs. 6/7/10) at chosen scale.

    PYTHONPATH=src python examples/simulate_cluster.py --rate 40 --arch llama3.2-3b
"""
import argparse

from repro.sim.experiment import compare_policies

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="llama3.2-3b")
ap.add_argument("--rate", type=float, default=40.0)
ap.add_argument("--duration", type=float, default=20.0)
ap.add_argument("--instances", type=int, default=16)
args = ap.parse_args()

res = compare_policies(args.arch, rate=args.rate, duration=args.duration,
                       E=args.instances)
print(f"{'policy':14s} {'TTFT(s)':>9s} {'p95':>9s} {'TPOT(ms)':>9s} "
      f"{'p95':>9s} {'tok/s':>8s}")
for kind, r in res.items():
    s = r.summary()
    print(f"{kind:14s} {s['ttft_mean']:9.3f} {s['ttft_p95']:9.3f} "
          f"{s['tpot_mean'] * 1e3:9.2f} {s['tpot_p95'] * 1e3:9.2f} "
          f"{s['throughput_tok_s']:8.0f}")
base = res["round-robin"].summary()
ca = res["cascade"].summary()
print(f"\ncascade vs round-robin: TTFT -{(1 - ca['ttft_mean'] / base['ttft_mean']) * 100:.0f}%  "
      f"TPOT -{(1 - ca['tpot_mean'] / base['tpot_mean']) * 100:.0f}%  "
      f"throughput x{ca['throughput_tok_s'] / base['throughput_tok_s']:.2f}")
