"""Paper Figs. 9/10/11: system throughput across request rates."""
from __future__ import annotations

from benchmarks.common import ARCH, CAPACITY, DURATION, E, row, standalone
from repro.sim.experiment import compare_policies


def run():
    rows = []
    for rate in (8.0, 24.0, 40.0):
        res = compare_policies(ARCH, rate=rate, duration=DURATION, E=E,
                               capacity_tokens=CAPACITY)
        thr = {k: r.throughput() for k, r in res.items()}
        rows.append(row(f"fig10/throughput@{rate:g}", thr["cascade"],
                        cascade=thr["cascade"], round_robin=thr["round-robin"],
                        llumnix=thr["llumnix"],
                        x_vs_rr=thr["cascade"] / max(thr["round-robin"], 1e-9),
                        x_vs_llumnix=thr["cascade"] / max(thr["llumnix"],
                                                          1e-9)))
    return rows


if __name__ == "__main__":
    standalone("fig10_throughput", run)
