"""Paper Figs. 9 & 11: platform and tensor-parallel sensitivity.

(a) Memory-constrained testbed (the paper's L40, 48 GB vs H20 141 GB):
    smaller KV capacity caps batch sizes and narrows the heterogeneity
    gap -> CascadeInfer's gains shrink but stay positive.
(b) Tensor parallelism (paper's Llama-70B TP=2/4): TP divides per-chip
    weight-access overhead, so attention heterogeneity dominates more and
    CascadeInfer's relative benefit grows with TP degree.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import ARCH, DURATION, row, standalone
from repro.sim.experiment import compare_policies


def run():
    rows = []
    # (a) capacity sweep: H20-like vs L40-like KV budgets
    for name, cap in (("h20-like", 400_000.0), ("l40-like", 120_000.0)):
        res = compare_policies(ARCH, rate=32.0, duration=DURATION, E=16,
                               capacity_tokens=cap)
        thr = {k: r.throughput() for k, r in res.items()}
        nl = {k: float(np.mean(r.normalized_latency()))
              for k, r in res.items()}
        rows.append(row(f"fig9_11/{name}", nl["cascade"] * 1e6,
                        thr_x_vs_rr=thr["cascade"] / max(thr["round-robin"],
                                                         1e-9),
                        nl_vs_rr=nl["cascade"] / max(nl["round-robin"],
                                                     1e-9),
                        cap_tokens=cap))
    # (b) TP sweep on a large model: qwen2.5-14b, 16 chips total
    from repro.sim.experiment import fitted_qoe, make_policy, run_policy
    from repro.sim.workload import WorkloadSpec, generate
    from repro.sim.cluster import RoundRobinPolicy
    from repro.core.partition import PipelinePlan, Stage

    arch = "qwen2.5-14b"
    for tp in (2, 4):
        E = 16 // tp
        rate = 24.0 / tp
        reqs = generate(WorkloadSpec(rate=rate, duration=DURATION, seed=13))
        qoe = fitted_qoe(arch, tp=tp)
        plan = PipelinePlan([Stage(0.0, 1500.0, E - E // 2),
                             Stage(1500.0, float("inf"), E // 2)], 0.0)
        from repro.sim.cluster import CascadePolicy
        rr = run_policy(arch, RoundRobinPolicy(), reqs, DURATION, E=E,
                        capacity_tokens=400_000.0 * tp, tp=tp)
        ca = run_policy(arch, CascadePolicy(plan, qoe), reqs, DURATION,
                        E=E, capacity_tokens=400_000.0 * tp, tp=tp)
        rows.append(row(f"fig9_11/tp{tp}", ca.summary()["tpot_mean"] * 1e6,
                        thr_x_vs_rr=ca.throughput() / max(rr.throughput(),
                                                          1e-9),
                        tpot_vs_rr=(ca.summary()["tpot_mean"]
                                    / max(rr.summary()["tpot_mean"], 1e-9)),
                        instances=E))
    return rows


if __name__ == "__main__":
    standalone("fig9_11_testbeds_tp", run)
