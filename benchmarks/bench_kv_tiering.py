"""Multi-tier KV cache: demote cold prefix chains to host RAM under
memory pressure, promote them back on a hit (DESIGN.md §Multi-tier KV).

Three views:

  * **engine** — a real reduced-model engine serves prompt P cold, then a
    large pressure prompt Q whose allocations reclaim (and, with the host
    tier on, DEMOTE) P's parked chain, then P again warm: the warm run
    must produce bit-identical greedy tokens while skipping >= 90% of the
    prefill block-work (the promoted blocks are staged h2d, not
    recomputed) and keeping the decode loop's one-d2h-per-step contract.
    ``host_kv_budget=0`` measures the drop-on-reclaim baseline: same
    pressure, zero hit, full recompute.
  * **parity** — the SAME 4-request trace (warm group -> pressure ->
    pressure -> re-admit group) through the discrete-event simulator AND
    the real server; their control planes must log identical route
    decisions, with the final arrival steered by the tiered-hit warm
    filter (host-warm instance) instead of the RR rotation, and both
    sides counting demotions and promotions.
  * **sim** — `compare_policies(workload="shared_prefix",
    host_kv_budget=...)` under tight per-instance capacity: the cluster
    tiering experiment (TTFT + tier traffic, tiered vs drop-on-reclaim).

Emits BENCH_kv_tiering.json at the repo root; `run()` feeds
benchmarks/run.py. The asserted acceptance (CI smoke): warm-after-
eviction tokens bit-identical to cold, >= 90% of prefill block-work
skipped with tiering ON (0% OFF), warm TTFT strictly below cold, no
extra d2h during the warm serve, and sim-vs-server route-decision
parity on the demote -> route-on-tiered-hit -> promote trace.

Run: PYTHONPATH=src python benchmarks/bench_kv_tiering.py
     [--prompt 2048] [--pressure 2560] [--budget 64] [--new-tokens 8]
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

try:
    from benchmarks.common import write_artifact
except ImportError:                     # run as a plain script
    from common import write_artifact

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import engine as engine_mod
from repro.serving.block_pool import blocks_for
from repro.serving.engine import Engine
from repro.serving.request import ServeRequest, State


def _serve(eng, req):
    """Submit and drain one request; returns (wall TTFT seconds,
    steps taken, d2h calls) — the last two bound the decode hot loop:
    tier traffic must never add a sync d2h inside step()."""
    eng.submit(req)
    steps0, d2h0 = eng.steps, engine_mod.D2H_CALLS
    t0 = time.perf_counter()
    ttft = None
    while req.state is not State.FINISHED:
        eng.step()
        if ttft is None and req.first_token_step is not None:
            ttft = time.perf_counter() - t0
    eng.allocator.check_invariants()
    return ttft, eng.steps - steps0, engine_mod.D2H_CALLS - d2h0


def run_engine_scenario(model, params, *, prompt_len, pressure_len, budget,
                        new_tokens, host_kv_budget, seed=0):
    """cold P -> pressure Q (demotes P's chain) -> warm P (promotes)."""
    rng = np.random.default_rng(seed)
    vocab = model.cfg.vocab_size
    bs = 16
    max_seq = 1 << (pressure_len + 2 * new_tokens + 64).bit_length()
    # pool sized so Q fits but its allocations must reclaim nearly all of
    # P's parked chain: the head blocks demote (or drop) depth-first
    num_blocks = blocks_for(pressure_len + new_tokens, bs) + 4
    eng = Engine(0, model, params, max_slots=2, max_seq=max_seq,
                 token_budget=num_blocks * bs, block_size=bs,
                 prefill_token_budget=budget, attn_backend="dense",
                 prefix_cache=True, host_kv_budget=host_kv_budget)
    # jit warmup on DIFFERENT prompts (same shapes, disjoint chains)
    # through the SAME cold -> pressure -> warm sequence, so the measured
    # runs pay no compilation — including the promote-scatter shape,
    # which only the warm-after-eviction path traces
    dummy = rng.integers(0, vocab, prompt_len).astype(np.int32)
    dummy_q = rng.integers(0, vocab, pressure_len).astype(np.int32)
    _serve(eng, ServeRequest(7, dummy.copy(), new_tokens))
    _serve(eng, ServeRequest(8, dummy_q.copy(), new_tokens))
    _serve(eng, ServeRequest(9, dummy.copy(), new_tokens))

    prompt = rng.integers(0, vocab, prompt_len).astype(np.int32)
    pressure = rng.integers(0, vocab, pressure_len).astype(np.int32)
    work0 = eng.prefill_work_blocks
    cold = ServeRequest(0, prompt.copy(), new_tokens)
    cold_ttft, _, _ = _serve(eng, cold)
    cold_work = eng.prefill_work_blocks - work0

    demote0, drop0 = eng.cache_demotions, eng.cache_drops
    _serve(eng, ServeRequest(1, pressure.copy(), new_tokens))
    demotions = eng.cache_demotions - demote0
    drops = eng.cache_drops - drop0

    promo0, pblocks0 = eng.cache_promotions, eng.promoted_blocks_total
    work1 = eng.prefill_work_blocks
    warm = ServeRequest(2, prompt.copy(), new_tokens)
    warm_ttft, warm_steps, warm_d2h = _serve(eng, warm)
    warm_work = eng.prefill_work_blocks - work1
    eng.check_drained()
    return {
        "host_kv_budget": host_kv_budget,
        "prompt_len": prompt_len,
        "pressure_len": pressure_len,
        "pool_blocks": num_blocks,
        "cold_ttft_s": cold_ttft,
        "warm_ttft_s": warm_ttft,
        "cold_work_blocks": cold_work,
        "warm_work_blocks": warm_work,
        "block_work_skipped": 1.0 - warm_work / max(cold_work, 1),
        "warm_cached_tokens": int(warm.cached_tokens),
        "demotions": int(demotions),
        "drops": int(drops),
        "promotions": int(eng.cache_promotions - promo0),
        "promoted_blocks": int(eng.promoted_blocks_total - pblocks0),
        "warm_steps": int(warm_steps),
        "warm_d2h_calls": int(warm_d2h),
        "tokens": {"cold": list(cold.generated),
                   "warm": list(warm.generated)},
    }


def _parity_trace():
    from repro.sim.workload import Request
    # req0 publishes group-0's chain on instance 0 (RR), req1 lands on
    # instance 1 (RR), req2 lands on instance 0 and its allocations
    # demote the idle group-0 chain, req3 re-admits the group: the warm
    # filter must steer it to the HOST-warm instance 0 (pure RR would
    # pick instance 1) and the admission promotes the chain back.
    return [Request(0, 0.0, 96, 8, prefix_group=0, prefix_len=95),
            Request(1, 5.0, 120, 8),
            Request(2, 6.0, 120, 8),
            Request(3, 30.0, 96, 8, prefix_group=0, prefix_len=95)]


def run_parity_scenario(*, seed=0):
    """Same demote -> route-on-tiered-hit -> promote trace through the
    simulator and the real server; route decision logs must match."""
    import math

    from repro.core.partition import PipelinePlan, Stage
    from repro.core.qoe import QoEModel
    from repro.serving.server import (MILSServer, ServerConfig,
                                      requests_from_trace)
    from repro.sim.cluster import CascadePolicy
    from repro.sim.experiment import run_policy

    trace = _parity_trace()
    plan = PipelinePlan([Stage(0.0, math.inf, 2)], 0.0)
    qoe = QoEModel(np.array([1e-3, 1e-4, 1e-6, 0.0, 1e-6]))
    # 12-block pools: the group chain (5-6 blocks) plus a 120-token
    # pressure prompt (8 blocks) cannot coexist, so admission must demote
    pool_tokens, host_tokens = 192, 192

    pol = CascadePolicy(plan, qoe, refinement="none", balancing="rr")
    res = run_policy("llama3.2-3b", pol, trace, 60.0, E=2,
                     capacity_tokens=pool_tokens, seed=seed,
                     prefill_token_budget=64, prefix_cache=True,
                     preemption=False, host_kv_budget=host_tokens)
    sim_routes = [d for d in pol.plane.decisions if d[0] == "route"]
    sim_sum = res.summary()

    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def factory(i):
        return Engine(i, model, params, max_slots=2, max_seq=256,
                      token_budget=pool_tokens, block_size=16,
                      prefill_token_budget=64, attn_backend="dense",
                      prefix_cache=True, host_kv_budget=host_tokens,
                      preemption=False)

    srv = MILSServer(model, params, plan, qoe,
                     ServerConfig(policy="cascade", refinement="none",
                                  balancing="rr", seed=seed,
                                  preemption=False,
                                  host_kv_budget=host_tokens),
                     max_slots=2, max_seq=256, engine_factory=factory)
    for req, step in requests_from_trace(trace, vocab_size=cfg.vocab_size,
                                         max_seq=256, seed=seed):
        srv.submit_at(req, step)
    srv.run(max_steps=400)
    srv_routes = [d for d in srv.plane.decisions if d[0] == "route"]
    srv_sum = srv.summary()
    return {
        "sim_routes": [list(d) for d in sim_routes],
        "server_routes": [list(d) for d in srv_routes],
        "sim": {k: sim_sum[k] for k in
                ("completed", "cache_demotions", "cache_drops",
                 "cache_promotions", "promoted_blocks_total")},
        "server": {"finished": len(srv.finished),
                   **{k: srv_sum[k] for k in
                      ("cache_demotions", "cache_drops",
                       "cache_promotions", "promoted_blocks_total")}},
    }


def run_sim_scenario(*, rate=8.0, duration=8.0, E=4, seed=0):
    """Cluster tiering experiment: shared-prefix workload under tight
    per-instance capacity, tiered vs drop-on-reclaim."""
    from repro.sim.experiment import compare_policies
    out = {}
    for label, budget in (("tiered", 2048), ("drop", 0)):
        res = compare_policies("llama3.2-3b", rate=rate, duration=duration,
                               E=E, seed=seed, workload="shared_prefix",
                               capacity_tokens=3000.0,
                               prefill_token_budget=512,
                               host_kv_budget=budget, kinds=("cascade",))
        s = res["cascade"].summary()
        out[label] = {"ttft_mean_s": s["ttft_mean"],
                      "ttft_p95_s": s["ttft_p95"],
                      "completed": s["completed"],
                      "cache_demotions": s["cache_demotions"],
                      "cache_drops": s["cache_drops"],
                      "cache_promotions": s["cache_promotions"]}
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--prompt", type=int, default=2048,
                    help="prompt length shared by the cold and warm run")
    ap.add_argument("--pressure", type=int, default=2560,
                    help="pressure prompt whose allocations demote the "
                         "parked chain")
    ap.add_argument("--budget", type=int, default=64,
                    help="prompt-chunk tokens per mixed iteration; the "
                         "chunk-grid work counter is quadratic in chunk "
                         "count, so the >=90%% skip needs >=19 cold chunks")
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--host-kv-budget", type=int, default=4096)
    ap.add_argument("--skip-sim", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    out = {"config": {"arch": cfg.name, "prompt": args.prompt,
                      "pressure": args.pressure, "budget": args.budget,
                      "jax_backend": jax.default_backend()}}
    on = run_engine_scenario(model, params, prompt_len=args.prompt,
                             pressure_len=args.pressure,
                             budget=args.budget,
                             new_tokens=args.new_tokens,
                             host_kv_budget=args.host_kv_budget)
    off = run_engine_scenario(model, params, prompt_len=args.prompt,
                              pressure_len=args.pressure,
                              budget=args.budget,
                              new_tokens=args.new_tokens,
                              host_kv_budget=0)
    # acceptance: the tier changes latency/work only, never tokens — the
    # warm-after-eviction run is bit-identical to cold on BOTH settings
    assert on["tokens"]["warm"] == on["tokens"]["cold"], \
        "tiered warm tokens diverged from cold"
    assert off["tokens"]["warm"] == off["tokens"]["cold"]
    assert on["tokens"]["cold"] == off["tokens"]["cold"], \
        "host tier changed cold-path tokens"
    assert on["demotions"] > 0, "pressure prompt demoted nothing"
    assert on["promotions"] > 0 and on["promoted_blocks"] > 0, \
        "warm re-admit promoted nothing"
    assert on["block_work_skipped"] >= 0.90, \
        f"only {on['block_work_skipped']:.1%} of prefill block-work skipped"
    assert off["block_work_skipped"] <= 0.0 and off["promotions"] == 0, \
        "drop-on-reclaim baseline unexpectedly hit the cache"
    assert on["warm_ttft_s"] < on["cold_ttft_s"], \
        "warm-after-eviction TTFT not below cold"
    # promote staging stays async: exactly the decode loop's one sync
    # d2h per step, nothing extra
    assert on["warm_d2h_calls"] == on["warm_steps"], \
        (on["warm_d2h_calls"], on["warm_steps"])
    for d in (on, off):
        d.pop("tokens")
    out["engine_tiered"], out["engine_drop"] = on, off
    print(f"-- cold ttft {on['cold_ttft_s']*1e3:8.1f} ms  "
          f"work {on['cold_work_blocks']} blocks")
    print(f"-- warm ttft {on['warm_ttft_s']*1e3:8.1f} ms  "
          f"work {on['warm_work_blocks']} blocks  "
          f"({on['block_work_skipped']:.1%} skipped; "
          f"{on['demotions']} demoted, {on['promoted_blocks']} promoted)")
    print(f"-- drop-on-reclaim warm work {off['warm_work_blocks']} blocks "
          f"({off['block_work_skipped']:.1%} skipped)")

    par = run_parity_scenario()
    assert par["sim_routes"] == par["server_routes"], \
        f"route decisions diverged: {par['sim_routes']} " \
        f"vs {par['server_routes']}"
    assert par["sim_routes"][-1][2] == par["sim_routes"][0][2], \
        "tiered-hit arrival not steered back to the demoting instance"
    for side in ("sim", "server"):
        assert par[side]["cache_demotions"] > 0, (side, par[side])
        assert par[side]["cache_promotions"] > 0, (side, par[side])
    out["parity"] = par
    print(f"-- parity routes {par['server_routes']}  "
          f"(sim == server; demote+promote on both)")

    if not args.skip_sim:
        out["sim"] = run_sim_scenario()
        for k, v in out["sim"].items():
            print(f"-- sim {k:7s} ttft mean {v['ttft_mean_s']:.3f} s  "
                  f"demotions {v['cache_demotions']}  "
                  f"promotions {v['cache_promotions']}")

    print("wrote", write_artifact("kv_tiering", out))
    return out


def run():
    """benchmarks/run.py entry: engine scenario + parity + sim compare."""
    from benchmarks.common import row
    out = main(["--prompt", "2048", "--pressure", "2560",
                "--budget", "64", "--new-tokens", "8"])
    on = out["engine_tiered"]
    rows = [row("kv_tiering/engine/cold", on["cold_ttft_s"] * 1e6,
                work_blocks=on["cold_work_blocks"]),
            row("kv_tiering/engine/warm", on["warm_ttft_s"] * 1e6,
                work_blocks=on["warm_work_blocks"],
                skipped=on["block_work_skipped"],
                promoted=on["promoted_blocks"]),
            row("kv_tiering/engine/drop",
                out["engine_drop"]["warm_ttft_s"] * 1e6,
                work_blocks=out["engine_drop"]["warm_work_blocks"])]
    for k, v in out.get("sim", {}).items():
        rows.append(row(f"kv_tiering/sim/{k}", v["ttft_mean_s"] * 1e6,
                        ttft_p95=v["ttft_p95_s"], completed=v["completed"],
                        demotions=v["cache_demotions"]))
    return rows


if __name__ == "__main__":
    main()
