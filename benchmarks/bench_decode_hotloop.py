"""Decode hot loop: device-resident engine vs. the host-driven loop.

For the same 16-way request mix — heterogeneous (128x prompt-length
spread) and uniform (same total tokens) — this measures, per decode step:

  * wall time / steps-per-second (after jit warmup),
  * device→host synchronizations (counted through `engine.d2h`). The
    host loop's sampling is already fused to one sync per decode step
    (this PR); its remaining tax is host-driven state — per-step
    block-table rebuild + upload and per-prefill syncs — which the
    device-resident loop removes, and `step(burst=n)` amortizes the one
    remaining sync across n fused steps,
  * grid accounting (acceptance): the flat grid runs Σ_b ceil(L_b/BS)
    work items (± pow2 bucket padding) where the padded grid ran
    B·max_b ceil(L_b/BS).

Emits BENCH_decode_hotloop.json at the repo root.

Run: PYTHONPATH=src python benchmarks/bench_decode_hotloop.py
     [--new-tokens N] [--burst B] [--backend dense|grid|flat]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

try:
    from benchmarks.common import write_artifact
except ImportError:                     # run as a plain script
    from common import write_artifact

import jax
import numpy as np

import repro.serving.engine as engine_mod
from repro.configs import get_config
from repro.kernels.cost import pow2_bucket
from repro.models import build_model
from repro.serving.block_pool import blocks_for
from repro.serving.engine import Engine
from repro.serving.request import ServeRequest

MAX_SEQ = 256
BLOCK_SIZE = 16
# 16-way heterogeneous: 128x spread, the regime of PAPER.md Fig. 2
HETERO = [2, 2, 3, 4, 4, 6, 8, 8, 12, 16, 24, 32, 48, 64, 96, 120]
UNIFORM = [sum(HETERO) // len(HETERO)] * len(HETERO)


def serve(model, params, prompts, new_tokens, *, device_resident, burst,
          backend):
    eng = Engine(0, model, params, max_slots=len(prompts), max_seq=MAX_SEQ,
                 paged=True, block_size=BLOCK_SIZE,
                 device_resident=device_resident, attn_backend=backend,
                 # one-step admission: this bench measures the decode hot
                 # loop, so the whole mix must enter (and finish) together
                 prefill_token_budget=sum(prompts) + len(prompts))

    def drain(measure: bool):
        rng = np.random.default_rng(0)
        reqs = [ServeRequest(i, rng.integers(0, model.cfg.vocab_size, p)
                             .astype(np.int32), new_tokens)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        eng.step(burst)        # admission + prefill: excluded from timing
        d2h0, steps0 = engine_mod.D2H_CALLS, eng.steps
        t0 = time.perf_counter()
        while any(r.finish_step is None for r in reqs):
            eng.step(burst)
        dt = time.perf_counter() - t0
        return dt, eng.steps - steps0, engine_mod.D2H_CALLS - d2h0

    drain(measure=False)             # jit warmup: identical request mix
    dt, steps, syncs = drain(measure=True)   # warm caches, decode-only
    grid = dict(eng.last_grid)       # grid accounting of the final decode
    steps = max(steps, 1)
    return {
        "decode_step_ms": dt / steps * 1e3,
        "steps_per_s": steps / dt,
        "host_syncs_per_step": syncs / steps,
        "grid": grid,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--burst", type=int, default=8)
    ap.add_argument("--backend", default=None,
                    choices=["dense", "grid", "flat"])
    args = ap.parse_args()

    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    out = {"config": {"arch": cfg.name, "max_seq": MAX_SEQ,
                      "block_size": BLOCK_SIZE, "new_tokens": args.new_tokens,
                      "burst": args.burst, "backend": args.backend or "auto",
                      "jax_backend": jax.default_backend()}}
    for name, prompts in (("hetero", HETERO), ("uniform", UNIFORM)):
        paths = {
            "old_host_loop": dict(device_resident=False, burst=1,
                                  backend="dense"),
            "new_device_loop": dict(device_resident=True, burst=1,
                                    backend=args.backend),
            "new_device_burst": dict(device_resident=True, burst=args.burst,
                                     backend=args.backend),
        }
        res = {k: serve(model, params, prompts, args.new_tokens, **kw)
               for k, kw in paths.items()}
        out[name] = res
        print(f"-- {name}: prompts {prompts}")
        for k, r in res.items():
            print(f"   {k:18s} step {r['decode_step_ms']:8.2f} ms   "
                  f"host syncs/step {r['host_syncs_per_step']:5.2f}   "
                  f"grid {r['grid'] or '-'}")

    # acceptance: flat work count == Σ ceil(L_b/BS) (± pow2 bucket) on the
    # 16-way hetero batch, vs B·max_b ceil(L_b/BS) for the padded grid.
    # All 16 requests share max_new, so the final decode step (whose grid
    # accounting `serve` captured) sees lengths p + new_tokens - 1.
    g = out["hetero"]["new_device_loop"]["grid"]
    final = [p + args.new_tokens - 1 for p in HETERO]
    real = sum(blocks_for(l, BLOCK_SIZE) for l in final)
    assert g["real_items"] == real, (g, real)
    assert g["flat_items"] == pow2_bucket(real), g
    assert g["padded_items"] == len(HETERO) * max(
        blocks_for(l, BLOCK_SIZE) for l in final), g
    assert g["flat_items"] <= g["padded_items"] / 2, g
    # acceptance: the device loop makes exactly one sync per step
    for name in ("hetero", "uniform"):
        assert out[name]["new_device_loop"]["host_syncs_per_step"] <= 1.0 + 1e-9
        assert out[name]["old_host_loop"]["host_syncs_per_step"] >= 1.0
    ratio = (g["padded_items"] / g["flat_items"])
    ran = ("ran" if g.get("backend") == "flat"
           else f"would run (this run used backend={g.get('backend')})")
    print(f"flat grid {ran}: {g['flat_items']} items "
          f"(Σ ceil = {g['real_items']}) vs padded {g['padded_items']}  "
          f"-> {ratio:.1f}x fewer block iterations on the hetero batch")

    print("wrote", write_artifact("decode_hotloop", out))


if __name__ == "__main__":
    main()
