"""Benchmark harness — one module per paper table/figure (DESIGN.md §6).
Prints ``name,us_per_call,derived`` CSV and writes one ``BENCH_<module>.
json`` artifact per module at the REPO ROOT (stable schema; see
``benchmarks/common.py``), then folds them into ``BENCH_summary.json``.

    PYTHONPATH=src python -m benchmarks.run [--only fig2,fig13]
"""
from __future__ import annotations

import argparse
import sys
import time

try:
    from benchmarks.common import merge_artifacts, write_artifact
except ImportError:                     # run as a plain script
    from common import merge_artifacts, write_artifact

MODULES = [
    "fig2_heterogeneity",     # Fig. 2  kernel heterogeneity tax
    "fig67_latency",          # Figs. 6/7 TTFT + TPOT
    "fig8_single_instance",   # Fig. 8  single-instance parity
    "fig10_throughput",       # Figs. 9/10/11 throughput
    "fig9_11_testbeds_tp",    # Figs. 9/11 platform + TP sensitivity
    "fig12_slo",              # Fig. 12 SLO attainment
    "fig13_qoe_error",        # Fig. 13 QoE model error
    "fig14_layouts",          # Fig. 14 layout ablation
    "fig15_refinement",       # Fig. 15 refinement ablation
    "fig16_bidask",           # Fig. 16 bid-ask CV
    "tab_partition_speed",    # §6.5   partition complexity
    "bench_roofline",         # §Roofline summary from the dry-run
    "bench_longtail",         # §Chunked prefill: 32K-128K prompt tail,
                              # chunked vs monolithic sim iterations
    "bench_prefix_cache",     # §Prefix cache: cold vs warm TTFT +
                              # prefill work skipped; shared-prefix sim
    "bench_slo_sched",        # §SLO scheduling: preemptive vs FCFS
                              # goodput-under-SLO + bit-identical resume
    "bench_fault_tolerance",  # §Fault tolerance: kill 1 of 4 instances
                              # mid-trace; conservation + bounded p99
    "bench_sharded_engine",   # §Sharded serving: tp scan (resident KV
                              # ~tp x, bit-identical tokens) + hetero
                              # 2+1+1 cluster vs uniform 4x1 in sim
    "bench_kv_tiering",       # §Multi-tier KV: demote under pressure,
                              # promote on hit (>=90% work skipped,
                              # bit-identical) + sim/server route parity
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = [m.strip() for m in args.only.split(",") if m.strip()]

    print("name,us_per_call,derived")
    failures = 0
    for mod_name in MODULES:
        if only and not any(mod_name.startswith(o) for o in only):
            continue
        t0 = time.time()
        rows = []
        status = "ok"
        try:
            mod = __import__(f"benchmarks.{mod_name}",
                             fromlist=["run"])
            for r in mod.run():
                rows.append(r)
                print(f"{r['name']},{r['us_per_call']:.3f},{r['derived']}",
                      flush=True)
        except Exception as e:  # noqa: BLE001 — report all, fail at end
            failures += 1
            status = f"ERROR={type(e).__name__}:{e}"
            print(f"{mod_name},nan,{status}", flush=True)
        wall = time.time() - t0
        write_artifact(mod_name, {"status": status, "wall_s": wall},
                       rows=rows, merge=False)
        print(f"# {mod_name} took {wall:.1f}s", file=sys.stderr, flush=True)
    merge_artifacts()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
