"""Long-context tail (32K–128K prompts) under chunked vs. monolithic
prefill — the simulated-cluster view of the chunked-prefill win.

Same 16-instance cluster and policies as Figs. 6/7, but on the
``longtail`` trace (`sim.workload.longtail_spec`): a log-normal dialogue
body with a heavy 32K–128K *prompt* tail. Each policy runs twice — the
legacy monolithic prefill model (one compute-bound iteration per prompt,
the §2.1 head-of-line baseline) and the chunked mixed-iteration scheduler
(`ClusterConfig.prefill_token_budget`) — and reports TTFT/TPOT. TPOT is
the paper's inter-token metric: monolithic prefill of a 64K neighbor
shows up directly in a short request's p95 TPOT; chunking removes it.
"""
from __future__ import annotations

from benchmarks.common import ARCH, CAPACITY, E, row, standalone
from repro.sim.experiment import compare_policies

RATE = 6.0
DURATION = 20.0
BUDGET = 2048          # chunk tokens per mixed iteration


def run():
    rows = []
    for label, budget in (("mono", None), ("chunked", BUDGET)):
        res = compare_policies(ARCH, rate=RATE, duration=DURATION, E=E,
                               capacity_tokens=CAPACITY,
                               workload="longtail",
                               prefill_token_budget=budget,
                               kinds=("round-robin", "cascade"))
        for kind, r in res.items():
            s = r.summary()
            rows.append(row(
                f"longtail/{kind}/{label}", s["tpot_mean"] * 1e6,
                ttft_mean=s["ttft_mean"], ttft_p95=s["ttft_p95"],
                tpot_mean=s["tpot_mean"], tpot_p95=s["tpot_p95"],
                completed=s["completed"]))
    return rows


if __name__ == "__main__":
    standalone("bench_longtail", run)
