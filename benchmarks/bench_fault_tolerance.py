"""Fault tolerance under instance loss (DESIGN.md §Fault tolerance).

The acceptance experiment for ISSUE 8, run in BOTH drivers of the
shared control plane:

  * the discrete-event simulator on an open-loop ShareGPT-ish trace over
    4 instances, killing one mid-run — compared against the identical
    fault-free run; and
  * the real-JAX-engine ``MILSServer``, killing 1 of 4 engines while it
    holds live decodes.

Asserted on every run (this file is the CI smoke for the subsystem):

  * request conservation under the fault: every submitted request is
    served, rejected, or failed-within-budget — nothing hangs;
  * every re-dispatched request that completes does so with tokens
    bit-identical to the fault-free reference (server driver; greedy
    decode is deterministic, so recovery may not change it);
  * tail degradation is bounded: the faulty run's p99 TTFT stays within
    ``P99_DEGRADATION_MAX``x of fault-free (losing 1 of 4 instances may
    hurt, but must not collapse the tail).

Run: PYTHONPATH=src python -m benchmarks.bench_fault_tolerance
Exits nonzero if any assertion fails (standalone() records the error).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, standalone
from repro.control.faults import FaultSpec
from repro.sim.experiment import make_policy, run_policy
from repro.sim.workload import WorkloadSpec, generate

SIM_ARCH = "llama3.2-3b"
SIM_E = 4
SIM_RATE = 30.0
SIM_DURATION = 12.0
SIM_CAPACITY = 60_000.0
CRASH_AT_S = 4.0           # mid-trace: instance 1 dies holding residents
VICTIM = 1

SRV_ARCH = "smollm-360m"
P99_DEGRADATION_MAX = 5.0


def _sim_kill_one() -> list:
    reqs = generate(WorkloadSpec(rate=SIM_RATE, duration=SIM_DURATION,
                                 seed=11, max_context=4096))
    rows, res = [], {}
    for name, faults in (("faultfree", None),
                         ("crash", FaultSpec(seed=0,
                                             crashes=((VICTIM, CRASH_AT_S),)))):
        pol = make_policy("cascade", SIM_ARCH, SIM_E)
        res[name] = run_policy(SIM_ARCH, pol, reqs, SIM_DURATION + 20.0,
                               E=SIM_E, capacity_tokens=SIM_CAPACITY,
                               seed=0, prefill_token_budget=512,
                               faults=faults)
        fs = res[name].fault_summary()
        p99 = float(np.percentile(res[name].ttft(), 99))
        rows.append(row(f"fault_tolerance/sim_{name}", 0.0,
                        completed=len(res[name].completed),
                        served=len(res[name].served),
                        ttft_p99_s=p99,
                        failed=fs["failed"], redispatched=fs["redispatched"],
                        retries=fs["retries"],
                        downtime_s=fs["downtime_total"]))
    # conservation: the crash loses capacity, never requests
    assert len(res["crash"].completed) == len(reqs), (
        f"crash run lost requests: {len(res['crash'].completed)} of "
        f"{len(reqs)}")
    ids = [r.req.req_id for r in res["crash"].completed]
    assert len(set(ids)) == len(ids), "a request finished twice"
    fs = res["crash"].fault_summary()
    assert fs["redispatched"] > 0, (
        "killing a loaded instance mid-trace must strand residents")
    assert fs["downtime_total"] > 0
    # bounded tail degradation
    p99_ok = float(np.percentile(res["faultfree"].ttft(), 99))
    p99_bad = float(np.percentile(res["crash"].ttft(), 99))
    ratio = p99_bad / max(p99_ok, 1e-9)
    assert ratio <= P99_DEGRADATION_MAX, (
        f"p99 TTFT degraded {ratio:.1f}x (> {P99_DEGRADATION_MAX}x): "
        f"{p99_ok:.3f}s -> {p99_bad:.3f}s")
    rows.append(row("fault_tolerance/sim_p99_degradation", 0.0,
                    faultfree_s=p99_ok, crash_s=p99_bad, ratio=ratio,
                    bound=P99_DEGRADATION_MAX))
    return rows


def _server_kill_one() -> list:
    import jax

    from repro.configs import get_config
    from repro.core.partition import PipelinePlan, Stage
    from repro.core.qoe import QoEModel
    from repro.models import build_model
    from repro.serving.request import ServeRequest
    from repro.serving.server import MILSServer, ServerConfig

    cfg = get_config(SRV_ARCH).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 20).astype(np.int32)
               for _ in range(8)]
    plan = PipelinePlan([Stage(0.0, 48.0, 2),
                         Stage(48.0, float("inf"), 2)], 0.0)
    qoe = QoEModel(np.array([1e-3, 1e-4, 1e-6, 0.0, 1e-6]))

    def build(faults):
        return MILSServer(model, params, plan, qoe,
                          ServerConfig(policy="cascade", seed=0,
                                       faults=faults),
                          max_slots=3, max_seq=96)

    ref = build(None).run([ServeRequest(i, p.copy(), 40)
                           for i, p in enumerate(prompts)], max_steps=600)
    ref_toks = {r.req_id: list(r.generated) for r in ref}

    srv = build(FaultSpec(seed=0, crashes=((0, 12),)))
    fin = srv.run([ServeRequest(i, p.copy(), 40)
                   for i, p in enumerate(prompts)],
                  max_steps=1000, drain=True)
    assert len(fin) == len(prompts), "server crash run lost requests"
    recovered = [r for r in fin if r.redispatches]
    assert recovered, "engine 0 must have held residents at death"
    mismatched = [r.req_id for r in fin
                  if not r.failed and list(r.generated) != ref_toks[r.req_id]]
    assert not mismatched, (
        f"recovery changed greedy decode for requests {mismatched}")
    s = srv.summary()
    assert s["failed"] + len([r for r in fin if not r.failed]) == len(fin)
    return [row("fault_tolerance/server_kill_1_of_4", 0.0,
                finished=len(fin), recovered=len(recovered),
                failed=s["failed"], retries=s["retries"],
                downtime_steps=s["downtime_total"],
                bit_identical=1)]


def run() -> list:
    return _sim_kill_one() + _server_kill_one()


if __name__ == "__main__":
    standalone("fault_tolerance", run)
