"""Paper Fig. 13: QoE-model prediction error vs static predictor
(fit/validation split)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import ARCH, row, standalone
from repro.configs import get_config
from repro.core.qoe import fit_qoe, relative_errors, static_baseline_errors
from repro.sim.costmodel import profile_from_config
from repro.sim.profiler import profile_and_fit


def run():
    prof = profile_from_config(get_config(ARCH))
    _, F, Q = profile_and_fit(prof, horizon_s=8.0, seed=0,
                              return_samples=True)
    n = len(Q)
    rng = np.random.default_rng(0)
    idx = rng.permutation(n)
    cut = int(0.7 * n)
    fit_i, val_i = idx[:cut], idx[cut:]
    model = fit_qoe(F[fit_i], Q[fit_i])
    err = np.abs(relative_errors(model, F[val_i], Q[val_i]))
    base = np.abs(static_baseline_errors(F[val_i], Q[val_i]))
    return [row("fig13/qoe_error", float(err.mean()) * 100,
                model_mean_err=float(err.mean()),
                model_median_err=float(np.median(err)),
                static_mean_err=float(base.mean()),
                paper="model 8.9% vs static 64%")]


if __name__ == "__main__":
    standalone("fig13_qoe_error", run)
