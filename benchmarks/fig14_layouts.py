"""Paper Fig. 14: planned cascade vs chain vs no-pipeline layouts."""
from __future__ import annotations

import numpy as np

from benchmarks.common import ARCH, CAPACITY, DURATION, E, row, standalone
from repro.sim.cluster import CascadePolicy
from repro.sim.experiment import (chain_plan, fitted_qoe, no_pipeline_plan,
                                  plan_pipeline, run_policy)
from repro.sim.workload import WorkloadSpec, generate


def run():
    qoe = fitted_qoe(ARCH)
    reqs = generate(WorkloadSpec(rate=40.0, duration=DURATION, seed=3))
    plans = {
        "cascade": plan_pipeline(ARCH, qoe, E),
        "chain": chain_plan(ARCH, qoe, E),
        "no-pipeline": no_pipeline_plan(E),
    }
    rows = []
    base = None
    for name, plan in plans.items():
        res = run_policy(ARCH, CascadePolicy(plan, qoe), reqs, DURATION,
                         E=E, capacity_tokens=CAPACITY)
        nl = float(np.mean(res.normalized_latency()))
        thr = res.throughput()
        if name == "cascade":
            base = (nl, thr)
        rows.append(row(f"fig14/{name}", nl * 1e6, norm_latency=nl,
                        throughput=thr,
                        nl_vs_cascade=nl / base[0],
                        thr_vs_cascade=thr / base[1],
                        completed=f"{len(res.completed)}/{res.num_submitted}"))
    return rows


if __name__ == "__main__":
    standalone("fig14_layouts", run)
