"""Paper Fig. 16: load-balance effect of the bid-ask protocol — CV of
per-instance output tokens per stage (4 stages x 4 instances), token-
weighted and averaged over 3 seeds."""
from __future__ import annotations

import numpy as np

from benchmarks.common import ARCH, CAPACITY, DURATION, row, standalone
from repro.core.partition import PipelinePlan, Stage
from repro.sim.cluster import CascadePolicy
from repro.sim.experiment import fitted_qoe, run_policy
from repro.sim.workload import WorkloadSpec, generate

SEEDS = (9, 10, 11, 12, 13)


def _weighted_cv(res) -> float:
    toks = res.output_tokens_by_instance()
    groups = {}
    for iid, si in enumerate(res.stage_of_instance):
        groups.setdefault(si, []).append(iid)
    cvs, ws = [], []
    for si in sorted(groups):
        vals = toks[groups[si]]
        if vals.sum() > 0:
            cvs.append(vals.std() / vals.mean())
            ws.append(vals.sum())
    return float(np.average(cvs, weights=ws))


def run():
    qoe = fitted_qoe(ARCH)
    # quantile-ish bounds: every stage sees substantial traffic
    bounds = [0.0, 600.0, 1200.0, 3000.0, float("inf")]
    plan = PipelinePlan([Stage(bounds[i], bounds[i + 1], 4)
                         for i in range(4)], 0.0)
    rows = []
    cvs = {}
    for mode, label in (("rr", "round-robin"),
                        ("inter-stage", "inter-stage-only"),
                        ("full", "full-bidask")):
        vals = []
        for seed in SEEDS:
            reqs = generate(WorkloadSpec(rate=32.0, duration=2 * DURATION,
                                         seed=seed))
            res = run_policy(ARCH,
                             CascadePolicy(plan, qoe, balancing=mode,
                                           refinement="none"),
                             reqs, 2 * DURATION, E=16,
                             capacity_tokens=CAPACITY, seed=seed)
            vals.append(_weighted_cv(res))
        cv = float(np.mean(vals))
        cvs[label] = cv
        rows.append(row(f"fig16/{label}", cv * 100, mean_stage_cv=cv,
                        seeds=",".join(f"{v:.3f}" for v in vals)))
    rows.append(row("fig16/reduction", 0.0,
                    full_vs_rr=1 - cvs["full-bidask"] / cvs["round-robin"],
                    full_vs_interstage=1 - cvs["full-bidask"]
                    / cvs["inter-stage-only"],
                    paper="40% vs inter-stage, 47% vs rr"))
    return rows


if __name__ == "__main__":
    standalone("fig16_bidask", run)
