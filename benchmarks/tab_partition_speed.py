"""Paper §6.5: stage-partition runtime — optimized DP vs naive estimate
(paper: 0.06 s vs ~51 h at 16 instances / 128K)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row, standalone
from repro.core.partition import full_dp, naive_cost_estimate, two_phase
from repro.core.qoe import QoEModel
from repro.core.workload_stats import build_stats, exp_bucket_edges


def run():
    rng = np.random.default_rng(0)
    qoe = QoEModel(np.array([5e-3, 5e-4, 2e-7, 1e-12, 3e-7]))
    reqs = list(zip(rng.lognormal(5.5, 1.3, 2000).clip(10, 120_000)
                    .astype(int).tolist(),
                    rng.lognormal(5.0, 1.0, 2000).clip(10, 60_000)
                    .astype(int).tolist()))
    stats = build_stats(reqs, exp_bucket_edges(131_072))

    t0 = time.perf_counter()
    plan_fast = two_phase(stats, 16, qoe)
    t_fast = time.perf_counter() - t0
    t0 = time.perf_counter()
    plan_full = full_dp(stats, 16, qoe)
    t_full = time.perf_counter() - t0
    # naive O(E^3 L^2) at ~1e8 ops/s python-equivalent
    naive_s = naive_cost_estimate(16, 131_072) / 1e8
    return [row("tab/partition_speed", t_fast * 1e6,
                two_phase_s=t_fast, bucketed_full_dp_s=t_full,
                naive_est_hours=naive_s / 3600,
                speedup=naive_s / max(t_fast, 1e-9),
                quality_gap=(plan_fast.quality - plan_full.quality)
                / max(plan_full.quality, 1e-9))]


if __name__ == "__main__":
    standalone("tab_partition_speed", run)
