"""Shared benchmark scaffolding + the BENCH_*.json artifact convention.

Every benchmark writes ONE machine-readable artifact at the REPO ROOT via
:func:`write_artifact` — stable schema ``{bench, schema_version, rows,
data}`` — and :func:`merge_artifacts` folds all of them into
``BENCH_summary.json`` so CI and later sessions read a single file."""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional

ARCH = "llama3.2-3b"       # the paper's own evaluation model (§6.1)
E = 16                     # paper testbed: 16 GPUs
DURATION = 20.0
LIGHT_RATE = 8.0
HEAVY_RATE = 40.0
CAPACITY = 400_000.0

REPO_ROOT = Path(__file__).resolve().parent.parent
SCHEMA_VERSION = 1


def canonical_name(name: str) -> str:
    """One canonical artifact name per benchmark: the module's short name
    WITHOUT any leading ``bench_`` prefix. ``benchmarks.run`` passes full
    module names (``bench_sharded_engine``) while modules' own
    ``__main__`` blocks historically passed short ones
    (``sharded_engine``) — normalizing here keeps both spellings writing
    the SAME ``BENCH_<name>.json`` instead of leaving stale duplicates."""
    return name[len("bench_"):] if name.startswith("bench_") else name


def artifact_path(name: str) -> Path:
    return REPO_ROOT / f"BENCH_{canonical_name(name)}.json"


def write_artifact(name: str, data: Dict, rows: Optional[List[Dict]] = None,
                   merge: bool = True) -> Path:
    """Write ``BENCH_<canonical name>.json`` at the repo root. ``rows``
    is the CSV-shaped row list (``{name, us_per_call, derived}``);
    ``data`` holds the benchmark's own structured results. Refreshes the
    summary."""
    name = canonical_name(name)
    doc = {"bench": name, "schema_version": SCHEMA_VERSION,
           "rows": rows or [], "data": data}
    path = artifact_path(name)
    path.write_text(json.dumps(doc, indent=2, sort_keys=True, default=str))
    if merge:
        merge_artifacts()
    return path


def merge_artifacts() -> Path:
    """Fold every ``BENCH_*.json`` at the repo root into
    ``BENCH_summary.json`` (canonical bench name → document), warning on
    collisions — two files claiming the same bench means a stale
    pre-canonicalization duplicate is still lying around."""
    summary = {}
    sources: Dict[str, str] = {}
    for p in sorted(REPO_ROOT.glob("BENCH_*.json")):
        if p.name == "BENCH_summary.json":
            continue
        try:
            doc = json.loads(p.read_text())
        except (OSError, json.JSONDecodeError):
            continue
        key = canonical_name(doc.get("bench", p.stem[len("BENCH_"):]))
        if key in summary:
            print(f"WARNING: artifact collision on bench '{key}': "
                  f"{sources[key]} vs {p.name} — delete the stale one",
                  file=sys.stderr)
        summary[key] = doc
        sources[key] = p.name
    out = REPO_ROOT / "BENCH_summary.json"
    out.write_text(json.dumps({"schema_version": SCHEMA_VERSION,
                               "benches": summary},
                              indent=2, sort_keys=True))
    return out


def row(name: str, us_per_call: float, **derived) -> Dict:
    return {"name": name, "us_per_call": us_per_call,
            "derived": ";".join(f"{k}={_fmt(v)}" for k, v in derived.items())}


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def timed(fn: Callable, *args, repeats: int = 3, **kw):
    """(result, us_per_call)."""
    fn(*args, **kw)  # warmup
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeats * 1e6
    return out, us


def standalone(name: str, run: Callable[[], List[Dict]]) -> Path:
    """Run one ``run() -> rows`` benchmark module directly (outside
    ``benchmarks.run``) with the SAME output contract: the CSV on stdout
    and ``BENCH_<name>.json`` at the repo root, folded into the summary.
    Modules call this from their ``__main__`` block so every benchmark is
    individually runnable and always leaves an artifact behind."""
    print("name,us_per_call,derived")
    t0 = time.perf_counter()
    rows = []
    status = "ok"
    try:
        for r in run():
            rows.append(r)
            print(f"{r['name']},{r['us_per_call']:.3f},{r['derived']}",
                  flush=True)
    except Exception as e:  # noqa: BLE001 — still record the failure
        status = f"ERROR={type(e).__name__}:{e}"
        print(f"{name},nan,{status}", flush=True)
    path = write_artifact(name, {"status": status,
                                 "wall_s": time.perf_counter() - t0},
                          rows=rows)
    if status != "ok":
        raise SystemExit(1)
    return path
