"""Shared benchmark scaffolding."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

ARCH = "llama3.2-3b"       # the paper's own evaluation model (§6.1)
E = 16                     # paper testbed: 16 GPUs
DURATION = 20.0
LIGHT_RATE = 8.0
HEAVY_RATE = 40.0
CAPACITY = 400_000.0


def row(name: str, us_per_call: float, **derived) -> Dict:
    return {"name": name, "us_per_call": us_per_call,
            "derived": ";".join(f"{k}={_fmt(v)}" for k, v in derived.items())}


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def timed(fn: Callable, *args, repeats: int = 3, **kw):
    """(result, us_per_call)."""
    fn(*args, **kw)  # warmup
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeats * 1e6
    return out, us
