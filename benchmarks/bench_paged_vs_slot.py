"""Paged vs. slot-slab engine on a heterogeneous batch.

Measures, for the same request mix served by the block-granular paged
engine and the monolithic slot engine:

  * decode step wall time (after jit warmup),
  * peak KV bytes *pinned* by requests (paged: allocated blocks × block
    bytes; slot: occupied slots × max_seq slab bytes).

The memory column is the tentpole claim: with per-batch length
heterogeneity, the slot engine pins a ``max_seq`` slab per request while
the paged engine pins ceil(L/BS) blocks — short requests stop taxing
admission, so the same HBM holds more concurrent requests.

Run: PYTHONPATH=src python benchmarks/bench_paged_vs_slot.py
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

try:
    from benchmarks.common import row, write_artifact
except ImportError:                     # run as a plain script
    from common import row, write_artifact

from repro.configs import get_config
from repro.models import build_model
from repro.serving.engine import Engine
from repro.serving.request import ServeRequest

MAX_SEQ = 256
MAX_SLOTS = 8
BLOCK_SIZE = 16
# heterogeneous: lengths span 32x, the regime the paper's Fig. 2 targets
PROMPTS = [4, 8, 8, 16, 16, 32, 64, 120]
NEW_TOKENS = 8


def serve(paged: bool, model, params):
    rng = np.random.default_rng(0)
    eng = Engine(0, model, params, max_slots=MAX_SLOTS, max_seq=MAX_SEQ,
                 paged=paged, block_size=BLOCK_SIZE)
    reqs = [ServeRequest(i, rng.integers(0, model.cfg.vocab_size, p)
                         .astype(np.int32), NEW_TOKENS)
            for i, p in enumerate(PROMPTS)]
    for r in reqs:
        eng.submit(r)
    eng.step()                      # prefill + first decode (jit warmup)
    eng.step()
    t0 = time.perf_counter()
    steps = 0
    while any(r.finish_step is None for r in reqs):
        eng.step()
        steps += 1
    dt = (time.perf_counter() - t0) / max(steps, 1)
    return dt * 1e3, eng.peak_kv_bytes


def main():
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"batch: {len(PROMPTS)} requests, prompts {PROMPTS}, "
          f"+{NEW_TOKENS} tokens each, max_seq={MAX_SEQ}, BS={BLOCK_SIZE}")
    results = {}
    for paged in (False, True):
        ms, peak = serve(paged, model, params)
        results[paged] = (ms, peak)
        name = "paged" if paged else "slot "
        print(f"{name}: decode step {ms:8.2f} ms   peak KV pinned "
              f"{peak/1e6:8.3f} MB")
    (ms_s, peak_s), (ms_p, peak_p) = results[False], results[True]
    print(f"peak KV bytes: paged/slot = {peak_p/peak_s:.3f}x "
          f"({'OK' if peak_p < peak_s else 'FAIL: paged must pin less'})")
    write_artifact("paged_vs_slot", {
        "slot": {"decode_step_ms": ms_s, "peak_kv_bytes": peak_s},
        "paged": {"decode_step_ms": ms_p, "peak_kv_bytes": peak_p},
        "peak_ratio_paged_over_slot": peak_p / peak_s,
    }, rows=[row("paged_vs_slot/slot", ms_s * 1e3, peak_kv_mb=peak_s / 1e6),
             row("paged_vs_slot/paged", ms_p * 1e3, peak_kv_mb=peak_p / 1e6)])
    assert peak_p < peak_s, "acceptance: paged must pin strictly fewer bytes"


if __name__ == "__main__":
    main()
