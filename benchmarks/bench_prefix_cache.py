"""Prefix cache: cold vs. warm TTFT and prefill work skipped
(DESIGN.md §Prefix cache).

Two views:

  * **engine** — a real reduced-model engine serves the SAME prompt cold,
    then warm: the warm run must produce bit-identical greedy tokens
    while skipping >= 90% of the prefill block-work (the engine's
    ``prefill_work_blocks`` counter — the chunk-grid-step mirror) and the
    matching attention FLOPs (``kernels.cost.prefill_flops_skipped``).
    ``--no-prefix-cache`` measures the legacy path for the delta.
  * **sim** — `compare_policies(workload="shared_prefix")`: the
    system-prompt/multi-turn cluster trace, cascade vs. round-robin, with
    the group-granular cache mirror on and off.

Emits BENCH_prefix_cache.json at the repo root; `run()` feeds
benchmarks/run.py. The asserted acceptance (CI smoke): warm tokens
bit-identical to cold, >= 90% of prefill block-work skipped, warm TTFT
strictly below cold.

Run: PYTHONPATH=src python benchmarks/bench_prefix_cache.py
     [--prompt 8192] [--budget 256] [--new-tokens 16]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

try:
    from benchmarks.common import write_artifact
except ImportError:                     # run as a plain script
    from common import write_artifact

import jax
import numpy as np

from repro.configs import get_config
from repro.kernels.cost import prefill_flops, prefill_flops_skipped
from repro.models import build_model
from repro.serving.engine import Engine
from repro.serving.request import ServeRequest, State
from repro.sim.costmodel import profile_from_config


def _serve(eng, req):
    """Submit and drain one request; returns wall TTFT seconds."""
    eng.submit(req)
    t0 = time.perf_counter()
    ttft = None
    while req.state is not State.FINISHED:
        eng.step()
        if ttft is None and req.first_token_step is not None:
            ttft = time.perf_counter() - t0
    eng.allocator.check_invariants()
    return ttft


def run_engine_scenario(model, params, *, prompt_len, budget, new_tokens,
                        prefix_cache=True, seed=0):
    rng = np.random.default_rng(seed)
    vocab = model.cfg.vocab_size
    max_seq = 1 << (prompt_len + 2 * new_tokens + 64).bit_length()
    eng = Engine(0, model, params, max_slots=2, max_seq=max_seq,
                 token_budget=2 * (prompt_len + new_tokens) + 1024,
                 prefill_token_budget=budget, attn_backend="dense",
                 prefix_cache=prefix_cache)
    # jit warmup on a DIFFERENT prompt (same shapes, disjoint chain),
    # served cold AND warm, so neither measured run pays compilation
    dummy = rng.integers(0, vocab, prompt_len).astype(np.int32)
    _serve(eng, ServeRequest(7, dummy.copy(), new_tokens))
    _serve(eng, ServeRequest(8, dummy.copy(), new_tokens))
    prompt = rng.integers(0, vocab, prompt_len).astype(np.int32)
    work0 = eng.prefill_work_blocks
    cold = ServeRequest(0, prompt.copy(), new_tokens)
    cold_ttft = _serve(eng, cold)
    cold_work = eng.prefill_work_blocks - work0
    warm = ServeRequest(1, prompt.copy(), new_tokens)
    warm_ttft = _serve(eng, warm)
    warm_work = eng.prefill_work_blocks - work0 - cold_work
    cached = warm.cached_tokens
    spec = profile_from_config(model.cfg).attn_spec
    return {
        "prefix_cache": prefix_cache,
        "prompt_len": prompt_len,
        "cold_ttft_s": cold_ttft,
        "warm_ttft_s": warm_ttft,
        "cold_work_blocks": cold_work,
        "warm_work_blocks": warm_work,
        "block_work_skipped": 1.0 - warm_work / max(cold_work, 1),
        "warm_cached_tokens": int(cached),
        "prefill_flops_total": prefill_flops(prompt_len, spec),
        "prefill_flops_skipped": prefill_flops_skipped(prompt_len, cached,
                                                       spec),
        "tokens": {"cold": list(cold.generated),
                   "warm": list(warm.generated)},
    }


def run_sim_scenario(*, rate=8.0, duration=12.0, E=4, seed=0):
    from repro.sim.experiment import compare_policies
    out = {}
    for label, pc in (("cached", True), ("cold", False)):
        res = compare_policies("llama3.2-3b", rate=rate, duration=duration,
                               E=E, seed=seed, workload="shared_prefix",
                               prefill_token_budget=512, prefix_cache=pc,
                               kinds=("round-robin", "cascade"))
        for kind, r in res.items():
            s = r.summary()
            out[f"{kind}/{label}"] = {
                "ttft_mean_s": s["ttft_mean"], "ttft_p95_s": s["ttft_p95"],
                "completed": s["completed"]}
    return out


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--prompt", type=int, default=8192,
                    help="prompt length shared by the cold and warm run")
    ap.add_argument("--budget", type=int, default=256)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--skip-sim", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    out = {"config": {"arch": cfg.name, "prompt": args.prompt,
                      "budget": args.budget,
                      "jax_backend": jax.default_backend()}}
    r = run_engine_scenario(model, params, prompt_len=args.prompt,
                            budget=args.budget, new_tokens=args.new_tokens)
    legacy = run_engine_scenario(model, params, prompt_len=args.prompt,
                                 budget=args.budget,
                                 new_tokens=args.new_tokens,
                                 prefix_cache=False)
    # acceptance: warm tokens bit-identical to cold — on BOTH paths — and
    # the cache changes latency/work only, never tokens
    assert r["tokens"]["warm"] == r["tokens"]["cold"], "warm tokens diverged"
    assert legacy["tokens"]["warm"] == legacy["tokens"]["cold"]
    assert r["tokens"]["cold"] == legacy["tokens"]["cold"], \
        "prefix cache changed cold-path tokens"
    assert r["block_work_skipped"] >= 0.90, \
        f"only {r['block_work_skipped']:.1%} of prefill block-work skipped"
    assert r["warm_ttft_s"] < r["cold_ttft_s"], "warm TTFT not below cold"
    for d in (r, legacy):
        d.pop("tokens")
    out["engine"], out["engine_legacy"] = r, legacy
    print(f"-- cold ttft {r['cold_ttft_s']*1e3:8.1f} ms  "
          f"work {r['cold_work_blocks']} blocks")
    print(f"-- warm ttft {r['warm_ttft_s']*1e3:8.1f} ms  "
          f"work {r['warm_work_blocks']} blocks  "
          f"({r['block_work_skipped']:.1%} skipped, "
          f"{r['prefill_flops_skipped']:.3g} FLOPs/layer)")

    if not args.skip_sim:
        out["sim"] = run_sim_scenario()
        for k, v in out["sim"].items():
            print(f"-- sim {k:22s} ttft mean {v['ttft_mean_s']:.3f} s  "
                  f"p95 {v['ttft_p95_s']:.3f} s")

    print("wrote", write_artifact("prefix_cache", out))
    return out


def run():
    """benchmarks/run.py entry: small engine scenario + the sim compare."""
    from benchmarks.common import row
    out = main(["--prompt", "2048", "--budget", "64", "--new-tokens", "8"])
    rows = [row("prefix_cache/engine/cold",
                out["engine"]["cold_ttft_s"] * 1e6,
                work_blocks=out["engine"]["cold_work_blocks"]),
            row("prefix_cache/engine/warm",
                out["engine"]["warm_ttft_s"] * 1e6,
                work_blocks=out["engine"]["warm_work_blocks"],
                skipped=out["engine"]["block_work_skipped"])]
    for k, v in out.get("sim", {}).items():
        rows.append(row(f"prefix_cache/sim/{k}", v["ttft_mean_s"] * 1e6,
                        ttft_p95=v["ttft_p95_s"], completed=v["completed"]))
    return rows


if __name__ == "__main__":
    main()
