"""Roofline summary (deliverable g): reads the dry-run artifact and reports
per-(arch x shape) terms and dominant bottlenecks. Requires
results/dryrun_baseline.json (produced by `python -m repro.launch.dryrun`)."""
from __future__ import annotations

import json
import os

from benchmarks.common import row, standalone


def run():
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "dryrun_baseline.json")
    if not os.path.exists(path):
        return [row("roofline/missing", 0.0,
                    note="run repro.launch.dryrun first")]
    with open(path) as f:
        rows_in = json.load(f)
    out = []
    for r in rows_in:
        if r["status"] != "ok":
            continue
        total = r["t_compute"] + r["t_memory"] + r["t_collective"]
        out.append(row(f"roofline/{r['arch']}/{r['shape']}@{r['mesh']}",
                       total * 1e6,
                       dom=r["dominant"],
                       t_comp=r["t_compute"], t_mem=r["t_memory"],
                       t_coll=r["t_collective"],
                       useful=r["useful_ratio"]))
    return out


if __name__ == "__main__":
    standalone("bench_roofline", run)
