"""Paper Figs. 6 & 7: mean and p95 TTFT / TPOT across systems and rates.

16-instance simulated cluster, ShareGPT-shaped workload, policies:
round-robin (vLLM/SGLang deployment), Llumnix-like, CascadeInfer.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (ARCH, CAPACITY, DURATION, E, HEAVY_RATE,
                               LIGHT_RATE, row, standalone)
from repro.sim.experiment import compare_policies


def run():
    rows = []
    for rate in (LIGHT_RATE, HEAVY_RATE):
        res = compare_policies(ARCH, rate=rate, duration=DURATION, E=E,
                               capacity_tokens=CAPACITY)
        base = res["round-robin"]
        for kind, r in res.items():
            s = r.summary()
            rows.append(row(
                f"fig6_7/{kind}@{rate:g}", s["tpot_mean"] * 1e6,
                ttft_mean=s["ttft_mean"], ttft_p95=s["ttft_p95"],
                tpot_mean=s["tpot_mean"], tpot_p95=s["tpot_p95"],
                vs_rr_ttft=(1 - s["ttft_mean"]
                            / max(base.summary()["ttft_mean"], 1e-12)),
                vs_rr_tpot=(1 - s["tpot_mean"]
                            / max(base.summary()["tpot_mean"], 1e-12)),
                completed=s["completed"]))
    return rows


if __name__ == "__main__":
    standalone("fig67_latency", run)
