"""Paper Fig. 12: SLO attainment at Nx the minimum-load SLO."""
from __future__ import annotations

from benchmarks.common import ARCH, CAPACITY, DURATION, E, row, standalone
from repro.configs import get_config
from repro.sim.costmodel import (decode_iter_time, prefill_time,
                                 profile_from_config)
from repro.sim.experiment import compare_policies
from repro.sim.workload import WorkloadSpec, sample_lengths
import numpy as np


def run():
    prof = profile_from_config(get_config(ARCH))
    # baseline SLO: TTFT/TPOT at minimum load (single median request)
    rng = np.random.default_rng(0)
    ins, _ = sample_lengths(WorkloadSpec(rate=1, duration=1), 1000, rng)
    ttft0 = prefill_time(int(np.median(ins)), prof)
    tpot0 = decode_iter_time([int(np.median(ins))], prof)
    res = compare_policies(ARCH, rate=32.0, duration=DURATION, E=E,
                           capacity_tokens=CAPACITY)
    rows = []
    for scale in (5.0, 10.0, 20.0):
        att = {k: r.slo_attainment(ttft0, tpot0, scale)
               for k, r in res.items()}
        rows.append(row(f"fig12/slo@{scale:g}x", att["cascade"] * 100,
                        cascade=att["cascade"],
                        round_robin=att["round-robin"],
                        llumnix=att["llumnix"],
                        x_vs_rr=att["cascade"] / max(att["round-robin"],
                                                     1e-9)))
    return rows


if __name__ == "__main__":
    standalone("fig12_slo", run)
