"""Paper Fig. 2: attention-backend sensitivity to length heterogeneity.

Two measurements:
  (a) TPU block cost model: padded-backend time for mixed-length batches
      vs. a homogeneous batch with identical total tokens (paper setups:
      1000 vs 50000 and 200 vs 10000, batch 512). Expected band 1.1–2.1×.
  (b) Interpret-mode wall time of the actual Pallas kernel at toy scale —
      structural confirmation that padded cost tracks max-length blocks
      while ragged tracks per-request blocks.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, standalone, timed
from repro.kernels.cost import AttnSpec, decode_attn_time_s, heterogeneity_tax


def run():
    rows = []
    spec = AttnSpec(num_q_heads=24, num_kv_heads=8, head_dim=128)
    for name, short, long_ in (("1000v50000", 1000, 50_000),
                               ("200v10000", 200, 10_000)):
        mixed = [short] * 256 + [long_] * 256
        tax = heterogeneity_tax(mixed, spec)
        t_pad = decode_attn_time_s(mixed, spec)
        t_rag = decode_attn_time_s(mixed, spec, ragged=True)
        rows.append(row(f"fig2/tax_{name}", t_pad * 1e6, tax=tax,
                        ragged_speedup=t_pad / t_rag,
                        paper_band="1.1-2.1x"))

    # (b) real kernel, interpret mode, toy scale
    import jax.numpy as jnp
    from repro.kernels.decode_attention import decode_attention
    rng = np.random.default_rng(0)
    B, S, H, Hkv, Dh, blk = 8, 512, 8, 2, 64, 64
    q = jnp.asarray(rng.normal(0, 1, (B, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, Dh)), jnp.float32)
    hetero = jnp.asarray([64] * 7 + [512], jnp.int32)

    def call(ragged):
        return decode_attention(q, k, v, hetero, block_s=blk, ragged=ragged,
                                interpret=True).block_until_ready()

    _, us_pad = timed(call, False, repeats=2)
    _, us_rag = timed(call, True, repeats=2)
    rows.append(row("fig2/kernel_interpret", us_pad, padded_us=us_pad,
                    ragged_us=us_rag, note="toy-scale structural check"))
    return rows


if __name__ == "__main__":
    standalone("fig2_heterogeneity", run)
