"""Tensor-parallel sharded serving (DESIGN.md §Sharded serving).

Two experiments, the acceptance numbers for ISSUE 9:

  * **Engine tp scan** — the same decode workload on `Engine(tp=t)` for
    t ∈ {1, 2, 4} at EQUAL per-device token budget, on a forced
    multi-device CPU mesh (`--xla_force_host_platform_device_count`,
    the launch/dryrun.py precedent). Asserted: max resident KV tokens
    scale exactly t× (the pool shards over KV heads, so each device
    pays the same bytes while the engine owns t× the blocks) and greedy
    tokens are bit-identical to tp=1. Per-step wall time is reported;
    off-TPU it's an interpret/shard_map-overhead wall, so it is NOT
    asserted (bench_fused_attention's precedent).

  * **Heterogeneous-cluster sim** — the same open-loop trace at equal
    TOTAL device count: four single-chip instances vs a 2+1+1 cluster
    whose tp=2 instance anchors a stage by itself via capacity-weighted
    stage partitioning (`scale_profile_tp` + `capacity_weight`).
    Asserted: request conservation on both clusters and the weighted
    partition actually engaging (the big instance claims a stage alone).

Emits BENCH_sharded_engine.json at the repo root.

Run: PYTHONPATH=src python benchmarks/bench_sharded_engine.py
     [--budget 256] [--decode-reqs 4] [--rate 20] [--duration 10]
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

# Virtual host devices for the tp scan: must land before the FIRST jax
# import in the process. Under benchmarks.run an earlier module has
# usually initialised jax already — then the scan degrades gracefully
# to the device count that exists (tp values that don't fit are skipped
# and reported as such).
if "jax" not in sys.modules:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = \
            _flags + " --xla_force_host_platform_device_count=4"

try:
    from benchmarks.common import write_artifact
except ImportError:                     # run as a plain script
    from common import write_artifact

import jax
import numpy as np

from repro.configs import get_config
from repro.core.partition import PipelinePlan, Stage
from repro.models import build_model
from repro.serving.engine import Engine
from repro.serving.request import ServeRequest
from repro.sim.cluster import Cluster, ClusterConfig
from repro.sim.costmodel import profile_from_config
from repro.sim.experiment import make_policy
from repro.sim.workload import WorkloadSpec, generate

ARCH = "smollm-360m"
SIM_ARCH = "llama3.2-3b"
SIM_CAPACITY = 60_000.0                 # per DEVICE, like token_budget
TP_SCAN = (1, 2, 4)


def _model():
    # reduced() caps kv heads at 2; lift to 4 (= num_heads, plain MHA)
    # so every tp in the scan divides the head axes
    cfg = dataclasses.replace(get_config(ARCH).reduced(), num_kv_heads=4)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def engine_scan(model, params, *, budget, decode_reqs, new_tokens=16,
                seed=0):
    """Same decode batch on Engine(tp=t), equal PER-DEVICE budget."""
    rng = np.random.default_rng(seed)
    vocab = model.cfg.vocab_size
    prompts = [rng.integers(0, vocab, int(p)).astype(np.int32)
               for p in np.linspace(9, 23, decode_reqs).astype(int)]
    out = {}
    for tp in TP_SCAN:
        if tp > len(jax.devices()):
            out[tp] = {"skipped": f"needs {tp} devices, "
                                  f"have {len(jax.devices())}"}
            continue
        eng = Engine(0, model, params, tp=tp, max_slots=decode_reqs,
                     max_seq=96, token_budget=budget,
                     attn_backend="dense")
        reqs = [ServeRequest(i, p, new_tokens)
                for i, p in enumerate(prompts)]
        for r in reqs:
            eng.submit(r)
        # prefill + reach steady decode (also warms the jit caches)
        while any(r.first_token_step is None for r in reqs):
            eng.step()
        step_s = []
        while any(r.finish_step is None for r in reqs):
            t0 = time.perf_counter()
            eng.step()
            jax.block_until_ready(eng.cache)
            step_s.append(time.perf_counter() - t0)
        out[tp] = {
            "num_blocks": eng.num_blocks,
            "resident_tokens_max": eng.num_blocks * eng.block_size,
            "token_budget_per_device": budget,
            "decode_step_s_median": float(np.median(step_s)),
            "decode_steps": len(step_s),
            "tokens": {r.req_id: list(r.generated) for r in reqs},
        }
    return out


def sim_hetero(*, rate, duration, seed=3):
    """Equal total devices: 4×tp1 instances vs a 2+1+1 cluster, both
    under the SAME 2-stage plan demanding 2+2 capacity units — so the
    hetero cluster only works if weighted claiming lets the tp=2
    instance satisfy a whole stage's demand alone."""
    reqs = generate(WorkloadSpec(rate=rate, duration=duration, seed=seed,
                                 max_context=8192))
    prof = profile_from_config(get_config(SIM_ARCH))
    plan = PipelinePlan([Stage(0.0, 512.0, 2),
                         Stage(512.0, float("inf"), 2)], 0.0)
    out = {"requests": len(reqs)}
    for name, E, tps in (("uniform_4x1", 4, None),
                         ("hetero_2_1_1", 3, (2, 1, 1))):
        pol = make_policy("cascade", SIM_ARCH, E, plan=plan)
        cfg = ClusterConfig(num_instances=E, capacity_tokens=SIM_CAPACITY,
                            seed=0, prefill_token_budget=512, tps=tps)
        res = Cluster(prof, pol, cfg).run(reqs, duration + 30.0)
        ttft = res.ttft()
        out[name] = {
            "instances": E,
            "tps": list(tps) if tps else [1] * E,
            "served": len(res.served),
            "completed": len(res.completed),
            "ttft_mean_s": float(np.mean(ttft)),
            "ttft_p99_s": float(np.percentile(ttft, 99)),
            "stage_instances": [list(s.instance_ids)
                                for s in pol.plane.stages],
        }
        assert len(res.completed) == len(reqs), \
            f"{name}: {len(res.completed)}/{len(reqs)} requests completed"
    # capacity-weighted partitioning must engage: the tp=2 instance
    # satisfies the short stage's 2-unit demand alone, the two tp=1
    # instances cover the long stage (tests/test_controlplane.py asserts
    # the same mechanism with server parity)
    assert out["uniform_4x1"]["stage_instances"] == [[0, 1], [2, 3]], \
        out["uniform_4x1"]["stage_instances"]
    assert out["hetero_2_1_1"]["stage_instances"] == [[0], [1, 2]], \
        out["hetero_2_1_1"]["stage_instances"]
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=int, default=256,
                    help="PER-DEVICE token budget for the tp scan")
    ap.add_argument("--decode-reqs", type=int, default=4)
    ap.add_argument("--rate", type=float, default=20.0)
    ap.add_argument("--duration", type=float, default=10.0)
    args = ap.parse_args(argv)

    model, params = _model()
    out = {"config": vars(args) | {"arch": ARCH, "sim_arch": SIM_ARCH,
                                   "devices": len(jax.devices()),
                                   "jax_backend": jax.default_backend()}}
    scan = engine_scan(model, params, budget=args.budget,
                       decode_reqs=args.decode_reqs)
    ran = [t for t in TP_SCAN if "skipped" not in scan[t]]
    for t in ran:
        print(f"-- tp={t}: resident {scan[t]['resident_tokens_max']:5d} "
              f"tokens  decode step "
              f"{scan[t]['decode_step_s_median']*1e3:7.2f} ms")
    base = scan[ran[0]]
    for t in ran:
        # pool shards over KV heads: t× blocks at equal per-device bytes
        assert scan[t]["resident_tokens_max"] == \
            t * base["resident_tokens_max"] // ran[0], scan[t]
        assert scan[t]["tokens"] == base["tokens"], \
            f"tp={t} greedy tokens diverge from tp={ran[0]}"
    if len(ran) > 1:
        print(f"resident KV tokens scale {ran[-1]}x at tp={ran[-1]} "
              f"(equal per-device budget), tokens bit-identical")
    out["engine_scan"] = {str(t): dict(scan[t], tokens=None) if
                          "skipped" not in scan[t] else scan[t]
                          for t in TP_SCAN}

    sim = sim_hetero(rate=args.rate, duration=args.duration)
    out["sim_hetero"] = sim
    u, h = sim["uniform_4x1"], sim["hetero_2_1_1"]
    print(f"sim, equal 4 devices: uniform 4x1 p99 TTFT "
          f"{u['ttft_p99_s']:.2f} s vs hetero 2+1+1 "
          f"{h['ttft_p99_s']:.2f} s (stages {h['stage_instances']})")

    print("wrote", write_artifact("sharded_engine", out))


def run():
    """CSV rows for benchmarks.run."""
    main([])
    import json
    doc = json.loads((Path(__file__).resolve().parent.parent
                      / "BENCH_sharded_engine.json").read_text())
    d = doc["data"]
    rows = []
    for t, s in d["engine_scan"].items():
        if "skipped" in s:
            continue
        rows.append({"name": f"tp{t}_decode_step",
                     "us_per_call": s["decode_step_s_median"] * 1e6,
                     "derived": f"resident_tokens="
                                f"{s['resident_tokens_max']}"})
    h = d["sim_hetero"]["hetero_2_1_1"]
    rows.append({"name": "sim_hetero_2_1_1_ttft_p99",
                 "us_per_call": h["ttft_p99_s"] * 1e6,
                 "derived": f"served={h['served']}"})
    return rows


if __name__ == "__main__":
    main()
