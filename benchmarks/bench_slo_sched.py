"""SLO-tiered preemptive scheduler vs FCFS (DESIGN.md §SLO scheduling).

The acceptance experiment for the tiered scheduler, run in BOTH drivers
of the shared control plane:

  * the discrete-event simulator on the open-loop diurnal+bursty SLO
    workload (``sim.workload.slo_spec``) at a saturating rate, and
  * the real-JAX-engine ``MILSServer`` on a deterministic contention
    trace (batch work holding every seat when interactive work lands).

Asserted on every run (this file is the CI smoke for the subsystem):

  * preemption strictly beats FCFS on interactive goodput-under-SLO in
    both drivers, and preemptions actually fired;
  * a park-preempted AND a recompute-preempted request finish with
    bit-identical tokens to an unpreempted reference run.

Run: PYTHONPATH=src python -m benchmarks.bench_slo_sched
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, standalone
from repro.sim.experiment import make_policy, run_policy
from repro.sim.workload import generate_slo, slo_spec

SIM_ARCH = "llama3.2-3b"
SIM_RATE = 14.0
SIM_DURATION = 40.0
SIM_E = 2
SIM_CAPACITY = 14_000.0

SRV_ARCH = "smollm-360m"


def _sim_goodput() -> list:
    """Saturated sim cluster: preemption on vs off, same trace."""
    reqs = generate_slo(slo_spec(SIM_RATE, SIM_DURATION, seed=7,
                                 max_context=8192))
    rows, results = [], {}
    for preempt in (False, True):
        pol = make_policy("cascade", SIM_ARCH, SIM_E)
        res = run_policy(SIM_ARCH, pol, reqs, SIM_DURATION, E=SIM_E,
                         capacity_tokens=SIM_CAPACITY, seed=0,
                         prefill_token_budget=512, preemption=preempt)
        results[preempt] = res
        name = "preemptive" if preempt else "fcfs"
        per = res.slo_summary()
        ps = res.preemption_stats()
        for cls in sorted(per):
            d = per[cls]
            rows.append(row(f"slo_sched/sim_{name}_{cls}",
                            0.0, attainment=d["attainment"],
                            goodput_tok_s=d["goodput_tok_s"],
                            requests=d["requests"],
                            preemptions=ps["preemptions"]))
    g_fcfs = results[False].slo_summary()["interactive"]["goodput_tok_s"]
    g_pre = results[True].slo_summary()["interactive"]["goodput_tok_s"]
    n_pre = results[True].preemption_stats()["preemptions"]
    assert n_pre > 0, "saturated sim run fired no preemptions"
    assert g_pre > g_fcfs, (
        f"preemptive interactive goodput {g_pre:.1f} must beat "
        f"FCFS {g_fcfs:.1f}")
    rows.append(row("slo_sched/sim_interactive_gain", 0.0,
                    fcfs=g_fcfs, preemptive=g_pre,
                    gain=g_pre / max(g_fcfs, 1e-9)))
    return rows


def _build_server(model, params, preemption: bool):
    from repro.core.partition import PipelinePlan, Stage
    from repro.serving.server import MILSServer, ServerConfig
    plan = PipelinePlan([Stage(0.0, float("inf"), 1)], 0.0)
    cfg = ServerConfig(policy="cascade", refinement="none",
                       balancing="inter-stage", preemption=preemption,
                       slo_time_scale=40.0)
    return MILSServer(model, params, plan, None, cfg,
                      max_slots=2, max_seq=128, paged=True)


def _server_trace(vocab_size: int):
    from repro.serving.request import ServeRequest
    rng = np.random.default_rng(3)
    trace = []
    for i in range(2):               # batch work grabs every seat at t=0
        r = ServeRequest(i, rng.integers(0, vocab_size, 16)
                         .astype(np.int32), 70)
        r.slo_class = "batch"
        trace.append((r, 0))
    for i in range(2):               # interactive lands mid-decode
        r = ServeRequest(10 + i, rng.integers(0, vocab_size, 12)
                         .astype(np.int32), 8)
        r.slo_class = "interactive"
        trace.append((r, 10))
    return trace


def _server_goodput() -> list:
    """Real engines: batch holds both seats, interactive arrives later.
    FCFS serves interactive only after a batch request drains; the
    preemptive scheduler parks/recomputes a batch victim immediately."""
    import jax
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config(SRV_ARCH).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rows, results = [], {}
    for preempt in (False, True):
        srv = _build_server(model, params, preempt)
        for req, step in _server_trace(cfg.vocab_size):
            srv.submit_at(req, step)
        srv.run(max_steps=600)
        s = srv.summary()
        results[preempt] = s
        name = "preemptive" if preempt else "fcfs"
        rows.append(row(
            f"slo_sched/server_{name}", 0.0,
            interactive_goodput=s.get("slo_interactive_goodput_tok_step",
                                      0.0),
            interactive_attainment=s.get("slo_interactive_attainment", 0.0),
            preemptions=s["preemptions"], resumes=s["resumes"]))
    g_fcfs = results[False].get("slo_interactive_goodput_tok_step", 0.0)
    g_pre = results[True].get("slo_interactive_goodput_tok_step", 0.0)
    assert results[True]["preemptions"] > 0, \
        "server contention trace fired no preemptions"
    assert g_pre > g_fcfs, (
        f"server preemptive interactive goodput {g_pre:.4f} must beat "
        f"FCFS {g_fcfs:.4f}")
    rows.append(row("slo_sched/server_interactive_gain", 0.0,
                    fcfs=g_fcfs, preemptive=g_pre))
    return rows


def _bit_identity() -> list:
    """Park and recompute round-trips reproduce the unpreempted tokens
    exactly (greedy decode ⇒ any divergence is a correctness bug)."""
    import jax
    from repro.configs import get_config
    from repro.models import build_model
    from repro.serving.engine import Engine
    from repro.serving.request import ServeRequest, State

    cfg = get_config(SRV_ARCH).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    shapes = [(10, 12), (14, 12), (8, 10)]

    def mkreqs():
        rng = np.random.default_rng(0)
        return [ServeRequest(i, rng.integers(0, cfg.vocab_size, p)
                             .astype(np.int32), n)
                for i, (p, n) in enumerate(shapes)]

    def drive(eng, reqs, preempt_mode=None):
        for r in reqs:
            eng.submit(r)
        for _ in range(6):
            eng.step()
        if preempt_mode is not None:
            slot = next(s for s, r in enumerate(eng.slots)
                        if r is not None and r.generated
                        and not r.prefilling)
            getattr(eng, preempt_mode)(slot)
        for _ in range(300):
            eng.step()
            eng.allocator.check_invariants()
            if all(r.state is State.FINISHED for r in reqs):
                break
        assert all(r.state is State.FINISHED for r in reqs)
        return [list(r.generated) for r in reqs]

    def fresh(preemption):
        return Engine(0, model, params, max_slots=4, max_seq=96,
                      paged=True, preemption=preemption)

    ref = drive(fresh(False), mkreqs())
    rows = []
    for mode in ("_preempt_park", "_preempt_recompute"):
        eng = fresh(True)
        got = drive(eng, mkreqs(), preempt_mode=mode)
        assert got == ref, f"{mode} diverged from the unpreempted run"
        rows.append(row(f"slo_sched/bit_identity{mode}", 0.0,
                        identical=1, preemptions=eng.preemptions,
                        resumes=eng.resumes))
    return rows


def run():
    return _sim_goodput() + _server_goodput() + _bit_identity()


if __name__ == "__main__":
    standalone("bench_slo_sched", run)
