"""Paper Fig. 15: adaptive vs quantity- vs memory-based range refinement."""
from __future__ import annotations

import numpy as np

from benchmarks.common import ARCH, CAPACITY, DURATION, E, row, standalone
from repro.sim.cluster import CascadePolicy
from repro.sim.experiment import fitted_qoe, plan_pipeline, run_policy
from repro.sim.workload import WorkloadSpec, generate


def run():
    qoe = fitted_qoe(ARCH)
    plan = plan_pipeline(ARCH, qoe, E)
    reqs = generate(WorkloadSpec(rate=32.0, duration=DURATION, seed=5,
                                 drift_mu=1.2))  # §4.3: drifting lengths
    rows = []
    base = None
    for mode in ("adaptive", "quantity", "memory", "none"):
        res = run_policy(ARCH, CascadePolicy(plan, qoe, refinement=mode),
                         reqs, DURATION, E=E, capacity_tokens=CAPACITY)
        nl = float(np.mean(res.normalized_latency()))
        thr = res.throughput()
        if mode == "adaptive":
            base = (nl, thr)
        rows.append(row(f"fig15/{mode}", nl * 1e6, norm_latency=nl,
                        throughput=thr, nl_vs_adaptive=nl / base[0]))
    return rows


if __name__ == "__main__":
    standalone("fig15_refinement", run)
