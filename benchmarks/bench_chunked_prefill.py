"""Chunked prefill vs. monolithic prefill: the head-of-line-blocking
benchmark (DESIGN.md §Chunked prefill).

Scenario — the one the paper's premise lives on: a busy decode batch is
streaming tokens when a long prompt (default 32K) arrives on the same
engine. The monolithic engine prefills it as ONE compute-bound iteration,
freezing every decode request for the whole prompt; the chunked engine
packs `prefill_token_budget` prompt tokens into each mixed iteration, so
decode requests keep producing a token per step and the stall collapses
to ~one iteration. Per engine this measures, in wall time:

  * each decode request's max inter-token gap while the prompt prefills
    (the decode-stall) and total stalled time beyond the pre-arrival
    steady-state step,
  * TTFT p50/p99 across all requests (the long prompt pays the same
    total prefill either way — chunking spreads it, never inflates tails
    for others),
  * chunked-vs-monolithic greedy-token parity on the shared requests.

Emits BENCH_chunked_prefill.json at the repo root. The asserted
acceptance: chunked decode-stall is >= 5x smaller than monolithic, no
decode request's gap exceeds ~one mixed iteration, tokens identical.

Run: PYTHONPATH=src python benchmarks/bench_chunked_prefill.py
     [--prompt 32768] [--budget 256] [--decode-reqs 6] [--new-tokens 48]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

try:
    from benchmarks.common import write_artifact
except ImportError:                     # run as a plain script
    from common import write_artifact

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving.engine import Engine
from repro.serving.request import ServeRequest


def percentile(xs, p):
    return float(np.percentile(np.asarray(xs, np.float64), p))


def run_scenario(model, params, *, prompt_len, budget, decode_reqs,
                 new_tokens, chunked, seed=0):
    vocab = model.cfg.vocab_size
    max_seq = 1 << (prompt_len + 64).bit_length()
    eng = Engine(0, model, params, max_slots=decode_reqs + 1,
                 max_seq=max_seq,
                 token_budget=prompt_len + 512 + decode_reqs * 512,
                 chunked_prefill=chunked, prefill_token_budget=budget,
                 attn_backend="dense")

    def one_pass():
        rng = np.random.default_rng(seed)
        decode = [ServeRequest(i, rng.integers(0, vocab, int(p))
                               .astype(np.int32),
                               new_tokens + prompt_len // max(budget, 1))
                  for i, p in enumerate(rng.integers(8, 48, decode_reqs))]
        long_req = ServeRequest(99, rng.integers(0, vocab, prompt_len)
                                .astype(np.int32), 4)
        first_t = {}
        counts = {r.req_id: 0 for r in decode}
        t0 = time.perf_counter()
        clock = lambda: time.perf_counter() - t0

        def observe(reqs):
            now = clock()
            for r in reqs:
                if r.first_token_step is not None and r.req_id not in first_t:
                    first_t[r.req_id] = now
            for r in decode:
                if len(r.generated) > counts[r.req_id]:
                    token_t[r.req_id].append(now)
                    counts[r.req_id] = len(r.generated)

        for r in decode:
            eng.submit(r)
        token_t = {r.req_id: [] for r in decode}
        for _ in range(6):                 # decode batch live pre-arrival
            eng.step()
            observe(decode)
        arrival = clock()
        eng.submit(long_req)
        while long_req.finish_step is None:
            eng.step()
            observe(decode + [long_req])
        stall_window = {r.req_id: [t for t in token_t[r.req_id]
                                   if t >= arrival] or [clock()]
                        for r in decode}
        while any(r.finish_step is None for r in decode):   # full streams
            eng.step()
            observe(decode)
        # decode-stall: a request's max token-to-token wall gap from the
        # long prompt's arrival until it finished prefilling
        gaps = []
        for r in decode:
            last_before = max([t for t in token_t[r.req_id]
                               if t < arrival] or [arrival])
            ts = [last_before] + stall_window[r.req_id]
            gaps.append(float(np.max(np.diff(ts))))
        ttfts = [first_t[i] for i in sorted(first_t) if i != 99]
        ttfts.append(first_t[99] - arrival)
        return {
            "mode": "chunked" if chunked else "monolithic",
            "prompt_len": prompt_len,
            "decode_stall_s": float(max(gaps)),
            "decode_stall_mean_s": float(np.mean(gaps)),
            "long_ttft_s": float(first_t[99] - arrival),
            "ttft_p50_s": percentile(ttfts, 50),
            "ttft_p99_s": percentile(ttfts, 99),
            "wall_s": clock(),
            "tokens": {r.req_id: list(r.generated) for r in decode},
        }

    one_pass()                             # jit warmup: identical shapes
    return one_pass()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--prompt", type=int, default=32_768,
                    help="long-prompt length (the 32K acceptance scenario)")
    ap.add_argument("--budget", type=int, default=256)
    ap.add_argument("--decode-reqs", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=48)
    args = ap.parse_args()

    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    out = {"config": {"arch": cfg.name, "prompt": args.prompt,
                      "budget": args.budget,
                      "decode_reqs": args.decode_reqs,
                      "jax_backend": jax.default_backend()}}
    for chunked in (True, False):
        r = run_scenario(model, params, prompt_len=args.prompt,
                         budget=args.budget, decode_reqs=args.decode_reqs,
                         new_tokens=args.new_tokens, chunked=chunked)
        out[r["mode"]] = r
        print(f"-- {r['mode']:10s} decode-stall max {r['decode_stall_s']*1e3:9.1f} ms  "
              f"long-prompt ttft {r['long_ttft_s']:6.2f} s  "
              f"ttft p50/p99 {r['ttft_p50_s']:.2f}/{r['ttft_p99_s']:.2f} s")

    ch, mono = out["chunked"], out["monolithic"]
    ratio = mono["decode_stall_s"] / max(ch["decode_stall_s"], 1e-9)
    out["decode_stall_reduction"] = ratio
    # chunking reshapes latency, never tokens: bit-identical greedy streams
    assert ch["tokens"] == mono["tokens"], "greedy parity broken"
    for r in (ch, mono):
        r.pop("tokens")
    # acceptance: >= 5x decode-stall reduction, and the chunked stall is
    # ~one mixed iteration (bounded by a small multiple of the post-
    # arrival steady step), not one whole prompt
    assert ratio >= 5.0, f"decode-stall reduction only {ratio:.1f}x"
    assert ch["decode_stall_s"] < mono["long_ttft_s"] / 5.0
    print(f"decode-stall reduced {ratio:.1f}x "
          f"({mono['decode_stall_s']*1e3:.0f} ms -> "
          f"{ch['decode_stall_s']*1e3:.0f} ms) with a "
          f"{args.prompt}-token prompt mid-decode")

    print("wrote", write_artifact("chunked_prefill", out))


if __name__ == "__main__":
    main()
