"""Fused mixed-iteration attention + int8 KV blocks: the one-launch-per-
step benchmark (DESIGN.md §Fused mixed-iteration attention, §Quantized KV
blocks).

Scenario — the hetero longtail mix the fused kernel exists for: a decode
batch whose context lengths spread ~100x is streaming tokens while a long
prompt chunks through the same engine. The separate-kernel engine issues
TWO attention-bearing device calls per mixed step (chunk batch + decode
batch), each padding its own pow2 work bucket; the fused engine packs
both into ONE tagged work list — one call, one launch per layer, the
same two padding tails (buckets stay split: pow2(dec)+pow2(ck), since
a merged pow2 bucket can overshoot the pair). Measures, per engine:

  * wall time per mixed step (median over the long prompt's chunk steps),
  * attention-bearing device calls per mixed step, via the engine's
    ``attn_call`` launch-count shim (trace-time counters can't see
    launches inside jit) — fused MUST be exactly 1, separate exactly 2,
  * greedy-token parity between the two engines (bf16: bit-identical),
  * int8 KV residency from REAL array bytes: resident requests at equal
    pool bytes must be >= 1.8x bf16 (the (Dh+4)/(2·Dh) layout bound).

Emits BENCH_fused_attention.json at the repo root. Asserted acceptance:
fused mixed-step time strictly below the two-launch baseline, exactly one
attention call per fused mixed step, int8 residency >= 1.8x, bf16 tokens
identical across backends. Off-TPU the kernels run in Pallas interpret
mode, whose per-grid-step Python overhead prices neither launches nor DMA
— there the strict mixed-step-time assertion uses the analytic kernel
mirror (``kernels.cost.mixed_iter_time_s``, fused vs flat on the SAME
workload shape; bench_decode_hotloop's "would run" precedent) and the
measured interpret-mode walls are reported unasserted.

Run: PYTHONPATH=src python benchmarks/bench_fused_attention.py
     [--long-prompt 2048] [--budget 64] [--decode-reqs 5]
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

try:
    from benchmarks.common import write_artifact
except ImportError:                     # run as a plain script
    from common import write_artifact

import jax
import numpy as np

import repro.serving.engine as engine_mod
from repro.configs import get_config
from repro.core.migration import kv_bytes
from repro.kernels.cost import AttnSpec, mixed_iter_time_s
from repro.models import build_model
from repro.serving.engine import DEFAULT_BLOCK_SIZE, Engine
from repro.serving.request import ServeRequest


def run_scenario(model, params, *, backend, kv_dtype, long_prompt, budget,
                 decode_reqs, seed=0):
    """Decode batch at ~100x context spread + one long chunking prompt.
    Returns per-mixed-step timings, attention calls per mixed step, and
    the decode requests' greedy streams."""
    vocab = model.cfg.vocab_size
    rng = np.random.default_rng(seed)
    # ~100x spread, none block-aligned — the heterogeneity the flat work
    # list amortizes and padded grids pay for
    plens = np.geomspace(7, 700, decode_reqs).astype(int)
    max_seq = 1 << int(long_prompt + 64).bit_length()
    eng = Engine(0, model, params, max_slots=decode_reqs + 1,
                 max_seq=max_seq,
                 token_budget=long_prompt + 512 + int(plens.sum()) + 4096,
                 attn_backend=backend, kv_dtype=kv_dtype,
                 prefill_token_budget=budget)
    decode = [ServeRequest(i, rng.integers(0, vocab, int(p))
                           .astype(np.int32),
                           8 + long_prompt // max(budget, 1))
              for i, p in enumerate(plens)]
    for r in decode:
        eng.submit(r)
    while any(r.prefilling or r.state.name == "WAITING" for r in decode):
        eng.step()
    for _ in range(4):                  # decode batch in steady state
        eng.step()
    long_req = ServeRequest(99, rng.integers(0, vocab, long_prompt)
                            .astype(np.int32), 2)
    eng.submit(long_req)
    step_s, calls = [], []
    while long_req.prefilling or long_req.first_token_step is None:
        c0 = engine_mod.ATTN_CALLS
        t0 = time.perf_counter()
        eng.step()
        jax.block_until_ready(eng.cache)
        step_s.append(time.perf_counter() - t0)
        calls.append(engine_mod.ATTN_CALLS - c0)
    while any(r.finish_step is None for r in decode):
        eng.step()
    # mixed steps = chunk work beside a live decode batch; drop compile
    # steps (num_work/chunk-bucket retraces) via the median
    return {
        "backend": backend,
        "kv_dtype": kv_dtype,
        "mixed_steps": len(step_s),
        "step_s_median": float(np.median(step_s)),
        "step_s_mean": float(np.mean(step_s)),
        "attn_calls_per_mixed_step": float(np.mean(calls)),
        "attn_calls_max": int(np.max(calls)),
        "tokens": {r.req_id: list(r.generated) for r in decode},
    }


def residency(model, block_size=16, num_blocks=64):
    """Resident-request ratio at EQUAL pool bytes, from real array bytes:
    how many int8 blocks fit in one full-precision pool's footprint.
    The asserted ``resident_ratio_vs_bf16`` normalizes the full pool to
    bf16 width (the reduced CPU model keeps f32 pools, which would
    overstate the win) — the layout bound is 2·Dh/(Dh+4)."""
    full = model.init_paged_cache(num_blocks, block_size)
    int8 = model.init_paged_cache(num_blocks, block_size, kv_dtype="int8")
    b_full, b_int8 = kv_bytes(full), kv_bytes(int8)
    itemsize = jax.tree.leaves(full)[0].dtype.itemsize
    return {
        "full_pool_bytes": int(b_full),
        "full_pool_itemsize": int(itemsize),
        "int8_pool_bytes": int(b_int8),
        "resident_ratio_raw": b_full / b_int8,
        "resident_ratio_vs_bf16": (b_full / b_int8) * 2.0 / itemsize,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--long-prompt", type=int, default=2048)
    ap.add_argument("--budget", type=int, default=64)
    ap.add_argument("--decode-reqs", type=int, default=5)
    args = ap.parse_args()

    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    out = {"config": {"arch": cfg.name, "long_prompt": args.long_prompt,
                      "budget": args.budget,
                      "decode_reqs": args.decode_reqs,
                      "jax_backend": jax.default_backend()}}
    kw = dict(long_prompt=args.long_prompt, budget=args.budget,
              decode_reqs=args.decode_reqs)
    # warmup pass populates each engine's jit caches at identical shapes
    for mode, backend, kvd in (("fused", "fused", "bf16"),
                               ("separate", "flat", "bf16"),
                               ("fused_int8", "fused", "int8")):
        run_scenario(model, params, backend=backend, kv_dtype=kvd, **kw)
        out[mode] = run_scenario(model, params, backend=backend,
                                 kv_dtype=kvd, **kw)
        print(f"-- {mode:10s} mixed-step median "
              f"{out[mode]['step_s_median']*1e3:7.2f} ms  "
              f"attn calls/step {out[mode]['attn_calls_per_mixed_step']:.2f}")

    fused, sep = out["fused"], out["separate"]
    # one-launch contract: EVERY fused mixed step made exactly one
    # attention-bearing device call; the separate path makes two
    assert fused["attn_calls_max"] == 1, \
        f"fused mixed step made {fused['attn_calls_max']} attention calls"
    assert sep["attn_calls_per_mixed_step"] == 2.0, \
        f"baseline made {sep['attn_calls_per_mixed_step']} calls/step"
    # greedy parity: fusing reshapes launches, never bf16 token values
    assert fused["tokens"] == sep["tokens"], "bf16 greedy parity broken"
    speedup = sep["step_s_median"] / max(fused["step_s_median"], 1e-12)
    out["mixed_step_speedup"] = speedup
    # analytic kernel mirror of the SAME mixed-iteration shape: the decode
    # batch mid-longtail plus one budget-sized chunk halfway through the
    # long prompt — identical padding tails, one launch vs two
    spec = AttnSpec(cfg.num_heads, cfg.num_kv_heads, cfg.head_dim,
                    block_s=DEFAULT_BLOCK_SIZE)
    plens = np.geomspace(7, 700, args.decode_reqs).astype(int)
    chunks = [(args.budget, args.long_prompt // 2)]
    t_fused = mixed_iter_time_s(chunks, list(plens), spec,
                                decode_backend="fused")
    t_sep = mixed_iter_time_s(chunks, list(plens), spec,
                              decode_backend="flat")
    out["analytic"] = {"fused_s": t_fused, "separate_s": t_sep,
                       "speedup": t_sep / t_fused}
    on_tpu = jax.default_backend() == "tpu"
    out["measured_assert"] = on_tpu
    assert t_fused < t_sep, \
        f"analytic fused not faster: {t_fused:.3e} vs {t_sep:.3e} s"
    if on_tpu:
        assert fused["step_s_median"] < sep["step_s_median"], \
            f"fused not faster: {fused['step_s_median']*1e3:.2f} ms vs " \
            f"{sep['step_s_median']*1e3:.2f} ms"
        print(f"fused mixed step {speedup:.2f}x the two-launch baseline "
              f"({sep['step_s_median']*1e3:.2f} -> "
              f"{fused['step_s_median']*1e3:.2f} ms)")
    else:
        print(f"off-TPU (interpret mode): analytic mixed step "
              f"{t_sep/t_fused:.2f}x below the two-launch baseline "
              f"({t_sep*1e6:.1f} -> {t_fused*1e6:.1f} us); measured "
              f"interpret walls reported unasserted")

    res = residency(model)
    out["residency"] = res
    assert res["resident_ratio_vs_bf16"] >= 1.8, \
        f"int8 residency only {res['resident_ratio_vs_bf16']:.2f}x vs bf16"
    print(f"int8 KV: {res['resident_ratio_vs_bf16']:.2f}x resident "
          f"requests at equal pool bytes vs bf16 (>= 1.8x required; "
          f"{res['resident_ratio_raw']:.2f}x vs this host's "
          f"{res['full_pool_itemsize']}-byte pools)")
    for k in ("fused", "separate", "fused_int8"):
        out[k].pop("tokens")

    print("wrote", write_artifact("fused_attention", out))


def run():
    """CSV rows for benchmarks.run."""
    main()
    import json
    doc = json.loads((Path(__file__).resolve().parent.parent
                      / "BENCH_fused_attention.json").read_text())
    d = doc["data"]
    return [
        {"name": "fused_mixed_step",
         "us_per_call": d["fused"]["step_s_median"] * 1e6,
         "derived": f"calls_per_step={d['fused']['attn_calls_per_mixed_step']}"},
        {"name": "separate_mixed_step",
         "us_per_call": d["separate"]["step_s_median"] * 1e6,
         "derived": f"speedup={d['mixed_step_speedup']:.3g};"
                    f"int8_residency="
                    f"{d['residency']['resident_ratio_vs_bf16']:.3g}"},
    ]


if __name__ == "__main__":
    main()
