"""Paper Fig. 8: single-instance parity — CascadeInfer's scheduling layer
adds no overhead at E=1 (matches the engine baseline)."""
from __future__ import annotations

from benchmarks.common import ARCH, CAPACITY, row, standalone
from repro.core.partition import PipelinePlan, Stage
from repro.sim.cluster import CascadePolicy, RoundRobinPolicy
from repro.sim.experiment import fitted_qoe, run_policy
from repro.sim.workload import WorkloadSpec, generate


def run():
    reqs = generate(WorkloadSpec(rate=4.0, duration=20.0, seed=11))
    rr = run_policy(ARCH, RoundRobinPolicy(), reqs, 20.0, E=1,
                    capacity_tokens=CAPACITY)
    plan = PipelinePlan([Stage(0.0, float("inf"), 1)], 0.0)
    ca = run_policy(ARCH, CascadePolicy(plan, fitted_qoe(ARCH)), reqs, 20.0,
                    E=1, capacity_tokens=CAPACITY)
    s_rr, s_ca = rr.summary(), ca.summary()
    return [row("fig8/single_instance", s_ca["tpot_mean"] * 1e6,
                cascade_tpot=s_ca["tpot_mean"],
                engine_tpot=s_rr["tpot_mean"],
                overhead=(s_ca["tpot_mean"] / max(s_rr["tpot_mean"], 1e-12)
                          - 1.0))]


if __name__ == "__main__":
    standalone("fig8_single_instance", run)
