"""SLO-tiered preemptive scheduler (DESIGN.md §SLO scheduling &
preemption): queue ordering, park-vs-recompute policy, allocator
park/unpark, bit-identical engine round-trips, and the goodput-under-SLO
acceptance comparison in both drivers (sim cluster + real server)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.kernels.cost import AttnSpec
from repro.sched import (PARK_RESTORE_COST_S, assign_classes, insert_sorted,
                         park_or_recompute, parse_class_mix, priority_of,
                         queue_key, recompute_cost_s, slo_of)
from repro.sched.slo import aging_promotion, tpot_hopeless
from repro.serving.block_pool import BlockAllocator
from repro.serving.request import ServeRequest, State


# ---------------------------------------------------------------------------
# queue keys & class parsing (pure)
# ---------------------------------------------------------------------------
def test_queue_key_priority_then_deadline_then_size():
    # interactive outranks standard outranks batch, whatever the arrivals
    assert queue_key("interactive", 100.0, 1e6, 9) \
        < queue_key("standard", 0.0, 1.0, 0)
    assert queue_key("standard", 100.0, 1e6, 9) \
        < queue_key("batch", 0.0, 1.0, 0)
    # within a class: earlier TTFT deadline first
    assert queue_key("standard", 1.0, 50.0, 1) \
        < queue_key("standard", 2.0, 5.0, 0)
    # equal deadline: shortest job first
    assert queue_key("standard", 1.0, 10.0, 5) \
        < queue_key("standard", 1.0, 20.0, 0)
    # time_scale stretches the deadline component
    assert queue_key("interactive", 4.0, 1.0, 0, time_scale=10.0)[1] \
        == pytest.approx(4.0 + 10.0 * slo_of("interactive").ttft_slo)


def test_insert_sorted_uniform_class_is_fcfs():
    @dataclasses.dataclass
    class Item:
        seq: int
        sched_key: tuple = None

    q = []
    for seq, arrival in enumerate([0.0, 1.0, 2.0, 3.0]):
        it = Item(seq)
        it.sched_key = queue_key("standard", arrival, 1000.0 - seq, seq)
        insert_sorted(q, it)
    assert [i.seq for i in q] == [0, 1, 2, 3]     # arrival order, not size
    # an interactive straggler still jumps the whole standard queue
    late = Item(99)
    late.sched_key = queue_key("interactive", 50.0, 1.0, 99)
    insert_sorted(q, late)
    assert q[0].seq == 99


def test_parse_class_mix_and_assign():
    mix = parse_class_mix("interactive:2,batch:2")
    assert dict(mix) == {"interactive": 0.5, "batch": 0.5}
    assert dict(parse_class_mix("standard=1")) == {"standard": 1.0}
    with pytest.raises(ValueError):
        parse_class_mix("gold:1")
    with pytest.raises(ValueError):
        parse_class_mix("interactive:0")
    classes = assign_classes(500, mix, np.random.default_rng(0))
    assert set(classes) == {"interactive", "batch"}
    assert 150 < classes.count("interactive") < 350


def test_priority_of_unknown_falls_back_to_standard():
    assert priority_of("no-such-class") == priority_of("standard")


# ---------------------------------------------------------------------------
# park-vs-recompute policy (priced via kernels/cost.py)
# ---------------------------------------------------------------------------
def test_park_or_recompute_rule():
    # memory pressure forces recompute: parking frees no blocks
    assert park_or_recompute(must_free_blocks=3, kv_tokens=4096) \
        == "recompute"
    # pure seat pressure without a cost model: park (keeps the KV)
    assert park_or_recompute(must_free_blocks=0, kv_tokens=4096) == "park"


def test_recompute_cost_monotone_and_priced():
    spec = AttnSpec(num_q_heads=8, num_kv_heads=8, head_dim=64)
    c1 = recompute_cost_s(256, spec)
    c2 = recompute_cost_s(4096, spec)
    assert 0.0 < c1 < c2                 # more KV -> strictly costlier
    assert recompute_cost_s(1, spec) > PARK_RESTORE_COST_S
    # with a spec, a seat-only preemption still parks (restore is cheaper)
    assert park_or_recompute(must_free_blocks=0, kv_tokens=2048,
                             spec=spec) == "park"


# ---------------------------------------------------------------------------
# allocator park/unpark
# ---------------------------------------------------------------------------
def test_allocator_park_unpark_invariants():
    alloc = BlockAllocator(num_blocks=8, block_size=16)
    alloc.reserve(4)
    blocks = alloc.allocate(4)
    alloc.park(blocks)
    assert alloc.parked_blocks == 4
    alloc.check_invariants()
    # a parked block's refs may not drop below its park count
    with pytest.raises(AssertionError):
        alloc.release(blocks[:1])
    alloc.check_invariants()
    alloc.unpark(blocks)
    assert alloc.parked_blocks == 0
    alloc.release(blocks)
    alloc.unreserve(4)
    alloc.check_invariants()
    assert alloc.free_blocks == 8


def test_allocator_park_requires_live_blocks():
    alloc = BlockAllocator(num_blocks=4, block_size=16)
    with pytest.raises(AssertionError):
        alloc.park([0])                  # free block: nothing to park


# ---------------------------------------------------------------------------
# engine round-trips (real model)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def setup():
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(model, params, **kw):
    from repro.serving.engine import Engine
    kw.setdefault("max_slots", 4)
    kw.setdefault("max_seq", 96)
    kw.setdefault("paged", True)
    return Engine(0, model, params, **kw)


def _mkreqs(vocab, shapes, classes=None, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for i, (p, n) in enumerate(shapes):
        r = ServeRequest(i, rng.integers(0, vocab, p).astype(np.int32), n)
        r.arrival_step = i
        if classes:
            r.slo_class = classes[i]
        out.append(r)
    return out


def _drive(eng, reqs, max_steps=300, check=False):
    for r in reqs:
        eng.submit(r)
    for _ in range(max_steps):
        eng.step()
        if check:
            eng.allocator.check_invariants()
        if all(r.state is State.FINISHED for r in reqs):
            break
    assert all(r.state is State.FINISHED for r in reqs)
    return [list(r.generated) for r in reqs]


SHAPES = [(10, 12), (14, 12), (8, 10)]


@pytest.mark.parametrize("mode", ["_preempt_park", "_preempt_recompute"])
def test_engine_preempt_resume_bit_identical(setup, mode):
    cfg, model, params = setup
    ref = _drive(_engine(model, params, preemption=False),
                 _mkreqs(cfg.vocab_size, SHAPES))
    eng = _engine(model, params, preemption=True)
    reqs = _mkreqs(cfg.vocab_size, SHAPES)
    for r in reqs:
        eng.submit(r)
    for _ in range(6):
        eng.step()
    slot = next(s for s, r in enumerate(eng.slots)
                if r is not None and r.generated and not r.prefilling)
    victim = eng.slots[slot]
    getattr(eng, mode)(slot)
    eng.allocator.check_invariants()
    assert victim.state in (State.PREEMPTED, State.WAITING)
    for _ in range(300):
        eng.step()
        eng.allocator.check_invariants()
        if all(r.state is State.FINISHED for r in reqs):
            break
    got = [list(r.generated) for r in reqs]
    assert got == ref
    assert eng.preemptions == 1 and eng.resumes == 1
    assert victim.preemptions == 1


def test_engine_uniform_class_fcfs_parity(setup):
    """preemption=True with single-class distinct-arrival traffic is
    bit-identical to preemption=False (the default-on safety claim)."""
    cfg, model, params = setup
    shapes = [(int(p), 10) for p in
              np.random.default_rng(1).integers(8, 20, 6)]
    a = _drive(_engine(model, params, max_slots=2, max_seq=64,
                       preemption=False),
               _mkreqs(cfg.vocab_size, shapes, seed=1))
    eng = _engine(model, params, max_slots=2, max_seq=64, preemption=True)
    b = _drive(eng, _mkreqs(cfg.vocab_size, shapes, seed=1), check=True)
    assert a == b
    assert eng.preemptions == 0


def test_engine_natural_seat_preemption(setup):
    """Batch work holding every seat gets preempted when interactive
    arrives; everyone still finishes and invariants hold throughout."""
    cfg, model, params = setup
    eng = _engine(model, params, max_slots=2, max_seq=96, preemption=True)
    rng = np.random.default_rng(2)
    batch = _mkreqs(cfg.vocab_size, [(12, 40), (12, 40)],
                    classes=["batch", "batch"], seed=2)
    for r in batch:
        eng.submit(r)
    for _ in range(8):
        eng.step()
    it = ServeRequest(99, rng.integers(0, cfg.vocab_size, 10)
                      .astype(np.int32), 8)
    it.slo_class = "interactive"
    it.arrival_step = 8
    eng.submit(it)
    everyone = batch + [it]
    for _ in range(400):
        eng.step()
        eng.allocator.check_invariants()
        if all(r.state is State.FINISHED for r in everyone):
            break
    assert all(r.state is State.FINISHED for r in everyone)
    assert eng.preemptions > 0
    assert eng.resumes > 0
    # the interactive request got served way before the batch drain
    assert it.first_token_step - it.arrival_step < 12


# ---------------------------------------------------------------------------
# sim: preemptive beats FCFS on interactive goodput-under-SLO
# ---------------------------------------------------------------------------
def test_sim_preemptive_beats_fcfs_interactive_goodput():
    from repro.sim.experiment import make_policy, run_policy
    from repro.sim.workload import generate_slo, slo_spec
    reqs = generate_slo(slo_spec(14.0, 25.0, seed=7, max_context=8192))
    got = {}
    for preempt in (False, True):
        pol = make_policy("cascade", "llama3.2-3b", 2)
        res = run_policy("llama3.2-3b", pol, reqs, 25.0, E=2,
                         capacity_tokens=14_000.0, seed=0,
                         prefill_token_budget=512, preemption=preempt)
        got[preempt] = (res.slo_summary(), res.preemption_stats())
    g_fcfs = got[False][0]["interactive"]["goodput_tok_s"]
    g_pre = got[True][0]["interactive"]["goodput_tok_s"]
    assert got[True][1]["preemptions"] > 0
    assert got[False][1]["preemptions"] == 0
    assert g_pre > g_fcfs
    # per-class summary is complete and internally consistent
    for cls, d in got[True][0].items():
        assert d["goodput_tokens"] <= d["tokens"]
        assert 0.0 <= d["attainment"] <= 1.0


# ---------------------------------------------------------------------------
# server: same claim over real engines + summary surface
# ---------------------------------------------------------------------------
def _contention_server(model, params, preemption):
    from repro.core.partition import PipelinePlan, Stage
    from repro.serving.server import MILSServer, ServerConfig
    plan = PipelinePlan([Stage(0.0, float("inf"), 1)], 0.0)
    cfg = ServerConfig(policy="cascade", refinement="none",
                       balancing="inter-stage", preemption=preemption,
                       slo_time_scale=40.0)
    return MILSServer(model, params, plan, None, cfg,
                      max_slots=2, max_seq=128, paged=True)


def _contention_trace(vocab):
    rng = np.random.default_rng(3)
    trace = []
    for i in range(2):
        r = ServeRequest(i, rng.integers(0, vocab, 16).astype(np.int32), 70)
        r.slo_class = "batch"
        trace.append((r, 0))
    for i in range(2):
        r = ServeRequest(10 + i, rng.integers(0, vocab, 12)
                         .astype(np.int32), 8)
        r.slo_class = "interactive"
        trace.append((r, 10))
    return trace


def test_server_preemptive_beats_fcfs_interactive_goodput(setup):
    cfg, model, params = setup
    summaries = {}
    for preempt in (False, True):
        srv = _contention_server(model, params, preempt)
        for req, step in _contention_trace(cfg.vocab_size):
            srv.submit_at(req, step)
        srv.run(max_steps=600)
        for eng in srv.engines:
            eng.allocator.check_invariants()
        summaries[preempt] = srv.summary()
    s_pre, s_fcfs = summaries[True], summaries[False]
    assert s_pre["preemptions"] > 0 and s_pre["resumes"] > 0
    assert s_fcfs["preemptions"] == 0
    assert s_pre["slo_interactive_goodput_tok_step"] \
        > s_fcfs["slo_interactive_goodput_tok_step"]
    assert s_pre["slo_interactive_attainment"] \
        > s_fcfs["slo_interactive_attainment"]
    # the summary reports every class present in the trace
    for key in ("slo_interactive_attainment", "slo_batch_attainment",
                "slo_interactive_requests", "slo_batch_requests",
                "preempt_recomputes"):
        assert key in s_pre


# ---------------------------------------------------------------------------
# starvation/aging guard + TPOT-deadline admission (ISSUE 9 satellites)
# ---------------------------------------------------------------------------
def test_aging_promotion_and_key_clamp():
    # a just-preempted request keeps its class; one full TTFT budget of
    # waiting earns one class, and promotion clamps at the top class
    assert aging_promotion("batch", 10.0, 10.0) == 0
    assert aging_promotion("batch", 10.0, 10.0 + slo_of("batch").ttft_slo
                           - 1e-6) == 0
    assert aging_promotion("batch", 10.0, 10.0 + slo_of("batch").ttft_slo
                           + 1e-6) == 1
    # time_scale converts the budget into engine steps
    assert aging_promotion("batch", 0.0, 4.0, time_scale=0.1) == 1
    assert queue_key("batch", 0.0, 1.0, 0, promote=99)[0] == 0
    # a promoted key ties with interactive on priority and keeps its OWN
    # TTFT deadline (arrival + 30s): it outranks interactive arrivals
    # whose deadline lands later, not every interactive ever
    assert queue_key("batch", 0.0, 1.0, 0, promote=2) \
        < queue_key("interactive", 40.0, 1.0, 1)
    assert queue_key("interactive", 5.0, 1.0, 1) \
        < queue_key("batch", 0.0, 1.0, 0, promote=2)


def test_tpot_hopeless_rule():
    # right after the first token nothing is hopeless
    assert not tpot_hopeless("interactive", 10.0, 10.0, 100)
    # budget is tpot_slo per remaining-token over the WHOLE output: an
    # 11-token interactive decode has 0.5s of slack after token one
    budget = slo_of("interactive").tpot_slo * 10
    assert not tpot_hopeless("interactive", 0.0, budget - 1e-6, 11)
    assert tpot_hopeless("interactive", 0.0, budget + 1e-6, 11)
    # time_scale stretches the budget (engine steps)
    assert not tpot_hopeless("interactive", 0.0, 10.0, 11, time_scale=40.0)


def test_engine_aging_unstarves_preempted_batch(setup):
    """The ISSUE-9 starvation guard on the real engine: a recompute-
    preempted batch request must finish WHILE a saturating interactive
    stream is still arriving (without aging it would sit behind the
    endless priority-0 queue until the stream ends)."""
    cfg, model, params = setup
    rng = np.random.default_rng(11)
    # one block of memory: every admission must recompute-preempt the
    # resident (parking frees no blocks), which arms the aging clock
    eng = _engine(model, params, max_slots=1, max_seq=64, token_budget=16,
                  preemption=True, slo_time_scale=0.05)
    batch = ServeRequest(0, rng.integers(0, cfg.vocab_size, 6)
                         .astype(np.int32), 8)
    batch.slo_class = "batch"
    eng.submit(batch)
    for _ in range(4):
        eng.step()
    assert batch.generated, "victim needs a synced continuation point"
    assert batch.state is State.RUNNING
    stream = []
    for step in range(150):
        if step % 2 == 0:                     # sustained interactive load
            it = ServeRequest(100 + step, rng.integers(0, cfg.vocab_size, 6)
                              .astype(np.int32), 2)
            it.slo_class = "interactive"
            it.arrival_step = eng.steps
            eng.submit(it)
            stream.append(it)
        eng.step()
        eng.allocator.check_invariants()
        if batch.state is State.FINISHED:
            break
    assert batch.state is State.FINISHED, \
        "aging must un-starve the preempted batch request mid-stream"
    assert eng.preempt_recomputes > 0
    assert batch.preemptions > 0
    # drain the rest of the stream (leak check runs after the test)
    for _ in range(400):
        if all(r.state is State.FINISHED for r in stream):
            break
        eng.step()
    assert all(r.state is State.FINISHED for r in stream)


def test_engine_tpot_hopeless_cannot_preempt(setup):
    """TPOT-deadline admission: a resumed decode that already blew its
    TPOT deadline beyond recovery is refused as a preemptor (counted
    once in tpot_skipped), while a fresh healthy request still evicts
    the batch resident."""
    cfg, model, params = setup
    rng = np.random.default_rng(12)
    eng = _engine(model, params, max_slots=1, preemption=True,
                  slo_time_scale=0.05)
    batch = ServeRequest(0, rng.integers(0, cfg.vocab_size, 10)
                         .astype(np.int32), 30)
    batch.slo_class = "batch"
    eng.submit(batch)
    for _ in range(6):
        eng.step()
    assert batch.generated and not batch.prefilling
    # a mid-stream interactive decode whose first token is 6 steps old:
    # budget = 0.05 * 0.05 * (4-1) steps << 6 steps elapsed -> hopeless
    hopeless = ServeRequest(1, rng.integers(0, cfg.vocab_size, 6)
                            .astype(np.int32), 4)
    hopeless.slo_class = "interactive"
    hopeless.generated = [1, 2]
    hopeless.first_token_step = 0
    assert not eng._preempt_for(hopeless)
    assert eng.tpot_skipped == 1
    assert not eng._preempt_for(hopeless)     # counted once per request
    assert eng.tpot_skipped == 1
    assert batch.state is State.RUNNING and eng.preemptions == 0
    # a fresh healthy interactive arrival still preempts the batch work
    healthy = ServeRequest(2, rng.integers(0, cfg.vocab_size, 6)
                           .astype(np.int32), 4)
    healthy.slo_class = "interactive"
    assert eng._preempt_for(healthy)
    assert eng.preemptions == 1
    eng.allocator.check_invariants()
    for _ in range(200):
        eng.step()
        if batch.state is State.FINISHED:
            break
    assert batch.state is State.FINISHED


def test_sim_aging_guard_engages_on_saturated_slo_trace(monkeypatch):
    """Sim mirror on the saturated ``slo_spec`` trace: recompute
    preemptions happen, the aging guard actually computes positive
    promotions for the waiting victims (observed through a recording
    shim), and every preempted request is still served — nothing
    starves to the horizon."""
    from repro.sim import instance as sim_instance
    from repro.sim.experiment import make_policy, run_policy
    from repro.sim.workload import generate_slo, slo_spec

    promotions = []
    real = sim_instance.aging_promotion

    def spy(*a, **k):
        promotions.append(real(*a, **k))
        return promotions[-1]

    monkeypatch.setattr(sim_instance, "aging_promotion", spy)
    reqs = generate_slo(slo_spec(14.0, 25.0, seed=7, max_context=8192))
    pol = make_policy("cascade", "llama3.2-3b", 2)
    res = run_policy("llama3.2-3b", pol, reqs, 60.0, E=2,
                     capacity_tokens=14_000.0, seed=0,
                     prefill_token_budget=512, preemption=True)
    stats = res.preemption_stats()
    assert stats["preempt_recomputes"] > 0
    assert "tpot_skipped" in stats
    assert promotions, "preempted waiters must be re-examined for aging"
    assert any(p > 0 for p in promotions), \
        "saturated trace must age at least one preempted waiter"
    preempted = [r for r in res.served if r.preemptions > 0]
    assert preempted, "saturated trace must recompute-preempt work"
    assert all(r.finish_t is not None for r in preempted)
