"""Launch-layer case construction + analytic roofline formulas."""
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.launch.cases import (SHAPES, adjusted_config, shape_kind,
                                skip_reason)
from repro.launch.roofline import (analytic_flops_global,
                                   analytic_min_bytes, model_flops)

ASSIGNED = [a for a in ARCHS if a != "llama3.2-3b"]


def test_shapes_table():
    assert SHAPES["train_4k"]["global_batch"] == 256
    assert SHAPES["long_500k"]["seq_len"] == 524_288
    assert shape_kind("decode_32k") == "decode"
    assert shape_kind("prefill_32k") == "prefill"


def test_skip_matrix():
    skips = [(a, s) for a in ASSIGNED for s in SHAPES
             if skip_reason(a, s)]
    assert skips == [("whisper-large-v3", "long_500k")]


def test_adjusted_config_long_context():
    for arch in ("qwen2.5-14b", "arctic-480b", "qwen2-vl-7b"):
        cfg = adjusted_config(arch, "long_500k")
        assert cfg.sliding_window == 8192, "dense/MoE/VLM need sub-quadratic"
    assert adjusted_config("rwkv6-7b", "long_500k").sliding_window == 0
    assert adjusted_config("zamba2-2.7b", "long_500k").sliding_window == 0


def test_adjusted_config_moe_uses_gshard():
    assert adjusted_config("qwen3-moe-30b-a3b", "train_4k").moe_impl == \
        "gshard"
    assert get_config("qwen3-moe-30b-a3b").moe_impl == "dense"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_analytic_flops_positive_and_ordered(arch):
    cfg = adjusted_config(arch, "train_4k")
    f_train = analytic_flops_global(cfg, "train_4k", 4096, 256)
    cfg_d = adjusted_config(arch, "decode_32k")
    f_dec = analytic_flops_global(cfg_d, "decode_32k", 32768, 128)
    assert f_train > f_dec > 0
    # executed >= matmul-core model flops
    assert f_train >= model_flops(cfg, "train_4k", 4096, 256) * 0.99


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "arctic-480b", "rwkv6-7b"])
def test_analytic_bytes_floor(arch):
    cfg = adjusted_config(arch, "decode_32k")
    b16 = analytic_min_bytes(cfg, "decode_32k", 32768, 128,
                             {"data": 16, "model": 16})
    b32 = analytic_min_bytes(cfg, "decode_32k", 32768, 128,
                             {"pod": 2, "data": 16, "model": 16})
    assert b16 > 0
    assert b32 <= b16  # more chips -> less per chip
    train = analytic_min_bytes(cfg, "train_4k", 4096, 256,
                               {"data": 16, "model": 16})
    assert train > b16  # optimizer traffic dominates


def test_sliding_window_shrinks_decode_flops():
    full = adjusted_config("qwen2.5-14b", "decode_32k")
    win = adjusted_config("qwen2.5-14b", "long_500k")
    f_full = analytic_flops_global(full, "decode_32k", 32768, 1)
    f_win = analytic_flops_global(win, "long_500k", 524_288, 1)
    # 500k with window 8192 does LESS attention than 32k full
    assert f_win < f_full
