import os
import sys

# src-layout import path (tests runnable without install)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Two virtual host devices so the tensor-parallel serving tests
# (tests/test_tp_engine.py, DESIGN.md §Sharded serving) get a real
# 2-device mesh on CPU. Must land before the first jax import anywhere
# in the session — conftest is imported before every test module, and
# launch/dryrun.py uses the same flag for its 512-chip dry run.
# Single-device code paths are unaffected: default placement stays on
# device 0 and tp=1 engines never enter shard_map.
if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=2")

import numpy as np
import pytest

# ---------------------------------------------------------------------------
# Optional-dependency shim: ``hypothesis`` drives the property tests but is
# not part of the core runtime. When it is missing, install a stub module
# whose ``@given`` marks the test skipped (instead of failing collection of
# the whole module). Real hypothesis, when installed, is used untouched.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    import types

    def _given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (see requirements.txt)")(fn)
        return deco

    def _settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    class _Strategy:
        """Inert placeholder: composable like a strategy, never executed."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, _name):
            return self

    _st = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "floats", "lists", "tuples", "booleans",
                  "sampled_from", "text", "composite", "just", "one_of",
                  "dictionaries", "fixed_dictionaries"):
        setattr(_st, _name, _Strategy())

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.assume = lambda *_a, **_k: True
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


# ---------------------------------------------------------------------------
# Drain-time leak check (DESIGN.md §Fault tolerance): after every test,
# walk all engines constructed so far and assert their allocator state is
# consistent — a migration rollback or crash path that leaks block
# reservations fails the very test that leaked, not some later one.
# Engines a test deliberately crashed are flagged ``_faulted`` and skipped.
# The engine module is looked up via sys.modules so tests that never touch
# the (jax-heavy) serving stack pay nothing.
# ---------------------------------------------------------------------------
@pytest.fixture(autouse=True)
def _engine_leak_check():
    yield
    eng_mod = sys.modules.get("repro.serving.engine")
    if eng_mod is None:
        return
    live = []
    for ref in eng_mod._LIVE_ENGINES:
        eng = ref()
        if eng is None:
            continue
        live.append(ref)
        if getattr(eng, "_faulted", False) or eng.cache is None:
            continue
        eng.check_drained(strict=False)
    eng_mod._LIVE_ENGINES[:] = live


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def qoe_linear():
    """A hand-built QoE model with plausible positive coefficients."""
    from repro.core.qoe import QoEModel
    return QoEModel(np.array([5e-3, 5e-4, 2e-7, 1e-12, 3e-7]))
