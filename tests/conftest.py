import os
import sys

# src-layout import path (tests runnable without install)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def qoe_linear():
    """A hand-built QoE model with plausible positive coefficients."""
    from repro.core.qoe import QoEModel
    return QoEModel(np.array([5e-3, 5e-4, 2e-7, 1e-12, 3e-7]))
