"""Block-granular paged KV cache, end to end: kernel vs. oracle, allocator
invariants, paged-vs-monolithic model numerics, block-budget engine
accounting, and the migration round-trip (paged AND monolithic paths)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.migration import gather_kv_blocks, kv_bytes, scatter_kv_blocks
from repro.kernels.decode_attention import paged_decode_attention
from repro.kernels.ref import decode_attention_ref
from repro.models import build_model
from repro.serving.block_pool import BlockAllocator, blocks_for
from repro.serving.engine import Engine
from repro.serving.request import ServeRequest, State

RNG = np.random.default_rng(0)


# --------------------------------------------------------------------------
# Kernel: block-table grid vs. the monolithic oracle
# --------------------------------------------------------------------------
def _paged_case(lengths, S, H, Hkv, Dh, BS, dtype):
    """Contiguous KV per request, scattered into a shuffled physical pool."""
    B = len(lengths)
    q = RNG.normal(0, 1, (B, H, Dh)).astype(np.float32)
    k = RNG.normal(0, 1, (B, S, Hkv, Dh)).astype(np.float32)
    v = RNG.normal(0, 1, (B, S, Hkv, Dh)).astype(np.float32)
    NBT = S // BS
    NB = B * NBT + 3
    perm = RNG.permutation(NB)
    k_pool = np.zeros((NB, BS, Hkv, Dh), np.float32)
    v_pool = np.zeros((NB, BS, Hkv, Dh), np.float32)
    bt = np.zeros((B, NBT), np.int32)
    pi = 0
    for b, L in enumerate(lengths):
        for j in range(blocks_for(L, BS)):
            pb = int(perm[pi]); pi += 1
            bt[b, j] = pb
            k_pool[pb] = k[b, j * BS:(j + 1) * BS]
            v_pool[pb] = v[b, j * BS:(j + 1) * BS]
    to = lambda a: jnp.asarray(a, dtype)
    return (to(q), to(k), to(v), to(k_pool), to(v_pool),
            jnp.asarray(bt), jnp.asarray(lengths, jnp.int32))


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 3e-5),
                                       (jnp.bfloat16, 1e-2)])
def test_paged_kernel_matches_ref_hetero(dtype, tol):
    """Acceptance: lengths spanning >= 8x (32..512), bf16 atol <= 1e-2,
    physical blocks deliberately shuffled to exercise the indirection."""
    lengths = [32, 100, 512, 64, 377]
    q, k, v, kp, vp, bt, ls = _paged_case(lengths, 512, 8, 2, 64, 64, dtype)
    ref = decode_attention_ref(q, k, v, ls)
    out = paged_decode_attention(q, kp, vp, bt, ls, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_paged_kernel_mqa_and_odd_blocks():
    lengths = [1, 7, 129]
    q, k, v, kp, vp, bt, ls = _paged_case(lengths, 256, 8, 1, 128, 32,
                                          jnp.float32)
    ref = decode_attention_ref(q, k, v, ls)
    out = paged_decode_attention(q, kp, vp, bt, ls, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=3e-5)


# --------------------------------------------------------------------------
# Allocator invariants
# --------------------------------------------------------------------------
def test_block_allocator_invariants():
    a = BlockAllocator(num_blocks=8, block_size=16)
    assert a.free_tokens() == 128 and a.allocated_blocks == 0
    assert a.can_reserve(8) and not a.can_reserve(9)
    a.reserve(5)
    ids = a.allocate(3)
    assert len(set(ids)) == 3 and a.allocated_blocks == 3
    assert a.free_blocks == 5 and a.reserved_blocks == 5
    # reservations cap admissions, not physical blocks
    assert not a.can_reserve(4) and a.can_reserve(3)
    a.free(ids[:2])
    assert a.allocated_blocks == 1
    a.unreserve(4)
    assert a.reserved_blocks == 1
    with pytest.raises(AssertionError):
        a.free(ids[:1])                # double free
    with pytest.raises(AssertionError):
        a.allocate(99)                 # over-allocate


def test_blocks_for():
    assert blocks_for(0, 16) == 0
    assert blocks_for(1, 16) == 1
    assert blocks_for(16, 16) == 1
    assert blocks_for(17, 16) == 2


def test_gather_scatter_blocks_roundtrip(rng):
    pool = {"k": jnp.asarray(rng.normal(0, 1, (2, 6, 4, 3, 8)), jnp.float32)}
    piece = gather_kv_blocks(pool, [4, 1])
    assert piece["k"].shape == (2, 2, 4, 3, 8)
    dst = {"k": jnp.zeros_like(pool["k"])}
    merged = scatter_kv_blocks(dst, piece, [0, 5])
    assert jnp.array_equal(merged["k"][:, 0], pool["k"][:, 4])
    assert jnp.array_equal(merged["k"][:, 5], pool["k"][:, 1])


# --------------------------------------------------------------------------
# Model + engine: paged vs. monolithic
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _req(rng, cfg, rid, plen=12, new=10):
    return ServeRequest(rid, rng.integers(0, cfg.vocab_size, plen)
                        .astype(np.int32), new)


def _run_engine(eng, reqs, max_steps=400):
    for r in reqs:
        eng.submit(r)
    done = []
    for _ in range(max_steps):
        done += eng.step()
        assert eng.free_tokens() >= 0
        if len(done) == len(reqs):
            break
    return done


def test_paged_engine_matches_monolithic_generation(setup, rng):
    """Same prompts through the paged and the slot-slab engine produce
    identical greedy generations — block tables are numerics-neutral."""
    cfg, model, params = setup
    prompts = [rng.integers(0, cfg.vocab_size, p).astype(np.int32)
               for p in (5, 17, 33, 12)]
    outs = []
    for paged in (True, False):
        eng = Engine(0, model, params, max_slots=4, max_seq=64, paged=paged)
        reqs = [ServeRequest(i, p.copy(), 8) for i, p in enumerate(prompts)]
        done = _run_engine(eng, reqs)
        assert len(done) == 4
        outs.append([r.generated for r in sorted(reqs, key=lambda r: r.req_id)])
    assert outs[0] == outs[1]


def test_paged_engine_pins_fewer_bytes_on_heterogeneous_batch(setup, rng):
    """The point of paging: a 16-token request pins ~16 tokens of cache,
    not a max_seq slab."""
    cfg, model, params = setup
    prompts = [4, 4, 4, 40]
    mk = lambda: [_req(rng, cfg, i, plen=p, new=4)
                  for i, p in enumerate(prompts)]
    peak = {}
    for paged in (True, False):
        eng = Engine(0, model, params, max_slots=4, max_seq=128, paged=paged)
        _run_engine(eng, mk())
        peak[paged] = eng.peak_kv_bytes
    assert peak[True] < peak[False], peak


def test_paged_engine_incremental_block_growth(setup, rng):
    """A request crossing block boundaries allocates blocks one at a time
    and frees them all on release."""
    cfg, model, params = setup
    eng = Engine(0, model, params, max_slots=1, max_seq=64, paged=True,
                 block_size=4)
    r = _req(rng, cfg, 0, plen=6, new=10)   # grows 7 -> 16 tokens
    eng.submit(r)
    eng.step()
    assert len(eng.block_tables[0]) == blocks_for(6, 4)
    seen = set()
    while r.state != State.FINISHED:
        seen.add(len(eng.block_tables[0]))
        eng.step()
    assert max(seen) == blocks_for(16, 4)
    assert eng.allocator.allocated_blocks == 0     # all freed
    assert eng.allocator.reserved_blocks == 0


def test_admission_respects_block_budget(setup, rng):
    """Unified accounting: admission gates on worst-case reservations, so
    the free budget is non-negative at every step (the old engine's
    admission and used_tokens() disagreed)."""
    cfg, model, params = setup
    eng = Engine(0, model, params, max_slots=4, max_seq=64, token_budget=40,
                 paged=True, block_size=16)
    reqs = [_req(rng, cfg, i, plen=16, new=4) for i in range(3)]
    done = _run_engine(eng, reqs)
    assert len(done) == 3                        # drains eventually
    assert eng.reserved_tokens() == 0


# --------------------------------------------------------------------------
# Migration round-trip (satellite: bit-identical logits, both layouts)
# --------------------------------------------------------------------------
def _next_logits(model, eng, req):
    """Next-token logits for a running request, computed from the engine's
    exported wire piece (contiguous [L, 1, len, ...])."""
    _, piece, _ = eng.export_slot(req.slot)
    cache = model.init_cache(1, eng.max_seq)
    cache = jax.tree.map(
        lambda a, p: a.at[:, :, :p.shape[2]].set(p.astype(a.dtype)),
        cache, piece)
    tok = jnp.asarray([req.generated[-1]], jnp.int32)
    pos = jnp.asarray([req.length - 1], jnp.int32)
    logits, _ = model.decode_step(model_params(eng), cache, tok, pos)
    return np.asarray(logits[0])


def model_params(eng):
    return eng.params


@pytest.mark.parametrize("paged", [True, False])
def test_migration_roundtrip_bit_identical_logits(setup, rng, paged):
    """export_slot -> evict_slot -> import_request on a second engine must
    produce bit-identical next-token logits vs. never migrating."""
    cfg, model, params = setup
    mk = lambda i: Engine(i, model, params, max_slots=2, max_seq=64,
                          paged=paged)
    src, dst, ref_eng = mk(0), mk(1), mk(2)
    prompt = rng.integers(0, cfg.vocab_size, 13).astype(np.int32)
    r = ServeRequest(0, prompt.copy(), 12)
    ref = ServeRequest(9, prompt.copy(), 12)
    src.submit(r)
    ref_eng.submit(ref)
    for _ in range(4):
        src.step()
        ref_eng.step()
    src_slot = r.slot          # import_request reassigns r.slot to dst's
    req, piece, nbytes = src.export_slot(src_slot)
    # wire piece is trimmed to the written rows (length-1), not max_seq
    assert nbytes == pytest.approx(
        kv_bytes(model.init_cache(1, src.max_seq))
        * (r.length - 1) / src.max_seq)
    assert dst.import_request(req, piece)
    src.evict_slot(src_slot)
    assert dst.slots[r.slot] is r
    assert dst.id in r.tokens_by_engine          # ledger updated on import
    lg_mig = _next_logits(model, dst, r)
    lg_ref = _next_logits(model, ref_eng, ref)
    np.testing.assert_array_equal(lg_mig, lg_ref)
    # and the continued decode stays greedy-identical to completion
    while r.state != State.FINISHED:
        dst.step()
    while ref.state != State.FINISHED:
        ref_eng.step()
    assert r.generated == ref.generated


def test_import_rejects_overflow(setup, rng):
    """A migrated-in request whose remaining generation cannot fit max_seq
    is refused instead of silently truncated."""
    cfg, model, params = setup
    src = Engine(0, model, params, max_slots=2, max_seq=128)
    dst = Engine(1, model, params, max_slots=2, max_seq=32)
    r = _req(rng, cfg, 0, plen=16, new=40)       # needs up to 56 tokens
    src.submit(r)
    src.step()
    req, piece, _ = src.export_slot(r.slot)
    assert not dst.import_request(req, piece)
    assert dst.free_tokens() == dst.token_budget  # nothing leaked


def test_oversized_prompt_rejected_not_wedged(setup, rng):
    """A prompt that can never fit max_seq is failed (rejected=True)
    instead of blocking the FCFS queue forever behind it."""
    cfg, model, params = setup
    eng = Engine(0, model, params, max_slots=2, max_seq=32)
    big = _req(rng, cfg, 0, plen=40, new=4)
    ok = _req(rng, cfg, 1, plen=8, new=4)
    done = _run_engine(eng, [big, ok])
    assert len(done) == 2
    assert big.rejected and big.generated == []
    assert not ok.rejected and len(ok.generated) == 4


def test_import_rejects_when_budget_reserved(setup, rng):
    cfg, model, params = setup
    src = Engine(0, model, params, max_slots=2, max_seq=64)
    dst = Engine(1, model, params, max_slots=2, max_seq=64,
                 token_budget=32, block_size=16)
    big = _req(rng, cfg, 1, plen=20, new=8)      # reserves 2 blocks = all
    dst.submit(big)
    dst.step()
    r = _req(rng, cfg, 0, plen=12, new=8)
    src.submit(r)
    src.step()
    req, piece, _ = src.export_slot(r.slot)
    assert not dst.import_request(req, piece)
