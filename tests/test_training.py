"""Training substrate: optimizer, schedule, data, checkpoint, loop."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.data import DataConfig, TokenStream
from repro.training.optimizer import (AdamWConfig, adamw_update, global_norm,
                                      init_adamw, schedule)
from repro.training.trainer import TrainConfig, train


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    lrs = [float(schedule(cfg, jnp.asarray(s))) for s in
           [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(1e-4, rel=1e-2)


def test_grad_clip():
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.full((4, 4), 100.0)}
    st = init_adamw(params)
    cfg = AdamWConfig(grad_clip=1.0, weight_decay=0.0)
    _, _, info = adamw_update(cfg, grads, st, params)
    assert float(info["grad_norm"]) == pytest.approx(400.0)


def test_adamw_moves_toward_minimum():
    params = {"w": jnp.asarray(5.0).reshape(1)}
    st = init_adamw(params)
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=1000,
                      weight_decay=0.0)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}        # d/dw w²
        params, st, _ = adamw_update(cfg, grads, st, params)
    assert abs(float(params["w"][0])) < 0.5


def test_data_stream_deterministic():
    cfg = get_config("smollm-360m").reduced()
    a = next(iter(TokenStream(cfg, DataConfig(seed=7))))
    b = next(iter(TokenStream(cfg, DataConfig(seed=7))))
    assert np.array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (8, 128)


def test_train_loss_decreases_and_checkpoints():
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    stream = TokenStream(cfg, DataConfig(batch_size=4, seq_len=32))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.npz")
        hist = train(model, params, stream,
                     TrainConfig(steps=40, log_every=10, ckpt_path=path,
                                 opt=AdamWConfig(lr=1e-3, warmup_steps=5,
                                                 total_steps=40)))
        assert hist["loss"][-1] < hist["loss"][0]
        restored, step = load_checkpoint(path, hist["params"])
        assert step == 40
        for a, b in zip(jax.tree.leaves(hist["params"]),
                        jax.tree.leaves(restored)):
            assert np.allclose(a, b)


def test_checkpoint_shape_mismatch_raises():
    tree = {"a": jnp.zeros((2, 2))}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "x.npz")
        save_checkpoint(path, tree)
        with pytest.raises(ValueError):
            load_checkpoint(path, {"a": jnp.zeros((3, 3))})
        with pytest.raises(KeyError):
            load_checkpoint(path, {"b": jnp.zeros((2, 2))})
