"""QoE model (§4.1): features, fitting, prediction error."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.qoe import (QoEModel, batch_features, fit_qoe,
                            relative_errors, static_baseline_errors)


def test_batch_features_values():
    F = batch_features([100, 200], [150, 400])
    assert np.allclose(F, [1.0, 2.0, 300.0, 100**2 + 200**2, 550.0])


def test_batch_features_weighted():
    F = batch_features([100], [150], weights=[0.5])
    assert np.allclose(F, [1.0, 0.5, 50.0, 5000.0, 75.0])


def test_fit_recovers_ground_truth(rng):
    D_true = np.array([5.0, 0.4, 1e-3, 1e-8, 2e-3])
    F = np.stack([batch_features(rng.integers(50, 2000, 16),
                                 rng.integers(100, 60000, 16))
                  for _ in range(500)])
    Q = F @ D_true
    m = fit_qoe(F, Q)
    pred = F @ m.D
    assert np.abs((pred - Q) / Q).max() < 1e-6


def test_fit_nonneg_projection(rng):
    # construct data where unconstrained LS goes negative on one column
    F = np.stack([batch_features(rng.integers(50, 200, 4),
                                 rng.integers(60, 260, 4))
                  for _ in range(200)])
    Q = F @ np.array([1.0, 0.1, 1e-4, 0.0, 1e-4]) + rng.normal(0, 5, 200)
    m = fit_qoe(F, Q, nonneg=True)
    assert (m.D >= 0).all()


def test_batch_q_scaling(qoe_linear):
    # Q^B = n·Q1: doubling the set should more than double batch QoE
    q1 = qoe_linear.batch_q([100] * 4, [200] * 4)
    q2 = qoe_linear.batch_q([100] * 8, [200] * 8)
    assert q2 > 2 * q1
    assert qoe_linear.batch_q([], []) == 0.0


def test_model_beats_static_baseline(rng):
    D_true = np.array([1e-2, 1e-3, 1e-6, 1e-11, 1e-6])
    F = np.stack([batch_features(rng.integers(50, 5000, 8),
                                 rng.integers(60, 30000, 8))
                  for _ in range(300)])
    Q = F @ D_true * rng.normal(1.0, 0.05, 300)
    m = fit_qoe(F, Q)
    err = np.abs(relative_errors(m, F, Q)).mean()
    base = np.abs(static_baseline_errors(F, Q)).mean()
    assert err < base / 3  # paper: 8.9% vs 64%


@given(st.lists(st.tuples(st.integers(1, 10_000), st.integers(1, 10_000)),
                min_size=1, max_size=64))
@settings(max_examples=50, deadline=None)
def test_batch_q_nonnegative_property(pairs):
    m = QoEModel(np.array([5e-3, 5e-4, 2e-7, 1e-12, 3e-7]))
    I = [p[0] for p in pairs]
    L = [p[0] + p[1] for p in pairs]
    assert m.batch_q(I, L) >= 0.0
