"""Decentralized bid-ask protocol (§4.4)."""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.bidask import (Bid, MigRequest, ReceiverState, SenderState,
                               STARVATION_THRESHOLD, is_overloaded,
                               select_receiver)


def test_select_receiver_filters_high_load():
    bids = [Bid(0, 100.0, 0.0, 0),   # lowest start but high load -> filtered
            Bid(1, 1.0, 5.0, 1),
            Bid(2, 2.0, 4.0, 2),
            Bid(3, 90.0, 0.1, 3)]
    # low-load half = {1, 2}; earliest starts keep both; first reply = 1
    assert select_receiver(bids) == 1


def test_select_receiver_first_reply_among_finalists():
    bids = [Bid(0, 1.0, 1.0, 5), Bid(1, 1.0, 1.0, 2), Bid(2, 1.0, 1.0, 9)]
    assert select_receiver(bids) == 1


def test_select_receiver_empty():
    assert select_receiver([]) is None


def test_overload_factor():
    assert is_overloaded(140, [100, 100, 100])        # 140 >= 1.25*110
    assert not is_overloaded(110, [100, 100, 100])
    assert not is_overloaded(0, [0, 0])


def test_sender_single_transmission():
    s = SenderState(0)
    a = s.offer(MigRequest(1, 100, 0))
    b = s.offer(MigRequest(2, 50, 0))
    assert s.load() == 150.0
    assert s.can_transmit(1)
    s.begin(1)
    assert not s.can_transmit(2)       # one transfer at a time
    s.finish(1)
    assert s.can_transmit(2)
    assert s.load() == 50.0


def test_receiver_priority_order():
    r = ReceiverState(9)
    lo = MigRequest(1, 10, 0, priority=5.0)
    hi = MigRequest(2, 10, 0, priority=50.0)
    r.win(lo)
    r.win(hi)
    got, starved = r.next_pull(lambda src: False)
    assert got.req_id == 2            # higher sender load first
    assert starved is None


def test_receiver_starvation_backpressure():
    r = ReceiverState(9)
    req = MigRequest(1, 10, 0, priority=5.0)
    r.win(req)
    starved = None
    for _ in range(STARVATION_THRESHOLD + 1):
        got, starved = r.next_pull(lambda src: True)   # sender always busy
        assert got is None
        if starved is not None:
            break
    assert starved == 1
    # receiver now blocks until the starved request arrives
    got, _ = r.next_pull(lambda src: False)
    assert got is None
    assert r.take(1).req_id == 1
    got, _ = r.next_pull(lambda src: False)
    assert got is None                 # queue empty


def test_sender_starved_priority():
    s = SenderState(0)
    s.offer(MigRequest(1, 10, 0))
    s.offer(MigRequest(2, 10, 0))
    s.mark_starved(2)
    assert not s.can_transmit(1)       # starved request jumps the line
    assert s.can_transmit(2)


@given(st.lists(st.tuples(st.floats(0, 1e6), st.floats(0, 1e6)),
                min_size=1, max_size=20))
@settings(max_examples=60, deadline=None)
def test_select_receiver_properties(loads_starts):
    bids = [Bid(i, l, s, i) for i, (l, s) in enumerate(loads_starts)]
    rid = select_receiver(bids)
    assert rid is not None
    # winner's load must be within the kept (lower-load) half
    loads = sorted(b.load for b in bids)
    keep = loads[:max(1, (len(loads) + 1) // 2)]
    assert bids[rid].load <= keep[-1] + 1e-9
