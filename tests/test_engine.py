"""Real-JAX serving engine: continuous batching, slots, budget, export."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.serving.engine import Engine
from repro.serving.request import ServeRequest, State


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _req(rng, cfg, rid, plen=12, new=10):
    return ServeRequest(rid, rng.integers(0, cfg.vocab_size, plen)
                        .astype(np.int32), new)


def test_engine_serves_to_completion(setup, rng):
    cfg, model, params = setup
    eng = Engine(0, model, params, max_slots=2, max_seq=64)
    reqs = [_req(rng, cfg, i) for i in range(4)]   # 4 reqs > 2 slots
    for r in reqs:
        eng.submit(r)
    done = []
    for _ in range(200):
        done += eng.step()
        if len(done) == 4:
            break
    assert len(done) == 4
    for r in done:
        assert len(r.generated) == r.max_new_tokens
        assert r.state == State.FINISHED


def test_engine_continuous_batching_admits_when_slot_frees(setup, rng):
    cfg, model, params = setup
    eng = Engine(0, model, params, max_slots=1, max_seq=64)
    a, b = _req(rng, cfg, 0, new=4), _req(rng, cfg, 1, new=4)
    eng.submit(a)
    eng.submit(b)
    eng.step()
    assert a.state == State.RUNNING and b.state == State.WAITING
    for _ in range(20):
        eng.step()
        if b.state == State.FINISHED:
            break
    assert b.state == State.FINISHED


def test_engine_greedy_determinism(setup, rng):
    """Same prompt twice (different engines) -> identical generations."""
    cfg, model, params = setup
    prompt = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    outs = []
    for _ in range(2):
        eng = Engine(0, model, params, max_slots=2, max_seq=64)
        r = ServeRequest(0, prompt.copy(), 8)
        eng.submit(r)
        while r.state != State.FINISHED:
            eng.step()
        outs.append(list(r.generated))
    assert outs[0] == outs[1]


def test_engine_export_import_slot(setup, rng):
    cfg, model, params = setup
    src = Engine(0, model, params, max_slots=2, max_seq=64)
    dst = Engine(1, model, params, max_slots=2, max_seq=64)
    r = _req(rng, cfg, 0, new=12)
    src.submit(r)
    for _ in range(3):
        src.step()
    # continue on src for reference
    ref_eng = Engine(2, model, params, max_slots=2, max_seq=64)
    ref = ServeRequest(9, r.prompt.copy(), 12)
    ref_eng.submit(ref)
    while ref.state != State.FINISHED:
        ref_eng.step()
    # migrate r to dst and finish there
    req, piece, nbytes = src.export_slot(r.slot)
    assert nbytes > 0
    assert dst.import_request(req, piece)
    src.evict_slot(0)
    while r.state != State.FINISHED:
        dst.step()
    assert r.generated == ref.generated, "migration must preserve decoding"


def test_engine_token_budget(setup, rng):
    cfg, model, params = setup
    eng = Engine(0, model, params, max_slots=4, max_seq=64, token_budget=40)
    reqs = [_req(rng, cfg, i, plen=16, new=4) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.step()
    running = sum(1 for r in reqs if r.state == State.RUNNING)
    assert running <= 2    # 3 × (16+..) would exceed the 40-token budget
    assert eng.free_tokens() >= 0


@pytest.mark.parametrize("paged", [True, False])
def test_engine_free_budget_never_negative(setup, rng, paged):
    """Admission and used_tokens() share one definition (worst-case
    reservations), so the free budget cannot go negative mid-decode —
    the old engine admitted on prompt length and then grew past budget."""
    cfg, model, params = setup
    eng = Engine(0, model, params, max_slots=4, max_seq=64,
                 token_budget=64, paged=paged)
    reqs = [_req(rng, cfg, i, plen=8, new=24) for i in range(4)]
    for r in reqs:
        eng.submit(r)
    for _ in range(200):
        eng.step()
        assert eng.free_tokens() >= 0
        assert eng.used_tokens() <= eng.reserved_tokens() <= eng.token_budget
        if all(r.state == State.FINISHED for r in reqs):
            break
    assert all(r.state == State.FINISHED for r in reqs)
