"""Tensor-parallel serving (DESIGN.md §Sharded serving, ISSUE 9).

Shard-count invariance on a CPU-forced 2-device mesh (conftest sets
``--xla_force_host_platform_device_count=2``): a ``tp=2`` engine shards
the paged KV pool and weights over KV heads via ``shard_map`` but must
be a pure implementation detail — greedy tokens bit-identical to
``tp=1`` (whose dense backend is the oracle), prefix-cache sharing,
park/recompute preemption resume, and cross-TP migration all
unchanged, while resident KV capacity doubles at equal PER-DEVICE pool
budget and the one-d2h / one-attention-launch-per-mixed-step
disciplines survive the mesh.
"""
import jax
import numpy as np
import pytest

from repro.serving.engine import Engine
from repro.serving import engine as engine_mod
from repro.serving.request import ServeRequest, State

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="tensor-parallel tests need >= 2 (virtual) devices")


@pytest.fixture(scope="module")
def setup():
    from repro.configs import get_config
    from repro.models import build_model
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(model, params, tp, **kw):
    kw.setdefault("max_slots", 4)
    kw.setdefault("max_seq", 96)
    kw.setdefault("token_budget", 256)
    kw.setdefault("attn_backend", "dense")
    return Engine(tp, model, params, tp=tp, **kw)


def _mkreqs(vocab, shapes, seed=0, **attrs):
    rng = np.random.default_rng(seed)
    out = []
    for i, (p, n) in enumerate(shapes):
        r = ServeRequest(i, rng.integers(0, vocab, p).astype(np.int32), n)
        r.arrival_step = i
        for k, v in attrs.items():
            setattr(r, k, v)
        out.append(r)
    return out


def _drive(eng, reqs, max_steps=400):
    for r in reqs:
        eng.submit(r)
    for _ in range(max_steps):
        eng.step()
        if all(r.state is State.FINISHED for r in reqs):
            break
    assert all(r.state is State.FINISHED for r in reqs)
    return [list(r.generated) for r in reqs]


SHAPES = [(9, 10), (21, 10), (13, 8), (6, 10)]


# --------------------------------------------------------------------------
# Greedy parity + capacity (the ISSUE-9 acceptance pair)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["dense", "fused"])
def test_tp2_greedy_tokens_bit_identical_to_tp1(setup, backend):
    """tp=2 emits bit-identical greedy tokens to tp=1 — and tp=1/dense
    IS the dense oracle, so both backends are transitively checked."""
    cfg, model, params = setup
    ref = _drive(_engine(model, params, 1),
                 _mkreqs(cfg.vocab_size, SHAPES))
    got = _drive(_engine(model, params, 2, attn_backend=backend),
                 _mkreqs(cfg.vocab_size, SHAPES))
    assert got == ref


def test_tp2_doubles_resident_kv_at_equal_per_device_budget(setup):
    """``token_budget`` is PER-DEVICE: each shard holds Hkv/tp heads of
    every block, so a tp=2 engine owns 2x the blocks (and resident
    tokens) at the same per-device pool bytes."""
    cfg, model, params = setup
    e1 = _engine(model, params, 1)
    e2 = _engine(model, params, 2)
    assert e2.num_blocks == 2 * e1.num_blocks
    assert e2.token_budget == 2 * e1.token_budget
    assert e2.free_tokens() == 2 * e1.free_tokens()
    # per-device bytes really are equal: the sharded pool splits the
    # kv-head axis, so each shard stores half of 2x the blocks
    leaf1 = jax.tree.leaves(e1.cache)[0]
    leaf2 = jax.tree.leaves(e2.cache)[0]
    assert leaf1.shape[1] == e1.num_blocks + 1           # +1 garbage block
    assert leaf2.shape[1] == e2.num_blocks + 1           # 2x global blocks
    shard = next(iter(leaf2.addressable_shards)).data
    assert shard.size == leaf2.size // 2                 # per-device half


def test_tp2_one_attn_call_one_d2h_per_mixed_step(setup, monkeypatch):
    """The fused one-launch and one-sync contracts hold under shard_map:
    a tp=2 mixed step (long prompt chunking beside live decodes) makes
    exactly ONE attention-bearing device call and ONE d2h."""
    cfg, model, params = setup
    d2h_calls = []
    real = engine_mod.d2h
    monkeypatch.setattr(engine_mod, "d2h",
                        lambda x: d2h_calls.append(1) or real(x))
    eng = _engine(model, params, 2, attn_backend="fused",
                  prefill_token_budget=8)
    short = _mkreqs(cfg.vocab_size, [(5, 10), (11, 10)], seed=3)
    for r in short:
        eng.submit(r)
    while any(r.prefilling or r.state is State.WAITING for r in short):
        eng.step()
    rng = np.random.default_rng(4)
    long_req = ServeRequest(9, rng.integers(0, cfg.vocab_size, 24)
                            .astype(np.int32), 2)
    eng.submit(long_req)
    attn, sync = [], []
    while long_req.prefilling or long_req.first_token_step is None:
        d2h_calls.clear()
        c0 = engine_mod.ATTN_CALLS
        eng.step()
        attn.append(engine_mod.ATTN_CALLS - c0)
        sync.append(len(d2h_calls))
    assert attn and max(attn) == 1, attn
    assert all(s == 1 for s in sync), sync
    while any(not r.done for r in short + [long_req]):
        eng.step()


# --------------------------------------------------------------------------
# Prefix cache, preemption, migration — all invariant under sharding
# --------------------------------------------------------------------------
def test_tp2_prefix_cache_sharing_parity(setup):
    """Shared-prefix admission (refcounted blocks, cached_tokens) works
    identically on the sharded pool: the warm request hits the cache on
    both engines and tokens stay bit-identical."""
    cfg, model, params = setup
    rng = np.random.default_rng(5)
    prefix = rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
    tails = [rng.integers(0, cfg.vocab_size, t).astype(np.int32)
             for t in (7, 5)]
    outs, hits = {}, {}
    for tp in (1, 2):
        eng = _engine(model, params, tp, prefill_token_budget=16)
        reqs = [ServeRequest(i, np.concatenate([prefix, t]), 8)
                for i, t in enumerate(tails)]
        eng.submit(reqs[0])
        while reqs[0].state is not State.FINISHED:    # publishes the prefix
            eng.step()
        eng.submit(reqs[1])
        while reqs[1].state is not State.FINISHED:
            eng.step()
        outs[tp] = [list(r.generated) for r in reqs]
        hits[tp] = reqs[1].cached_tokens
        eng.allocator.check_invariants()
    assert outs[2] == outs[1]
    assert hits[2] == hits[1] > 0, "warm request must share the prefix"


@pytest.mark.parametrize("mode", ["_preempt_park", "_preempt_recompute"])
def test_tp2_preempt_resume_bit_identical(setup, mode):
    """Park and drop-and-recompute preemption resume bit-identically on
    the sharded engine (the allocator and resume machinery never see the
    mesh; recompute replays through the sharded chunked prefill)."""
    cfg, model, params = setup
    shapes = SHAPES[:3]
    ref = _drive(_engine(model, params, 1, preemption=False),
                 _mkreqs(cfg.vocab_size, shapes))
    eng = _engine(model, params, 2, preemption=True)
    reqs = _mkreqs(cfg.vocab_size, shapes)
    for r in reqs:
        eng.submit(r)
    for _ in range(6):
        eng.step()
    slot = next(s for s, r in enumerate(eng.slots)
                if r is not None and r.generated and not r.prefilling)
    getattr(eng, mode)(slot)
    eng.allocator.check_invariants()
    for _ in range(400):
        eng.step()
        if all(r.state is State.FINISHED for r in reqs):
            break
    assert [list(r.generated) for r in reqs] == ref
    assert eng.preemptions == 1 and eng.resumes == 1


def test_migration_round_trip_between_different_tp(setup):
    """Live migration tp=1 -> tp=2 -> tp=1: the wire format is the same
    contiguous unsharded piece (export gathers shards to host, import
    re-pins under the receiver's sharding), so engines of different TP
    interoperate and the decode continues bit-identically."""
    cfg, model, params = setup
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, cfg.vocab_size, 13).astype(np.int32)
    r = ServeRequest(0, prompt.copy(), 14)
    ref = ServeRequest(9, prompt.copy(), 14)
    a = _engine(model, params, 1, max_slots=2)
    b = _engine(model, params, 2, max_slots=2)
    ref_eng = _engine(model, params, 1, max_slots=2)
    a.submit(r)
    ref_eng.submit(ref)
    for _ in range(4):
        a.step()
        ref_eng.step()
    src_slot = r.slot
    req, piece, nbytes = a.export_slot(src_slot)
    assert nbytes > 0
    assert b.import_request(req, piece)           # tp=1 piece -> tp=2 pool
    a.evict_slot(src_slot)
    a.allocator.check_invariants()
    for _ in range(4):
        b.step()
        ref_eng.step()
    src_slot = r.slot
    req, piece, _ = b.export_slot(src_slot)       # tp=2 piece -> tp=1 pool
    assert a.import_request(req, piece)
    b.evict_slot(src_slot)
    b.allocator.check_invariants()
    while r.state is not State.FINISHED:
        a.step()
    while ref.state is not State.FINISHED:
        ref_eng.step()
    assert r.generated == ref.generated
    assert set(r.tokens_by_engine) >= {a.id, b.id}
