"""Fault-tolerance suite (DESIGN.md §Fault tolerance, ISSUE 8).

Covers the three layers independently of the parity tests in
test_controlplane.py:

  * the fault model itself — BackoffPolicy schedule, FaultInjector
    determinism and per-attempt re-draws;
  * the control plane over the pure-python mock backend — the no-spin
    regression (a receiver that always fails the transfer cannot make
    the plane retry forever), health transitions, stage folding and
    rejoin re-expansion, dead-instance re-dispatch and budget-exhausted
    failure;
  * the simulator under chaos — rollback invariants after lost
    transfers, request conservation under random crash interleavings
    (hypothesis), downtime/rejoin accounting;
  * the real JAX engine — a mid-decode engine kill whose re-dispatched
    residents continue bit-identically, plus drain/shutdown leak checks.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.control import MIG_FAILED, ControlConfig
from repro.control.faults import (HEALTH_ALIVE, HEALTH_DEAD, HEALTH_SUSPECT,
                                  XFER_LOST, XFER_OK, BackoffPolicy,
                                  FaultInjector, FaultSpec)
from test_controlplane import (MockBackend, MockRequest, make_plane,
                               run_workload, two_stage_plan)


# --------------------------------------------------------------------------
# Fault model
# --------------------------------------------------------------------------
def test_backoff_policy_grows_and_caps():
    pol = BackoffPolicy(max_retries=6, base=1.0, multiplier=2.0, cap=32.0)
    assert [pol.delay(n) for n in range(1, 8)] == \
        [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 32.0]
    assert pol.delay(0) == 1.0          # defensive: never negative-exponent


def test_fault_injector_is_deterministic_and_redraws_per_attempt():
    spec = FaultSpec(seed=7, transfer_loss_p=0.5)
    a, b = FaultInjector(spec), FaultInjector(spec)
    seq_a = [a.transfer_event(3) for _ in range(32)]
    seq_b = [b.transfer_event(3) for _ in range(32)]
    assert seq_a == seq_b, "same spec must yield identical fates"
    assert XFER_OK in seq_a and XFER_LOST in seq_a, \
        "p=0.5 retries must re-draw, not repeat the first fate"
    # attempt counter is per-request: another request draws independently
    assert [FaultInjector(spec).transfer_event(4) for _ in range(32)] != seq_a


def test_rack_events_expand_into_crashes():
    """A rack event is sugar for several same-tick crashes: ``all_crashes``
    folds racks after the scripted singles, and the injector resolves
    crash_time for every member."""
    spec = FaultSpec(seed=0, crashes=((0, 1.0),),
                     racks=(((1, 2), 4.0), ((3,), 9.0)))
    assert spec.all_crashes == ((0, 1.0), (1, 4.0), (2, 4.0), (3, 9.0))
    inj = FaultInjector(spec)
    assert inj.crash_time(1) == 4.0 and inj.crash_time(2) == 4.0
    assert inj.crash_time(3) == 9.0 and inj.crash_time(0) == 1.0
    assert FaultSpec(seed=0).all_crashes == ()


def test_fault_injector_scripted_lookups():
    spec = FaultSpec(seed=0, crashes=((2, 5.0),), rejoins=((2, 9.0),),
                     slowdowns=((1, 3.0), (0, 0.5)))
    inj = FaultInjector(spec)
    assert inj.crash_time(2) == 5.0 and inj.crash_time(0) is None
    assert inj.rejoin_time(2) == 9.0 and inj.rejoin_time(1) is None
    assert inj.slowdown(1) == 3.0
    assert inj.slowdown(0) == 1.0, "slowdown factors clamp at 1.0"
    assert inj.transfer_event(0) == XFER_OK, "no wire faults configured"


# --------------------------------------------------------------------------
# Control plane: retry backoff + no-spin bound (satellite of ISSUE 8)
# --------------------------------------------------------------------------
class FailingWireBackend(MockBackend):
    """Every migration attempt fails at the backend (the receiver looked
    willing at offer time but the transfer never succeeds) — the
    pathological case that used to retry unboundedly."""

    def __init__(self, *a, **k):
        super().__init__(*a, **k)
        self.attempts = 0

    def start_migration(self, r, src_id, dst_id):
        self.attempts += 1
        return MIG_FAILED


def test_permanently_failing_receiver_cannot_spin():
    backend = FailingWireBackend(2)
    plane = make_plane(backend, two_stage_plan(2, boundary=64.0),
                       ControlConfig(refinement="none"))
    req = MockRequest(0, 32, 200)       # crosses the boundary at step 32
    run_workload(backend, plane, [req], max_steps=400)

    pol = plane.cfg.mig_backoff
    assert req in backend.finished, "request must complete on its source"
    assert backend.attempts == pol.max_retries + 1, \
        "attempts must be exactly max_retries + 1 (initial + retries)"
    assert plane.retries == pol.max_retries + 1
    assert ("mig_giveup", 0) in plane.decisions
    # backoff spacing: consecutive attempts are at least delay(n) rounds
    # apart, so the attempt count stays tiny even over hundreds of steps
    assert backend.attempts < 10


def test_backoff_delays_spread_attempts():
    """The n-th retry waits delay(n) pump rounds: with base=2 the second
    attempt cannot happen on the round right after the first failure."""
    backend = FailingWireBackend(2)
    plane = make_plane(backend, two_stage_plan(2, boundary=8.0),
                       ControlConfig(refinement="none",
                                     mig_backoff=BackoffPolicy(
                                         max_retries=2, base=4.0,
                                         multiplier=2.0, cap=16.0)))
    req = MockRequest(0, 6, 100)
    attempt_rounds = []
    orig = backend.start_migration

    def spy(r, s, d):
        attempt_rounds.append(plane._round)
        return orig(r, s, d)

    backend.start_migration = spy
    run_workload(backend, plane, [req], max_steps=200)
    assert len(attempt_rounds) == 3      # max_retries=2 -> 3 attempts
    gaps = np.diff(attempt_rounds)
    assert gaps[0] >= 4.0 and gaps[1] >= 8.0, gaps


# --------------------------------------------------------------------------
# Control plane: liveness, folding, re-dispatch
# --------------------------------------------------------------------------
class RecoveringBackend(MockBackend):
    """MockBackend + the optional recovery ops the plane probes for."""

    def __init__(self, *a, **k):
        super().__init__(*a, **k)
        self.failed = []
        self.downed = []

    def redispatch(self, r, iid):
        self.instances[iid].waiting.append(r)
        return True

    def fail_request(self, r):
        r.done = True
        self.failed.append(r)

    def instance_down(self, iid):
        self.downed.append(iid)
        inst = self.instances[iid]
        inst.running.clear()
        inst.waiting.clear()


def _beat_all(plane, ids, t):
    for i in ids:
        plane.heartbeat(i, t)


def test_health_transitions_and_routing_filter():
    backend = RecoveringBackend(4)
    plane = make_plane(backend, two_stage_plan(4, boundary=64.0),
                       ControlConfig(refinement="none"))
    _beat_all(plane, range(4), 0.0)
    plane.check_liveness(2.0)
    assert set(plane.instance_health().values()) == {HEALTH_ALIVE}

    # instance 1 goes silent: alive -> suspect -> dead
    _beat_all(plane, (0, 2, 3), 4.0)
    plane.check_liveness(4.0)
    assert plane.instance_health()[1] == HEALTH_SUSPECT
    assert ("suspect", 1) in plane.decisions
    # suspect instances stop receiving new work (stage 0 = {0, 1})
    routes = {plane.route(100 + i, 10.0) for i in range(4)}
    assert routes == {0}

    _beat_all(plane, (0, 2, 3), 7.0)
    plane.check_liveness(7.0)
    assert plane.instance_health()[1] == HEALTH_DEAD
    assert ("dead", 1) in plane.decisions and 1 in backend.downed

    # rejoin: a heartbeat from a dead instance restores routing
    plane.heartbeat(1, 8.0)
    assert ("rejoin", 1) in plane.decisions
    assert {plane.route(200 + i, 10.0) for i in range(4)} == {0, 1}


def test_dead_stage_folds_into_neighbor():
    backend = RecoveringBackend(4)
    plane = make_plane(backend, two_stage_plan(4, boundary=64.0),
                       ControlConfig(refinement="none"))
    _beat_all(plane, range(4), 0.0)
    _beat_all(plane, (2, 3), 10.0)      # whole stage 0 dies
    plane.check_liveness(10.0)
    assert plane.instance_health()[0] == HEALTH_DEAD
    assert plane.instance_health()[1] == HEALTH_DEAD
    # short arrivals fold into the surviving later stage instead of
    # black-holing the [0, 64) length range
    assert {plane.route(i, 10.0) for i in range(4)} == {2, 3}


def test_dead_instance_residents_are_redispatched():
    backend = RecoveringBackend(4)
    plane = make_plane(backend, two_stage_plan(4, boundary=64.0),
                       ControlConfig(refinement="none"))
    reqs = [MockRequest(i, 10, 50) for i in range(2)]
    run_workload(backend, plane, reqs, max_steps=2)   # routed 0 and 1
    assert backend.residences(reqs[1]) == [1]

    _beat_all(plane, range(4), 0.0)
    _beat_all(plane, (0, 2, 3), 10.0)
    plane.check_liveness(10.0)          # instance 1 dies holding reqs[1]
    red = [d for d in plane.decisions if d[0] == "redispatch"]
    assert red == [("redispatch", 1, 0)], red
    assert backend.residences(reqs[1]) == [0]
    assert plane.redispatches == 1 and not backend.failed


def test_redispatch_budget_exhaustion_fails_request():
    backend = RecoveringBackend(4)
    plane = make_plane(backend, two_stage_plan(4, boundary=64.0),
                       ControlConfig(refinement="none", redispatch_budget=0))
    reqs = [MockRequest(i, 10, 50) for i in range(2)]
    run_workload(backend, plane, reqs, max_steps=2)
    _beat_all(plane, range(4), 0.0)
    _beat_all(plane, (0, 2, 3), 10.0)
    plane.check_liveness(10.0)
    assert backend.failed == [reqs[1]], \
        "over-budget residents surface as failed, not silently dropped"
    assert ("fail", 1) in plane.decisions
    assert 1 in plane.failed_ids


# --------------------------------------------------------------------------
# Simulator chaos
# --------------------------------------------------------------------------
def _sim_run(lens, faults, duration=60.0, n_instances=4, **cfg_kw):
    from repro.configs import get_config
    from repro.core.partition import PipelinePlan, Stage
    from repro.sim.cluster import CascadePolicy, Cluster, ClusterConfig
    from repro.sim.costmodel import profile_from_config
    from repro.sim.workload import Request

    plan = PipelinePlan([Stage(0.0, 32.0, n_instances - n_instances // 2),
                         Stage(32.0, float("inf"), n_instances // 2)], 0.0)
    trace = [Request(i, 0.05 * i, il, ol) for i, (il, ol) in enumerate(lens)]
    policy = CascadePolicy(plan, None, refinement="none", balancing="rr")
    cluster = Cluster(profile_from_config(get_config("llama3.2-3b")), policy,
                      ClusterConfig(num_instances=n_instances, seed=0,
                                    prefill_token_budget=8, faults=faults,
                                    **cfg_kw))
    res = cluster.run(trace, duration=duration)
    return cluster, policy, res


def test_sim_lost_transfers_roll_back_cleanly():
    """transfer_loss_p=1: every migration times out. The sender must
    roll back (request keeps decoding at the source), receiver-side
    reservations must be released, and the retry ban must bound the
    total attempt count."""
    spec = FaultSpec(seed=1, transfer_loss_p=1.0)
    cluster, policy, res = _sim_run([(20, 4000), (8, 4)], spec,
                                    duration=120.0, migration_timeout_s=0.5)
    assert len(res.completed) == 2
    assert all(not r.failed and not r.rejected for r in res.completed)
    for inst in cluster.instances:
        assert inst.inbound_reserved == 0, "leaked receiver reservation"
        assert not inst.migrations.active, "transfer never cleaned up"
    assert res.retries == BackoffPolicy().max_retries + 1
    assert res.summary()["retries"] == res.retries


def test_sim_crash_redispatch_rejoin_and_downtime_accounting():
    spec = FaultSpec(seed=0, crashes=((2, 0.8),), rejoins=((2, 5.0),))
    cluster, policy, res = _sim_run([(20, 500), (8, 4), (20, 500), (10, 6)],
                                    spec, duration=60.0,
                                    suspect_after_s=1.0, dead_after_s=2.0)
    log = policy.plane.decisions
    assert ("dead", 2) in log and ("rejoin", 2) in log
    assert any(d[0] == "redispatch" for d in log)
    assert len(res.completed) == 4
    assert all(not r.failed for r in res.completed)
    recovered = [r for r in res.completed if r.redispatches]
    assert recovered, "the crashed instance held at least one resident"
    s = res.summary()
    assert s["redispatched"] == len(recovered)
    assert s["downtime_total"] > 0 and s["downtime_i2"] > 0
    assert s["failed"] == 0


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), crash_at=st.floats(0.05, 2.0),
       victim=st.integers(0, 3))
def test_sim_conserves_requests_under_random_crashes(seed, crash_at, victim):
    """Chaos property: whatever instance dies whenever, every submitted
    request ends exactly once — served, rejected, or failed. Nothing
    hangs, nothing double-finishes."""
    spec = FaultSpec(seed=seed, crashes=((victim, crash_at),))
    lens = [(20, 300), (8, 4), (20, 300), (10, 6), (12, 40), (28, 100)]
    _, _, res = _sim_run(lens, spec, duration=80.0)
    assert len(res.completed) == len(lens)
    ids = [r.req.req_id for r in res.completed]
    assert len(set(ids)) == len(ids), "a request finished twice"


def test_sim_rack_crash_conserves_requests_and_folds_stage():
    """Correlated-failure chaos (ISSUE 9): a rack event kills BOTH
    stage-1 instances in one tick. The whole stage folds into the
    survivors (no length range black-holes), every resident is
    re-dispatched, and request conservation holds — each submitted
    request ends exactly once."""
    spec = FaultSpec(seed=0, racks=(((2, 3), 0.9),))
    lens = [(20, 400), (8, 4), (20, 400), (10, 6), (40, 30), (36, 20)]
    cluster, policy, res = _sim_run(lens, spec, duration=80.0,
                                    suspect_after_s=1.0, dead_after_s=2.0)
    log = policy.plane.decisions
    assert ("dead", 2) in log and ("dead", 3) in log, \
        "both rack members must die"
    # both deaths land in the same liveness tick: no routing happens
    # between them, only the first victim's resident re-dispatch
    deads = [i for i, d in enumerate(log) if d[0] == "dead"]
    assert len(deads) == 2
    between = log[deads[0] + 1:deads[1]]
    assert all(d[0] == "redispatch" for d in between), between
    assert len(res.completed) == len(lens)
    ids = [r.req.req_id for r in res.completed]
    assert len(set(ids)) == len(ids), "a request finished twice"
    assert all(not r.failed for r in res.completed)
    s = res.summary()
    assert s["downtime_i2"] > 0 and s["downtime_i3"] > 0
    # long requests kept arriving at stage 1 after the fold: they must
    # have been served by the surviving short-stage instances
    long_done = [r for r in res.completed if r.req.input_len >= 36]
    assert long_done and all(set(r.tokens_by_instance) <= {0, 1}
                             for r in long_done if r.req.arrival > 0.9)


def test_sim_slowdown_shifts_load_not_correctness():
    spec = FaultSpec(seed=0, slowdowns=((0, 4.0),))
    _, _, res = _sim_run([(10, 30)] * 6, spec, duration=60.0)
    assert len(res.completed) == 6
    assert all(not r.failed and not r.rejected for r in res.completed)


# --------------------------------------------------------------------------
# Shared failure-accounting formula
# --------------------------------------------------------------------------
def test_fault_summary_formula():
    from repro.sim.metrics import fault_summary
    flags = [(False, False, 0), (True, False, 0), (False, True, 2),
             (False, False, 1)]
    s = fault_summary(flags, retries=5, downtime={1: 3.5, 3: 1.5})
    assert s["rejected"] == 1 and s["failed"] == 1
    assert s["redispatched"] == 2       # requests with >= 1 redispatch
    assert s["retries"] == 5
    assert s["downtime_total"] == 5.0
    assert s["downtime_i1"] == 3.5 and s["downtime_i3"] == 1.5


# --------------------------------------------------------------------------
# Real engine: bit-identical recovery + drain/shutdown
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def engine_setup():
    import jax

    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _server(model, params, faults=None, **kw):
    from repro.core.partition import PipelinePlan, Stage
    from repro.core.qoe import QoEModel
    from repro.serving.server import MILSServer, ServerConfig

    plan = PipelinePlan([Stage(0.0, 48.0, 2),
                         Stage(48.0, float("inf"), 2)], 0.0)
    qoe = QoEModel(np.array([1e-3, 1e-4, 1e-6, 0.0, 1e-6]))
    return MILSServer(model, params, plan, qoe,
                      ServerConfig(policy="cascade", seed=0, faults=faults),
                      max_slots=3, max_seq=96, **kw)


def test_engine_crash_redispatch_is_bit_identical(engine_setup):
    """Kill one engine mid-decode: its residents replay prompt +
    generated-so-far through chunked prefill elsewhere and must continue
    with EXACTLY the tokens a fault-free run produces (greedy decode is
    deterministic; recovery may not change it)."""
    from repro.control.faults import FaultSpec
    from repro.serving.request import ServeRequest

    cfg, model, params = engine_setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 20).astype(np.int32)
               for _ in range(6)]

    ref_srv = _server(model, params)
    ref = ref_srv.run([ServeRequest(i, p.copy(), 40)
                       for i, p in enumerate(prompts)], max_steps=500)
    ref_toks = {r.req_id: list(r.generated) for r in ref}

    srv = _server(model, params, faults=FaultSpec(seed=0, crashes=((0, 12),)))
    fin = srv.run([ServeRequest(i, p.copy(), 40)
                   for i, p in enumerate(prompts)],
                  max_steps=800, drain=True)
    assert len(fin) == len(prompts)
    recovered = [r for r in fin if r.redispatches]
    assert recovered, "engine 0 must have held residents at death"
    for r in fin:
        if not r.failed:
            assert list(r.generated) == ref_toks[r.req_id], \
                f"req {r.req_id}: recovery changed greedy decode"
    s = srv.summary()
    assert s["redispatched"] == len(recovered)
    assert s["downtime_i0"] > 0
    log = srv.plane.decisions
    assert ("dead", 0) in log


def test_engine_drain_check_and_shutdown(engine_setup):
    from repro.serving.request import ServeRequest

    cfg, model, params = engine_setup
    rng = np.random.default_rng(1)
    srv = _server(model, params)
    fin = srv.run([ServeRequest(i, rng.integers(0, cfg.vocab_size, 12)
                                .astype(np.int32), 6) for i in range(3)],
                  max_steps=200, drain=True)     # run() asserts drained
    assert len(fin) == 3
    for eng in srv.engines:
        eng.shutdown()                           # strict check, then free
        assert eng.cache is None
    with pytest.raises(AssertionError):
        busy = _server(model, params)
        req = ServeRequest(99, rng.integers(0, cfg.vocab_size, 12)
                           .astype(np.int32), 6)
        busy.engines[0].submit(req)
        busy.engines[0].check_drained(strict=True)
