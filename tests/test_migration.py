"""Live KV migration (§5) and real KV slice/merge."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.migration import (MAX_CONCURRENT, MigrationManager, kv_bytes,
                                  merge_kv_batch, plan_live_migration,
                                  slice_kv_batch)


def test_live_migration_converges():
    t = plan_live_migration(tokens=50_000, decode_tok_per_s=30,
                            bytes_per_token=2e5, bandwidth=25e9)
    assert t.total_s > 0
    assert t.stall_s <= t.total_s
    assert t.rounds >= 1
    assert t.bytes_moved >= 50_000 * 2e5


def test_more_bandwidth_is_faster():
    slow = plan_live_migration(50_000, 30, 2e5, 10e9)
    fast = plan_live_migration(50_000, 30, 2e5, 100e9)
    assert fast.total_s < slow.total_s
    assert fast.stall_s <= slow.stall_s + 1e-12


def test_stall_is_small_fraction():
    # live migration's whole point: stop-and-copy residual is tiny
    t = plan_live_migration(100_000, 20, 2e5, 25e9)
    assert t.stall_s < 0.05 * t.total_s + 1e-6


def test_manager_concurrency_cap():
    m = MigrationManager()
    for i in range(MAX_CONCURRENT):
        assert m.can_start(True)
        m.start(i, 1.0)
    assert not m.can_start(True)           # cap reached
    m.finish(0)
    assert m.can_start(True)
    assert not m.can_start(False)          # no idle slot on target


def test_kv_slice_merge_roundtrip(rng):
    cache = {"k": jnp.asarray(rng.normal(0, 1, (2, 4, 8, 3, 16)),
                              jnp.float32),
             "v": jnp.asarray(rng.normal(0, 1, (2, 4, 8, 3, 16)),
                              jnp.float32)}
    piece = slice_kv_batch(cache, 2)
    assert piece["k"].shape == (2, 1, 8, 3, 16)
    target = jax.tree.map(jnp.zeros_like, cache)
    merged = merge_kv_batch(target, piece, 0)
    assert jnp.allclose(merged["k"][:, 0], cache["k"][:, 2])
    assert kv_bytes(piece) == 2 * 2 * 8 * 3 * 16 * 4


@given(st.integers(100, 10**6), st.floats(1, 1000), st.floats(1e9, 1e11))
@settings(max_examples=40, deadline=None)
def test_migration_properties(tokens, rate, bw):
    t = plan_live_migration(tokens, rate, 2e5, bw)
    assert t.total_s >= t.stall_s >= 0
    assert t.rounds <= 9
