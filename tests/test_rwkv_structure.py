"""RWKV6 hoisted-projection structure: sequence path == stepwise path."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import rwkv6


def test_seq_equals_stepwise():
    cfg = get_config("rwkv6-7b").reduced()
    pl = rwkv6.init_layer(jax.random.PRNGKey(0), cfg)
    B, T, D = 2, 9, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, D))
    y_seq = rwkv6.time_mix_seq(pl, cfg, x)
    # stepwise reference
    H, K = rwkv6._heads(cfg)
    S = jnp.zeros((B, H, K, K), jnp.float32)
    prev = jnp.zeros((B, D))
    ys = []
    for t in range(T):
        y, S = rwkv6.time_mix_step(pl, cfg, x[:, t], prev, S)
        prev = x[:, t]
        ys.append(y)
    y_ref = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_ref),
                               atol=2e-5, rtol=2e-4)


def test_prefill_state_continues_decode():
    cfg = get_config("rwkv6-7b").reduced()
    p = rwkv6.init_model(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                                cfg.vocab_size)
    logits_pre, state = rwkv6.prefill(p, cfg, tokens)
    nxt = jnp.argmax(logits_pre, -1).astype(jnp.int32)
    logits_dec, _ = rwkv6.forward_decode(p, cfg, nxt, state)
    # reference: full forward over tokens + nxt
    full = jnp.concatenate([tokens, nxt[:, None]], axis=1)
    ref, _, _ = rwkv6.forward_full(p, cfg, full)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(ref[:, -1]), atol=5e-5, rtol=5e-4)


def test_ssd_chunked_equals_sequential():
    """Chunked SSD (the real Mamba2 algorithm) == sequential scan."""
    import dataclasses
    from repro.models import mamba2
    cfg = get_config("zamba2-2.7b").reduced()
    pl = mamba2.init_mamba_block(jax.random.PRNGKey(0), cfg)
    d_inner, H, P, N = mamba2.dims(cfg)
    B, T = 2, 64
    k = jax.random.split(jax.random.PRNGKey(1), 5)
    x = jax.random.normal(k[0], (B, T, d_inner))
    Bm = jax.random.normal(k[1], (B, T, N))
    Cm = jax.random.normal(k[2], (B, T, N))
    dt = jax.random.normal(k[3], (B, T, H)) * 0.5
    S0 = jax.random.normal(k[4], (B, H, P, N))
    y1, S1 = mamba2._ssd_scan(pl, cfg, x, Bm, Cm, dt, S0=S0)
    y2, S2 = mamba2._ssd_chunked(pl, cfg, x, Bm, Cm, dt, S0=S0, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(S1), np.asarray(S2), atol=1e-4,
                               rtol=1e-4)


def test_zamba_loss_same_with_chunked():
    import dataclasses
    from repro.models import build_model, synthetic_batch
    base = get_config("zamba2-2.7b").reduced()
    chunked = dataclasses.replace(base, ssm_chunk=8)
    m1, m2 = build_model(base), build_model(chunked)
    params = m1.init(jax.random.PRNGKey(0))
    batch = synthetic_batch(base, 2, 16)
    l1, _ = m1.loss(params, batch)
    l2, _ = m2.loss(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
