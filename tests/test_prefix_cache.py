"""Refcounted prefix-cached KV pool, end to end (DESIGN.md §Prefix cache):
allocator share/release/reclaim invariants (unit + hypothesis random
interleavings), the aliased-block-table decode-kernel oracle (shared
physical blocks in multiple tables — zero kernel changes), warm-vs-cold
engine acceptance (bit-identical tokens, >= 90% of prefill block-work
skipped), tail-only admission reservations, LRU reclaim, and the
migrated-shared-prefix round trip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.kernels.cost import AttnSpec, prefill_flops, prefill_flops_skipped
from repro.kernels.decode_attention import (paged_decode_attention,
                                            paged_decode_attention_flat)
from repro.kernels.ref import decode_attention_ref
from repro.models import build_model
from repro.serving.block_pool import (BlockAllocator, blocks_for, chain_hash,
                                      prompt_chain)
from repro.serving.engine import Engine
from repro.serving.request import ServeRequest, State

RNG = np.random.default_rng(11)


# --------------------------------------------------------------------------
# Chain hashing
# --------------------------------------------------------------------------
def test_prompt_chain_is_parent_chained_and_capped():
    p = np.arange(40, dtype=np.int32)
    full = prompt_chain(p, 16)
    assert len(full) == 2                       # 40 tokens -> 2 full blocks
    assert full[0] == chain_hash(0, p[:16])
    assert full[1] == chain_hash(full[0], p[16:32])
    # identical prefixes chain identically; divergence breaks the chain
    q = p.copy()
    q[20] += 1
    qc = prompt_chain(q, 16)
    assert qc[0] == full[0] and qc[1] != full[1]
    # the lookup cap leaves >= 1 token to prefill: a 32-token prompt may
    # share at most 1 block
    assert len(prompt_chain(p[:32], 16, limit=(32 - 1) // 16)) == 1


# --------------------------------------------------------------------------
# Allocator: share / release / publish / reclaim
# --------------------------------------------------------------------------
def test_share_release_refcounts_and_revival():
    a = BlockAllocator(num_blocks=8, block_size=16)
    a.reserve(3)
    ids = a.allocate(3)
    digests = [chain_hash(0, [1] * 16)]
    assert a.publish(ids[0], digests[0], head=True)
    assert not a.publish(ids[1], digests[0])    # first writer wins
    a.share([ids[0]])                           # second reference
    assert a.ref(ids[0]) == 2
    # owner leaves; the shared cached block stays resident, counted once
    a.release(ids, owned=True)
    a.unreserve(3)
    assert a.allocated_blocks == 1 and a.ref(ids[0]) == 1
    assert a.free_blocks == 7                   # 2 freed + 5 never used
    # last sharer leaves: the block parks reclaimable (still free capacity)
    a.release([ids[0]], owned=False)
    assert a.allocated_blocks == 0 and a.free_blocks == 8
    assert a.lookup(digests) == [ids[0]]        # still servable
    # revival: share straight out of the reclaimable LRU
    a.share([ids[0]])
    assert a.ref(ids[0]) == 1 and a.allocated_blocks == 1
    a.release([ids[0]], owned=False)
    a.check_invariants()


def test_double_free_asserts_with_free_set():
    a = BlockAllocator(num_blocks=4, block_size=16)
    a.reserve(2)
    ids = a.allocate(2)
    a.free(ids)
    for b in ids:
        with pytest.raises(AssertionError):
            a.free([b])
    a.check_invariants()


def test_lru_reclaim_evicts_oldest_cached_never_referenced():
    a = BlockAllocator(num_blocks=6, block_size=4)
    ha = prompt_chain(np.arange(8, dtype=np.int32), 4)
    hb = prompt_chain(np.arange(8, 16, dtype=np.int32), 4)
    a.reserve(2)
    ia = a.allocate(2)
    for j, h in enumerate(ha):
        a.publish(ia[j], h, head=(j == 0))
    a.release(ia)
    a.unreserve(2)
    a.reserve(2)
    ib = a.allocate(2)
    for j, h in enumerate(hb):
        a.publish(ib[j], h, head=(j == 0))
    a.release(ib)
    a.unreserve(2)
    assert a.free_blocks == 6 and a.cached_blocks == 4
    # revive chain B: its blocks are referenced and must survive reclaim
    a.share(a.lookup(hb))
    a.reserve(4)
    got = a.allocate(4)                 # 2 free + reclaim both of chain A
    assert a.cache_evictions == 2
    assert a.lookup(ha) == []                   # A evicted, LRU first
    assert a.lookup(hb) == ib                   # B referenced: untouched
    assert set(got).isdisjoint(ib)
    a.check_invariants()
    with pytest.raises(AssertionError):
        a.allocate(1)                   # nothing reclaimable is referenced


# --------------------------------------------------------------------------
# Hypothesis: random share/release/reclaim interleavings
# --------------------------------------------------------------------------
def _run_random_program(seed: int, num_blocks: int, n_ops: int) -> None:
    """Engine-shaped random program over a tiny prompt alphabet (chains
    collide constantly): after every op — admit-with-lookup, incremental
    growth, publish, finish — the allocator holds
    free + allocated == num_blocks, no block is both free and referenced,
    nothing double-frees, and reclaim never evicts a referenced block
    (``check_invariants`` + the allocator's own asserts)."""
    rng = np.random.default_rng(seed)
    BS = 4
    a = BlockAllocator(num_blocks, BS)
    live = {}            # rid -> [digests, shared_ids, owned_ids, reserved]
    published = set()
    rid = 0
    for _ in range(n_ops):
        ops = ["admit"]
        if live:
            ops += ["grow", "publish", "finish"]
        op = ops[rng.integers(0, len(ops))]
        if op == "admit":
            nblk = int(rng.integers(1, 5))
            prompt = np.repeat(rng.integers(0, 3, nblk).astype(np.int32),
                               BS)
            digests = prompt_chain(prompt, BS)
            worst = nblk + int(rng.integers(0, 3))        # growth headroom
            chain = a.lookup(digests)
            # the engine's gate: tail reservation + revival charge for
            # parked (refcount-0) chain blocks share() is about to revive
            if not a.can_reserve(worst - len(chain)
                                 + a.revival_cost(chain)):
                continue
            a.reserve(worst - len(chain))
            if chain:
                a.share(chain)
            owned = a.allocate(nblk - len(chain))
            live[rid] = [digests, list(chain), owned, worst - len(chain)]
            rid += 1
        elif op == "grow":
            r = sorted(live)[rng.integers(0, len(live))]
            _, _, owned, reserved = live[r]
            if reserved > len(owned):       # still covered: cannot fail
                owned.extend(a.allocate(1))
        elif op == "publish":
            r = sorted(live)[rng.integers(0, len(live))]
            if r in published:
                continue
            published.add(r)
            digests, shared, owned, _ = live[r]
            table = shared + owned
            for j, h in enumerate(digests):
                a.publish(table[j], h, head=(j == 0))
        else:   # finish
            r = sorted(live)[rng.integers(0, len(live))]
            digests, shared, owned, reserved = live.pop(r)
            if shared:
                a.release(shared, owned=False)
            if owned:
                a.release(owned, owned=True)
            a.unreserve(reserved)
        a.check_invariants()
        assert a.allocated_blocks + a.free_blocks == a.num_blocks
        assert a.free_tokens() >= 0
    for r in sorted(live):                      # drain
        digests, shared, owned, reserved = live[r]
        if shared:
            a.release(shared, owned=False)
        if owned:
            a.release(owned, owned=True)
        a.unreserve(reserved)
        a.check_invariants()
    assert a.allocated_blocks == 0 and a.reserved_blocks == 0


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 2**16), num_blocks=st.integers(6, 20),
       n_ops=st.integers(1, 60))
def test_allocator_invariants_random_interleavings(seed, num_blocks, n_ops):
    _run_random_program(seed, num_blocks, n_ops)


@pytest.mark.parametrize("seed", range(6))
def test_allocator_invariants_fixed_seeds(seed):
    """The same property on fixed seeds — runs even where hypothesis is
    stubbed out (see conftest shim)."""
    _run_random_program(seed, num_blocks=8 + 2 * seed, n_ops=60)


def test_warm_admission_charges_revival_of_parked_chain(setup, rng):
    """Regression (PR-5 review): sharing a PARKED (refcount-0) cached
    chain revives it into cached_live, so the admission gate must charge
    the revival — otherwise reserved + cached_live can overshoot
    num_blocks and a reservation-covered mid-decode allocation asserts."""
    cfg, model, params = setup
    # 10-block pool: publisher leaves a 4-block parked chain; a cold
    # hog reserves 6 of the 10 blocks; the warm request (worst 5,
    # chain 4, revival 4) must then be REFUSED: 6 + (5-4) + 4 = 11 > 10.
    eng = Engine(0, model, params, max_slots=3, max_seq=256,
                 token_budget=160, block_size=16,
                 prefill_token_budget=64, attn_backend="dense")
    prompt = rng.integers(0, cfg.vocab_size, 70).astype(np.int32)  # 4 full
    pub = ServeRequest(0, prompt.copy(), 10)       # worst 80 -> 5 blocks
    eng.submit(pub)
    while pub.state is not State.FINISHED:
        eng.step()
    assert eng.allocator.cached_blocks == 4        # parked chain
    hog = ServeRequest(1, rng.integers(0, cfg.vocab_size, 60)
                       .astype(np.int32), 36)      # worst 96 -> 6 blocks
    eng.submit(hog)
    eng.step()
    assert hog.state is State.RUNNING
    warm = ServeRequest(2, prompt.copy(), 10)
    assert not eng.can_accept(warm), \
        "revival of the parked chain must be charged against admission"
    eng.submit(warm)
    for _ in range(200):                           # hog drains, warm admits
        eng.step()
        eng.allocator.check_invariants()
        if warm.state is State.FINISHED:
            break
    assert warm.state is State.FINISHED
    assert warm.cached_tokens > 0                  # still served warm later


# --------------------------------------------------------------------------
# Aliased block tables: the zero-kernel-change proof
# --------------------------------------------------------------------------
def _aliased_case(BS, Hkv, Dh, H, shared_blocks, lengths, dtype):
    """Requests 0 and 1 share their first ``shared_blocks`` PHYSICAL
    blocks (one copy in the pool, two tables pointing at it) — exactly
    what the prefix cache produces. The oracle sees the duplicated
    contiguous KV."""
    B = len(lengths)
    NBT = -(-max(lengths) // BS)
    S = NBT * BS                     # block-padded KV rows
    q = RNG.normal(0, 1, (B, H, Dh)).astype(np.float32)
    k = RNG.normal(0, 1, (B, S, Hkv, Dh)).astype(np.float32)
    v = RNG.normal(0, 1, (B, S, Hkv, Dh)).astype(np.float32)
    sh = shared_blocks * BS
    k[1, :sh] = k[0, :sh]            # identical prefix content
    v[1, :sh] = v[0, :sh]
    NB = B * NBT + 2
    perm = RNG.permutation(NB)
    kp = np.zeros((NB, BS, Hkv, Dh), np.float32)
    vp = np.zeros((NB, BS, Hkv, Dh), np.float32)
    bt = np.zeros((B, NBT), np.int32)
    pi = 0
    for b, L in enumerate(lengths):
        for j in range(blocks_for(L, BS)):
            if b == 1 and j < shared_blocks:
                bt[1, j] = bt[0, j]          # ALIAS: same physical block
                continue
            pb = int(perm[pi]); pi += 1
            bt[b, j] = pb
            kp[pb] = k[b, j * BS:(j + 1) * BS]
            vp[pb] = v[b, j * BS:(j + 1) * BS]
    to = lambda x: jnp.asarray(x, dtype)
    return (to(q), to(k), to(v), to(kp), to(vp),
            jnp.asarray(bt), jnp.asarray(lengths, jnp.int32))


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 3e-5),
                                       (jnp.bfloat16, 1e-2)])
def test_decode_kernels_with_aliased_block_tables(dtype, tol):
    """Both paged decode kernels (block-table grid and flat work list)
    are bit-for-bit indifferent to two tables sharing physical blocks —
    block tables were always arbitrary, so prefix sharing needs ZERO
    kernel changes."""
    q, k, v, kp, vp, bt, ls = _aliased_case(
        BS=32, Hkv=2, Dh=64, H=8, shared_blocks=3,
        lengths=[200, 137, 64], dtype=dtype)
    ref = decode_attention_ref(q, k, v, ls)
    grid = paged_decode_attention(q, kp, vp, bt, ls, interpret=True)
    flat = paged_decode_attention_flat(q, kp, vp, bt, ls, interpret=True)
    for out in (grid, flat):
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=tol, rtol=tol)


# --------------------------------------------------------------------------
# Engine: warm identical prompt — the acceptance criterion
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _drain(eng, req, max_steps=400):
    eng.submit(req)
    for _ in range(max_steps):
        eng.step()
        eng.allocator.check_invariants()
        assert eng.free_tokens() >= 0
        if req.state is State.FINISHED:
            return
    raise AssertionError("request did not finish")


def test_warm_prompt_bit_identical_and_skips_90pct_block_work(setup, rng):
    """ISSUE-5 acceptance: a warm identical-prompt request produces
    bit-identical tokens to the cold run while skipping >= 90% of the
    prefill block-work (cost counters), allocator invariants asserted at
    every step."""
    cfg, model, params = setup
    prompt = rng.integers(0, cfg.vocab_size, 1024).astype(np.int32)
    eng = Engine(0, model, params, max_slots=2, max_seq=2048,
                 block_size=16, prefill_token_budget=32,
                 attn_backend="dense")
    cold = ServeRequest(0, prompt.copy(), 6)
    _drain(eng, cold)
    cold_work = eng.prefill_work_blocks
    assert eng.cached_prompt_tokens_total == 0
    warm = ServeRequest(1, prompt.copy(), 6)
    _drain(eng, warm)
    warm_work = eng.prefill_work_blocks - cold_work
    assert warm.generated == cold.generated, "warm tokens diverged"
    assert eng.cached_prompt_tokens_total == 1008    # 63 of 64 blocks
    skipped = 1.0 - warm_work / cold_work
    assert skipped >= 0.90, f"only {skipped:.1%} of block-work skipped"
    # everything drains: shared blocks released, only cache entries remain
    assert eng.allocator.allocated_blocks == 0
    assert eng.allocator.reserved_blocks == 0
    assert eng.allocator.cached_blocks > 0


def test_prefix_cache_off_is_bit_parity_legacy(setup, rng):
    cfg, model, params = setup
    prompt = rng.integers(0, cfg.vocab_size, 200).astype(np.int32)
    outs = []
    for pc in (True, False):
        eng = Engine(0, model, params, max_slots=2, max_seq=512,
                     block_size=16, prefill_token_budget=64,
                     attn_backend="dense", prefix_cache=pc)
        reqs = [ServeRequest(i, prompt.copy(), 5) for i in range(2)]
        for r in reqs:
            _drain(eng, r)
        outs.append([r.generated for r in reqs])
        if not pc:
            assert eng.cached_prompt_tokens_total == 0
    assert outs[0] == outs[1]


def test_shared_prefix_admits_where_cold_would_not(setup, rng):
    """Tail-only reservations are the capacity win: two long-prefix
    requests run CONCURRENTLY in a pool a cold pair cannot share."""
    cfg, model, params = setup
    prompt = rng.integers(0, cfg.vocab_size, 120).astype(np.int32)
    concurrent = {}
    for pc in (True, False):
        eng = Engine(0, model, params, max_slots=4, max_seq=256,
                     token_budget=192, block_size=16,
                     prefill_token_budget=64, attn_backend="dense",
                     prefix_cache=pc)
        r0 = ServeRequest(0, prompt.copy(), 20)
        eng.submit(r0)
        while r0.first_token_step is None:      # prefill done -> published
            eng.step()
        r1 = ServeRequest(1, prompt.copy(), 20)
        eng.submit(r1)
        eng.step()
        eng.step()
        concurrent[pc] = (r0.state is State.RUNNING
                          and r1.state is State.RUNNING)
        eng.allocator.check_invariants()
        while not (r0.state is State.FINISHED
                   and r1.state is State.FINISHED):
            eng.step()
        assert eng.allocator.allocated_blocks == 0
    assert concurrent[True], "warm request should share the prefix blocks"
    assert not concurrent[False], "cold pair cannot fit: test is vacuous"


def test_migrated_shared_prefix_reimports_private(setup, rng):
    """A request sharing cached prefix blocks migrates mid-decode: the
    receiver re-imports it as private (fresh blocks, true-length
    reservation), tokens stay bit-identical, and the source's cache plus
    refcounts stay consistent."""
    cfg, model, params = setup
    prompt = rng.integers(0, cfg.vocab_size, 100).astype(np.int32)
    mk = lambda i: Engine(i, model, params, max_slots=2, max_seq=256,
                          block_size=16, prefill_token_budget=64,
                          attn_backend="dense")
    src, dst, ref_eng = mk(0), mk(1), mk(2)
    pub = ServeRequest(0, prompt.copy(), 30)      # publisher, keeps running
    src.submit(pub)
    while pub.first_token_step is None:
        src.step()
    warm = ServeRequest(1, prompt.copy(), 12)
    ref = ServeRequest(9, prompt.copy(), 12)
    src.submit(warm)
    ref_eng.submit(ref)
    for _ in range(4):
        src.step()
        ref_eng.step()
    assert warm.cached_tokens > 0, "sharer never hit the cache"
    src_slot = warm.slot               # import_request reassigns warm.slot
    req, piece, _ = src.export_slot(src_slot)
    assert dst.import_request(req, piece)
    src.evict_slot(src_slot)
    src.allocator.check_invariants()
    dst.allocator.check_invariants()
    assert warm.cached_tokens == 0                # private on the receiver
    # publisher's blocks still referenced on the source (pub is running)
    assert src.allocator.allocated_blocks > 0
    while warm.state is not State.FINISHED:
        dst.step()
    while ref.state is not State.FINISHED:
        ref_eng.step()
    assert warm.generated == ref.generated
    while pub.state is not State.FINISHED:
        src.step()
    assert src.allocator.allocated_blocks == 0
    src.allocator.check_invariants()


def test_prefix_hint_and_queued_tokens_use_uncached_length(setup, rng):
    cfg, model, params = setup
    prompt = rng.integers(0, cfg.vocab_size, 160).astype(np.int32)
    eng = Engine(0, model, params, max_slots=1, max_seq=512,
                 block_size=16, prefill_token_budget=64,
                 attn_backend="dense")
    r0 = ServeRequest(0, prompt.copy(), 24)
    _d, c, _p = eng.prefix_hint(r0)
    assert c == 0                                 # cold
    eng.submit(r0)
    while r0.first_token_step is None:
        eng.step()
    digest, cached, promo = eng.prefix_hint(ServeRequest(1, prompt.copy(), 4))
    assert digest == chain_hash(0, prompt[:16])
    assert cached == 144                          # 9 of 10 blocks (cap)
    assert promo == 0                             # all device-resident
    assert digest in eng.prefix_digests()
    # the slot is occupied, so the warm submit waits — queued as its
    # 16-token effective self, not a 160-token prompt
    r1 = ServeRequest(1, prompt.copy(), 4)
    eng.submit(r1)
    assert eng.queued_tokens() == 160 - 144


def test_sim_admission_charges_prefix_revival():
    """Regression (PR-5 review): a published prefix with NO live sharer
    is parked (free capacity) in the sim too, so admitting a warm request
    must charge the revived blocks — otherwise the sim admits past
    capacity where the engine's revival_cost refuses, and free_tokens()
    goes negative."""
    from repro.sim.costmodel import profile_from_config
    from repro.sim.events import EventQueue
    from repro.sim.instance import Instance, SimRequest
    from repro.sim.workload import Request

    prof = profile_from_config(get_config("llama3.2-3b"))
    ev = EventQueue()
    inst = Instance(0, prof, 512.0, ev, block_size=16, prefill_budget=512)
    inst.on_iteration_end = lambda i, t: None
    free_floor = []
    inst.on_request_done = lambda i, r, t: free_floor.append(i.free_tokens())
    grp = dict(prefix_group=0, prefix_len=256)
    r0 = SimRequest(req=Request(0, 0.0, 272, 2, **grp), length=272)
    inst.enqueue(r0, 0.0)
    ev.run_until(ev.now + 1e3)
    assert r0.done and 0 in inst.prefix_digests()
    # hog pins 272 of 512 tokens; the warm arrival needs 16 (tail) + 256
    # (revived prefix) = 272 > 240 free, so it must WAIT
    hog = SimRequest(req=Request(1, 0.0, 260, 40), length=260)
    warm = SimRequest(req=Request(2, 0.0, 272, 2, **grp), length=272)
    inst.enqueue(hog, ev.now)
    inst.enqueue(warm, ev.now)
    assert warm in inst.waiting, "revival of parked prefix was not charged"
    orig_end = inst._end_iteration
    seen_free = []

    def spy(t, admitted):
        orig_end(t, admitted)
        seen_free.append(inst.free_tokens())
    inst._end_iteration = spy
    ev.run_until(ev.now + 1e3)
    assert hog.done and warm.done
    assert warm.cached_tokens == 256
    assert min(seen_free) >= 0, "sim budget went negative"
    assert inst.free_tokens() == inst.capacity


# --------------------------------------------------------------------------
# Cost mirrors
# --------------------------------------------------------------------------
def test_prefill_flops_cached_accounting():
    spec = AttnSpec(8, 2, 64)
    full = prefill_flops(4096, spec)
    warm = prefill_flops(4096, spec, cached_tokens=4080)
    assert warm < 0.01 * full
    assert prefill_flops_skipped(4096, 4080, spec) == pytest.approx(
        full - warm)
    # summing tail-after-cached plus the cached part's own cold prefill
    # recovers the whole-prompt count (chunk-sum identity)
    from repro.kernels.cost import prefill_chunk_flops
    assert prefill_chunk_flops(2048, 0, spec) \
        + prefill_chunk_flops(2048, 2048, spec) \
        == pytest.approx(prefill_flops(4096, spec), rel=1e-6)


def test_shared_prefix_workload_generator():
    from repro.sim.workload import generate_shared_prefix, shared_prefix_spec
    reqs = generate_shared_prefix(shared_prefix_spec(
        4.0, 20.0, seed=3, num_groups=3, prefix_len=512, turns=2))
    assert len(reqs) > 10
    groups = {r.prefix_group for r in reqs}
    assert len(groups) > 1
    for r in reqs:
        assert r.prefix_group >= 0
        assert 0 < r.prefix_len <= r.input_len - 16
    # popular groups repeat — the whole point of prefix caching
    from collections import Counter
    assert Counter(r.prefix_group for r in reqs).most_common(1)[0][1] >= 3


# --------------------------------------------------------------------------
# Multi-tier KV (DESIGN.md §Multi-tier KV): demote / promote / host bound
# --------------------------------------------------------------------------
def _run_random_tiered_program(seed: int, num_blocks: int, host_blocks: int,
                               n_ops: int) -> None:
    """The engine-shaped random program of ``_run_random_program``, with
    the host tier ON and park/unpark in the mix: admissions consume
    two-tier chain hits (share the device run, promote the host run —
    the engine's ``_promote_blocks`` sequence: pop payloads FIRST, then
    allocate under the reservation, then re-publish with chain links).
    After every op the device invariant (``check_invariants`` — which
    also walks the host store: capacity bound, parent residency, single-
    tier residence) and the explicit host capacity bound must hold."""
    rng = np.random.default_rng(seed)
    BS = 4
    a = BlockAllocator(num_blocks, BS, host_blocks=host_blocks)
    a.set_demote_fetch(lambda b: ("snap", b))
    live = {}        # rid -> [digests, shared, owned, reserved, parked?]
    published = set()
    rid = 0
    for _ in range(n_ops):
        ops = ["admit", "materialize"]
        if live:
            ops += ["grow", "publish", "finish", "parkflip"]
        op = ops[rng.integers(0, len(ops))]
        if op == "admit":
            nblk = int(rng.integers(1, 5))
            prompt = np.repeat(rng.integers(0, 3, nblk).astype(np.int32),
                               BS)
            digests = prompt_chain(prompt, BS)
            worst = nblk + int(rng.integers(0, 3))        # growth headroom
            dev, host_run = a.lookup_tiered(digests)
            need = worst - len(dev) + a.revival_cost(dev)
            if not a.can_reserve(need):
                continue
            a.reserve(worst - len(dev))
            if dev:
                a.share(dev)
            # promote: pop payloads BEFORE allocating — the allocation's
            # own reclaim-demotes must never evict what's being promoted
            payloads = [a.host_pop(h) for h in host_run]
            assert all(p is not None for p in payloads)
            owned = a.allocate(nblk - len(dev))
            for j, h in enumerate(host_run):
                d0 = len(dev) + j
                a.publish(owned[j], h, head=(d0 == 0),
                          parent=digests[d0 - 1] if d0 else 0)
            live[rid] = [digests, list(dev), owned, worst - len(dev),
                         None]
            if host_run:
                published.add(rid)      # promoted digests are re-indexed
            rid += 1
        elif op == "materialize":
            a.host_materialize(lambda p: ("mat", p))
        elif op == "grow":
            r = sorted(live)[rng.integers(0, len(live))]
            _, _, owned, reserved, parked = live[r]
            # a parked request is preempted: it never grows until resumed
            if parked is None and reserved > len(owned):  # covered: cannot fail
                owned.extend(a.allocate(1))
        elif op == "publish":
            r = sorted(live)[rng.integers(0, len(live))]
            if r in published:
                continue
            published.add(r)
            digests, shared, owned, _, _ = live[r]
            table = shared + owned
            for j, h in enumerate(digests):
                a.publish(table[j], h, head=(j == 0),
                          parent=digests[j - 1] if j else 0)
        elif op == "parkflip":
            r = sorted(live)[rng.integers(0, len(live))]
            digests, shared, owned, _, parked = live[r]
            if parked is not None:
                a.unpark(parked)            # resume: exact parked snapshot
                live[r][4] = None
            elif shared + owned:
                live[r][4] = list(shared + owned)
                a.park(live[r][4])
        else:   # finish
            r = sorted(live)[rng.integers(0, len(live))]
            digests, shared, owned, reserved, parked = live.pop(r)
            if parked is not None:
                a.unpark(parked)
            if shared:
                a.release(shared, owned=False)
            if owned:
                a.release(owned, owned=True)
            a.unreserve(reserved)
        a.check_invariants()
        assert a.allocated_blocks + a.free_blocks == a.num_blocks
        assert a.host_blocks_used <= host_blocks
        assert a.free_tokens() >= 0
    for r in sorted(live):                      # drain
        digests, shared, owned, reserved, parked = live.pop(r)
        if parked is not None:
            a.unpark(parked)
        if shared:
            a.release(shared, owned=False)
        if owned:
            a.release(owned, owned=True)
        a.unreserve(reserved)
        a.check_invariants()
    assert a.allocated_blocks == 0 and a.reserved_blocks == 0
    assert a.host_blocks_used <= host_blocks
    # the split counters tile the legacy one exactly
    assert a.cache_evictions == a.cache_demotions + a.cache_drops


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 2**16), num_blocks=st.integers(6, 20),
       host_blocks=st.integers(1, 10), n_ops=st.integers(1, 60))
def test_tiered_allocator_invariants_random_interleavings(
        seed, num_blocks, host_blocks, n_ops):
    _run_random_tiered_program(seed, num_blocks, host_blocks, n_ops)


@pytest.mark.parametrize("seed", range(6))
def test_tiered_allocator_invariants_fixed_seeds(seed):
    """Same property on fixed seeds — runs even where hypothesis is
    stubbed out (see conftest shim)."""
    _run_random_tiered_program(seed, num_blocks=8 + 2 * seed,
                               host_blocks=1 + seed, n_ops=60)


def test_partially_dropped_chain_never_promotes():
    """A chain whose demote was cut short (host tier too small: admitting
    a later block evicted its own ancestors) must never advertise a
    promotable run — ``lookup_tiered`` stops at the first digest in
    neither tier, and the host store drops orphaned descendants rather
    than keeping unreachable payloads."""
    prompt = np.repeat(np.arange(3, dtype=np.int32), 4)
    digests = prompt_chain(prompt, 4)          # 3-block chain
    for cap, want_host in ((3, 3), (2, 0)):
        a = BlockAllocator(num_blocks=4, block_size=4, host_blocks=cap)
        a.set_demote_fetch(lambda b: ("snap", b))
        a.reserve(3)
        ids = a.allocate(3)
        for j, h in enumerate(digests):
            a.publish(ids[j], h, head=(j == 0),
                      parent=digests[j - 1] if j else 0)
        a.release(ids)
        a.unreserve(3)                         # chain parked, reclaimable
        a.reserve(4)
        a.allocate(4)                          # reclaims the whole chain
        a.check_invariants()
        # cap 3: whole chain demotes -> fully promotable. cap 2: block 3's
        # put evicts LRU (the chain HEAD) which cascades through its own
        # descendants -> nothing survives, nothing promotable, and no
        # orphaned host entries linger
        dev, host_run = a.lookup_tiered(digests)
        assert dev == []
        assert len(host_run) == want_host
        assert a.host_blocks_used == want_host
        if want_host == 0:
            assert a.host_head_digests() == frozenset()
        assert a.cache_demotions + a.cache_drops >= 3


def test_int8_scales_round_trip_demote_promote(setup, rng):
    """int8 KV blocks demote WITH their quantization scales and promote
    back bit-exactly: cold -> pressure (demotes the parked chain) ->
    warm re-admit of the same prompt must produce bit-identical greedy
    tokens from the promoted int8 payloads."""
    cfg, model, params = setup
    prompt = rng.integers(0, cfg.vocab_size, 256).astype(np.int32)
    pressure = rng.integers(0, cfg.vocab_size, 320).astype(np.int32)
    # pool: pressure (21 blocks worst) + slack 2; the cold chain (16
    # blocks) cannot stay device-resident through the pressure serve
    eng = Engine(0, model, params, max_slots=2, max_seq=512,
                 token_budget=23 * 16, block_size=16,
                 prefill_token_budget=64, attn_backend="dense",
                 kv_dtype="int8", host_kv_budget=512)
    cold = ServeRequest(0, prompt.copy(), 6)
    _drain(eng, cold)
    d0 = eng.cache_demotions
    _drain(eng, ServeRequest(1, pressure.copy(), 6))
    assert eng.cache_demotions > d0, "pressure prompt demoted nothing"
    p0 = eng.cache_promotions
    warm = ServeRequest(2, prompt.copy(), 6)
    _drain(eng, warm)
    assert eng.cache_promotions > p0, "warm re-admit promoted nothing"
    assert warm.cached_tokens > 0
    assert warm.generated == cold.generated, \
        "int8 demote->promote round trip changed tokens"
    eng.check_drained()
