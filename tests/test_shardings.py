"""Sharding rules + roofline extraction (host-scale checks; the 256/512-chip
lowering is exercised by launch/dryrun.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.roofline import (Roofline, collective_wire_bytes,
                                   model_flops, parse_collectives)
from repro.launch.shardings import param_spec, param_shardings
from repro.models import build_model


def test_param_spec_rules():
    assert param_spec("embed", (512, 64), 16) == P("model", None)
    assert param_spec("unembed", (64, 512), 16) == P(None, "model")
    assert param_spec("layers/attn/wq", (4, 64, 512), 16) == \
        P(None, None, "model")
    assert param_spec("layers/attn/wo", (4, 512, 64), 16) == \
        P(None, "model", None)
    assert param_spec("layers/moe/w_gate", (4, 128, 64, 32), 16) == \
        P(None, "model", None, None)
    assert param_spec("layers/moe/router", (4, 64, 128), 16) == P()
    assert param_spec("layers/ln_attn", (4, 64), 16) == P()


def test_param_spec_divisibility_fallback():
    # 100 not divisible by 16 -> replicate; divisible by 10 -> shard
    assert param_spec("layers/attn/wq", (2, 100, 100), 16) == P()
    assert param_spec("layers/attn/wq", (2, 100, 100), 10) == \
        P(None, None, "model")


def test_all_params_get_spec_without_error():
    mesh = make_host_mesh()
    for arch in ("smollm-360m", "qwen3-moe-30b-a3b", "rwkv6-7b",
                 "zamba2-2.7b", "whisper-large-v3"):
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        shardings = param_shardings(shapes, mesh)
        assert (len(jax.tree.leaves(shardings))
                == len(jax.tree.leaves(shapes)))


def test_host_mesh_lowering_smoke():
    """End-to-end pjit lowering on the local device mesh."""
    mesh = make_host_mesh()
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    ps = param_shardings(shapes, mesh)
    batch = {"tokens": jax.ShapeDtypeStruct((2, 16), jnp.int32)}

    def loss_fn(p, b):
        return model.loss(p, b)[0]

    with mesh:
        lowered = jax.jit(loss_fn, in_shardings=(ps, None)).lower(
            shapes, batch)
        compiled = lowered.compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):   # jax<=0.4.x: one dict per device
            ca = ca[0]
        assert ca["flops"] > 0


# ---- roofline extraction ----------------------------------------------------
FAKE_HLO = """
HloModule test
%add { ... }
  %p0 = bf16[128,256]{1,0} parameter(0)
  %dot.1 = f32[8,4096]{1,0} dot(%p0, %p0)
  %all-reduce.2 = f32[8,4096]{1,0} all-reduce(%dot.1), replica_groups=[32,16]<=[512]
  %ag.3 = bf16[64,256]{1,0} all-gather(%p0), dimensions={0}
  %rs = f32[8,256]{1,0} reduce-scatter(%all-reduce.2), dimensions={0}
  %cp = bf16[128,256]{1,0} collective-permute(%p0)
"""


def test_parse_collectives():
    recs = parse_collectives(FAKE_HLO)
    kinds = sorted(r["op"] for r in recs)
    assert kinds == ["all-gather", "all-reduce", "collective-permute",
                     "reduce-scatter"]
    ar = next(r for r in recs if r["op"] == "all-reduce")
    assert ar["operand_bytes"] == 8 * 4096 * 4          # resolved via defs
    ag = next(r for r in recs if r["op"] == "all-gather")
    assert ag["result_bytes"] == 64 * 256 * 2


def test_collective_wire_bytes_factors():
    recs = parse_collectives(FAKE_HLO)
    total = collective_wire_bytes(recs)
    expect = (2.0 * 8 * 4096 * 4            # all-reduce 2x operand
              + 64 * 256 * 2                # all-gather result
              + 8 * 4096 * 4                # reduce-scatter operand
              + 128 * 256 * 2)              # collective-permute operand
    assert total == pytest.approx(expect)


def test_roofline_terms_and_dominance():
    rl = Roofline(arch="x", shape="train_4k", mesh="16x16",
                  flops_per_chip=197e12, bytes_per_chip=0.0,
                  collective_bytes_per_chip=0.0, num_chips=256,
                  model_flops_global=197e12 * 256 / 2)
    assert rl.t_compute == pytest.approx(1.0)
    assert rl.dominant == "compute"
    assert rl.useful_flops_ratio == pytest.approx(0.5)


def test_model_flops_scaling():
    cfg = get_config("smollm-360m")
    train = model_flops(cfg, "train_4k", 4096, 256)
    dec = model_flops(cfg, "decode_32k", 32768, 128)
    assert train > dec
    assert train == pytest.approx(3 * model_flops(cfg, "prefill_32k", 4096,
                                                  256), rel=1e-6)
