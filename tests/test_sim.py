"""Discrete-event MILS simulator: conservation, policies, paper claims."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.partition import PipelinePlan, Stage
from repro.core.qoe import QoEModel
from repro.sim.cluster import (CascadePolicy, Cluster, ClusterConfig,
                               LlumnixLikePolicy, RoundRobinPolicy)
from repro.sim.costmodel import (decode_iter_time, prefill_time,
                                 profile_from_config)
from repro.sim.profiler import profile_point
from repro.sim.workload import Request, WorkloadSpec, generate


@pytest.fixture(scope="module")
def prof():
    return profile_from_config(get_config("llama3.2-3b"))


@pytest.fixture(scope="module")
def qoe():
    return QoEModel(np.array([5e-3, 5e-4, 2e-7, 1e-12, 3e-7]))


def _plan(E):
    return PipelinePlan(
        [Stage(0.0, 1024.0, E // 2), Stage(1024.0, float("inf"), E - E // 2)],
        0.0)


def _run(policy, prof, requests, duration=20.0, E=4):
    cfg = ClusterConfig(num_instances=E, capacity_tokens=200_000.0, seed=0)
    return Cluster(prof, policy, cfg).run(requests, duration)


def test_workload_generator_deterministic():
    spec = WorkloadSpec(rate=5, duration=10, seed=3)
    a, b = generate(spec), generate(spec)
    assert [r.input_len for r in a] == [r.input_len for r in b]
    assert all(r.input_len + r.output_len <= spec.max_context for r in a)


def test_cost_model_monotonicity(prof):
    t_small = decode_iter_time([100] * 4, prof)
    t_big = decode_iter_time([100] * 64, prof)
    assert t_big > t_small
    assert prefill_time(10_000, prof) > prefill_time(100, prof)
    # heterogeneity tax: same tokens, mixed lengths is slower
    homog = decode_iter_time([5000] * 16, prof)
    hetero = decode_iter_time([100] * 15 + [5000 * 16 - 1500], prof)
    assert hetero > homog


def test_all_requests_complete_rr(prof):
    reqs = generate(WorkloadSpec(rate=3, duration=10, seed=1))
    res = _run(RoundRobinPolicy(), prof, reqs)
    assert len(res.completed) == len(reqs)
    # token conservation: every request generated exactly output_len tokens
    for r in res.completed:
        assert r.generated == r.req.output_len
        assert sum(r.tokens_by_instance.values()) == r.req.output_len


def test_all_requests_complete_cascade(prof, qoe):
    reqs = generate(WorkloadSpec(rate=3, duration=10, seed=1))
    res = _run(CascadePolicy(_plan(4), qoe), prof, reqs)
    assert len(res.completed) == len(reqs)
    for r in res.completed:
        assert sum(r.tokens_by_instance.values()) == r.req.output_len


def test_cascade_migrates_growing_requests(prof, qoe):
    # one long request must cross the 1024 boundary and land downstream
    reqs = [Request(0, 0.0, 900, 600)]
    res = _run(CascadePolicy(_plan(4), qoe,
                             refinement="none"), prof, reqs)
    r = res.completed[0]
    assert len(r.tokens_by_instance) >= 2, "request should have migrated"


def test_cascade_beats_baselines_under_heavy_load(prof, qoe):
    """The paper's headline claim, at mini scale."""
    reqs = generate(WorkloadSpec(rate=14, duration=15, seed=2))
    rr = _run(RoundRobinPolicy(), prof, reqs, E=4)
    ca = _run(CascadePolicy(_plan(4), qoe), prof, reqs, E=4)
    assert np.mean(ca.tpot()) < np.mean(rr.tpot())
    assert np.mean(ca.ttft()) < np.mean(rr.ttft()) * 1.5


def test_llumnix_like_completes(prof):
    reqs = generate(WorkloadSpec(rate=5, duration=10, seed=4))
    res = _run(LlumnixLikePolicy(), prof, reqs)
    assert len(res.completed) == len(reqs)


def test_metrics_shapes(prof, qoe):
    reqs = generate(WorkloadSpec(rate=3, duration=8, seed=5))
    res = _run(CascadePolicy(_plan(4), qoe), prof, reqs)
    s = res.summary()
    assert s["completed"] == len(reqs)
    assert s["throughput_tok_s"] > 0
    assert 0.0 <= res.slo_attainment(1.0, 0.1) <= 1.0
    assert len(res.stage_cv()) == 2


def test_profiler_keeps_batch_in_flight(prof):
    F, Q = profile_point(prof, (256, 512), batch_size=8, horizon_s=3.0)
    assert len(Q) > 4
    # average batch size seen by requests ~ 8
    assert 4.0 <= F[:, 1].mean() <= 9.0


def test_ragged_backend_profile_is_faster():
    cfg = get_config("llama3.2-3b")
    padded = profile_from_config(cfg, ragged_backend=False)
    ragged = profile_from_config(cfg, ragged_backend=True)
    lengths = [200] * 31 + [40_000]
    assert (decode_iter_time(lengths, ragged)
            < decode_iter_time(lengths, padded))
