"""Pallas kernel sweeps vs. the pure-jnp oracle (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.cost import (AttnSpec, decode_attn_time_s,
                                heterogeneity_tax, padded_blocks,
                                ragged_blocks)
from repro.kernels.decode_attention import decode_attention
from repro.kernels.prefill_attention import prefill_attention
from repro.kernels.ref import decode_attention_ref, prefill_attention_ref

RNG = np.random.default_rng(0)


def _mk(B, S, H, Hkv, Dh, dtype):
    q = jnp.asarray(RNG.normal(0, 1, (B, H, Dh)), dtype)
    k = jnp.asarray(RNG.normal(0, 1, (B, S, Hkv, Dh)), dtype)
    v = jnp.asarray(RNG.normal(0, 1, (B, S, Hkv, Dh)), dtype)
    return q, k, v


DECODE_SWEEP = [
    # B, S, H, Hkv, Dh, block
    (1, 128, 4, 4, 64, 64),     # MHA
    (2, 256, 8, 2, 64, 64),     # GQA 4:1
    (4, 256, 8, 1, 128, 128),   # MQA
    (3, 512, 16, 4, 128, 256),  # bigger heads
    (2, 128, 10, 5, 64, 32),    # odd head counts (smollm-like)
]


@pytest.mark.parametrize("B,S,H,Hkv,Dh,blk", DECODE_SWEEP)
@pytest.mark.parametrize("ragged", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_kernel_sweep(B, S, H, Hkv, Dh, blk, ragged, dtype):
    q, k, v = _mk(B, S, H, Hkv, Dh, dtype)
    lengths = jnp.asarray(RNG.integers(1, S + 1, B), jnp.int32)
    ref = decode_attention_ref(q, k, v, lengths)
    out = decode_attention(q, k, v, lengths, block_s=blk, ragged=ragged,
                           interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("T,bq,bk", [(128, 32, 32), (256, 64, 128)])
@pytest.mark.parametrize("H,Hkv", [(4, 4), (8, 2)])
def test_prefill_kernel_sweep(T, bq, bk, H, Hkv):
    B, Dh = 2, 64
    q = jnp.asarray(RNG.normal(0, 1, (B, T, H, Dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (B, T, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (B, T, Hkv, Dh)), jnp.float32)
    lengths = jnp.asarray([T, T - 37], jnp.int32)
    ref = prefill_attention_ref(q, k, v, lengths)
    out = prefill_attention(q, k, v, lengths, block_q=bq, block_k=bk,
                            interpret=True)
    for b, L in enumerate(np.asarray(lengths)):
        np.testing.assert_allclose(np.asarray(out[b, :L]),
                                   np.asarray(ref[b, :L]), atol=2e-5,
                                   rtol=2e-5)


@given(st.lists(st.integers(1, 256), min_size=1, max_size=8),
       st.integers(0, 3))
@settings(max_examples=25, deadline=None)
def test_decode_kernel_random_lengths(lengths, seed):
    S, H, Hkv, Dh, blk = 256, 4, 2, 64, 64
    B = len(lengths)
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(0, 1, (B, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, Dh)), jnp.float32)
    ls = jnp.asarray(lengths, jnp.int32)
    ref = decode_attention_ref(q, k, v, ls)
    out = decode_attention(q, k, v, ls, block_s=blk, ragged=True,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5,
                               rtol=3e-5)


# ---- cost model ------------------------------------------------------------
def test_block_counts():
    assert padded_blocks([100, 5000], 512) == 2 * 10
    assert ragged_blocks([100, 5000], 512) == 1 + 10


def test_heterogeneity_tax_matches_paper_band():
    """Paper Fig. 2: mixed 1000/50000 at constant tokens -> 1.1–2.1×."""
    spec = AttnSpec(num_q_heads=24, num_kv_heads=8, head_dim=128)
    mixed = [1000] * 256 + [50000] * 256
    tax = heterogeneity_tax(mixed, spec)
    assert 1.1 < tax < 2.5


def test_ragged_backend_cheaper_on_heterogeneous():
    spec = AttnSpec(num_q_heads=24, num_kv_heads=8, head_dim=128)
    lengths = [500] * 63 + [60_000]
    assert (decode_attn_time_s(lengths, spec, ragged=True)
            < decode_attn_time_s(lengths, spec, ragged=False))


def test_homogeneous_has_no_tax():
    spec = AttnSpec(num_q_heads=8, num_kv_heads=8, head_dim=128)
    assert heterogeneity_tax([4096] * 32, spec) == pytest.approx(1.0)
