"""Multi-engine CascadeInfer server over real model state."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.partition import PipelinePlan, Stage
from repro.core.qoe import QoEModel
from repro.models import build_model
from repro.serving.request import ServeRequest
from repro.serving.server import MILSServer, ServerConfig


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _plan(E, boundary=48.0):
    lo = E // 2
    return PipelinePlan([Stage(0.0, boundary, E - lo),
                         Stage(boundary, float("inf"), lo)], 0.0)


def _qoe():
    return QoEModel(np.array([1e-3, 1e-4, 1e-6, 0.0, 1e-6]))


def _reqs(rng, cfg, n, plen=20, new=(8, 50)):
    return [ServeRequest(i, rng.integers(0, cfg.vocab_size, plen)
                         .astype(np.int32), int(rng.integers(*new)))
            for i in range(n)]


def test_cascade_server_completes_and_migrates(setup, rng):
    cfg, model, params = setup
    srv = MILSServer(model, params, _plan(4), _qoe(),
                     ServerConfig(policy="cascade", seed=0),
                     max_slots=3, max_seq=96)
    reqs = _reqs(rng, cfg, 8)
    fin = srv.run(reqs, max_steps=400)
    assert len(fin) == 8
    assert srv.migrations > 0, "long requests must cross the stage boundary"


def test_migrated_decode_identical_to_single_engine(setup, rng):
    cfg, model, params = setup
    srv = MILSServer(model, params, _plan(4), _qoe(),
                     ServerConfig(policy="cascade", seed=0),
                     max_slots=3, max_seq=96)
    reqs = _reqs(rng, cfg, 6, new=(30, 60))
    fin = srv.run(reqs, max_steps=400)
    for r in fin[:3]:
        single = MILSServer(model, params,
                            PipelinePlan([Stage(0.0, float("inf"), 1)], 0.0),
                            _qoe(), ServerConfig(policy="round-robin"),
                            max_slots=3, max_seq=96)
        ref = ServeRequest(100 + r.req_id, r.prompt.copy(),
                           r.max_new_tokens)
        single.run([ref], max_steps=400)
        assert r.generated == ref.generated, \
            f"req {r.req_id}: migration changed greedy decode"


def test_round_robin_and_least_loaded_policies(setup, rng):
    cfg, model, params = setup
    for policy in ("round-robin", "least-loaded"):
        srv = MILSServer(model, params, _plan(2), _qoe(),
                         ServerConfig(policy=policy), max_slots=3,
                         max_seq=96)
        fin = srv.run(_reqs(rng, cfg, 4), max_steps=300)
        assert len(fin) == 4


def test_open_loop_arrivals_stream_and_tail_metrics(setup, rng):
    cfg, model, params = setup
    tokens = []
    srv = MILSServer(model, params, _plan(4), _qoe(),
                     ServerConfig(policy="cascade", seed=0),
                     max_slots=3, max_seq=96,
                     on_token=lambda r, t: tokens.append((r.req_id, t)))
    reqs = _reqs(rng, cfg, 6)
    for i, r in enumerate(reqs):
        srv.submit_at(r, step=3 * i)
    fin = srv.run(max_steps=400)
    assert len(fin) == 6
    # arrival schedule honored: nothing starts before its arrival step
    for r in fin:
        assert r.arrival_step >= 0 and r.first_token_step > r.arrival_step
    # every generated token streamed exactly once
    assert len(tokens) == sum(len(r.generated) for r in fin)
    s = srv.summary()
    for key in ("ttft_steps_p50", "ttft_steps_p95", "ttft_steps_p99",
                "e2e_steps_p50", "e2e_steps_p95", "e2e_steps_p99"):
        assert key in s and s[key] >= 0
    assert s["ttft_steps_p50"] <= s["ttft_steps_p99"]
    # per-stage-pair migration counts sum to the total
    assert sum(v for k, v in s.items()
               if k.startswith("migrations_s")) == s["migrations"]


@pytest.mark.parametrize("refinement,balancing",
                         [("quantity", "full"), ("memory", "inter-stage"),
                          ("none", "rr")])
def test_server_runs_ablation_knobs(setup, rng, refinement, balancing):
    """Fig. 15/16 ablations on the real-engine path (previously sim-only)."""
    cfg, model, params = setup
    srv = MILSServer(model, params, _plan(4), _qoe(),
                     ServerConfig(policy="cascade", refinement=refinement,
                                  balancing=balancing, refine_every=4),
                     max_slots=3, max_seq=96)
    fin = srv.run(_reqs(rng, cfg, 6), max_steps=400)
    assert len(fin) == 6
    bounds = srv.stage_bounds
    assert bounds[0][0] == 0.0 and bounds[-1][1] == float("inf")
    if refinement == "none":
        assert bounds[0][1] == 48.0, "refinement=none must freeze boundaries"


def test_boundaries_stay_monotone_under_refinement(setup, rng):
    cfg, model, params = setup
    srv = MILSServer(model, params, _plan(4), _qoe(),
                     ServerConfig(policy="cascade", refine_every=4, seed=1),
                     max_slots=3, max_seq=96)
    srv.run(_reqs(rng, cfg, 10), max_steps=400)
    bounds = srv.stage_bounds
    assert bounds[0][0] == 0.0
    assert bounds[-1][1] == float("inf")
    for (lo, hi), (lo2, hi2) in zip(bounds, bounds[1:]):
        assert hi == lo2 and lo < hi
