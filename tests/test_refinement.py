"""Adaptive range refinement (§4.3)."""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.qoe import QoEModel
from repro.core.refinement import (BoundaryRefiner, divide_evenly,
                                   memory_based_split, optimal_split,
                                   quantity_based_split)


def test_optimal_split_matches_bruteforce(rng, qoe_linear):
    reqs = [(float(i), float(l)) for i, l in
            zip(rng.integers(10, 500, 20), rng.integers(20, 5000, 20))]
    b_idx, boundary = optimal_split(reqs, qoe_linear)
    # brute force over the sorted list
    arr = sorted(reqs, key=lambda r: r[1])
    best = np.inf
    best_i = None
    for i in range(len(arr) + 1):
        left, right = arr[:i], arr[i:]
        q = (qoe_linear.batch_q([r[0] for r in left], [r[1] for r in left])
             + qoe_linear.batch_q([r[0] for r in right],
                                  [r[1] for r in right]))
        if q < best:
            best, best_i = q, i
    assert b_idx == best_i


def test_divide_evenly():
    vals = np.arange(100)
    sub = divide_evenly(vals, 4)
    assert len(sub) == 25
    assert sub[0] == 2           # starts at n/2-th element
    assert np.all(np.diff(sub) == 4)


def test_low_traffic_freeze(qoe_linear):
    r = BoundaryRefiner(qoe_linear, boundary=1000.0, min_requests=5)
    out = r.refine([(100.0, 200.0)], [])       # 1 request < 5 -> freeze
    assert out == 1000.0


def test_ema_smoothing(qoe_linear):
    r = BoundaryRefiner(qoe_linear, boundary=1000.0, ema=0.5)
    own = [(10.0, float(l)) for l in range(100, 120)]
    succ = [[(10.0, float(l)) for l in range(5000, 5020)]]
    out = r.refine(own, succ)
    # new raw boundary is far from 1000; EMA keeps it between
    assert out != 1000.0
    assert 100.0 < out < 5020.0


def test_quantity_and_memory_splits():
    reqs = [(10.0, float(l)) for l in [10, 20, 30, 40, 1000]]
    qs = quantity_based_split(reqs)
    ms = memory_based_split(reqs)
    assert qs == 30.0                  # median count split
    assert ms >= qs                    # memory split skews toward the long one


@given(st.lists(st.tuples(st.floats(1, 1e4), st.floats(1, 1e5)),
                min_size=1, max_size=40))
@settings(max_examples=40, deadline=None)
def test_optimal_split_in_range(reqs):
    qoe = QoEModel(np.array([5e-3, 5e-4, 2e-7, 1e-12, 3e-7]))
    b_idx, boundary = optimal_split(reqs, qoe)
    assert 0 <= b_idx <= len(reqs)
    lens = [r[1] for r in reqs]
    assert min(lens) <= boundary <= max(lens)
