"""Cross-path numerical consistency: prefill+decode == full forward,
MoE dispatch equivalence, sliding-window semantics, flash == naive."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model, synthetic_batch
from repro.models import mamba2, rwkv6, transformer, whisper
from repro.models.attention import (_causal_mask, _gqa_sdpa,
                                    flash_attention_xla)
from repro.models.mlp import init_moe, moe_dense, moe_gshard

CONSISTENCY_ARCHS = ["smollm-360m", "qwen3-moe-30b-a3b", "qwen2-vl-7b",
                     "rwkv6-7b", "zamba2-2.7b", "whisper-large-v3"]


def _full_logits(cfg, params, batch):
    if cfg.family in ("dense", "moe", "vlm"):
        out, _, _ = transformer.forward_full(
            params, cfg, batch["tokens"],
            vision_embeds=batch.get("vision_embeds"),
            vision_mask=batch.get("vision_mask"),
            mrope_positions=batch.get("mrope_positions"))
    elif cfg.family == "ssm":
        out, _, _ = rwkv6.forward_full(params, cfg, batch["tokens"])
    elif cfg.family == "hybrid":
        out, _, _ = mamba2.forward_full(params, cfg, batch["tokens"])
    else:
        out, _, _ = whisper.forward_full(params, cfg, batch["tokens"],
                                         batch["audio_embeds"])
    return out


@pytest.mark.parametrize("arch", CONSISTENCY_ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, T = 2, 12
    full = synthetic_batch(cfg, B, T + 1)
    pre = {k: (v[:, :T] if k in ("tokens", "vision_mask", "mrope_positions")
               else v) for k, v in full.items()}
    _, cache = model.prefill(params, pre, cache_len=T + 4)
    pos = jnp.full((B,), T, jnp.int32)
    extras = ({"mrope_positions": full["mrope_positions"][:, T:T + 1]}
              if cfg.use_mrope else {})
    dec, _ = model.decode_step(params, cache, full["tokens"][:, T], pos,
                               **extras)
    ref = _full_logits(cfg, params, full)[:, -1]
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref),
                               atol=5e-5, rtol=5e-4)


def test_moe_gshard_matches_dense_f64():
    with jax.experimental.enable_x64():
        cfg = dataclasses.replace(get_config("qwen3-moe-30b-a3b").reduced(),
                                  dtype=jnp.float64)
        p = init_moe(jax.random.PRNGKey(1), cfg)
        p = jax.tree.map(lambda a: a.astype(jnp.float64), p)
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, cfg.d_model),
                              jnp.float64)
        yd, auxd = moe_dense(p, cfg, x)
        yg, auxg = moe_gshard(p, cfg, x, capacity_factor=8.0)
        np.testing.assert_allclose(np.asarray(yd), np.asarray(yg),
                                   atol=1e-10)
        assert float(auxd) == pytest.approx(float(auxg))


def test_moe_gshard_drops_over_capacity():
    cfg = get_config("qwen3-moe-30b-a3b").reduced()
    p = init_moe(jax.random.PRNGKey(1), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 16, cfg.d_model))
    y_tight, _ = moe_gshard(p, cfg, x, capacity_factor=0.25)
    y_large, _ = moe_gshard(p, cfg, x, capacity_factor=8.0)
    assert not np.allclose(np.asarray(y_tight), np.asarray(y_large))


def test_sliding_window_decode_matches_full_for_short_seq():
    """Window ≥ sequence length -> sliding == full attention."""
    base = get_config("smollm-360m").reduced()
    win = dataclasses.replace(base, sliding_window=64)
    m_full = build_model(base)
    m_win = build_model(win)
    params = m_full.init(jax.random.PRNGKey(0))
    batch = synthetic_batch(base, 2, 10)
    _, c1 = m_full.prefill(params, batch, cache_len=32)
    _, c2 = m_win.prefill(params, batch, cache_len=32)
    pos = jnp.full((2,), 10, jnp.int32)
    tok = batch["tokens"][:, 0]
    l1, _ = m_full.decode_step(params, c1, tok, pos)
    l2, _ = m_win.decode_step(params, c2, tok, pos)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=5e-5, rtol=5e-4)


def test_sliding_window_forgets_distant_tokens():
    base = get_config("smollm-360m").reduced()
    win = dataclasses.replace(base, sliding_window=4)
    m = build_model(win)
    params = m.init(jax.random.PRNGKey(0))
    batch = synthetic_batch(win, 1, 12)
    # perturb the FIRST token: with window 4 and prefill of 12, the decode
    # at pos 12 must be unaffected
    b2 = dict(batch)
    t2 = np.asarray(batch["tokens"]).copy()
    t2[0, 0] = (t2[0, 0] + 1) % win.vocab_size
    b2["tokens"] = jnp.asarray(t2)
    _, c1 = m.prefill(params, batch, cache_len=16)
    _, c2 = m.prefill(params, b2, cache_len=16)
    pos = jnp.full((1,), 12, jnp.int32)
    tok = jnp.asarray([5], jnp.int32)
    l1, _ = m.decode_step(params, c1, tok, pos)
    l2, _ = m.decode_step(params, c2, tok, pos)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-6)


def test_flash_equals_naive_attention():
    rng = np.random.default_rng(3)
    B, T, H, Hkv, Dh = 2, 257, 8, 2, 64   # odd T exercises padding
    q = jnp.asarray(rng.normal(0, 1, (B, T, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, T, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, T, Hkv, Dh)), jnp.float32)
    for window in (0, 32):
        ref = _gqa_sdpa(q, k, v, _causal_mask(T, T, 0, window))
        out = flash_attention_xla(q, k, v, causal=True, window=window,
                                  block_q=64, block_k=96)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=3e-5, rtol=3e-5)


def test_flash_gradients_finite():
    rng = np.random.default_rng(4)
    B, T, H, Hkv, Dh = 1, 128, 4, 2, 32
    q = jnp.asarray(rng.normal(0, 1, (B, T, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, T, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, T, Hkv, Dh)), jnp.float32)

    def f(q, k, v):
        return flash_attention_xla(q, k, v, block_q=32, block_k=32).sum()

    grads = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    for g in grads:
        assert bool(jnp.isfinite(g).all())
