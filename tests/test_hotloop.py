"""Device-resident decode hot loop (DESIGN.md §Decode hot path): the
work-flattened Pallas grid vs. the oracle at extreme length spread, the
one-device-sync-per-step contract, and greedy-token bit-parity of the
device-resident engine loop against the host-driven reference — on a mock
model (pure plumbing) and the real reduced model."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.serving.engine as engine_mod
from repro.configs import get_config
from repro.kernels.cost import (decode_attn_time_flat_s, flat_grid_blocks,
                                pow2_bucket, ragged_blocks)
from repro.kernels.decode_attention import (flat_work_list,
                                            paged_decode_attention_flat)
from repro.kernels.ref import decode_attention_ref
from repro.models import build_model
from repro.models.model import Model
from repro.serving.block_pool import blocks_for
from repro.serving.engine import Engine
from repro.serving.request import ServeRequest, State

RNG = np.random.default_rng(7)


# --------------------------------------------------------------------------
# Flat-grid kernel vs. oracle
# --------------------------------------------------------------------------
def _paged_case(lengths, S, H, Hkv, Dh, BS, dtype):
    """Contiguous KV per request, scattered into a shuffled physical pool."""
    B = len(lengths)
    q = RNG.normal(0, 1, (B, H, Dh)).astype(np.float32)
    k = RNG.normal(0, 1, (B, S, Hkv, Dh)).astype(np.float32)
    v = RNG.normal(0, 1, (B, S, Hkv, Dh)).astype(np.float32)
    NBT = S // BS
    NB = B * NBT + 3
    perm = RNG.permutation(NB)
    k_pool = np.zeros((NB, BS, Hkv, Dh), np.float32)
    v_pool = np.zeros((NB, BS, Hkv, Dh), np.float32)
    bt = np.zeros((B, NBT), np.int32)
    pi = 0
    for b, L in enumerate(lengths):
        for j in range(blocks_for(L, BS)):
            pb = int(perm[pi]); pi += 1
            bt[b, j] = pb
            k_pool[pb] = k[b, j * BS:(j + 1) * BS]
            v_pool[pb] = v[b, j * BS:(j + 1) * BS]
    to = lambda a: jnp.asarray(a, dtype)
    return (to(q), to(k), to(v), to(k_pool), to(v_pool),
            jnp.asarray(bt), jnp.asarray(lengths, jnp.int32))


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 3e-5),
                                       (jnp.bfloat16, 1e-2)])
def test_flat_kernel_matches_ref_128x_spread(dtype, tol):
    """Acceptance: 128x length spread (4..512) including a single-token
    request and exact full-block-boundary lengths (64, 256, 512)."""
    lengths = [4, 512, 1, 64, 377, 256]
    q, k, v, kp, vp, bt, ls = _paged_case(lengths, 512, 8, 2, 64, 64, dtype)
    ref = decode_attention_ref(q, k, v, ls)
    total = sum(math.ceil(l / 64) for l in lengths)
    for W in (total, pow2_bucket(total), None):
        out = paged_decode_attention_flat(q, kp, vp, bt, ls, num_work=W,
                                          interpret=True)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=tol, rtol=tol)


def test_flat_kernel_dead_slots_and_mqa():
    """lengths==0 rows (dead engine slots) produce zero work items and do
    not disturb live rows' outputs."""
    lengths = [0, 7, 0, 129, 1]
    q, k, v, kp, vp, bt, ls = _paged_case(lengths, 256, 8, 1, 128, 32,
                                          jnp.float32)
    ref = decode_attention_ref(q, k, v, ls)
    out = paged_decode_attention_flat(q, kp, vp, bt, ls, num_work=8,
                                      interpret=True)
    live = [1, 3, 4]
    np.testing.assert_allclose(np.asarray(out)[live], np.asarray(ref)[live],
                               atol=3e-5, rtol=3e-5)


def test_flat_work_list_structure():
    """Real prefix enumerates (request, block) request-major in block
    order; the padding tail aliases the LAST request with-work, with
    sentinel block index NBT (always skipped by the length guard)."""
    lengths = jnp.asarray([5, 0, 33, 16], jnp.int32)   # BS=16 -> 1,0,3,1
    wr, wb = flat_work_list(lengths, nbt=4, block_s=16, num_work=8)
    wr, wb = np.asarray(wr), np.asarray(wb)
    np.testing.assert_array_equal(wr[:5], [0, 2, 2, 2, 3])
    np.testing.assert_array_equal(wb[:5], [0, 0, 1, 2, 0])
    np.testing.assert_array_equal(wr[5:], [3, 3, 3])   # aliases last request
    np.testing.assert_array_equal(wb[5:], [4, 4, 4])   # sentinel = NBT


def test_cost_model_flat_terms():
    lengths = [1, 16, 512]
    assert ragged_blocks(lengths, 512) == 3
    assert flat_grid_blocks(lengths, 512) == 4            # pow2 bucket
    assert flat_grid_blocks(lengths, 512, bucketed=False) == 3
    spec_lengths = [32] * 15 + [4096]
    from repro.kernels.cost import AttnSpec, decode_attn_time_s
    spec = AttnSpec(num_q_heads=32, num_kv_heads=8, head_dim=128)
    flat = decode_attn_time_flat_s(spec_lengths, spec)
    padded = decode_attn_time_s(spec_lengths, spec, ragged=False)
    assert flat < padded / 4     # the heterogeneity tax, removed


# --------------------------------------------------------------------------
# Mock model: pure plumbing parity (token_{t+1} = f(token_t, pos_t))
# --------------------------------------------------------------------------
MOCK_VOCAB = 97


def _mock_next(tok, pos):
    return (31 * tok + 7 * pos + 3) % MOCK_VOCAB


def make_mock_model():
    cfg = get_config("smollm-360m").reduced()

    def init(rng):
        return {}

    def _logits(tok, pos):
        return jax.nn.one_hot(_mock_next(tok, pos), MOCK_VOCAB)

    def prefill(params, batch, cache_len=None):
        tokens = batch["tokens"]                      # [1, T]
        T = tokens.shape[1]
        piece = {"kv": jnp.zeros((1, 1, T, 1, 1), jnp.float32)}
        return _logits(tokens[:, -1], jnp.full((1,), T - 1)), piece

    def prefill_bucketed(params, batch, true_len):
        tokens = batch["tokens"]                      # [1, P] padded
        P = tokens.shape[1]
        last = jnp.take_along_axis(tokens, true_len[None, None] - 1,
                                   axis=1)[:, 0]
        piece = {"kv": jnp.zeros((1, 1, P, 1, 1), jnp.float32)}
        return _logits(last, true_len[None] - 1), piece

    def decode_step_paged(params, pool, token, block_tables, pos, **extras):
        return _logits(token, pos), pool

    def decode_step(params, cache, token, pos, **extras):
        return _logits(token, pos), cache

    def init_paged_cache(num_blocks, block_size):
        return {"kv": jnp.zeros((1, num_blocks, block_size, 1, 1),
                                jnp.float32)}

    def init_cache(batch, seq):
        return {"kv": jnp.zeros((1, batch, seq, 1, 1), jnp.float32)}

    return Model(cfg, init, loss=None, prefill=prefill,
                 decode_step=decode_step, init_cache=init_cache,
                 init_paged_cache=init_paged_cache,
                 decode_step_paged=decode_step_paged,
                 prefill_bucketed=prefill_bucketed)


def _mock_reqs(n=5, seed=1):
    r = np.random.default_rng(seed)
    plens = [3, 9, 1, 17, 6, 12, 4][:n]
    news = [7, 2, 11, 1, 9, 5, 8][:n]
    return [ServeRequest(i, r.integers(0, MOCK_VOCAB, p).astype(np.int32), m)
            for i, (p, m) in enumerate(zip(plens, news))]


def _drain(eng, reqs, burst=1, max_iters=400):
    for r in reqs:
        eng.submit(r)
    out = []
    for _ in range(max_iters):
        out += eng.step(burst)
        if len(out) == len(reqs):
            return out
    raise AssertionError("engine did not drain")


@pytest.mark.parametrize("burst", [1, 8])
def test_mock_engine_bit_parity_device_vs_host(burst):
    """Fixed trace, mock model: the device-resident loop (single-step and
    lax.scan fused) emits exactly the host loop's greedy tokens, steps,
    and finish bookkeeping — including max_new_tokens=1 requests that
    finish at prefill."""
    model = make_mock_model()
    runs = {}
    for mode, b in (("host", 1), ("device", burst)):
        eng = Engine(0, model, {}, max_slots=3, max_seq=32,
                     device_resident=(mode == "device"))
        reqs = _mock_reqs()
        _drain(eng, reqs, burst=b)
        runs[mode] = ([list(r.generated) for r in reqs],
                      [r.finish_step for r in reqs],
                      [r.first_token_step for r in reqs],
                      eng.steps, eng.tokens_out)
    assert runs["host"][0] == runs["device"][0]        # tokens, bit-equal
    assert runs["host"] == runs["device"]              # all bookkeeping


def test_mock_engine_eos_mid_burst_parity():
    """eos finishes are data-dependent, so the fused micro-batch decodes
    past them and truncates at the sync — the visible result must equal
    the host loop's."""
    model = make_mock_model()
    prompt = np.asarray([5, 11, 2], np.int32)
    # pick eos = the 3rd greedy token of this trace so it hits mid-burst
    probe = Engine(0, model, {}, max_slots=1, max_seq=32)
    pr = ServeRequest(0, prompt.copy(), 10)
    _drain(probe, [pr])
    eos = pr.generated[2]
    outs = {}
    for mode, burst in (("host", 1), ("device", 8)):
        eng = Engine(0, model, {}, max_slots=1, max_seq=32,
                     device_resident=(mode == "device"))
        r = ServeRequest(0, prompt.copy(), 10, eos_token=eos)
        _drain(eng, [r], burst=burst)
        outs[mode] = (list(r.generated), r.finish_step)
    assert outs["host"] == outs["device"]


def test_engine_one_device_sync_per_step(monkeypatch):
    """Acceptance: Engine.step() performs exactly one device->host
    transfer per step (counted through the d2h shim), admissions
    included; a fused burst still costs one."""
    model = make_mock_model()
    calls = []
    real = engine_mod.d2h
    monkeypatch.setattr(engine_mod, "d2h", lambda x: calls.append(1) or real(x))
    eng = Engine(0, model, {}, max_slots=3, max_seq=64)
    reqs = _mock_reqs(3)
    for r in reqs:
        eng.submit(r)
    eng.step()                       # admission + prefill + decode step
    assert len(calls) == 1
    for _ in range(4):               # steady-state decode
        calls.clear()
        eng.step()
        assert len(calls) == 1
    calls.clear()
    eng.step(8)                      # fused micro-batch: still one sync
    assert len(calls) == 1


def test_engine_grid_accounting_16way_hetero():
    """Acceptance: on a 16-way heterogeneous batch the flat grid runs
    Σ_b ceil(L_b/BS) items (± pow2 bucket padding) where the old grid ran
    B·max_b ceil(L_b/BS)."""
    model = make_mock_model()
    plens = [2, 2, 3, 4, 4, 6, 8, 8, 12, 16, 24, 32, 48, 64, 96, 120]
    eng = Engine(0, model, {}, max_slots=16, max_seq=256, block_size=16)
    reqs = [ServeRequest(i, np.full(p, 1, np.int32), 4)
            for i, p in enumerate(plens)]
    for r in reqs:
        eng.submit(r)
    eng.step()
    g = eng.last_grid
    expect = sum(blocks_for(p + 1, 16) for p in plens)
    assert g["real_items"] == expect
    assert expect <= g["flat_items"] < 2 * expect      # pow2 bucket only
    assert g["padded_items"] == 16 * blocks_for(121, 16)
    assert g["real_items"] < g["padded_items"] / 3     # the heterogeneity tax
    assert g["flat_items"] <= g["padded_items"] / 2    # survives pow2 padding


# --------------------------------------------------------------------------
# Real model: device loop + kernel backends
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_real_engine_bit_parity_device_vs_host(setup, rng):
    cfg, model, params = setup
    prompts = [rng.integers(0, cfg.vocab_size, p).astype(np.int32)
               for p in (5, 17, 12)]
    outs = []
    for device_resident in (False, True):
        eng = Engine(0, model, params, max_slots=3, max_seq=64,
                     device_resident=device_resident)
        reqs = [ServeRequest(i, p.copy(), 8) for i, p in enumerate(prompts)]
        _drain(eng, reqs)
        outs.append([list(r.generated) for r in reqs])
    assert outs[0] == outs[1]


def test_real_prefill_bucketed_matches_unpadded(setup, rng):
    """Padding the prompt to a pow2 bucket must not change the last-token
    logits or the written KV rows (causality)."""
    cfg, model, params = setup
    T = 13
    toks = rng.integers(0, cfg.vocab_size, (1, T)).astype(np.int32)
    ref_logits, ref_piece = model.prefill(
        params, {"tokens": jnp.asarray(toks)}, cache_len=T)
    P = pow2_bucket(T)
    padded = np.zeros((1, P), np.int32)
    padded[0, :T] = toks
    logits, piece = model.prefill_bucketed(
        params, {"tokens": jnp.asarray(padded)}, jnp.int32(T))
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(ref_logits, np.float32),
                               atol=2e-5, rtol=2e-5)
    # the first T KV rows (what the engine scatters into blocks) match too
    for a, b in zip(jax.tree.leaves(piece), jax.tree.leaves(ref_piece)):
        np.testing.assert_allclose(np.asarray(a, np.float32)[:, :, :T],
                                   np.asarray(b, np.float32)[:, :, :T],
                                   atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("backend", ["grid", "flat"])
def test_real_model_kernel_backend_matches_dense(setup, rng, backend):
    """forward_decode_paged through the Pallas kernels (interpret mode)
    agrees with the dense-gather XLA path."""
    cfg, model, params = setup
    eng = Engine(0, model, params, max_slots=2, max_seq=64,
                 attn_backend=backend)
    # off-TPU the kernels run interpreted; on TPU they compile for real
    assert eng.attn_interpret == (jax.default_backend() != "tpu")
    ref = Engine(0, model, params, max_slots=2, max_seq=64,
                 attn_backend="dense")
    prompts = [rng.integers(0, cfg.vocab_size, p).astype(np.int32)
               for p in (6, 21)]
    outs = []
    for e in (eng, ref):
        reqs = [ServeRequest(i, p.copy(), 6) for i, p in enumerate(prompts)]
        _drain(e, reqs)
        outs.append([list(r.generated) for r in reqs])
    assert outs[0] == outs[1]


def test_device_engine_migration_roundtrip(setup, rng):
    """export -> evict -> import across device-resident engines keeps the
    greedy continuation identical (device mirrors re-seeded on import)."""
    cfg, model, params = setup
    mk = lambda i: Engine(i, model, params, max_slots=2, max_seq=64)
    src, dst, ref_eng = mk(0), mk(1), mk(2)
    prompt = rng.integers(0, cfg.vocab_size, 11).astype(np.int32)
    r = ServeRequest(0, prompt.copy(), 10)
    ref = ServeRequest(9, prompt.copy(), 10)
    src.submit(r)
    ref_eng.submit(ref)
    for _ in range(3):
        src.step()
        ref_eng.step()
    req, piece, _ = src.export_slot(r.slot)
    assert dst.import_request(req, piece)
    src.evict_slot(0)
    while r.state != State.FINISHED:
        dst.step()
    while ref.state != State.FINISHED:
        ref_eng.step()
    assert r.generated == ref.generated
