"""Fused mixed-iteration attention + quantized KV blocks (DESIGN.md
§Fused mixed-iteration attention, §Quantized KV blocks): the one-launch
kernel vs. the two-kernel reference and the dense oracle on mixed batches
at 128x length spread — dead slots, aliased prefix blocks, interleaved
tags — int8 bounded error, engine greedy bit-parity, the one-attention-
call and one-d2h-per-mixed-step contracts, and the split-pow2 cost
mirror."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.serving.engine as engine_mod
from repro.configs import get_config
from repro.kernels.cost import (AttnSpec, LAUNCH_OVERHEAD_S,
                                fused_grid_items, kv_bytes_per_elem,
                                mixed_iter_time_s, pow2_bucket)
from repro.kernels.decode_attention import paged_decode_attention_flat
from repro.kernels.mixed_attention import paged_mixed_attention
from repro.kernels.prefill_attention import paged_prefill_attention
from repro.kernels.ref import decode_attention_ref
from repro.models import build_model
from repro.models.attention import (KVCache, dequantize_piece,
                                    quantize_kv, quantize_piece,
                                    resolve_paged_backend)
from repro.serving.engine import Engine
from repro.serving.request import ServeRequest

RNG = np.random.default_rng(23)


# --------------------------------------------------------------------------
# Kernel: fused work list vs. the two kernels it replaces
# --------------------------------------------------------------------------
def _mixed_case(segs, C, H, Hkv, Dh, BS, dtype, alias=None):
    """Build one mixed iteration. ``segs``: ``("dec", L)`` is a decode row
    whose cache holds L tokens (ctx = L-1, seg = 1; L = 0 is a dead slot
    contributing zero work items) and ``("ck", ctx, clen)`` a prefill
    chunk. ``alias=(i, j, nb)`` makes segments i and j share their first
    ``nb`` physical blocks (prefix-cache aliasing). Returns the fused
    operands plus each segment's contiguous K/V for the oracle."""
    B = len(segs)
    totals = [(s[1] if s[0] == "dec" else s[1] + s[2]) for s in segs]
    NBT = max(max(-(-t // BS) for t in totals), 1) + 1
    NB = sum(-(-t // BS) for t in totals) + 3
    perm = RNG.permutation(NB)
    k_pool = np.zeros((NB, BS, Hkv, Dh), np.float32)
    v_pool = np.zeros_like(k_pool)
    bt = np.full((B, NBT), NB - 1, np.int32)
    full = []
    pi = 0
    for s, t in enumerate(totals):
        kk = RNG.normal(0, 1, (NBT * BS, Hkv, Dh)).astype(np.float32)
        vv = RNG.normal(0, 1, (NBT * BS, Hkv, Dh)).astype(np.float32)
        if alias and s == alias[1]:
            n = alias[2] * BS
            kk[:n], vv[:n] = full[alias[0]][0][:n], full[alias[0]][1][:n]
        full.append((kk, vv))
        for j in range(-(-t // BS)):
            if alias and s == alias[1] and j < alias[2]:
                bt[s, j] = bt[alias[0], j]       # shared prefix block
                continue
            pb = int(perm[pi]); pi += 1
            bt[s, j] = pb
            k_pool[pb] = kk[j * BS:(j + 1) * BS]
            v_pool[pb] = vv[j * BS:(j + 1) * BS]
    q = RNG.normal(0, 1, (B, C, H, Dh)).astype(np.float32)
    ctx = np.asarray([s[1] - 1 if s[0] == "dec" else s[1] for s in segs],
                     np.int32)
    seg = np.asarray([1 if s[0] == "dec" else s[2] for s in segs], np.int32)
    tags = np.asarray([0 if s[0] == "dec" else 1 for s in segs], np.int32)
    to = lambda a: jnp.asarray(a, dtype)
    return (to(q), to(k_pool), to(v_pool), jnp.asarray(bt),
            jnp.asarray(ctx), jnp.asarray(seg), jnp.asarray(tags), full)


# interleaved tags, 128x total-context spread (4..512), a dead slot, and
# two decode rows sharing their first prefix block
SEGS = [("dec", 4), ("ck", 48, 17), ("dec", 512), ("dec", 0),
        ("ck", 0, 23), ("dec", 65), ("dec", 77)]
ALIAS = (5, 6, 1)          # segs 5 and 6 share physical block 0


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 3e-5),
                                       (jnp.bfloat16, 2e-2)])
def test_fused_matches_two_kernel_paths(dtype, tol):
    """One fused launch == the decode-flat + prefill-chunk pair it
    replaces, on the SAME pool, for real/pow2/worst-case work buckets."""
    C, BS = 32, 16
    q, kp, vp, bt, ctx, seg, tags, full = _mixed_case(
        SEGS, C, 8, 2, 64, BS, dtype, alias=ALIAS)
    dec = np.asarray([i for i, s in enumerate(SEGS)
                      if s[0] == "dec" and s[1] > 0])
    ck = np.asarray([i for i, s in enumerate(SEGS) if s[0] == "ck"])
    lens = jnp.asarray([SEGS[i][1] for i in dec], jnp.int32)
    ref_dec = paged_decode_attention_flat(
        q[dec, 0], kp, vp, bt[dec, :], lens, interpret=True)
    ref_ck = paged_prefill_attention(
        q[ck, :], kp, vp, bt[ck, :], ctx[ck], seg[ck], interpret=True)
    real = sum(math.ceil((int(ctx[i]) + int(seg[i])) / BS)
               for i in range(len(SEGS)))
    for W in (real, pow2_bucket(real), None):
        out = np.asarray(paged_mixed_attention(
            q, kp, vp, bt, ctx, seg, tags, num_work=W, interpret=True),
            np.float32)
        for r, i in enumerate(dec):
            np.testing.assert_allclose(
                out[i, 0], np.asarray(ref_dec, np.float32)[r],
                atol=tol, rtol=tol)
        for r, i in enumerate(ck):
            cl = int(seg[i])
            np.testing.assert_allclose(
                out[i, :cl], np.asarray(ref_ck, np.float32)[r, :cl],
                atol=tol, rtol=tol)


def test_fused_decode_rows_match_dense_oracle():
    """Anchor beyond kernel-vs-kernel: fused decode rows reproduce the
    dense attention oracle over each segment's contiguous cache."""
    C, BS, H, Hkv, Dh = 32, 16, 8, 2, 64
    q, kp, vp, bt, ctx, seg, tags, full = _mixed_case(
        SEGS, C, H, Hkv, Dh, BS, jnp.float32, alias=ALIAS)
    out = np.asarray(paged_mixed_attention(
        q, kp, vp, bt, ctx, seg, tags, interpret=True), np.float32)
    dec = np.asarray([i for i, s in enumerate(SEGS)
                      if s[0] == "dec" and s[1] > 0])
    kd = jnp.asarray(np.stack([full[i][0] for i in dec]))
    vd = jnp.asarray(np.stack([full[i][1] for i in dec]))
    ref = decode_attention_ref(q[dec, 0], kd, vd,
                               jnp.asarray([SEGS[i][1] for i in dec],
                                           jnp.int32))
    np.testing.assert_allclose(out[dec, 0], np.asarray(ref, np.float32),
                               atol=3e-5, rtol=3e-5)


def test_fused_int8_bounded_error():
    """Contract (DESIGN.md §Quantized KV blocks): per-row symmetric int8
    with per-(block, position, kv-head) scales keeps every live output row
    within cos >= 0.999 / abs <= 0.05 of the full-precision kernel."""
    C, BS = 32, 16
    q, kp, vp, bt, ctx, seg, tags, _ = _mixed_case(
        SEGS, C, 8, 2, 64, BS, jnp.float32, alias=ALIAS)
    ref = np.asarray(paged_mixed_attention(
        q, kp, vp, bt, ctx, seg, tags, interpret=True), np.float32)
    kq, ks = quantize_kv(kp)
    vq, vs = quantize_kv(vp)
    out = np.asarray(paged_mixed_attention(
        q, kq, vq, bt, ctx, seg, tags, ks, vs, interpret=True), np.float32)
    for i, s in enumerate(SEGS):
        rows = range(1 if s[0] == "dec" else s[2])
        if s[0] == "dec" and s[1] == 0:
            continue                             # dead slot: garbage row
        for r in rows:
            a, b = out[i, r].ravel(), ref[i, r].ravel()
            cos = float(a @ b / max(np.linalg.norm(a) * np.linalg.norm(b),
                                    1e-12))
            assert cos >= 0.999, (i, r, cos)
            assert float(np.abs(a - b).max()) <= 0.05, (i, r)


def test_quantize_roundtrip_and_garbage_blocks():
    """quantize -> dequantize is a contraction (error < one quant step per
    element); zero-initialized garbage blocks carry zero scales and
    dequantize to EXACT zeros, keeping the sentinel discipline intact."""
    x = jnp.asarray(RNG.normal(0, 1, (4, 16, 2, 64)), jnp.float32)
    piece = KVCache(x, -x)
    back = dequantize_piece(quantize_piece(piece), jnp.float32)
    step = np.abs(np.asarray(x)).max(-1, keepdims=True) / 127.0
    assert np.all(np.abs(np.asarray(back.k - x)) <= step * 0.5 + 1e-7)
    zero = KVCache(jnp.zeros_like(x), jnp.zeros_like(x))
    zq = quantize_piece(zero)
    assert float(jnp.abs(dequantize_piece(zq, jnp.float32).k).max()) == 0.0


# --------------------------------------------------------------------------
# Engine: greedy parity + the one-call / one-sync contracts
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _drain(eng, reqs, max_iters=400):
    for r in reqs:
        eng.submit(r)
    out = []
    for _ in range(max_iters):
        out += eng.step()
        if len(out) == len(reqs):
            return out
    raise AssertionError("engine did not drain")


@pytest.mark.parametrize("kv_dtype,exact", [("bf16", True), ("int8", False)])
def test_fused_engine_greedy_parity_vs_dense(setup, rng, kv_dtype, exact):
    """Full-precision fused engine emits bit-identical greedy tokens to
    the dense baseline (fusing reshapes launches, never values); int8
    drifts boundedly — same stream lengths, documented accuracy contract
    covered at the kernel level."""
    cfg, model, params = setup
    prompts = [rng.integers(0, cfg.vocab_size, p).astype(np.int32)
               for p in (5, 23, 12)]
    outs = {}
    for backend, kvd in (("dense", "bf16"), ("fused", kv_dtype)):
        eng = Engine(0, model, params, max_slots=3, max_seq=64,
                     attn_backend=backend, kv_dtype=kvd,
                     prefill_token_budget=8)
        assert eng.fused_mixed == (backend == "fused")
        reqs = [ServeRequest(i, p.copy(), 8) for i, p in enumerate(prompts)]
        _drain(eng, reqs)
        outs[backend] = [list(r.generated) for r in reqs]
    if exact:
        assert outs["fused"] == outs["dense"]
    else:
        assert [len(t) for t in outs["fused"]] == \
            [len(t) for t in outs["dense"]]


def test_fused_mixed_step_one_attn_call_one_sync(setup, rng, monkeypatch):
    """Acceptance: while a long prompt chunks beside a live decode batch,
    EVERY fused mixed step makes exactly ONE attention-bearing device
    call (attn_call shim) and exactly ONE device->host sync (d2h shim);
    the separate-kernel reference makes two calls on the same trace."""
    cfg, model, params = setup
    d2h_calls = []
    real = engine_mod.d2h
    monkeypatch.setattr(engine_mod, "d2h",
                        lambda x: d2h_calls.append(1) or real(x))

    def trace(backend):
        eng = Engine(0, model, params, max_slots=4, max_seq=128,
                     attn_backend=backend, prefill_token_budget=8)
        short = [ServeRequest(i, rng.integers(0, cfg.vocab_size, p)
                              .astype(np.int32), 12)
                 for i, p in enumerate((5, 11))]
        for r in short:
            eng.submit(r)
        while any(r.prefilling or r.state.name == "WAITING" for r in short):
            eng.step()
        long_req = ServeRequest(9, rng.integers(0, cfg.vocab_size, 24)
                                .astype(np.int32), 2)
        eng.submit(long_req)
        attn, sync, grids = [], [], []
        while long_req.prefilling or long_req.first_token_step is None:
            d2h_calls.clear()
            c0 = engine_mod.ATTN_CALLS
            eng.step()
            attn.append(engine_mod.ATTN_CALLS - c0)
            sync.append(len(d2h_calls))
            grids.append(eng.last_grid.get("backend"))
        return attn, sync, grids

    attn, sync, grids = trace("fused")
    assert attn and max(attn) == 1, attn
    assert all(s == 1 for s in sync), sync
    assert "fused" in grids                      # mixed steps went fused
    attn_sep, sync_sep, _ = trace("flat")
    assert 2 in attn_sep, attn_sep               # the two-launch baseline
    assert all(s == 1 for s in sync_sep), sync_sep


# --------------------------------------------------------------------------
# Backend resolution + the split-pow2 cost mirror
# --------------------------------------------------------------------------
def test_resolve_backend_fused_auto_on_tpu_dense_elsewhere(monkeypatch):
    monkeypatch.delenv("REPRO_PAGED_ATTN", raising=False)
    choice, interpret = resolve_paged_backend()
    on_tpu = jax.default_backend() == "tpu"
    assert choice == ("fused" if on_tpu else "dense")
    choice, interpret = resolve_paged_backend("fused")
    assert choice == "fused" and interpret == (not on_tpu)


def test_cost_fused_split_buckets_and_launch_saving():
    """fused_grid_items buckets decode and chunk halves separately —
    pow2(9+8)=32 would overshoot 16+8 — so the fused analytic time is the
    separate path minus EXACTLY the extra launch, for any shape."""
    BS = 16
    dec = [16 * 9]                               # 9 blocks -> pow2 16
    chunks = [(8 * BS, 0)]                       # 8 blocks -> pow2 8
    assert fused_grid_items(chunks, dec, BS) == 16 + 8
    spec = AttnSpec(8, 2, 64, block_s=BS)
    for lens, cks in (([7, 32, 152, 700], [(64, 256)]),   # unlucky bucket
                      ([16 * 9], [(8 * 16, 0)]),
                      ([4, 512, 1], [(32, 100), (17, 48)])):
        t_fused = mixed_iter_time_s(cks, lens, spec, decode_backend="fused")
        t_sep = mixed_iter_time_s(cks, lens, spec, decode_backend="flat")
        assert t_fused < t_sep
        np.testing.assert_allclose(t_sep - t_fused, LAUNCH_OVERHEAD_S,
                                   rtol=1e-9)
    # no chunks -> no extra launch to save: fused == flat exactly
    assert mixed_iter_time_s([], [64, 256], spec, decode_backend="fused") \
        == mixed_iter_time_s([], [64, 256], spec, decode_backend="flat")


def test_cost_kv_bytes_per_elem():
    assert kv_bytes_per_elem("bf16", 128) == 2.0
    assert kv_bytes_per_elem("int8", 128) == pytest.approx(1.03125)
    # the residency bound: 2*Dh/(Dh+4) ~ 1.94x at Dh=128, 1.88x at Dh=64
    for dh, bound in ((128, 1.939), (64, 1.88)):
        assert 2.0 / kv_bytes_per_elem("int8", dh) >= bound
