"""End-to-end behaviour tests for the whole system: the paper's claims
reproduced at test scale (simulator), and the real-engine control plane
exercising every CascadeInfer mechanism in one run."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.partition import two_phase
from repro.core.qoe import QoEModel, relative_errors, static_baseline_errors
from repro.core.workload_stats import build_stats, exp_bucket_edges
from repro.models import build_model
from repro.sim.cluster import (CascadePolicy, Cluster, ClusterConfig,
                               RoundRobinPolicy)
from repro.sim.costmodel import profile_from_config
from repro.sim.profiler import profile_and_fit
from repro.sim.workload import WorkloadSpec, generate, sample_lengths


@pytest.fixture(scope="module")
def fitted():
    """Profile -> fit -> plan, the full §4 pipeline at small scale."""
    prof = profile_from_config(get_config("llama3.2-3b"))
    qoe, F, Q = profile_and_fit(
        prof, buckets=((128, 512), (512, 2048), (2048, 8192),
                       (8192, 32768)),
        batch_sizes=(1, 4, 16, 48), horizon_s=4.0, return_samples=True)
    return prof, qoe, F, Q


def test_qoe_fit_beats_static_predictor(fitted):
    """Paper Fig. 13: fitted model ≪ static mean predictor."""
    _, qoe, F, Q = fitted
    model_err = np.abs(relative_errors(qoe, F, Q)).mean()
    static_err = np.abs(static_baseline_errors(F, Q)).mean()
    assert model_err < static_err / 3
    assert (qoe.D >= 0).all()


def test_full_pipeline_plan_and_serve(fitted):
    """profile -> fit -> DP plan -> simulate: cascade completes everything
    and improves latency vs round-robin under load."""
    prof, qoe, _, _ = fitted
    rng = np.random.default_rng(0)
    spec = WorkloadSpec(rate=1, duration=1)
    ins, outs = sample_lengths(spec, 800, rng)
    stats = build_stats(list(zip(ins.tolist(), outs.tolist())),
                        exp_bucket_edges(131_072))
    plan = two_phase(stats, 4, qoe,
                     kv_bytes_per_token=prof.kv_bytes_per_token)
    assert plan.num_instances == 4

    reqs = generate(WorkloadSpec(rate=12, duration=15, seed=7))
    cfg = ClusterConfig(num_instances=4, capacity_tokens=200_000, seed=0)
    rr = Cluster(prof, RoundRobinPolicy(), cfg).run(reqs, 15.0)
    ca = Cluster(prof, CascadePolicy(plan, qoe),
                 ClusterConfig(num_instances=4, capacity_tokens=200_000,
                               seed=0)).run(reqs, 15.0)
    assert len(ca.completed) == len(reqs)
    assert np.mean(ca.tpot()) < np.mean(rr.tpot())


def test_real_engine_cluster_end_to_end(rng):
    """Real JAX engines: routing, migration, refinement, completion."""
    from repro.core.partition import PipelinePlan, Stage
    from repro.serving.request import ServeRequest
    from repro.serving.server import MILSServer, ServerConfig

    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    plan = PipelinePlan([Stage(0.0, 40.0, 2), Stage(40.0, float("inf"), 2)],
                        0.0)
    qoe = QoEModel(np.array([1e-3, 1e-4, 1e-6, 0.0, 1e-6]))
    srv = MILSServer(model, params, plan, qoe,
                     ServerConfig(policy="cascade", refine_every=8, seed=0),
                     max_slots=3, max_seq=96)
    reqs = [ServeRequest(i, rng.integers(0, cfg.vocab_size, 16)
                         .astype(np.int32), int(rng.integers(10, 55)))
            for i in range(10)]
    fin = srv.run(reqs, max_steps=500)
    assert len(fin) == 10
    assert srv.migrations > 0
    out_tokens = sum(len(r.generated) for r in fin)
    assert out_tokens == sum(r.max_new_tokens for r in fin)
