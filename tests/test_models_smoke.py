"""Per-architecture smoke tests (assignment deliverable f).

Each assigned architecture instantiates a REDUCED variant of its family
(≤2 layers, d_model ≤ 256-ish, ≤4 experts) and runs one forward/train
step and one decode step on CPU, asserting output shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import build_model, synthetic_batch

ASSIGNED = [a for a in ARCHS if a != "llama3.2-3b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_config_exact_specs(arch):
    cfg = get_config(arch)
    assert cfg.source, "every config must cite its source"
    # spot-check the assignment numbers
    expected = {
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "rwkv6-7b": (32, 4096, 0, 0, 14336, 65536),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
    }
    if arch in expected:
        L, d, h, kv, ff, v = expected[arch]
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads,
                cfg.num_kv_heads, cfg.d_ff, cfg.vocab_size) == \
            (L, d, h, kv, ff, v)
    if arch == "qwen3-moe-30b-a3b":
        assert (cfg.num_experts, cfg.experts_per_token) == (128, 8)
    if arch == "arctic-480b":
        assert (cfg.num_experts, cfg.experts_per_token,
                cfg.dense_residual) == (128, 2, True)
    if arch == "zamba2-2.7b":
        assert cfg.ssm_state == 64


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_decode(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 2 or cfg.family == "hybrid"
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, T = 2, 16
    batch = synthetic_batch(cfg, B, T)

    loss, aux = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss is not finite"

    logits, cache = model.prefill(params, batch, cache_len=T + 8)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    pos = jnp.full((B,), T, jnp.int32)
    logits2, cache2 = model.decode_step(params, cache, tok, pos)
    assert logits2.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2).all()), f"{arch}: decode NaN"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_one_train_step(arch):
    from repro.training.optimizer import AdamWConfig, init_adamw
    from repro.training.trainer import make_train_step
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_adamw(params)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3,
                                                      warmup_steps=1,
                                                      total_steps=10)))
    batch = {k: jnp.asarray(v) for k, v in synthetic_batch(cfg, 2, 16).items()}
    p2, o2, m = step(params, opt, batch)
    assert bool(jnp.isfinite(m["loss"]))
    assert bool(jnp.isfinite(m["grad_norm"]))
    # params must actually change
    moved = any(not np.allclose(a, b) for a, b in
                zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved, f"{arch}: train step did not update params"
