"""Bucketed workload statistics (§4.2 substrate)."""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.workload_stats import build_stats, exp_bucket_edges


def test_exp_buckets_cover():
    e = exp_bucket_edges(131_072)
    assert e[0] == 0 and e[-1] >= 131_072
    assert len(e) < 20                       # O(log L) cut points


def test_residency_weights_sum_to_one():
    edges = exp_bucket_edges(8192)
    stats = build_stats([(100, 500), (1000, 2000)], edges)
    # F1 (count) accumulated over all buckets = one unit per request
    total = stats.range_features(0, stats.nb)
    assert np.isclose(total[1], 2.0)


def test_range_features_additive():
    edges = exp_bucket_edges(8192)
    stats = build_stats([(50, 100), (300, 1000), (2000, 3000)], edges)
    mid = stats.nb // 2
    left = stats.range_features(0, mid)
    right = stats.range_features(mid, stats.nb)
    full = stats.range_features(0, stats.nb)
    assert np.allclose(left[1:] + right[1:], full[1:])


def test_edge_crossings():
    edges = np.array([0.0, 100.0, 1000.0, 10000.0])
    stats = build_stats([(50, 200), (50, 20)], edges)   # only first crosses 100
    assert stats.edge_crossings(1) == 1.0
    assert stats.edge_crossings(2) == 0.0


@given(st.lists(st.tuples(st.integers(1, 50_000), st.integers(1, 20_000)),
                min_size=1, max_size=50))
@settings(max_examples=40, deadline=None)
def test_stats_properties(reqs):
    stats = build_stats(reqs, exp_bucket_edges(131_072))
    F = stats.range_features(0, stats.nb)
    assert np.isclose(F[1], len(reqs), atol=1e-6)
    assert F[2] >= 0 and F[3] >= 0 and F[4] >= 0
    # ΣL over residency ≥ ΣI contribution-weighted... sanity: positive
    assert (stats.cross >= 0).all()
