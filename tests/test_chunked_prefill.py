"""Chunked paged prefill + mixed-iteration scheduling (DESIGN.md §Chunked
prefill): the flat work-list prefill kernel vs. a dense oracle,
chunk-by-chunk vs. whole-prompt parity on logits and pool contents (mock
and real model), mixed-iteration decode parity against the monolithic
(PR 3) loop, decode-stall bounds while a long prompt chunks, migration
round-trip of a half-prefilled request, and the analytic cost mirrors."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.serving.engine as engine_mod
from repro.configs import get_config
from repro.kernels.cost import (AttnSpec, mixed_iter_time_s, pow2_bucket,
                                prefill_chunk_blocks, prefill_chunk_flops)
from repro.kernels.prefill_attention import (paged_prefill_attention,
                                             prefill_attention)
from repro.kernels.ref import prefill_attention_ref
from repro.models import build_model
from repro.models.model import Model
from repro.serving.block_pool import blocks_for
from repro.serving.engine import Engine
from repro.serving.request import ServeRequest, State

RNG = np.random.default_rng(11)


# --------------------------------------------------------------------------
# Satellite: prefill_attention no longer requires T % block == 0
# --------------------------------------------------------------------------
@pytest.mark.parametrize("T,bq,bk", [(100, 32, 32), (37, 64, 32), (1, 64, 64)])
def test_prefill_attention_pads_internally(T, bq, bk):
    B, H, Hkv, Dh = 2, 4, 2, 64
    q = jnp.asarray(RNG.normal(0, 1, (B, T, H, Dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (B, T, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (B, T, Hkv, Dh)), jnp.float32)
    lens = jnp.asarray([T, max(T // 3, 1)], jnp.int32)
    ref = prefill_attention_ref(q, k, v, lens)
    out = prefill_attention(q, k, v, lens, block_q=bq, block_k=bk,
                            interpret=True)
    assert out.shape == q.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=3e-5)


# --------------------------------------------------------------------------
# Kernel: chunked paged prefill vs. dense oracle
# --------------------------------------------------------------------------
def _chunk_case(chunks, C, H, Hkv, Dh, BS, dtype):
    """Per chunk (ctx, clen): contiguous KV for positions [0, ctx+clen)
    scattered into a shuffled physical pool; the reference attends
    causally over it."""
    Bc = len(chunks)
    NBT = max(-(-(ctx + C) // BS) for ctx, _ in chunks) + 1
    NB = Bc * NBT + 2
    perm = RNG.permutation(NB)
    k_pool = np.zeros((NB, BS, Hkv, Dh), np.float32)
    v_pool = np.zeros_like(k_pool)
    bt = np.full((Bc, NBT), NB - 1, np.int32)
    full = []
    pi = 0
    for c, (ctx, clen) in enumerate(chunks):
        kk = RNG.normal(0, 1, (NBT * BS, Hkv, Dh)).astype(np.float32)
        vv = RNG.normal(0, 1, (NBT * BS, Hkv, Dh)).astype(np.float32)
        full.append((kk, vv))
        for j in range(-(-(ctx + clen) // BS)):
            pb = int(perm[pi]); pi += 1
            bt[c, j] = pb
            k_pool[pb] = kk[j * BS:(j + 1) * BS]
            v_pool[pb] = vv[j * BS:(j + 1) * BS]
    q = RNG.normal(0, 1, (Bc, C, H, Dh)).astype(np.float32)
    ref = np.zeros((Bc, C, H, Dh), np.float32)
    for c, (ctx, clen) in enumerate(chunks):
        kk, vv = full[c]
        for i in range(clen):
            qi = q[c, i].reshape(Hkv, H // Hkv, Dh)
            n = ctx + i + 1                     # causal: kv pos <= ctx + i
            s = np.einsum("hgd,shd->hgs", qi, kk[:n]) / np.sqrt(Dh)
            w = np.exp(s - s.max(-1, keepdims=True))
            w /= w.sum(-1, keepdims=True)
            ref[c, i] = np.einsum("hgs,shd->hgd", w, vv[:n]).reshape(H, Dh)
    to = lambda a: jnp.asarray(a, dtype)
    return (to(q), to(k_pool), to(v_pool), jnp.asarray(bt),
            jnp.asarray([c for c, _ in chunks], jnp.int32),
            jnp.asarray([l for _, l in chunks], jnp.int32), ref)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 3e-5),
                                       (jnp.bfloat16, 1e-2)])
def test_paged_prefill_kernel_matches_ref(dtype, tol):
    """Mixed batch: a fresh chunk (ctx 0), a resumed mid-prompt chunk, a
    single-token chunk dragging a long context, and an exact
    block-boundary case — each attends to its own context only."""
    chunks = [(0, 20), (48, 32), (167, 1), (64, 32)]      # (ctx, clen)
    C, BS = 32, 16
    q, kp, vp, bt, ctx, clen, ref = _chunk_case(chunks, C, 8, 2, 64, BS,
                                                dtype)
    total = sum(-(-(a + b) // BS) for a, b in chunks)
    for W in (total, pow2_bucket(total), None):
        out = paged_prefill_attention(q, kp, vp, bt, ctx, clen,
                                      num_work=W, interpret=True)
        out = np.asarray(out, np.float32)
        for c, (_, cl) in enumerate(chunks):
            np.testing.assert_allclose(out[c, :cl], ref[c, :cl],
                                       atol=tol, rtol=tol)


# --------------------------------------------------------------------------
# Real model: chunk-by-chunk == whole-prompt (logits AND pool contents)
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-360m").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_real_chunked_matches_whole_prompt(setup):
    """Acceptance: running a prompt chunk-by-chunk through the paged pool
    reproduces the whole-prompt prefill's next-token logits and every
    cache row (and the greedy first token exactly)."""
    cfg, model, params = setup
    T, BS = 29, 8
    toks = RNG.integers(0, cfg.vocab_size, (1, T)).astype(np.int32)
    ref_logits, ref_piece = model.prefill(
        params, {"tokens": jnp.asarray(toks)}, cache_len=T)

    NB = 16
    pool = model.init_paged_cache(NB, BS)
    ids = [5, 2, 9, 11]
    garbage = NB - 1
    fn = jax.jit(model.prefill_chunk)
    ctx = 0
    for clen in (10, 8, 11):                    # uneven chunk plan
        C = 16
        t = np.zeros((1, C), np.int32)
        t[0, :clen] = toks[0, ctx:ctx + clen]
        bt = np.full((1, blocks_for(ctx + C, BS)), garbage, np.int32)
        nreal = blocks_for(ctx + clen, BS)
        bt[0, :nreal] = ids[:nreal]
        logits, pool = fn(params, pool, jnp.asarray(t), jnp.asarray(bt),
                          jnp.int32(ctx), jnp.int32(clen))
        ctx += clen

    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(ref_logits, np.float32),
                               atol=2e-5, rtol=2e-5)
    assert int(jnp.argmax(logits[0])) == int(jnp.argmax(ref_logits[0]))
    for pool_l, piece_l in zip((pool.k, pool.v), (ref_piece.k, ref_piece.v)):
        got = np.asarray(pool_l, np.float32)[:, ids]
        got = got.reshape(got.shape[0], -1, *got.shape[3:])[:, :T]
        np.testing.assert_allclose(got, np.asarray(piece_l, np.float32)[:, 0],
                                   atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------
# Mock model (plumbing parity): token_{t+1} = f(token_t, pos_t)
# --------------------------------------------------------------------------
MOCK_VOCAB = 97


def _mock_next(tok, pos):
    return (31 * tok + 7 * pos + 3) % MOCK_VOCAB


def make_chunk_mock_model():
    """The test_hotloop mock plus a prefill_chunk — the engine's chunked
    scheduler sees a model whose first token depends only on the LAST
    prompt token and position, so chunked and whole-prompt prefill must
    emit identical greedy streams."""
    cfg = get_config("smollm-360m").reduced()

    def _logits(tok, pos):
        return jax.nn.one_hot(_mock_next(tok, pos), MOCK_VOCAB)

    def prefill(params, batch, cache_len=None):
        tokens = batch["tokens"]
        T = tokens.shape[1]
        piece = {"kv": jnp.zeros((1, 1, T, 1, 1), jnp.float32)}
        return _logits(tokens[:, -1], jnp.full((1,), T - 1)), piece

    def prefill_bucketed(params, batch, true_len):
        tokens = batch["tokens"]
        last = jnp.take_along_axis(tokens, true_len[None, None] - 1,
                                   axis=1)[:, 0]
        piece = {"kv": jnp.zeros((1, 1, tokens.shape[1], 1, 1), jnp.float32)}
        return _logits(last, true_len[None] - 1), piece

    def prefill_chunk(params, pool, tokens, block_tables, ctx_len,
                      chunk_len, **kw):
        B = tokens.shape[0]
        clen = jnp.broadcast_to(jnp.asarray(chunk_len, jnp.int32)
                                .reshape(-1), (B,))
        ctx = jnp.broadcast_to(jnp.asarray(ctx_len, jnp.int32)
                               .reshape(-1), (B,))
        last = jnp.take_along_axis(tokens, (clen - 1)[:, None],
                                   axis=1)[:, 0]
        return _logits(last, ctx + clen - 1), pool

    def decode_step_paged(params, pool, token, block_tables, pos, **kw):
        return _logits(token, pos), pool

    def decode_step(params, cache, token, pos, **kw):
        return _logits(token, pos), cache

    def init_paged_cache(num_blocks, block_size):
        return {"kv": jnp.zeros((1, num_blocks, block_size, 1, 1),
                                jnp.float32)}

    def init_cache(batch, seq):
        return {"kv": jnp.zeros((1, batch, seq, 1, 1), jnp.float32)}

    return Model(cfg, lambda rng: {}, loss=None, prefill=prefill,
                 decode_step=decode_step, init_cache=init_cache,
                 init_paged_cache=init_paged_cache,
                 decode_step_paged=decode_step_paged,
                 prefill_bucketed=prefill_bucketed,
                 prefill_chunk=prefill_chunk)


def _drain(eng, reqs, burst=1, max_iters=500):
    for r in reqs:
        eng.submit(r)
    for _ in range(max_iters):
        eng.step(burst)
        if all(r.state is State.FINISHED for r in reqs):
            return
    raise AssertionError("engine did not drain")


def _mock_reqs(plens, news, seed=1):
    r = np.random.default_rng(seed)
    return [ServeRequest(i, r.integers(0, MOCK_VOCAB, p).astype(np.int32),
                         m)
            for i, (p, m) in enumerate(zip(plens, news))]


@pytest.mark.parametrize("mode", ["host", "device", "device_burst"])
def test_mock_mixed_iteration_matches_monolithic(mode):
    """Acceptance (mixed-iteration decode bit-parity vs. the PR 3 loop):
    the chunked scheduler — prompts larger than the budget, max_new=1
    requests, slot reuse — emits exactly the monolithic engine's greedy
    tokens on every path (host, device, fused burst)."""
    plens = [3, 41, 9, 17, 26]
    news = [7, 5, 1, 11, 4]
    model = make_chunk_mock_model()
    device = mode != "host"
    burst = 8 if mode == "device_burst" else 1
    base = Engine(0, model, {}, max_slots=3, max_seq=64,
                  device_resident=device, chunked_prefill=False)
    reqs_a = _mock_reqs(plens, news)
    _drain(base, reqs_a, burst)
    chunked = Engine(0, model, {}, max_slots=3, max_seq=64,
                     device_resident=device, prefill_token_budget=8)
    reqs_b = _mock_reqs(plens, news)
    _drain(chunked, reqs_b, burst)
    assert [r.generated for r in reqs_a] == [r.generated for r in reqs_b]
    assert chunked.free_tokens() >= 0 and chunked.queued_tokens() == 0


def test_mock_no_decode_stall_while_long_prompt_chunks():
    """Acceptance: a long prompt arriving into a busy decode batch never
    opens an inter-token gap — every running decode request gains exactly
    one token per mixed iteration while the prompt chunks, and the
    prompt's first token lands after ceil(T/budget) iterations."""
    model = make_chunk_mock_model()
    budget = 8
    eng = Engine(0, model, {}, max_slots=4, max_seq=256,
                 prefill_token_budget=budget)
    decode = _mock_reqs([4, 6, 5], [120, 120, 120])
    for r in decode:
        eng.submit(r)
    for _ in range(4):                           # decode batch fully live
        eng.step()
    assert all(not r.prefilling for r in decode)
    T = 64
    long = ServeRequest(9, RNG.integers(0, MOCK_VOCAB, T).astype(np.int32),
                        4)
    eng.submit(long)
    steps = 0
    while long.prefilling:
        before = [len(r.generated) for r in decode]
        eng.step()
        steps += 1
        after = [len(r.generated) for r in decode]
        assert [a - b for a, b in zip(after, before)] == [1, 1, 1], \
            "a decode request stalled during chunked prefill"
    assert steps == -(-T // budget)              # one budget per iteration
    # the final-chunk step emits the first token AND decodes once (the
    # completed request joins the decode batch the same step, like PR 3
    # whole-prompt admission did)
    assert len(long.generated) == 2
    assert long.first_token_step == eng.steps
    # monolithic baseline for contrast: whole-prompt admission in 1 step
    mono = Engine(0, model, {}, max_slots=4, max_seq=256,
                  chunked_prefill=False)
    ml = ServeRequest(9, long.prompt.copy(), 4)
    mono.submit(ml)
    mono.step()
    assert ml.ctx_done == T                      # one shot, one iteration


def test_mock_chunked_one_device_sync_per_step(monkeypatch):
    """The mixed iteration keeps the PR 3 contract: chunk calls, final-
    chunk first tokens, and the decode burst all ride AT MOST one d2h per
    step — exactly one whenever a token reaches the host, zero on
    pure-chunk steps (nothing to transfer at all)."""
    model = make_chunk_mock_model()
    calls = []
    real = engine_mod.d2h
    monkeypatch.setattr(engine_mod, "d2h",
                        lambda x: calls.append(1) or real(x))
    for burst in (1, 8):
        eng = Engine(0, model, {}, max_slots=3, max_seq=64,
                     prefill_token_budget=8)
        reqs = _mock_reqs([20, 3, 11], [6, 6, 6])
        for r in reqs:
            eng.submit(r)
        saw_zero_sync_chunk_step = False
        while any(r.state is not State.FINISHED for r in reqs):
            before = sum(len(r.generated) for r in reqs)
            calls.clear()
            eng.step(burst)
            emitted = sum(len(r.generated) for r in reqs) - before
            assert len(calls) <= 1
            assert len(calls) == 1 or emitted == 0
            saw_zero_sync_chunk_step |= (len(calls) == 0)
        assert saw_zero_sync_chunk_step, \
            "expected at least one pure-chunk step with zero transfers"


def test_mock_queued_tokens_counts_unprefilled_only():
    model = make_chunk_mock_model()
    eng = Engine(0, model, {}, max_slots=2, max_seq=256,
                 prefill_token_budget=8)
    eng.submit(ServeRequest(0, np.ones(30, np.int32), 4))
    eng.submit(ServeRequest(1, np.ones(12, np.int32), 4))
    assert eng.queued_tokens() == 42
    eng.step()     # 8 tokens of req 0 chunked; req 1 still fully queued
    assert eng.queued_tokens() == 22 + 12
    assert eng.used_tokens() == blocks_for(8, eng.block_size) \
        * eng.block_size
    eng.step()
    assert eng.queued_tokens() == 14 + 12


# --------------------------------------------------------------------------
# Real model: engine-level chunked parity + migration of a partial prompt
# --------------------------------------------------------------------------
def test_real_engine_chunked_parity_all_paths(setup):
    """Greedy streams are identical across monolithic/chunked ×
    host/device — chunked prefill changes latency shape, never tokens."""
    cfg, model, params = setup
    prompts = [RNG.integers(0, cfg.vocab_size, p).astype(np.int32)
               for p in (5, 23, 12)]
    outs = {}
    for name, kw in {
        "mono": dict(chunked_prefill=False),
        "chunk_host": dict(device_resident=False, prefill_token_budget=8),
        "chunk_dev": dict(device_resident=True, prefill_token_budget=8),
    }.items():
        eng = Engine(0, model, params, max_slots=3, max_seq=64, **kw)
        reqs = [ServeRequest(i, p.copy(), 8) for i, p in enumerate(prompts)]
        _drain(eng, reqs)
        outs[name] = [list(r.generated) for r in reqs]
    assert outs["mono"] == outs["chunk_host"] == outs["chunk_dev"]


@pytest.mark.parametrize("backend", ["grid", "flat"])
def test_real_chunked_kernel_backend_matches_dense(setup, backend):
    """The chunked path through the Pallas prefill kernel (interpret mode
    off-TPU) agrees with the dense-gather fallback."""
    cfg, model, params = setup
    prompts = [RNG.integers(0, cfg.vocab_size, p).astype(np.int32)
               for p in (21, 6)]
    outs = []
    for be in (backend, "dense"):
        eng = Engine(0, model, params, max_slots=2, max_seq=64,
                     attn_backend=be, prefill_token_budget=8)
        reqs = [ServeRequest(i, p.copy(), 5) for i, p in enumerate(prompts)]
        _drain(eng, reqs)
        outs.append([list(r.generated) for r in reqs])
    assert outs[0] == outs[1]


def test_half_prefilled_migration_roundtrip(setup):
    """Acceptance: a request exported mid-prefill ships exactly its
    ctx_done written rows, the receiver resumes chunking, and the final
    greedy stream equals an unmigrated run."""
    cfg, model, params = setup
    mk = lambda i: Engine(i, model, params, max_slots=2, max_seq=64,
                          prefill_token_budget=8)
    src, dst, ref_eng = mk(0), mk(1), mk(2)
    prompt = RNG.integers(0, cfg.vocab_size, 30).astype(np.int32)
    r = ServeRequest(0, prompt.copy(), 6)
    ref = ServeRequest(9, prompt.copy(), 6)
    src.submit(r)
    ref_eng.submit(ref)
    src.step()
    src.step()
    assert r.ctx_done == 16 and r.prefilling
    req, piece, nbytes = src.export_slot(r.slot)
    assert jax.tree.leaves(piece)[0].shape[2] == 16, \
        "partial export must ship exactly the written rows"
    assert dst.import_request(req, piece)
    src.evict_slot(0)
    assert src.used_tokens() == 0 and src.queued_tokens() == 0
    while r.state is not State.FINISHED:
        dst.step()
    while ref.state is not State.FINISHED:
        ref_eng.step()
    assert r.generated == ref.generated
    assert r.tokens_by_engine[1] == len(r.generated)


def test_partial_import_refused_without_chunking(setup):
    cfg, model, params = setup
    src = Engine(0, model, params, max_slots=2, max_seq=64,
                 prefill_token_budget=8)
    mono = Engine(1, model, params, max_slots=2, max_seq=64,
                  chunked_prefill=False)
    r = ServeRequest(0, RNG.integers(0, cfg.vocab_size, 30)
                     .astype(np.int32), 6)
    src.submit(r)
    src.step()
    req, piece, _ = src.export_slot(r.slot)
    assert req.prefilling
    assert not mono.import_request(req, piece)


# --------------------------------------------------------------------------
# Cost-model mirrors
# --------------------------------------------------------------------------
def test_prefill_chunk_cost_mirrors():
    spec = AttnSpec(num_q_heads=32, num_kv_heads=8, head_dim=128)
    # grid work: chunk × context blocks
    assert prefill_chunk_blocks(256, 4096, 512) == math.ceil(4352 / 512)
    # summing a prompt's chunks recovers the causal whole-prompt count
    I, C = 8192, 256
    whole = prefill_chunk_flops(I, 0, spec)
    chunked = sum(prefill_chunk_flops(C, i * C, spec) for i in range(I // C))
    assert abs(chunked - whole) / whole < 0.05
    # a mixed iteration costs ~one chunk, not one monolithic prompt
    mixed = mixed_iter_time_s([(256, 16384)], [1024] * 8, spec)
    mono = prefill_chunk_flops(32768, 0, spec) / 197e12
    assert mixed < mono / 20


def test_sim_mixed_iterations_bound_decode_gaps():
    """Sim mirror of the engine acceptance: with the chunked scheduler a
    32K prompt landing on a busy instance never stretches an iteration
    beyond ~one budget's work; monolithic prefill stalls the whole batch
    for the full prompt."""
    from repro.sim.costmodel import profile_from_config
    from repro.sim.events import EventQueue
    from repro.sim.instance import Instance, SimRequest
    from repro.sim.workload import Request

    prof = profile_from_config(get_config("llama3.2-3b"))
    gaps = {}
    for name, budget in (("chunked", 2048), ("mono", None)):
        ev = EventQueue()
        inst = Instance(0, prof, 200_000, ev, prefill_budget=budget)
        for i in range(4):
            inst.enqueue(SimRequest(req=Request(i, 0.0, 64, 400),
                                    length=64), 0.0)
        ev.run_until(1.0)                       # decode batch warm
        token_t = {}
        gap = [0.0]

        def on_iter(ins, t, _gap=gap, _last=token_t):
            for r in ins.running:
                if not r.prefilling and r.req.req_id < 4:
                    if r.req.req_id in _last:
                        _gap[0] = max(_gap[0], t - _last[r.req.req_id])
                    _last[r.req.req_id] = t

        inst.on_iteration_end = on_iter
        inst.enqueue(SimRequest(req=Request(9, 1.0, 32_768, 4),
                                length=32_768), ev.now)
        ev.run_until(ev.now + 60.0)
        gaps[name] = gap[0]
    # chunked: gaps stay ~one mixed iteration; mono: one gap is the whole
    # 32K prefill (~2s in this profile)
    assert gaps["mono"] > 1.0
    assert gaps["chunked"] < gaps["mono"] / 5
    assert gaps["chunked"] < 0.2


def test_sim_chunked_admission_respects_capacity():
    """Admission must reserve the UNWRITTEN remainder of already-admitted
    prompts: chunks only land at iteration end, so without the pending
    reservation two prompts could both pass the gate and overflow
    capacity once their chunks materialize."""
    from repro.sim.costmodel import profile_from_config
    from repro.sim.events import EventQueue
    from repro.sim.instance import Instance, SimRequest
    from repro.sim.workload import Request

    prof = profile_from_config(get_config("llama3.2-3b"))
    ev = EventQueue()
    inst = Instance(0, prof, 128, ev, prefill_budget=256)
    low = [0.0]
    inst.on_iteration_end = lambda ins, t: low.__setitem__(
        0, min(low[0], ins.free_tokens()))
    done = []
    inst.on_request_done = lambda ins, sr, t: done.append(sr)
    for i in range(2):
        inst.enqueue(SimRequest(req=Request(i, 0.0, 100, 4), length=100),
                     0.0)
    ev.run_until(120.0)
    assert len(done) == 2, "both requests must eventually be served"
    assert low[0] >= 0.0, f"capacity overflowed: min free {low[0]}"


def test_mixed_iter_time_reduces_to_decode_iter_time():
    """With no chunks packed, a mixed iteration must price EXACTLY like a
    plain decode iteration under the same backend flag — so chunked-vs-
    monolithic experiments attribute nothing but prefill scheduling to
    chunking."""
    from repro.sim.costmodel import (decode_iter_time, mixed_iter_time,
                                     profile_from_config)
    for ragged in (False, True):
        prof = profile_from_config(get_config("llama3.2-3b"),
                                   ragged_backend=ragged)
        L = [100, 2000, 50]
        assert abs(mixed_iter_time([], L, prof)
                   - decode_iter_time(L, prof)) < 1e-12


def test_longtail_workload_targets_32k_128k():
    from repro.sim.workload import generate_longtail
    reqs = generate_longtail(6.0, 40.0, seed=3)
    tail = [r.input_len for r in reqs if r.input_len >= 32_000]
    assert len(tail) >= 5, "tail too thin to exercise long prompts"
    assert max(r.input_len for r in reqs) <= 131_072
    assert max(tail) > 64_000, "tail should reach deep into 32K-128K"
    body = [r.input_len for r in reqs if r.input_len < 32_000]
    assert len(body) > len(tail), "body must remain the bulk"
