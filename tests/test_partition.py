"""Stage partition DP (§4.2): optimality, structure, heuristic quality."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.partition import (PipelinePlan, Stage, full_dp,
                                  naive_cost_estimate, two_phase)
from repro.core.qoe import QoEModel
from repro.core.workload_stats import build_stats, exp_bucket_edges


def _stats(rng, n=300, max_len=65536):
    ins = rng.lognormal(5.5, 1.2, n).clip(10, max_len // 2).astype(int)
    outs = rng.lognormal(5.0, 1.0, n).clip(10, max_len // 2).astype(int)
    return build_stats(list(zip(ins.tolist(), outs.tolist())),
                       exp_bucket_edges(max_len))


def _check_plan(plan: PipelinePlan, E: int):
    assert plan.num_instances == E
    assert plan.stages[0].lo == 0.0
    assert plan.stages[-1].hi == float("inf")
    for a, b in zip(plan.stages, plan.stages[1:]):
        assert a.hi == b.lo, "ranges must tile the length space"
        assert a.lo < a.hi
    for s in plan.stages:
        assert s.num_instances >= 1


def test_full_dp_structure(rng, qoe_linear):
    plan = full_dp(_stats(rng), 8, qoe_linear)
    _check_plan(plan, 8)


def test_two_phase_structure(rng, qoe_linear):
    plan = two_phase(_stats(rng), 8, qoe_linear)
    _check_plan(plan, 8)


def test_full_dp_not_worse_than_two_phase(rng, qoe_linear):
    stats = _stats(rng)
    opt = full_dp(stats, 6, qoe_linear)
    heur = two_phase(stats, 6, qoe_linear)
    assert opt.quality <= heur.quality * 1.0001


def test_single_instance_plan(rng, qoe_linear):
    plan = full_dp(_stats(rng), 1, qoe_linear)
    assert len(plan.stages) == 1
    _check_plan(plan, 1)


def test_stage_for_length(rng, qoe_linear):
    plan = two_phase(_stats(rng), 8, qoe_linear)
    for L in (1, 100, 5000, 100_000, 10**7):
        si = plan.stage_for_length(L)
        st_ = plan.stages[si]
        assert st_.lo <= L < st_.hi or si == len(plan.stages) - 1


def test_more_instances_never_hurt(rng, qoe_linear):
    stats = _stats(rng)
    q4 = full_dp(stats, 4, qoe_linear).quality
    q8 = full_dp(stats, 8, qoe_linear).quality
    assert q8 <= q4 * 1.0001


def test_naive_complexity_speedup():
    # §6.5: optimized vs naive ~3e6 speedup at 16 instances / 128K
    assert naive_cost_estimate(16, 131_072) > 1e13


@given(st.integers(2, 10), st.integers(1, 9999))
@settings(max_examples=20, deadline=None)
def test_partition_property(E, seed):
    rng = np.random.default_rng(seed)
    qoe = QoEModel(np.array([5e-3, 5e-4, 2e-7, 1e-12, 3e-7]))
    stats = _stats(rng, n=80)
    plan = two_phase(stats, E, qoe)
    _check_plan(plan, E)
    assert np.isfinite(plan.quality)
