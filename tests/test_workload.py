"""Workload-generator properties: determinism, rate scaling, class mix,
arrival curves, and server-replay round-trips (DESIGN.md §SLO
scheduling; ROADMAP item 4's open-loop harness)."""
import numpy as np
import pytest

from repro.sched import SLO_CLASSES
from repro.sim.workload import (ArrivalCurve, Request, WorkloadSpec,
                                arrival_times, burst_windows, generate,
                                generate_longtail, generate_shared_prefix,
                                generate_slo, rate_at, shared_prefix_spec,
                                slo_spec, trace_requests)


# ---------------------------------------------------------------------------
# seed determinism: same spec -> identical trace, different seed -> not
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("gen", [
    lambda seed: generate(WorkloadSpec(rate=5.0, duration=10.0, seed=seed)),
    lambda seed: generate_longtail(5.0, 10.0, seed=seed),
    lambda seed: generate_shared_prefix(
        shared_prefix_spec(5.0, 10.0, seed=seed, turns=3)),
    lambda seed: generate_slo(slo_spec(5.0, 10.0, seed=seed)),
])
def test_generators_seed_deterministic(gen):
    a, b = gen(7), gen(7)
    assert a == b                       # frozen dataclasses compare by value
    c = gen(8)
    assert a != c


def test_trace_requests_round_trip(tmp_path):
    pairs = np.array([[100, 20], [5000, 80], [64, 8]], dtype=np.int64)
    p = tmp_path / "trace.csv"
    np.savetxt(p, pairs, fmt="%d", delimiter=",")
    a = trace_requests(str(p), rate=2.0, seed=3)
    b = trace_requests(str(p), rate=2.0, seed=3)
    assert a == b
    assert [(r.input_len, r.output_len) for r in a] == \
        [tuple(row) for row in pairs.tolist()]
    arr = [r.arrival for r in a]
    assert arr == sorted(arr) and arr[0] > 0.0


# ---------------------------------------------------------------------------
# rate scaling
# ---------------------------------------------------------------------------
def test_generate_rate_scaling():
    lo = generate(WorkloadSpec(rate=2.0, duration=50.0, seed=0))
    hi = generate(WorkloadSpec(rate=20.0, duration=50.0, seed=0))
    assert len(hi) > 3 * len(lo)


def test_slo_rate_scaling():
    lo = generate_slo(slo_spec(2.0, 50.0, seed=0))
    hi = generate_slo(slo_spec(20.0, 50.0, seed=0))
    assert len(hi) > 3 * len(lo)


# ---------------------------------------------------------------------------
# arrival curves: diurnal + bursty λ(t), thinning sanity
# ---------------------------------------------------------------------------
def test_rate_at_diurnal_and_burst():
    curve = ArrivalCurve(base_rate=10.0, diurnal_amp=0.5,
                         diurnal_period=40.0, burst_factor=4.0)
    t = np.array([10.0, 30.0])          # sine peak / trough
    lam = rate_at(curve, t, windows=[])
    assert lam[0] == pytest.approx(15.0)
    assert lam[1] == pytest.approx(5.0)
    lam_b = rate_at(curve, t, windows=[(25.0, 35.0)])
    assert lam_b[0] == pytest.approx(15.0)      # outside the burst
    assert lam_b[1] == pytest.approx(20.0)      # 4x inside

def test_burst_windows_disabled_and_bounded():
    rng = np.random.default_rng(0)
    flat = ArrivalCurve(base_rate=5.0, burst_factor=1.0)
    assert burst_windows(flat, 100.0, rng) == []
    bursty = ArrivalCurve(base_rate=5.0, burst_factor=6.0,
                          burst_every=10.0, burst_len=2.0)
    wins = burst_windows(bursty, 100.0, np.random.default_rng(1))
    assert wins
    for s, e in wins:
        assert 0.0 <= s < e <= 100.0


def test_arrival_times_mean_rate():
    """Thinned non-homogeneous arrivals land near the time-average rate."""
    curve = ArrivalCurve(base_rate=20.0, diurnal_amp=0.5,
                         diurnal_period=60.0, burst_factor=4.0,
                         burst_every=20.0, burst_len=2.0)
    duration = 240.0
    rng = np.random.default_rng(2)
    times, wins = arrival_times(curve, duration, rng)
    assert np.all(np.diff(times) >= 0.0)
    assert np.all((times >= 0.0) & (times <= duration))
    grid = np.linspace(0.0, duration, 20_001)
    lam = rate_at(curve, grid, wins)
    expect = float(np.sum((lam[1:] + lam[:-1]) / 2.0 * np.diff(grid)))
    assert abs(len(times) - expect) < 4 * np.sqrt(expect)


# ---------------------------------------------------------------------------
# SLO trace shape: class mix, tenant prefixes, length sanity
# ---------------------------------------------------------------------------
def test_generate_slo_class_mix_proportions():
    mix = (("interactive", 0.6), ("batch", 0.4))
    reqs = generate_slo(slo_spec(40.0, 60.0, seed=5, class_mix=mix))
    assert len(reqs) > 500
    counts = {c: 0 for c in SLO_CLASSES}
    for r in reqs:
        counts[r.slo_class] += 1
    assert counts["standard"] == 0
    frac = counts["interactive"] / len(reqs)
    assert 0.52 < frac < 0.68


def test_generate_slo_request_invariants():
    reqs = generate_slo(slo_spec(15.0, 40.0, seed=9))
    assert reqs
    spec_max = 131_072
    tenants = set()
    for r in reqs:
        assert isinstance(r, Request)
        assert r.slo_class in SLO_CLASSES
        assert 16 <= r.input_len <= spec_max - 64
        assert r.output_len >= 4
        assert r.input_len + r.output_len <= spec_max
        if r.prefix_group >= 0:
            assert 0 < r.prefix_len <= r.input_len - 16
            tenants.add(r.prefix_group)
        else:
            assert r.prefix_len == 0
    assert 1 < len(tenants) <= 8        # Zipf population actually multi-tenant


def test_generate_slo_batch_tail():
    """The Pareto tail rides on batch prompts only."""
    reqs = generate_slo(slo_spec(30.0, 60.0, seed=11))
    batch = [r.input_len for r in reqs if r.slo_class == "batch"]
    other = [r.input_len for r in reqs if r.slo_class != "batch"]
    assert batch and other
    assert max(batch) > 32_000          # tail fired
    assert max(other) < 32_000          # interactive/standard stay short


def test_requests_from_trace_round_trip():
    """Server replay preserves ids, classes and prefix groups, and caps
    lengths to the reduced engine."""
    from repro.serving.server import requests_from_trace
    reqs = generate_slo(slo_spec(10.0, 20.0, seed=4))
    out = requests_from_trace(reqs, vocab_size=512, max_seq=128, seed=0)
    assert len(out) == len(reqs)
    for (sr, step), r in zip(out, reqs):
        assert sr.req_id == r.req_id
        assert sr.slo_class == r.slo_class
        assert sr.prefix_group == r.prefix_group
        assert len(sr.prompt) + sr.max_new_tokens <= 128
        assert step == int(round(r.arrival))
    # same trace, same seed -> identical prompts (replay determinism)
    out2 = requests_from_trace(reqs, vocab_size=512, max_seq=128, seed=0)
    assert all(np.array_equal(a[0].prompt, b[0].prompt)
               for a, b in zip(out, out2))
