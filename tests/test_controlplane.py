"""Conformance suite for the backend-agnostic control plane.

A pure-python mock backend drives `ControlPlane` through the same
`InstanceView`/`ClusterOps` protocol the simulator and the real server
use, checking the invariants any backend may rely on:

  * request conservation — every submitted request finishes exactly once
    and is never resident on two instances at the same time;
  * boundary monotonicity under every refinement mode;
  * §5 flow control — migrations start only when the receiver could
    admit the request, per-source concurrency and per-tick budgets hold;
  * sim-vs-server parity — the discrete-event driver and the step-
    synchronous MILSServer (over a deterministic fake engine) produce
    identical routing and migration decision logs on a fixed trace.
"""
import collections
from collections import deque

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.control import (MIG_COMPLETED, MIG_STARTED, ControlConfig,
                           ControlPlane, ReqView)
from repro.core.partition import PipelinePlan, Stage


# --------------------------------------------------------------------------
# Mock backend
# --------------------------------------------------------------------------
class MockRequest:
    def __init__(self, req_id, input_len, output_len):
        self.req_id = req_id
        self.input_len = input_len
        self.output_len = output_len
        self.length = input_len
        self.generated = 0
        self.done = False
        self.finishes = 0

    def __repr__(self):
        return f"R{self.req_id}(len={self.length})"


class MockInstance:
    def __init__(self, iid, capacity):
        self.id = iid
        self.capacity = capacity
        self.running = []
        self.waiting = deque()

    def load(self):
        return float(sum(r.length for r in self.running)
                     + sum(r.length for r in self.waiting))

    def free_tokens(self):
        return float(self.capacity - sum(r.length for r in self.running))

    def used_tokens(self):
        return float(sum(r.length for r in self.running))

    def queued_tokens(self):
        return float(sum(r.length for r in self.waiting))

    def requests(self):
        return [ReqView(r, r.req_id, float(r.input_len), float(r.length))
                for r in self.running]

    def request_view(self):
        return [(float(r.input_len), float(r.length)) for r in self.running]

    def has_request(self, r):
        return not r.done and r in self.running

    def can_accept(self, r):
        return self.free_tokens() >= r.length


class MockBackend:
    """ClusterOps + a toy serving loop: admit, grow one token per step,
    finish at output_len. ``transfer_delay`` > 0 makes migrations async
    (delivered N steps later, like the simulator's fabric)."""

    def __init__(self, n_instances, capacity=10_000, transfer_delay=0):
        self.instances = [MockInstance(i, capacity) for i in range(n_instances)]
        self.transfer_delay = transfer_delay
        self.in_flight = []            # (deliver_at_step, req, src, dst)
        self.finished = []
        self.boundary_log = []
        self.migration_starts = []     # (req_id, src, dst, dst_could_accept)
        self.step_count = 0
        self.plane = None

    # ---- ClusterOps ------------------------------------------------------
    def dispatch(self, r, iid):
        self.instances[iid].waiting.append(r)

    def start_migration(self, r, src_id, dst_id):
        dst = self.instances[dst_id]
        self.migration_starts.append((r.req_id, src_id, dst_id,
                                      dst.can_accept(r)))
        if self.transfer_delay <= 0:
            self._deliver(r, src_id, dst_id)
            return MIG_COMPLETED
        self.in_flight.append((self.step_count + self.transfer_delay,
                               r, src_id, dst_id))
        return MIG_STARTED

    def set_boundary(self, stage_idx, hi):
        self.boundary_log.append((stage_idx, hi))

    # ---- mechanics -------------------------------------------------------
    def _deliver(self, r, src_id, dst_id):
        src = self.instances[src_id]
        if r.done or r not in src.running:
            return False               # finished mid-flight: drop the move
        src.running.remove(r)
        self.instances[dst_id].running.append(r)
        return True

    def residences(self, r):
        return [i.id for i in self.instances
                if r in i.running or r in i.waiting]

    def step(self):
        self.step_count += 1
        # async transfers land first (the wire is faster than the batch)
        due = [t for t in self.in_flight if t[0] <= self.step_count]
        self.in_flight = [t for t in self.in_flight if t[0] > self.step_count]
        for _, r, src_id, dst_id in due:
            arrived = self._deliver(r, src_id, dst_id)
            self.plane.migration_finished(r.req_id, arrived)
        for inst in self.instances:
            while inst.waiting and inst.can_accept(inst.waiting[0]):
                inst.running.append(inst.waiting.popleft())
            for r in list(inst.running):
                r.generated += 1
                r.length += 1
                if r.generated >= r.output_len:
                    r.done = True
                    r.finishes += 1
                    inst.running.remove(r)
                    self.finished.append(r)
            self.plane.on_instance_iteration(inst.id)


def make_plane(backend, plan, cfg, qoe=None):
    plane = ControlPlane(plan, qoe, cfg, ops=backend,
                         instances=backend.instances)
    backend.plane = plane
    return plane


def two_stage_plan(E, boundary=64.0):
    return PipelinePlan([Stage(0.0, boundary, E - E // 2),
                         Stage(boundary, float("inf"), E // 2)], 0.0)


def run_workload(backend, plane, requests, max_steps=500,
                 balance_every=4, refine_every=8):
    for r in requests:
        plane.submit(r, r.req_id, r.length)
    steps = 0
    while len(backend.finished) < len(requests) and steps < max_steps:
        backend.step()
        plane.pump_all()
        if steps % balance_every == 0:
            plane.balance()
        if steps % refine_every == 0:
            plane.refine()
        steps += 1
    while backend.in_flight:    # quiesce: land transfers still on the wire
        backend.step()
    plane.pump_all()
    return steps


def mixed_requests(rng, n, boundary=64):
    """Half short-lived, half crossing the stage boundary."""
    out = []
    for i in range(n):
        if i % 2:
            out.append(MockRequest(i, int(rng.integers(4, boundary // 2)),
                                   int(rng.integers(2, 10))))
        else:
            out.append(MockRequest(i, int(rng.integers(8, boundary - 4)),
                                   int(rng.integers(boundary, 2 * boundary))))
    return out


# --------------------------------------------------------------------------
# Conservation
# --------------------------------------------------------------------------
@pytest.mark.parametrize("transfer_delay", [0, 3])
def test_request_conservation(transfer_delay):
    rng = np.random.default_rng(0)
    backend = MockBackend(4, transfer_delay=transfer_delay)
    plane = make_plane(backend, two_stage_plan(4),
                       ControlConfig(refinement="none"))
    reqs = mixed_requests(rng, 16)
    for r in reqs:
        plane.submit(r, r.req_id, r.length)
    for _ in range(400):
        backend.step()
        plane.pump_all()
        plane.balance()
        # a live request is resident on exactly one instance — a request
        # mid-transfer stays on the source until the backend delivers it
        for r in reqs:
            if not r.done:
                assert len(backend.residences(r)) == 1, (r, backend.residences(r))
        if len(backend.finished) == len(reqs):
            break
    assert len(backend.finished) == len(reqs)
    for r in reqs:
        assert r.finishes == 1, f"{r} finished {r.finishes} times"
    # quiesce: land transfers still on the wire, drain stale offers (real
    # drivers keep stepping/pumping; the mock must do it explicitly)
    while backend.in_flight:
        backend.step()
    plane.pump_all()
    assert plane.pending_ids() == set(), "negotiation state leaked"
    assert plane._dst_of == {}, "transfer bookkeeping leaked"
    assert plane.migrations > 0, "boundary-crossing requests must migrate"


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), n=st.integers(1, 24),
       delay=st.integers(0, 4), capacity=st.integers(300, 10_000))
def test_request_conservation_property(seed, n, delay, capacity):
    rng = np.random.default_rng(seed)
    backend = MockBackend(4, capacity=capacity, transfer_delay=delay)
    plane = make_plane(backend, two_stage_plan(4),
                       ControlConfig(refinement="none"))
    reqs = mixed_requests(rng, n)
    # drop requests that can never fit an instance (mock has no reject path)
    reqs = [r for r in reqs if r.input_len + r.output_len <= capacity]
    run_workload(backend, plane, reqs, max_steps=4000)
    assert len(backend.finished) == len(reqs)
    assert all(r.finishes == 1 for r in reqs)
    assert plane.pending_ids() == set()


# --------------------------------------------------------------------------
# Routing
# --------------------------------------------------------------------------
def test_arrivals_route_round_robin_within_stage():
    """§3.2: dispatch is RR within the covering stage — bid-ask governs
    migrations, not arrivals (the old server used bid-ask here)."""
    backend = MockBackend(4)
    plane = make_plane(backend, two_stage_plan(4, boundary=64.0),
                       ControlConfig())
    short = [MockRequest(i, 10, 5) for i in range(6)]
    long = [MockRequest(10 + i, 100, 5) for i in range(4)]
    picks_short = [plane.route(r.req_id, r.length) for r in short]
    picks_long = [plane.route(r.req_id, r.length) for r in long]
    assert picks_short == [0, 1, 0, 1, 0, 1]
    assert picks_long == [2, 3, 2, 3]


def test_baseline_policies_route():
    backend = MockBackend(3)
    plane = make_plane(backend, PipelinePlan([Stage(0.0, float("inf"), 3)],
                                             0.0),
                       ControlConfig(policy="round-robin"))
    assert [plane.route(i, 10) for i in range(5)] == [0, 1, 2, 0, 1]

    backend2 = MockBackend(3)
    plane2 = make_plane(backend2, PipelinePlan([Stage(0.0, float("inf"), 3)],
                                               0.0),
                        ControlConfig(policy="least-loaded"))
    backend2.instances[0].running.append(MockRequest(99, 500, 100))
    assert plane2.route(0, 10) in (1, 2)


# --------------------------------------------------------------------------
# Boundary refinement
# --------------------------------------------------------------------------
def _bounds_monotone(plane):
    bounds = plane.bounds()
    assert bounds[0][0] == 0.0
    assert bounds[-1][1] == float("inf")
    for (lo, hi), (lo2, hi2) in zip(bounds, bounds[1:]):
        assert hi == lo2 and lo < hi


@pytest.mark.parametrize("mode", ["adaptive", "quantity", "memory"])
def test_boundaries_stay_monotone(mode, qoe_linear):
    rng = np.random.default_rng(1)
    backend = MockBackend(4)
    plane = make_plane(backend, two_stage_plan(4),
                       ControlConfig(refinement=mode), qoe=qoe_linear)
    reqs = mixed_requests(rng, 20)
    for r in reqs:
        plane.submit(r, r.req_id, r.length)
    for step in range(200):
        backend.step()
        plane.pump_all()
        if step % 4 == 0:
            plane.refine()
            _bounds_monotone(plane)
        if len(backend.finished) == len(reqs):
            break
    assert backend.boundary_log, f"{mode} refinement never moved a boundary"
    for si, hi in backend.boundary_log:
        assert 0.0 < hi < float("inf")


@pytest.mark.parametrize("mode", ["quantity", "memory", "adaptive"])
def test_last_boundary_keeps_floor_three_stages(mode, qoe_linear):
    """The boundary feeding the unbounded last stage must still respect
    its stage's lower edge: with mostly-short live requests a naive split
    lands *below* stage lo and would invert the range."""
    plan = PipelinePlan([Stage(0.0, 48.0, 2), Stage(48.0, 96.0, 1),
                         Stage(96.0, float("inf"), 1)], 0.0)
    backend = MockBackend(4)
    plane = make_plane(backend, plan, ControlConfig(refinement=mode),
                       qoe=qoe_linear)
    # short requests everywhere: split points sit far below 48/96
    for iid in range(4):
        for j in range(6):
            backend.instances[iid].running.append(
                MockRequest(100 * iid + j, 8, 40))
    for _ in range(5):
        plane.refine()
        _bounds_monotone(plane)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16),
       mode=st.sampled_from(["adaptive", "quantity", "memory"]))
def test_boundary_monotonicity_property(seed, mode, qoe_linear):
    rng = np.random.default_rng(seed)
    backend = MockBackend(4)
    plane = make_plane(backend, two_stage_plan(4),
                       ControlConfig(refinement=mode), qoe=qoe_linear)
    reqs = mixed_requests(rng, int(rng.integers(6, 24)))
    for r in reqs:
        plane.submit(r, r.req_id, r.length)
    for _ in range(60):
        backend.step()
        plane.refine()
        _bounds_monotone(plane)


# --------------------------------------------------------------------------
# Flow control + migration caps (§5)
# --------------------------------------------------------------------------
def test_migrations_gated_on_receiver_room():
    """A migration only starts when the receiver could admit the request
    at decision time; an over-full stage keeps requests on the source."""
    rng = np.random.default_rng(2)
    backend = MockBackend(4, capacity=260, transfer_delay=2)
    plane = make_plane(backend, two_stage_plan(4, boundary=32.0),
                       ControlConfig(refinement="none"))
    reqs = [MockRequest(i, 20, 60) for i in range(8)]
    run_workload(backend, plane, reqs, max_steps=600)
    assert backend.migration_starts, "nothing migrated under pressure"
    for req_id, src, dst, could_accept in backend.migration_starts:
        assert could_accept, \
            f"req {req_id} sent to {dst} which could not admit it"


def test_per_source_transfers_serialized():
    """§4.4/§5 concurrency control: each source has at most one outbound
    transfer in flight (sender serialization), even with many crossers."""
    backend = MockBackend(4, transfer_delay=50)   # transfers never land
    plane = make_plane(backend, two_stage_plan(4, boundary=16.0),
                       ControlConfig(refinement="none"))
    # many boundary-crossers on one source instance
    reqs = [MockRequest(i, 20, 100) for i in range(10)]
    for r in reqs:
        backend.dispatch(r, 0)        # all on instance 0, bypassing routing
    for _ in range(30):
        backend.step()
        plane.pump_all()
        in_flight_src = [plane._pending[req_id][1]
                         for req_id in plane._dst_of]
        per_src = collections.Counter(in_flight_src)
        assert all(n <= 1 for n in per_src.values()), per_src
        for src, sender in plane.senders.items():
            if sender.transmitting is not None:
                assert per_src.get(src, 0) == 1
    assert plane._dst_of, "pressure never started a transfer"


def test_per_tick_migration_budget():
    backend = MockBackend(4, transfer_delay=0)
    plane = make_plane(backend, two_stage_plan(4, boundary=16.0),
                       ControlConfig(refinement="none",
                                     max_migrations_per_tick=2))
    reqs = [MockRequest(i, 20, 100) for i in range(12)]
    for i, r in enumerate(reqs):
        backend.dispatch(r, i % 2)
    for _ in range(20):
        before = plane.migrations
        plane.begin_tick()
        backend.step()                # on_instance_iteration -> handover
        assert plane.migrations - before <= 2, "tick budget exceeded"


def test_starvation_backpressure_does_not_livelock():
    """Once a receiver blocks on a starved request (§4.4), the pump must
    start that transfer as soon as the sender frees up — sender and
    receiver must not wait on each other while offers pile up."""
    backend = MockBackend(4, transfer_delay=6)
    plane = make_plane(backend, two_stage_plan(4, boundary=16.0),
                       ControlConfig(refinement="none"))
    # long-lived crossers all on one source: slow transfers + repeated
    # failed pulls trip the starvation threshold
    reqs = [MockRequest(i, 20, 400) for i in range(6)]
    for r in reqs:
        backend.dispatch(r, 0)
    for _ in range(100):
        backend.step()
        plane.pump_all()
    migrated = {r[0] for r in backend.migration_starts}
    assert len(migrated) == 6, \
        f"only {sorted(migrated)} migrated — starvation wedged the sender"


def test_request_never_double_offered():
    """Pending-transfer tracking: while a transfer is negotiated or in
    flight, handover and balance must not offer the request again."""
    backend = MockBackend(4, transfer_delay=10)
    plane = make_plane(backend, two_stage_plan(4, boundary=16.0),
                       ControlConfig(refinement="none"))
    reqs = [MockRequest(i, 20, 200) for i in range(4)]
    for r in reqs:
        plane.submit(r, r.req_id, r.length)
    for _ in range(40):
        backend.step()
        plane.balance()
        plane.pump_all()
    starts = collections.Counter(r[0] for r in backend.migration_starts)
    for req_id, n in starts.items():
        assert n <= 1, f"req {req_id} transferred {n} times concurrently"


# --------------------------------------------------------------------------
# Sim-vs-server parity
# --------------------------------------------------------------------------
class FakeEngine:
    """Deterministic, compute-free stand-in for `serving.engine.Engine`:
    same lifecycle (admit → one token per step → finish), same accounting
    surface, instant exports/imports. ``prefill_budget`` mirrors the
    chunked mixed-iteration scheduler: at most that many prompt tokens
    progress per step (oldest request first), a request generates only
    once its prompt is fully prefilled, and views report prefill
    progress."""

    def __init__(self, eid, max_slots=8, token_budget=100_000,
                 max_seq=100_000, prefill_budget=None, block_size=16,
                 prefix_cache=True):
        self.id = eid
        self.max_slots = max_slots
        self.token_budget = token_budget
        self.max_seq = max_seq
        self.prefill_budget = prefill_budget
        self.block_size = block_size
        # group-granular prefix-cache mirror (same model as sim.Instance):
        # prefix_group -> shareable blocks, published at prefill completion
        self.prefix_cache = prefix_cache and prefill_budget is not None
        # mid-decode dead-engine recovery replays prompt+generated through
        # chunked prefill — only the chunked scheduler can host a resume
        self.chunked_prefill = prefill_budget is not None
        self._prefix_store = {}
        self.slots = [None] * max_slots
        self.waiting = deque()
        self._prefill_order = []
        self.steps = 0
        self.tokens_out = 0

    def active(self):
        return [r for r in self.slots if r is not None]

    def used_tokens(self):
        return sum(r.length for r in self.active())

    def queued_tokens(self):
        return (sum(r.prefill_target_len - r.cached_tokens
                    for r in self.waiting)
                + sum(r.prefill_target_len - r.ctx_done
                      for r in self.active() if r.prefilling))

    # ---- prefix-cache mirror (DESIGN.md §Prefix cache) -------------------
    def _cached_for(self, req):
        g = getattr(req, "prefix_group", -1)
        if not self.prefix_cache or g < 0 or g not in self._prefix_store:
            return 0
        cap = (len(req.prompt) - 1) // self.block_size
        return min(self._prefix_store[g], cap) * self.block_size

    def prefix_hint(self, req):
        g = getattr(req, "prefix_group", -1)
        if not self.prefix_cache or g < 0:
            return None, 0
        return g, self._cached_for(req)

    def prefix_digests(self):
        return frozenset(self._prefix_store)

    def _publish(self, req):
        g = getattr(req, "prefix_group", -1)
        if (not self.prefix_cache or g < 0 or g in self._prefix_store
                or req.prefix_len < self.block_size):
            return
        self._prefix_store[g] = req.prefix_len // self.block_size
        req.cached_tokens = max(req.cached_tokens,
                                self._prefix_store[g] * self.block_size)

    def free_tokens(self):
        return self.token_budget - self.used_tokens()

    def load(self):
        return float(self.used_tokens() + self.queued_tokens())

    def request_view(self):
        return [(float(len(r.prompt)), float(r.length))
                for r in self.active()]

    def can_accept(self, req):
        if not any(s is None for s in self.slots):
            return False
        worst = min(len(req.prompt) + req.max_new_tokens, self.max_seq)
        return self.used_tokens() + worst <= self.token_budget

    def submit(self, req):
        from repro.serving.request import State
        req.state = State.WAITING
        req.cached_tokens = self._cached_for(req)
        self.waiting.append(req)

    def _place(self, req):
        from repro.serving.request import State
        slot = self.slots.index(None)
        self.slots[slot] = req
        req.state = State.RUNNING
        req.engine_id = self.id
        req.slot = slot
        req.tokens_by_engine.setdefault(self.id, 0)
        return slot

    def _release(self, slot):
        if self.slots[slot] in self._prefill_order:
            self._prefill_order.remove(self.slots[slot])
        self.slots[slot] = None

    def _first_token(self, req):
        self._publish(req)                   # finished prompt is shareable
        if req.generated:                    # resume: prefill re-derives
            return                           # generated[-1], no new token
        req.generated.append(0)              # prefill's first token
        req.first_token_step = self.steps
        req.tokens_by_engine[self.id] += 1
        self.tokens_out += 1

    def step(self):
        from repro.serving.request import State
        self.steps += 1
        finished = []
        budget = self.prefill_budget
        if budget is None:
            while self.waiting and self.can_accept(self.waiting[0]):
                req = self.waiting.popleft()
                self._place(req)
                req.ctx_done = req.prefill_target_len
                self._first_token(req)
        else:
            # chunked mixed iteration: resume oldest-first, then admit
            for req in list(self._prefill_order):
                if budget <= 0:
                    break
                c = min(req.prefill_target_len - req.ctx_done, budget)
                req.ctx_done += c
                budget -= c
                if req.ctx_done >= req.prefill_target_len:
                    self._prefill_order.remove(req)
                    self._first_token(req)
            while (self.waiting and budget > 0
                   and self.can_accept(self.waiting[0])):
                req = self.waiting.popleft()
                self._place(req)
                # cached admission: the shared prefix never re-prefils
                req.cached_tokens = self._cached_for(req)
                req.ctx_done = max(req.ctx_done, req.cached_tokens)
                c = min(req.prefill_target_len - req.ctx_done, budget)
                req.ctx_done += c
                budget -= c
                if req.ctx_done >= req.prefill_target_len:
                    self._first_token(req)
                else:
                    self._prefill_order.append(req)
        for slot, req in enumerate(list(self.slots)):
            if req is None or req.prefilling:
                continue                     # mid-prefill: no decode yet
            req.generated.append(0)
            req.tokens_by_engine[self.id] = \
                req.tokens_by_engine.get(self.id, 0) + 1
            self.tokens_out += 1
            if req.done:
                req.state = State.FINISHED
                req.finish_step = self.steps
                finished.append(req)
                self._release(slot)
        return finished

    def export_slot(self, slot):
        return self.slots[slot], None, 0.0

    def evict_slot(self, slot):
        self._release(slot)

    def import_request(self, req, piece):
        from repro.serving.request import State
        if not self.can_accept(req):
            return False
        req.cached_tokens = 0       # shared prefix re-imports as private
        self._place(req)
        if req.prefilling:                      # resume chunking here
            self._prefill_order.append(req)
        return True


@pytest.mark.parametrize("prefill_budget", [None, 8])
def test_sim_and_server_make_identical_decisions(prefill_budget):
    """The acceptance test of ISSUE 2 (now with prefill-progress-aware
    views): both drivers of the shared core — discrete-event simulator
    and step-synchronous server — produce the same routing AND migration
    decision log on a fixed trace, with monolithic prefill and with the
    chunked mixed-iteration scheduler (prompts span several iterations
    before their first token, queued_tokens counts un-prefilled only).

    Setup keeps decisions timing-independent: deterministic rr handover
    (no load-sensitive bids), frozen boundaries, spaced arrivals, uniform
    growth until the stage boundary."""
    from repro.configs import get_config
    from repro.serving.request import ServeRequest
    from repro.serving.server import MILSServer, ServerConfig
    from repro.sim.cluster import CascadePolicy, Cluster, ClusterConfig
    from repro.sim.costmodel import profile_from_config
    from repro.sim.workload import Request

    plan = two_stage_plan(4, boundary=32.0)
    # 6 arrivals, every other one outgrows stage 0 (20 + 40 > 32)
    lens = [(20, 40), (8, 4), (20, 40), (10, 6), (20, 40), (20, 40)]

    # --- sim driver -------------------------------------------------------
    trace = [Request(i, 8.0 * i, il, ol) for i, (il, ol) in enumerate(lens)]
    policy = CascadePolicy(plan, None, refinement="none", balancing="rr")
    cluster = Cluster(profile_from_config(get_config("llama3.2-3b")),
                      policy, ClusterConfig(num_instances=4, seed=0,
                                            prefill_token_budget=
                                            prefill_budget))
    res = cluster.run(trace, duration=60.0)
    assert len(res.completed) == len(trace)
    sim_log = policy.plane.decisions

    # --- server driver (fake engines, no JAX) -----------------------------
    srv = MILSServer(None, None, plan, None,
                     ServerConfig(refinement="none", balancing="rr", seed=0),
                     engine_factory=lambda i: FakeEngine(
                         i, prefill_budget=prefill_budget))
    for i, (il, ol) in enumerate(lens):
        srv.submit_at(ServeRequest(i, np.zeros(il, np.int32), ol),
                      step=8 * i)
    fin = srv.run(max_steps=400)
    assert len(fin) == len(lens)
    srv_log = srv.plane.decisions

    routes = lambda log: [d for d in log if d[0] == "route"]
    migs = lambda log: [d for d in log if d[0] == "migrate"]
    assert routes(sim_log) == routes(srv_log)
    assert migs(sim_log) == migs(srv_log)
    assert len(migs(sim_log)) == 4, "every boundary-crosser migrates once"


@pytest.mark.parametrize("prefix_cache", [True, False])
def test_sim_and_server_parity_with_prefix_caching(prefix_cache):
    """The ISSUE-5 acceptance parity: on a shared-prefix trace, both
    drivers agree on every routing AND migration decision with prefix
    caching on — cached admission (warm prompts finish prefill in one
    chunk), effective-length stage routing (a long warm prompt stays in
    the short stage), and prefix-affinity dispatch (repeat groups land on
    the instance advertising their digest) all mirror exactly. With
    ``prefix_cache=False`` both drivers fall back to the legacy path —
    and the long warm prompt routes to the long stage instead."""
    from repro.configs import get_config
    from repro.serving.server import (MILSServer, ServerConfig,
                                      requests_from_trace)
    from repro.sim.cluster import CascadePolicy, Cluster, ClusterConfig
    from repro.sim.costmodel import profile_from_config
    from repro.sim.workload import Request

    plan = two_stage_plan(4, boundary=32.0)
    BS = 16
    # (input, output, group, prefix): group 0's 16-token prefix publishes
    # when r0 finishes prefill; r2/r3/r5 arrive warm. r5 is the routing
    # witness: true length 40 -> stage 1, effective 40-16=24 -> stage 0.
    lens = [(24, 40, 0, 16), (8, 4, -1, 0), (24, 4, 0, 16),
            (24, 40, 0, 16), (20, 4, 1, 16), (40, 4, 0, 16)]
    trace = [Request(i, 8.0 * i, il, ol, prefix_group=g, prefix_len=p)
             for i, (il, ol, g, p) in enumerate(lens)]

    # --- sim driver -------------------------------------------------------
    policy = CascadePolicy(plan, None, refinement="none", balancing="rr")
    cluster = Cluster(profile_from_config(get_config("llama3.2-3b")),
                      policy, ClusterConfig(num_instances=4, seed=0,
                                            prefill_token_budget=8,
                                            prefix_cache=prefix_cache))
    res = cluster.run(trace, duration=80.0)
    assert len(res.completed) == len(trace)
    sim_log = policy.plane.decisions

    # --- server driver (fake engines, no JAX) -----------------------------
    srv = MILSServer(None, None, plan, None,
                     ServerConfig(refinement="none", balancing="rr", seed=0),
                     engine_factory=lambda i: FakeEngine(
                         i, prefill_budget=8, block_size=BS,
                         prefix_cache=prefix_cache))
    for req, step in requests_from_trace(trace, vocab_size=100):
        srv.submit_at(req, step)
    fin = srv.run(max_steps=600)
    assert len(fin) == len(lens)
    srv_log = srv.plane.decisions

    routes = lambda log: [d for d in log if d[0] == "route"]
    migs = lambda log: [d for d in log if d[0] == "migrate"]
    assert routes(sim_log) == routes(srv_log)
    assert migs(sim_log) == migs(srv_log)
    route_of = {d[1]: d[2] for d in routes(sim_log)}
    if prefix_cache:
        # effective-length routing: warm 40-token prompt stays short-stage
        assert route_of[5] in (0, 1)
        # prefix affinity: warm group-0 arrivals follow r0's instance
        assert route_of[2] == route_of[0]
    else:
        assert route_of[5] in (2, 3), "legacy path must route true length"


def test_sim_and_server_parity_on_heterogeneous_tp_cluster():
    """The ISSUE-9 acceptance parity: a heterogeneous-TP cluster — one
    tp=2 instance plus three tp=1 — makes identical routing AND
    migration decisions in both drivers. Capacity weights flow in
    through ``InstanceView.capacity_weight()`` (sim: the scaled
    profile's num_devices; server: the engine's ``tp``), so weighted
    stage claiming gives the big instance the whole short stage
    (weight 2 satisfies ``num_instances=2``) and the last stage takes
    the remaining three."""
    from repro.configs import get_config
    from repro.serving.request import ServeRequest
    from repro.serving.server import MILSServer, ServerConfig
    from repro.sim.cluster import CascadePolicy, Cluster, ClusterConfig
    from repro.sim.costmodel import profile_from_config
    from repro.sim.workload import Request

    plan = two_stage_plan(4, boundary=32.0)
    tps = (2, 1, 1, 1)
    lens = [(20, 40), (8, 4), (20, 40), (10, 6), (20, 40), (20, 40)]

    # --- sim driver -------------------------------------------------------
    trace = [Request(i, 8.0 * i, il, ol) for i, (il, ol) in enumerate(lens)]
    policy = CascadePolicy(plan, None, refinement="none", balancing="rr")
    cluster = Cluster(profile_from_config(get_config("llama3.2-3b")),
                      policy, ClusterConfig(num_instances=4, seed=0,
                                            prefill_token_budget=8,
                                            tps=tps))
    res = cluster.run(trace, duration=60.0)
    assert len(res.completed) == len(trace)
    sim_log = policy.plane.decisions

    # --- server driver (fake engines carrying a tp attr, no JAX) ----------
    def factory(i):
        eng = FakeEngine(i, prefill_budget=8)
        eng.tp = tps[i]
        return eng

    srv = MILSServer(None, None, plan, None,
                     ServerConfig(refinement="none", balancing="rr", seed=0),
                     tp=tps, engine_factory=factory)
    for i, (il, ol) in enumerate(lens):
        srv.submit_at(ServeRequest(i, np.zeros(il, np.int32), ol),
                      step=8 * i)
    fin = srv.run(max_steps=400)
    assert len(fin) == len(lens)
    srv_log = srv.plane.decisions

    # weighted stage claiming: the tp=2 instance IS the short stage
    for plane in (policy.plane, srv.plane):
        assert plane.stages[0].instance_ids == [0]
        assert plane.stages[1].instance_ids == [1, 2, 3]

    routes = lambda log: [d for d in log if d[0] == "route"]
    migs = lambda log: [d for d in log if d[0] == "migrate"]
    assert routes(sim_log) == routes(srv_log)
    assert migs(sim_log) == migs(srv_log)
    # every arrival lands on the short stage's big instance; the four
    # boundary-crossers migrate rr across the three tp=1 instances
    assert all(d[2] == 0 for d in routes(sim_log))
    assert len(migs(sim_log)) == 4


def test_server_conserves_requests_with_fake_engines():
    """Open-loop server over the mock engine: conservation + streaming."""
    from repro.serving.request import ServeRequest
    from repro.serving.server import MILSServer, ServerConfig

    tokens = []
    srv = MILSServer(None, None, two_stage_plan(4, boundary=24.0), None,
                     ServerConfig(refinement="none"),
                     engine_factory=lambda i: FakeEngine(i),
                     on_token=lambda r, t: tokens.append(r.req_id))
    rng = np.random.default_rng(3)
    n = 12
    for i in range(n):
        srv.submit_at(ServeRequest(i, np.zeros(int(rng.integers(4, 30)),
                                               np.int32),
                                   int(rng.integers(4, 40))),
                      step=int(rng.integers(0, 20)))
    fin = srv.run(max_steps=300)
    assert len(fin) == n
    assert len(set(r.req_id for r in fin)) == n, "a request finished twice"
    per_req = collections.Counter(tokens)
    for r in fin:
        assert per_req[r.req_id] == len(r.generated), "streaming missed tokens"


# --------------------------------------------------------------------------
# Faulty-trace sim-vs-server parity (ISSUE 8)
# --------------------------------------------------------------------------
def _fault_parity_logs(fault_spec, lens, *, crash_step, crash_time,
                       arrive_step=5, arrive_s=0.05, duration=30.0,
                       max_steps=4000, boundary=32.0):
    """Run the same trace + fault script through both drivers and return
    (sim_decisions, server_decisions). The FaultSpec passed in carries the
    sim-clock crash time; the server gets the same spec re-stamped with
    the step-clock crash point — everything else (seed, loss/stall
    probabilities) is shared, so per-attempt transfer fates hash
    identically in both backends."""
    import dataclasses as _dc

    from repro.configs import get_config
    from repro.serving.request import ServeRequest
    from repro.serving.server import MILSServer, ServerConfig
    from repro.sim.cluster import CascadePolicy, Cluster, ClusterConfig
    from repro.sim.costmodel import profile_from_config
    from repro.sim.workload import Request

    plan = two_stage_plan(4, boundary=boundary)
    sim_spec = _dc.replace(
        fault_spec,
        crashes=tuple((i, crash_time) for i, _ in fault_spec.crashes))
    srv_spec = _dc.replace(
        fault_spec,
        crashes=tuple((i, float(crash_step)) for i, _ in fault_spec.crashes))

    trace = [Request(i, arrive_s * i, il, ol)
             for i, (il, ol) in enumerate(lens)]
    policy = CascadePolicy(plan, None, refinement="none", balancing="rr")
    cluster = Cluster(profile_from_config(get_config("llama3.2-3b")),
                      policy,
                      ClusterConfig(num_instances=4, seed=0,
                                    prefill_token_budget=8,
                                    migration_timeout_s=0.5,
                                    faults=sim_spec))
    res = cluster.run(trace, duration=duration)
    assert len(res.completed) == len(trace), "sim lost a request to the fault"

    srv = MILSServer(None, None, plan, None,
                     ServerConfig(refinement="none", balancing="rr", seed=0,
                                  faults=srv_spec),
                     engine_factory=lambda i: FakeEngine(i, prefill_budget=8))
    for i, (il, ol) in enumerate(lens):
        srv.submit_at(ServeRequest(i, np.zeros(il, np.int32), ol),
                      step=arrive_step * i)
    fin = srv.run(max_steps=max_steps)
    assert len(fin) == len(lens), "server lost a request to the fault"
    return policy.plane.decisions, srv.plane.decisions


def test_sim_and_server_parity_with_instance_crash():
    """The ISSUE-8 acceptance parity: kill a stage-1 instance while it
    holds a long decode; both drivers must agree on every route, every
    migration, the death verdict, and the re-dispatch target — the chaos
    harness extends decision-log parity to faulty runs.

    Trace: two boundary-crossers (migrate to instances 2 and 3), two
    shorts that finish early. Instance 2 dies after both migrations have
    settled and the shorts have drained, so at detection time its only
    resident is request 0, which must be re-dispatched to the surviving
    stage-1 instance 3 in BOTH backends."""
    from repro.control.faults import FaultSpec

    spec = FaultSpec(seed=0, crashes=((2, 0.0),))
    lens = [(20, 200), (8, 4), (20, 200), (10, 6)]
    sim_log, srv_log = _fault_parity_logs(
        spec, lens, crash_step=60, crash_time=0.8)

    for kind in ("route", "migrate", "dead", "redispatch", "fail"):
        sub = lambda log: [d for d in log if d[0] == kind]
        assert sub(sim_log) == sub(srv_log), f"{kind} decisions diverge"
    assert [d for d in sim_log if d[0] == "dead"] == [("dead", 2)]
    red = [d for d in sim_log if d[0] == "redispatch"]
    assert red == [("redispatch", 0, 3)], red


def test_sim_and_server_parity_with_lost_transfers():
    """Transfer-fault parity: with every wire transfer lost, both drivers
    draw identical per-attempt fates from the seeded injector, so the
    migrate/mig_fail/mig_giveup decision sequences match exactly — and
    both give up after the same number of capped-backoff retries instead
    of spinning."""
    from repro.control.faults import BackoffPolicy, FaultSpec

    spec = FaultSpec(seed=3, transfer_loss_p=1.0)
    lens = [(20, 4000), (8, 4)]
    sim_log, srv_log = _fault_parity_logs(
        spec, lens, crash_step=0, crash_time=0.0, duration=120.0,
        max_steps=6000)

    for kind in ("route", "migrate", "mig_fail", "mig_giveup"):
        sub = lambda log: [d for d in log if d[0] == kind]
        assert sub(sim_log) == sub(srv_log), f"{kind} decisions diverge"
    fails = [d for d in sim_log if d[0] == "mig_fail"]
    assert len(fails) == BackoffPolicy().max_retries + 1, \
        "attempts must be bounded by max_retries + 1"
    assert [d for d in sim_log if d[0] == "mig_giveup"] == [("mig_giveup", 0)]
