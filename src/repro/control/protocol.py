"""The control-plane ↔ backend contract.

The :class:`~repro.control.plane.ControlPlane` makes every scheduling
*decision* (where an arrival goes, which request migrates where, where a
stage boundary sits); the backend owns every *mechanism* (queues, KV
movement, clocks). The split is deliberately timing-free: the core never
sleeps, schedules, or measures time — drivers call into it when their
notion of time advances (a discrete event, a synchronous step) and
execute its callbacks with whatever latency their world has.

Backends supply one :class:`InstanceView` per serving instance and one
:class:`ClusterOps` for cluster-wide actions. Request objects are opaque
to the core: it only sees :class:`ReqView` snapshots the backend builds
(identity + lengths) and hands the ``ref`` back unchanged in callbacks.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Protocol, Tuple, runtime_checkable

# ``ClusterOps.start_migration`` outcomes
MIG_STARTED = "started"      # async transfer in flight; backend will call
                             # ControlPlane.migration_finished(req_id) later
MIG_COMPLETED = "completed"  # synchronous transfer already landed
MIG_FAILED = "failed"        # backend refused (e.g. admission re-check);
                             # the core rolls the negotiation state back


@dataclasses.dataclass(frozen=True)
class ReqView:
    """Point-in-time snapshot of a live request, built by the backend.

    ``ref`` is the backend's own request object — the core treats it as
    an opaque token and passes it back through ``ClusterOps`` calls.

    Prefill progress (chunked-prefill backends): ``ctx_done`` prompt
    tokens are written to cache out of ``ctx_total``. Backends without
    chunked prefill report ``ctx_done == ctx_total`` (the 0/0 default
    also reads as done). A not-yet-done request is live and migratable —
    its KV piece is the ``ctx_done`` written rows, and the receiver
    resumes chunking.
    """
    ref: Any
    req_id: int
    input_len: float
    length: float               # current sequence length
    ctx_done: float = 0.0       # prompt tokens whose KV is written
    ctx_total: float = 0.0      # prompt tokens overall
    # prompt tokens served from the backend's prefix cache (block-aligned,
    # <= ctx_done). Effective — uncached — lengths drive stage routing and
    # queue accounting; migration reservations still use true length,
    # because a migrated shared prefix re-imports as private.
    cached_tokens: float = 0.0
    # SLO service class (repro.sched.slo.SLO_CLASSES). Routing prefers
    # least-queued instances for interactive arrivals, and bid-ask victim
    # selection / receiver queues order by class priority so interactive
    # work is never parked behind batch transfers.
    slo_class: str = "standard"

    @property
    def prefill_done(self) -> bool:
        return self.ctx_done >= self.ctx_total


@runtime_checkable
class InstanceView(Protocol):
    """Read-only window onto one serving instance."""

    id: int

    def load(self) -> float:
        """Scheduling pressure: pinned KV tokens + queued prompt tokens."""
        ...

    def free_tokens(self) -> float:
        """Unpinned KV budget (block-granular where the backend is)."""
        ...

    def used_tokens(self) -> float:
        """KV tokens pinned by running requests."""
        ...

    def queued_tokens(self) -> float:
        """UN-PREFILLED, UNCACHED prompt tokens: whole waiting prompts
        (minus their prefix-cache hit) plus the unwritten remainder of
        requests mid-chunked-prefill. The written part of a partial
        prompt is pinned cache and belongs to ``used_tokens`` — the two
        never count a token twice."""
        ...

    def prefix_digests(self) -> frozenset:
        """Compact advertisement of the instance's prefix cache: the head
        digest (first full block) of every cached chain. Within-stage
        dispatch tie-breaks toward instances advertising an arrival's
        digest; backends without a prefix cache return an empty set."""
        ...

    def requests(self) -> List[ReqView]:
        """Live, migratable requests (backends exclude ones already in a
        backend-level transfer)."""
        ...

    def request_view(self) -> List[Tuple[float, float]]:
        """(input_len, current_len) pairs for boundary refinement."""
        ...

    def has_request(self, ref: Any) -> bool:
        """Is ``ref`` still resident (running, unfinished) here?"""
        ...

    def can_accept(self, ref: Any) -> bool:
        """Admission/flow-control gate: could this instance adopt ``ref``
        right now (slot + memory headroom)? §5: migrations that fail this
        stay on the source."""
        ...

    def all_requests(self) -> List[ReqView]:
        """EVERY resident request — running, waiting, parked — regardless
        of migratability. Dead-instance recovery enumerates these (a
        queued request dies with its instance just as surely as a running
        one). Optional: the core falls back to :meth:`requests` on views
        that predate fault tolerance."""
        ...

    # Optional (resolved via getattr, like the fault-tolerance hooks):
    #
    #   def tiered_digests(self) -> Dict[int, str]
    #
    # Tier-tagged form of :meth:`prefix_digests` for multi-tier KV
    # backends (DESIGN.md §Multi-tier KV): head digest -> "device" |
    # "host". Routing's warm filter prefers device-warm instances (hit
    # is free) over host-warm ones (hit pays a promote price). Views
    # without the hook are treated as all-device, preserving legacy
    # warm-routing bit-for-bit.
    #
    #   def capacity_weight(self) -> float
    #
    # Relative capacity of this instance in homogeneous instance-units
    # (DESIGN.md §Sharded serving): a tp=N tensor-parallel engine returns
    # N — its KV pool is N× deeper and its iteration throughput higher.
    # The control plane divides every load/queue comparison by it and
    # lets one instance satisfy N units of a stage's instance demand.
    # Views without the hook weigh 1.0, preserving legacy behavior
    # bit-for-bit.


@runtime_checkable
class ClusterOps(Protocol):
    """Actions the control plane asks the backend to perform."""

    def dispatch(self, ref: Any, instance_id: int) -> None:
        """Place a new arrival on an instance (routing decision made)."""
        ...

    def start_migration(self, ref: Any, src_id: int, dst_id: int) -> str:
        """Move ``ref``'s KV from ``src_id`` to ``dst_id``. Returns one of
        MIG_STARTED (async; report completion via
        ``ControlPlane.migration_finished``), MIG_COMPLETED (done
        synchronously) or MIG_FAILED (refused; core rolls back)."""
        ...

    def set_boundary(self, stage_idx: int, hi: float) -> None:
        """Observe a refined stage boundary (stage ``stage_idx`` now ends
        at ``hi``). The core owns the authoritative bounds; this hook is
        for backend-side mirrors/telemetry."""
        ...

    # ---- fault tolerance (DESIGN.md §Fault tolerance) --------------------
    # The three hooks below are OPTIONAL: the core resolves them via
    # getattr, and backends that predate fault tolerance simply lose the
    # recovery behaviors (requests on a dead instance are reported failed
    # instead of re-dispatched).

    def redispatch(self, ref: Any, instance_id: int) -> bool:
        """Re-place a request recovered from a dead instance: its KV is
        gone, so the backend must rebuild state by replaying
        ``prompt + generated-so-far`` through (chunked) prefill on
        ``instance_id`` — the same drop-and-recompute machinery
        preemption uses, so the continuation stays bit-identical.
        Returns False when the target cannot replay (e.g. no chunked
        prefill for a mid-decode resume); the core then fails the
        request."""
        ...

    def fail_request(self, ref: Any) -> None:
        """Mark a request permanently failed (retry budget exhausted or
        no healthy replay target): the backend must surface it as
        ``failed`` in its accounting and release any bookkeeping so
        drain loops terminate — a failed request must never hang the
        run."""
        ...

    def instance_down(self, instance_id: int) -> None:
        """The core declared this instance dead. The backend clears the
        carcass (queues, reservations, transfer state) so a later rejoin
        starts from an empty instance. Called after the core snapshots
        the residents it will re-dispatch."""
        ...
