"""The CascadeInfer scheduling core (paper §3–§5), backend-agnostic.

One `ControlPlane` owns every *decision* the paper's control plane makes:

  * length routing — arrivals go round-robin within the earliest covering
    stage (§3.2; bid-ask governs migrations, not dispatch);
  * growth-triggered inter-stage handover with sender/receiver bid-ask
    negotiation, priority pull loop and starvation backpressure (§4.4);
  * intra-stage rebalancing of overloaded instances (§4.4);
  * boundary refinement — adaptive (§4.3) plus the quantity/memory
    ablations of Fig. 15, with monotone-boundary clipping;
  * §5 flow control — a migration starts only if the receiver can admit
    the request *now*, the source is under its concurrency cap, and (for
    step-synchronous drivers) the per-tick budget allows it; otherwise
    the request stays on the source and is retried.

The core holds no clock and performs no I/O: drivers feed it events
(`submit`, `on_instance_iteration`, timer-driven `balance`/`refine`/
`pump_all`, `migration_finished`) and it calls back through `ClusterOps`
(`dispatch`, `start_migration`, `set_boundary`). The discrete-event
simulator and the real multi-engine JAX server are two such drivers —
they execute identical policy code, so sim-validated behavior carries to
the prototype unchanged (ISSUE 2; cf. Helix's sim-first methodology).

Every decision is appended to ``decisions`` — the parity tests diff these
logs across drivers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.control.bidask import (Bid, MigRequest, ReceiverState, SenderState,
                                  is_overloaded, select_receiver)
from repro.control.faults import (HEALTH_ALIVE, HEALTH_DEAD, HEALTH_SUSPECT,
                                  BackoffPolicy)
from repro.control.protocol import (MIG_COMPLETED, MIG_FAILED, MIG_STARTED,
                                    ClusterOps, InstanceView, ReqView)
from repro.control.refinement import (BoundaryRefiner, memory_based_split,
                                      quantity_based_split)
from repro.core.partition import PipelinePlan
from repro.sched.slo import priority_of

POLICIES = ("cascade", "round-robin", "least-loaded")
REFINEMENTS = ("adaptive", "quantity", "memory", "none")   # Fig. 15
BALANCINGS = ("full", "inter-stage", "rr")                 # Fig. 16

_RR_GLOBAL = -2      # round-robin-policy arrival counter
_RR_HANDOVER = -1    # balancing="rr" handover counter


@dataclasses.dataclass
class ControlConfig:
    policy: str = "cascade"
    refinement: str = "adaptive"
    balancing: str = "full"
    # §5 concurrency control: per-source transfers are serialized by the
    # §4.4 sender state machine (at most one outbound in flight); step-
    # synchronous drivers additionally bound moves per tick (begin_tick()).
    max_migrations_per_tick: int = 0     # 0 = uncapped (async drivers)
    seed: int = 0
    # ---- fault tolerance (DESIGN.md §Fault tolerance) ----
    # liveness thresholds, in the DRIVER's clock (the units it passes to
    # heartbeat/check_liveness: sim seconds, server steps). An instance
    # with no heartbeat for suspect_after units stops receiving new work;
    # after dead_after it is declared dead and its residents recovered.
    suspect_after: float = 3.0
    dead_after: float = 6.0
    # retry schedule for failed migrations (receiver refusal, transfer
    # timeout, receiver death) — measured in pump rounds
    mig_backoff: BackoffPolicy = BackoffPolicy()
    # how many times a request may be re-dispatched off dead instances
    # before it is surfaced as failed instead of retried again
    redispatch_budget: int = 2


@dataclasses.dataclass
class StageState:
    lo: float
    hi: float
    instance_ids: List[int]


class ControlPlane:
    def __init__(self, plan: PipelinePlan, qoe, cfg: ControlConfig,
                 ops: ClusterOps, instances: Sequence[InstanceView]):
        assert cfg.policy in POLICIES, cfg.policy
        assert cfg.refinement in REFINEMENTS, cfg.refinement
        assert cfg.balancing in BALANCINGS, cfg.balancing
        self.cfg = cfg
        self.ops = ops
        self.plan = plan
        self.qoe = qoe
        self.rng = np.random.default_rng(cfg.seed)
        self.instances: Dict[int, InstanceView] = {v.id: v for v in instances}
        self._order = [v.id for v in instances]
        # stage assignment: the plan's stages claim instances in order.
        # Claiming is CAPACITY-WEIGHTED (DESIGN.md §Sharded serving): a
        # tp=N engine advertises capacity_weight N and satisfies N units
        # of a stage's num_instances demand, so a plan solved in
        # homogeneous instance-units maps onto a heterogeneous cluster
        # without re-solving the DP. Uniform weight 1 claims exactly one
        # instance per unit — bit-identical to the legacy slicing — and
        # on weighted clusters the last stage absorbs any remainder.
        weights = {i: self._weight(i) for i in self._order}
        uniform = all(w == 1.0 for w in weights.values())
        self.stages: List[StageState] = []
        self.stage_of_instance: Dict[int, int] = {}
        nxt = 0
        for si, st in enumerate(plan.stages):
            if si == len(plan.stages) - 1 and not uniform:
                ids = self._order[nxt:]
                nxt = len(self._order)
            else:
                ids = []
                acc = 0.0
                while nxt < len(self._order) and acc < st.num_instances:
                    ids.append(self._order[nxt])
                    acc += weights[self._order[nxt]]
                    nxt += 1
            self.stages.append(StageState(st.lo, st.hi, ids))
            for i in ids:
                self.stage_of_instance[i] = si
        if uniform:
            need = sum(st.num_instances for st in plan.stages)
            assert need == len(self._order), \
                f"plan uses {need} instances, backend has {len(self._order)}"
        self.refiners = [BoundaryRefiner(qoe, boundary=s.hi)
                         for s in self.stages[:-1]]
        # negotiation state (§4.4)
        self.senders = {i: SenderState(i) for i in self._order}
        self.receivers = {i: ReceiverState(i) for i in self._order}
        self._pending: Dict[int, Tuple[Any, int]] = {}   # req_id -> (ref, src)
        self._dst_of: Dict[int, int] = {}                # in-flight transfers
        self._rr: Dict[int, int] = {}
        self._tick_started = 0
        # ---- fault tolerance (DESIGN.md §Fault tolerance) ----
        # liveness: driver-clock heartbeats; everything starts alive
        self.health: Dict[int, str] = {i: HEALTH_ALIVE for i in self._order}
        self.last_seen: Dict[int, float] = {}
        # migration backoff: pump rounds are the plane's retry clock
        # (pump_all() advances it — every driver already calls that
        # periodically), so legacy drivers that never heartbeat still get
        # working retries
        self._round = 0
        self._mig_fails: Dict[int, int] = {}         # req_id -> failures
        self._mig_not_before: Dict[int, float] = {}  # req_id -> round
        self._mig_banned: set = set()                # gave up migrating
        self._redispatch_count: Dict[int, int] = {}
        # telemetry
        self.migrations = 0
        self.migrations_by_stage: Dict[Tuple[int, int], int] = {}
        self.retries = 0          # failed migration attempts (backoff'd)
        self.redispatches = 0     # dead-instance recoveries performed
        self.failed_ids: set = set()
        self.decisions: List[Tuple] = []

    # ---- observability ------------------------------------------------------
    def bounds(self) -> List[Tuple[float, float]]:
        return [(s.lo, s.hi) for s in self.stages]

    def pending_ids(self) -> set:
        return set(self._pending)

    def instance_health(self) -> Dict[int, str]:
        return dict(self.health)

    # ---- liveness (DESIGN.md §Fault tolerance) ------------------------------
    def _alive(self, iid: int) -> bool:
        return self.health.get(iid, HEALTH_ALIVE) == HEALTH_ALIVE

    def _weight(self, iid: int) -> float:
        """Capacity weight of an instance (optional InstanceView hook,
        DESIGN.md §Sharded serving): a tp=N engine weighs N — its pool
        is N× deeper and its per-iteration throughput higher, so every
        load comparison normalizes by weight. Views without the hook
        weigh 1.0, keeping legacy clusters bit-identical."""
        fn = getattr(self.instances[iid], "capacity_weight", None)
        w = float(fn()) if callable(fn) else 1.0
        return max(w, 1e-9)

    def heartbeat(self, iid: int, now: float) -> None:
        """Driver-reported proof of life. Any heartbeat restores alive;
        a heartbeat from a DEAD instance is a rejoin — the driver must
        have rebuilt/cleared the instance first (`instance_down` wiped
        the old state), and stage coverage re-expands automatically
        because every health filter recomputes per decision."""
        self.last_seen[iid] = now
        state = self.health.get(iid, HEALTH_ALIVE)
        if state != HEALTH_ALIVE:
            self.health[iid] = HEALTH_ALIVE
            if state == HEALTH_DEAD:
                self.decisions.append(("rejoin", iid))

    def check_liveness(self, now: float) -> None:
        """Transition instances whose heartbeats stopped: alive ->
        suspect (stop routing to it) -> dead (expire its offers, recover
        its residents). Thresholds are ControlConfig.suspect_after /
        dead_after in the driver's clock."""
        for iid in self._order:
            if self.health[iid] == HEALTH_DEAD:
                continue
            seen = self.last_seen.get(iid)
            if seen is None:
                self.last_seen[iid] = now    # first observation
                continue
            dt = now - seen
            if dt >= self.cfg.dead_after:
                self._mark_dead(iid)
            elif dt >= self.cfg.suspect_after \
                    and self.health[iid] == HEALTH_ALIVE:
                self.health[iid] = HEALTH_SUSPECT
                self.decisions.append(("suspect", iid))

    def _healthy_stage(self, si: int) -> Tuple[int, List[int]]:
        """Alive instances serving stage ``si``. A stage whose instances
        are all down folds into its neighbors — later stages first (they
        can hold longer sequences), then earlier — so the length
        partition degrades gracefully instead of black-holing a range.
        Returns (effective_stage, ids); ids is empty only when the whole
        cluster is down."""
        ids = [i for i in self.stages[si].instance_ids if self._alive(i)]
        if ids:
            return si, ids
        for sj in (list(range(si + 1, len(self.stages)))
                   + list(range(si - 1, -1, -1))):
            ids = [i for i in self.stages[sj].instance_ids
                   if self._alive(i)]
            if ids:
                return sj, ids
        return si, []

    # ---- routing (§3.2) -----------------------------------------------------
    def stage_for(self, length: float) -> int:
        for i, s in enumerate(self.stages):
            if length < s.hi:
                return i
        return len(self.stages) - 1

    def route(self, req_id: int, length: float, *,
              cached_tokens: float = 0.0,
              prefix_digest: Optional[int] = None,
              promote_cost_tokens: float = 0.0,
              slo_class: str = "standard") -> int:
        """Pure placement decision for one arrival.

        Cache-aware routing (DESIGN.md §Prefix cache): the length that
        matters is the UNCACHED one — a 30K prompt whose first 28K tokens
        are resident somewhere is a short request, so stage selection uses
        ``length - cached_tokens`` (reservations on the chosen backend
        still cover true length). Within the stage, dispatch tie-breaks
        toward instances advertising the request's prefix-head digest, so
        repeat prefixes land where their blocks already live; the stage RR
        counter advances either way, keeping placement deterministic.

        Tier-aware pricing (DESIGN.md §Multi-tier KV): a hit whose blocks
        were demoted to a host tier is NOT free — ``promote_cost_tokens``
        (the h2d staging price in token units, from
        ``kernels.cost.promote_cost_tokens``) is added back to the
        effective length, so a host-tier hit routes as
        ``uncached_tail + promote_cost``. Within the stage, the warm
        filter prefers device-warm instances over host-warm ones via the
        optional ``tiered_digests()`` view hook.

        SLO-aware dispatch (DESIGN.md §SLO scheduling): interactive
        arrivals pick the least-queued instance of the candidate set —
        their TTFT deadline cannot absorb a deep queue RR might assign —
        while standard/batch keep the RR rotation that spreads prefix
        diversity."""
        alive = [i for i in self._order if self._alive(i)] or self._order
        if self.cfg.policy == "round-robin":
            c = self._rr.get(_RR_GLOBAL, 0)
            self._rr[_RR_GLOBAL] = c + 1
            iid = alive[c % len(alive)]
        elif self.cfg.policy == "least-loaded":
            iid = min(alive,
                      key=lambda i: self.instances[i].load() / self._weight(i))
        else:
            si, ids = self._healthy_stage(
                self.stage_for(max(length - cached_tokens
                                   + promote_cost_tokens, 1.0)))
            if not ids:            # whole cluster down: legacy placement
                ids = self.stages[si].instance_ids
            c = self._rr.get(si, 0)
            self._rr[si] = c + 1
            if prefix_digest is not None:
                dev_warm, host_warm = [], []
                for i in ids:
                    view = self.instances[i]
                    fn = getattr(view, "tiered_digests", None)
                    if fn is not None:
                        tier = fn().get(prefix_digest)
                        if tier == "device":
                            dev_warm.append(i)
                        elif tier is not None:
                            host_warm.append(i)
                    elif prefix_digest in view.prefix_digests():
                        dev_warm.append(i)   # untiered views are all-device
                warm = dev_warm or host_warm
                if warm:
                    ids = warm
            if priority_of(slo_class) == 0 and len(ids) > 1:
                iid = min(ids,
                          key=lambda i: (self.instances[i].queued_tokens()
                                         / self._weight(i), i))
            else:
                iid = ids[c % len(ids)]
        self.decisions.append(("route", req_id, iid))
        return iid

    def submit(self, ref: Any, req_id: int, length: float, *,
               cached_tokens: float = 0.0,
               prefix_digest: Optional[int] = None,
               promote_cost_tokens: float = 0.0,
               slo_class: str = "standard") -> int:
        """Route an arrival and hand it to the backend."""
        iid = self.route(req_id, length, cached_tokens=cached_tokens,
                         prefix_digest=prefix_digest,
                         promote_cost_tokens=promote_cost_tokens,
                         slo_class=slo_class)
        self.ops.dispatch(ref, iid)
        return iid

    # ---- growth-triggered handover (§3.2) -----------------------------------
    def on_instance_iteration(self, inst_id: int) -> None:
        """Offer every request that outgrew its stage to the next stage."""
        if self.cfg.policy != "cascade" or not self._alive(inst_id):
            return                 # a dead instance's view is stale
        si = self.stage_of_instance[inst_id]
        hi = self.stages[si].hi
        if hi == float("inf"):
            return
        for rv in self.instances[inst_id].requests():
            if rv.length >= hi and rv.req_id not in self._pending:
                nxt = min(si + 1, len(self.stages) - 1)
                _, cands = self._healthy_stage(nxt)
                self._offer(inst_id, rv, cands)

    def handover_all(self) -> None:
        for iid in self._order:
            self.on_instance_iteration(iid)

    def begin_tick(self) -> None:
        """Step-synchronous drivers: reset the per-tick migration budget."""
        self._tick_started = 0

    def _tick_ok(self) -> bool:
        return (self.cfg.max_migrations_per_tick <= 0
                or self._tick_started < self.cfg.max_migrations_per_tick)

    # ---- bid-ask negotiation (§4.4) -----------------------------------------
    def _offer(self, src_id: int, rv: ReqView,
               candidate_ids: Sequence[int]) -> None:
        if not self._mig_ready(rv.req_id):
            return                 # banned, or backing off after failures
        sender = self.senders[src_id]
        mig = MigRequest(rv.req_id, int(rv.length), src_id,
                         slo_priority=priority_of(rv.slo_class))
        sender.offer(mig)
        self._pending[rv.req_id] = (rv.ref, src_id)
        cands = [self.instances[i] for i in candidate_ids
                 if i != src_id and self._alive(i)
                 and self.instances[i].can_accept(rv.ref)]
        if self.cfg.balancing == "rr":
            # Fig.-16 ablation: hand over round-robin, no negotiation
            c = self._rr.get(_RR_HANDOVER, 0)
            self._rr[_RR_HANDOVER] = c + 1
            rid = cands[c % len(cands)].id if cands else None
        else:
            bids = [Bid(c.id, c.load() / self._weight(c.id),
                        self.receivers[c.id].earliest_start(),
                        int(self.rng.integers(0, 1 << 30)))
                    for c in cands]
            rid = select_receiver(bids)
        if rid is None:
            sender.drop(mig.req_id)
            self._pending.pop(rv.req_id, None)
            return
        self.receivers[rid].win(mig)
        self._pump(rid)

    # ---- receiver pull loop -------------------------------------------------
    def _sender_busy(self, src_id: int) -> bool:
        return self.senders[src_id].transmitting is not None

    def _pump(self, rid: int) -> None:
        recv = self.receivers[rid]
        self._unwedge(recv)
        if recv.waiting_for is not None:
            # §4.4 starvation: this receiver is committed to the starved
            # request and next_pull stays blocked until it lands — so the
            # pump must try that transfer directly (the sender's
            # starved-first gate admits it as soon as it is free);
            # otherwise sender and receiver deadlock on each other
            req_id = recv.waiting_for
            if not self._mig_ready(req_id):
                return               # blocked AND backing off: wait it out
            mig = recv.take(req_id)          # clears the block
            if mig is None:
                return
            if not self._begin_transfer(mig, rid):
                recv.win(mig)
                recv.waiting_for = req_id    # still blocked: sender busy
            return
        deferred: List[MigRequest] = []      # backoff-gated, re-queued below
        try:
            while True:
                mig, starved = recv.next_pull(self._sender_busy)
                if starved is not None:
                    entry = self._pending.get(starved)
                    if entry is not None:
                        self.senders[entry[1]].mark_starved(starved)
                if mig is None:
                    return
                if not self._mig_ready(mig.req_id):
                    if mig.req_id in self._mig_banned:
                        # retry budget exhausted: cancel the negotiation,
                        # the request completes on its source
                        entry = self._pending.pop(mig.req_id, None)
                        if entry is not None:
                            self.senders[entry[1]].drop(mig.req_id)
                        continue
                    # backing off: skip WITHOUT a starvation fail, try the
                    # next queued offer
                    deferred.append(mig)
                    continue
                if not self._begin_transfer(mig, rid):
                    recv.win(mig)      # put back; retry on next pump
                    return
        finally:
            for m in deferred:
                recv.win(m)

    def pump_all(self) -> None:
        # one pump round = one unit of the migration-backoff clock
        self._round += 1
        for rid in self._order:
            if len(self.receivers[rid]):
                self._pump(rid)

    def _unwedge(self, recv: ReceiverState) -> None:
        """A receiver blocked on a starved request stays blocked until that
        request transfers — but the request may instead have *finished* on
        its source. Drop such stale blocks so the pull loop keeps flowing."""
        req_id = recv.waiting_for
        if req_id is None:
            return
        entry = self._pending.get(req_id)
        if entry is None:
            recv.take(req_id)          # finalized elsewhere: drop the win
            return
        ref, src_id = entry
        if not self.instances[src_id].has_request(ref):
            self.senders[src_id].drop(req_id)
            self._pending.pop(req_id, None)
            recv.take(req_id)

    # ---- migration retry backoff (DESIGN.md §Fault tolerance) ---------------
    def _mig_ready(self, req_id: int) -> bool:
        """May this request attempt (or be offered for) a migration now?
        False while banned or inside its backoff window."""
        if req_id in self._mig_banned:
            return False
        return self._round >= self._mig_not_before.get(req_id, 0)

    def _note_mig_failure(self, req_id: int) -> bool:
        """Record a counted migration failure (receiver refusal, wire
        timeout, receiver death — NOT benign sender-busy / tick-budget
        defers). Returns True when the retry budget is exhausted: the
        request is permanently banned from migrating (it completes on
        its source), which is the strict no-spin bound — total attempts
        are <= max_retries + 1."""
        self.retries += 1
        n = self._mig_fails.get(req_id, 0) + 1
        self._mig_fails[req_id] = n
        pol = self.cfg.mig_backoff
        if n > pol.max_retries:
            self._mig_banned.add(req_id)
            self._mig_not_before.pop(req_id, None)
            self.decisions.append(("mig_giveup", req_id))
            return True
        self._mig_not_before[req_id] = self._round + pol.delay(n)
        return False

    def _cancel_offer(self, req_id: int) -> None:
        """Unwind a live negotiation without penalizing the request."""
        entry = self._pending.pop(req_id, None)
        if entry is not None:
            self.senders[entry[1]].drop(req_id)

    def _begin_transfer(self, mig: MigRequest, dst_id: int) -> bool:
        """Returns True when the pull was consumed (transfer started, the
        offer was stale, or the negotiation was cancelled), False when
        the receiver should retry later."""
        entry = self._pending.get(mig.req_id)
        if entry is None:
            return True                # already finalized elsewhere
        ref, src_id = entry
        src = self.instances[src_id]
        dst = self.instances[dst_id]
        sender = self.senders[src_id]
        if not src.has_request(ref):   # finished before the transfer began
            sender.drop(mig.req_id)
            self._pending.pop(mig.req_id, None)
            return True
        if not sender.can_transmit(mig.req_id):
            return False               # benign defer: no backoff penalty
        if not self._tick_ok():
            return False               # benign defer: budget resets next tick
        # §5 flow control: stay on the source unless the receiver is alive
        # and can admit the request right now. A refusal here is a COUNTED
        # failure (unlike the defers above): retries run through the
        # capped exponential backoff, and past the budget the negotiation
        # is cancelled for good.
        if not self._alive(dst_id) or not dst.can_accept(ref):
            if self._note_mig_failure(mig.req_id):
                self._cancel_offer(mig.req_id)
                return True            # consumed: banned, stays on source
            return False
        sender.begin(mig.req_id)
        self._tick_started += 1
        status = self.ops.start_migration(ref, src_id, dst_id)
        if status == MIG_FAILED:
            sender.abort(mig.req_id)
            self._tick_started -= 1
            if self._note_mig_failure(mig.req_id):
                self._cancel_offer(mig.req_id)
                return True
            return False
        assert status in (MIG_STARTED, MIG_COMPLETED), status
        self.decisions.append(("migrate", mig.req_id, src_id, dst_id))
        self._dst_of[mig.req_id] = dst_id
        if status == MIG_COMPLETED:
            self._finalize(mig.req_id, arrived=True)
        return True

    def migration_finished(self, req_id: int, arrived: bool = True) -> None:
        """Async backends report a transfer's end here: ``arrived`` tells
        whether the request landed on the receiver, or the move was
        dropped because the request finished mid-flight."""
        dst_id = self._finalize(req_id, arrived)
        if dst_id is not None:
            self._pump(dst_id)

    def _finalize(self, req_id: int, arrived: bool) -> Optional[int]:
        dst_id = self._dst_of.pop(req_id, None)
        entry = self._pending.pop(req_id, None)
        if entry is not None:
            src_id = entry[1]
            self.senders[src_id].finish(req_id)
            if dst_id is not None and arrived:
                key = (self.stage_of_instance[src_id],
                       self.stage_of_instance[dst_id])
                self.migrations += 1
                self.migrations_by_stage[key] = \
                    self.migrations_by_stage.get(key, 0) + 1
        if dst_id is not None:
            self.receivers[dst_id].complete(req_id)
        # the negotiation ended: earlier refusal penalties are moot
        self._mig_fails.pop(req_id, None)
        self._mig_not_before.pop(req_id, None)
        return dst_id

    # ---- failure handling (DESIGN.md §Fault tolerance) ----------------------
    def migration_failed(self, req_id: int) -> None:
        """Backend/driver reports that a STARTED transfer will never land
        (wire timeout, lost payload, receiver died mid-flight). Rolls the
        negotiation back so the request survives on its source, applies
        the retry backoff, and wakes the receiver. Idempotent — a late
        timeout racing a completed transfer is a no-op."""
        dst_id = self._dst_of.pop(req_id, None)
        entry = self._pending.pop(req_id, None)
        if entry is None and dst_id is None:
            return                     # already settled elsewhere
        if entry is not None:
            sender = self.senders[entry[1]]
            if sender.transmitting == req_id:
                sender.finish(req_id)  # frees the (serialized) uplink
            else:
                sender.drop(req_id)
        if dst_id is not None:
            self.receivers[dst_id].complete(req_id)
        self.decisions.append(("mig_fail", req_id))
        self._note_mig_failure(req_id)
        if dst_id is not None:
            self._pump(dst_id)

    def _mark_dead(self, iid: int) -> None:
        """Liveness declared this instance dead: fail its in-flight
        transfers, expire its bid-ask offers, reset its negotiation
        state, then recover every resident request."""
        self.health[iid] = HEALTH_DEAD
        self.decisions.append(("dead", iid))
        # in-flight transfers touching the dead instance fail — either
        # endpoint of the wire is gone
        for req_id in [r for r, d in list(self._dst_of.items())
                       if d == iid
                       or self._pending.get(r, (None, None))[1] == iid]:
            self.migration_failed(req_id)
        # won-but-unstarted offers destined HERE return to their senders
        for mig in self.receivers[iid].drain():
            self._cancel_offer(mig.req_id)
        # offers sourced here vanish with the instance, wherever queued
        for req_id in [r for r, (_, s) in list(self._pending.items())
                       if s == iid]:
            self._pending.pop(req_id, None)
            for recv in self.receivers.values():
                recv.take(req_id)
        self.senders[iid] = SenderState(iid)
        self.receivers[iid] = ReceiverState(iid)
        # recover residents: snapshot BEFORE the backend clears the
        # carcass (all_requests when the view has it — queued/parked
        # requests die with their instance just like running ones)
        view = self.instances[iid]
        allreq = getattr(view, "all_requests", None)
        residents = list(allreq() if callable(allreq) else view.requests())
        down = getattr(self.ops, "instance_down", None)
        if callable(down):
            down(iid)
        for rv in residents:
            self._redispatch(rv)

    def _redispatch(self, rv: ReqView) -> None:
        """Recover one resident of a dead instance. Its KV is gone, so
        the backend must replay prompt + generated-so-far on a healthy
        instance (ClusterOps.redispatch). Over the budget — or with no
        healthy target, or a backend without the hook — the request
        surfaces as failed instead of hanging the run."""
        rid = rv.req_id
        # fresh life: migration penalties died with the instance
        self._mig_fails.pop(rid, None)
        self._mig_not_before.pop(rid, None)
        self._mig_banned.discard(rid)
        n = self._redispatch_count.get(rid, 0) + 1
        self._redispatch_count[rid] = n
        redo = getattr(self.ops, "redispatch", None)
        si, ids = self._healthy_stage(self.stage_for(max(rv.length, 1.0)))
        if n > self.cfg.redispatch_budget or not callable(redo) or not ids:
            self._fail(rv)
            return
        c = self._rr.get(si, 0)        # shared stage RR counter: parity-
        self._rr[si] = c + 1           # deterministic across backends
        iid = ids[c % len(ids)]
        self.decisions.append(("redispatch", rid, iid))
        if redo(rv.ref, iid):
            self.redispatches += 1
        else:
            self._fail(rv)             # target cannot replay this request

    def _fail(self, rv: ReqView) -> None:
        self.failed_ids.add(rv.req_id)
        self.decisions.append(("fail", rv.req_id))
        fail = getattr(self.ops, "fail_request", None)
        if callable(fail):
            fail(rv.ref)

    # ---- intra-stage rebalancing (§4.4) -------------------------------------
    def balance(self) -> None:
        if self.cfg.policy != "cascade" or self.cfg.balancing != "full":
            return
        for stage in self.stages:
            ids = [i for i in stage.instance_ids if self._alive(i)]
            if len(ids) < 2:
                continue
            # weight-normalized: a tp=4 engine at 4× the raw tokens of a
            # tp=1 peer is equally loaded, not overloaded
            loads = {i: self.instances[i].load() / self._weight(i)
                     for i in ids}
            for i in ids:
                peers = [l for j, l in loads.items() if j != i]
                if not is_overloaded(loads[i], peers):
                    continue
                cands = [rv for rv in self.instances[i].requests()
                         if rv.req_id not in self._pending]
                if not cands:
                    continue
                # memory-aware AND SLO-aware: among the migratable
                # requests, move the largest KV footprint of the LOWEST
                # service class first (batch before standard before
                # interactive) — rebalancing should never add transfer
                # latency to a tight-deadline request while batch work is
                # available to move
                victim = max(cands, key=lambda rv: (priority_of(rv.slo_class),
                                                    rv.length))
                self._offer(i, victim, [j for j in ids if j != i])

    # ---- boundary refinement (§4.3, Fig. 15) --------------------------------
    def refine(self) -> None:
        if self.cfg.policy != "cascade" or self.cfg.refinement == "none":
            return
        if self.cfg.refinement == "adaptive" and self.qoe is None:
            return
        for bi in range(len(self.stages) - 1):
            own = [rv for i in self.stages[bi].instance_ids
                   if self._alive(i)        # dead views are stale
                   for rv in self.instances[i].request_view()]
            succ = [self.instances[i].request_view()
                    for i in self.stages[bi + 1].instance_ids
                    if self._alive(i)]
            if self.cfg.refinement == "adaptive":
                b = self.refiners[bi].refine(own, succ)
            else:
                merged = own + [r for s in succ for r in s]
                if len(merged) < self.refiners[bi].min_requests:
                    continue
                if self.cfg.refinement == "quantity":
                    b = quantity_based_split(merged)
                else:
                    b = memory_based_split(merged)
                self.refiners[bi].boundary = b
            # keep boundaries monotone across stages
            lo = self.stages[bi].lo
            hi_next = self.stages[bi + 1].hi
            b = max(float(b), lo + 1.0)
            if hi_next != float("inf"):
                b = min(b, hi_next - 1.0)
            self.stages[bi].hi = b
            self.stages[bi + 1].lo = b
            self.decisions.append(("boundary", bi, b))
            self.ops.set_boundary(bi, b)
