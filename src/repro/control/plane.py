"""The CascadeInfer scheduling core (paper §3–§5), backend-agnostic.

One `ControlPlane` owns every *decision* the paper's control plane makes:

  * length routing — arrivals go round-robin within the earliest covering
    stage (§3.2; bid-ask governs migrations, not dispatch);
  * growth-triggered inter-stage handover with sender/receiver bid-ask
    negotiation, priority pull loop and starvation backpressure (§4.4);
  * intra-stage rebalancing of overloaded instances (§4.4);
  * boundary refinement — adaptive (§4.3) plus the quantity/memory
    ablations of Fig. 15, with monotone-boundary clipping;
  * §5 flow control — a migration starts only if the receiver can admit
    the request *now*, the source is under its concurrency cap, and (for
    step-synchronous drivers) the per-tick budget allows it; otherwise
    the request stays on the source and is retried.

The core holds no clock and performs no I/O: drivers feed it events
(`submit`, `on_instance_iteration`, timer-driven `balance`/`refine`/
`pump_all`, `migration_finished`) and it calls back through `ClusterOps`
(`dispatch`, `start_migration`, `set_boundary`). The discrete-event
simulator and the real multi-engine JAX server are two such drivers —
they execute identical policy code, so sim-validated behavior carries to
the prototype unchanged (ISSUE 2; cf. Helix's sim-first methodology).

Every decision is appended to ``decisions`` — the parity tests diff these
logs across drivers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.control.bidask import (Bid, MigRequest, ReceiverState, SenderState,
                                  is_overloaded, select_receiver)
from repro.control.protocol import (MIG_COMPLETED, MIG_FAILED, MIG_STARTED,
                                    ClusterOps, InstanceView, ReqView)
from repro.control.refinement import (BoundaryRefiner, memory_based_split,
                                      quantity_based_split)
from repro.core.partition import PipelinePlan
from repro.sched.slo import priority_of

POLICIES = ("cascade", "round-robin", "least-loaded")
REFINEMENTS = ("adaptive", "quantity", "memory", "none")   # Fig. 15
BALANCINGS = ("full", "inter-stage", "rr")                 # Fig. 16

_RR_GLOBAL = -2      # round-robin-policy arrival counter
_RR_HANDOVER = -1    # balancing="rr" handover counter


@dataclasses.dataclass
class ControlConfig:
    policy: str = "cascade"
    refinement: str = "adaptive"
    balancing: str = "full"
    # §5 concurrency control: per-source transfers are serialized by the
    # §4.4 sender state machine (at most one outbound in flight); step-
    # synchronous drivers additionally bound moves per tick (begin_tick()).
    max_migrations_per_tick: int = 0     # 0 = uncapped (async drivers)
    seed: int = 0


@dataclasses.dataclass
class StageState:
    lo: float
    hi: float
    instance_ids: List[int]


class ControlPlane:
    def __init__(self, plan: PipelinePlan, qoe, cfg: ControlConfig,
                 ops: ClusterOps, instances: Sequence[InstanceView]):
        assert cfg.policy in POLICIES, cfg.policy
        assert cfg.refinement in REFINEMENTS, cfg.refinement
        assert cfg.balancing in BALANCINGS, cfg.balancing
        self.cfg = cfg
        self.ops = ops
        self.plan = plan
        self.qoe = qoe
        self.rng = np.random.default_rng(cfg.seed)
        self.instances: Dict[int, InstanceView] = {v.id: v for v in instances}
        self._order = [v.id for v in instances]
        # stage assignment: the plan's stages claim instances in order
        self.stages: List[StageState] = []
        self.stage_of_instance: Dict[int, int] = {}
        nxt = 0
        for si, st in enumerate(plan.stages):
            ids = self._order[nxt:nxt + st.num_instances]
            nxt += st.num_instances
            self.stages.append(StageState(st.lo, st.hi, ids))
            for i in ids:
                self.stage_of_instance[i] = si
        assert nxt == len(self._order), \
            f"plan uses {nxt} instances, backend has {len(self._order)}"
        self.refiners = [BoundaryRefiner(qoe, boundary=s.hi)
                         for s in self.stages[:-1]]
        # negotiation state (§4.4)
        self.senders = {i: SenderState(i) for i in self._order}
        self.receivers = {i: ReceiverState(i) for i in self._order}
        self._pending: Dict[int, Tuple[Any, int]] = {}   # req_id -> (ref, src)
        self._dst_of: Dict[int, int] = {}                # in-flight transfers
        self._rr: Dict[int, int] = {}
        self._tick_started = 0
        # telemetry
        self.migrations = 0
        self.migrations_by_stage: Dict[Tuple[int, int], int] = {}
        self.decisions: List[Tuple] = []

    # ---- observability ------------------------------------------------------
    def bounds(self) -> List[Tuple[float, float]]:
        return [(s.lo, s.hi) for s in self.stages]

    def pending_ids(self) -> set:
        return set(self._pending)

    # ---- routing (§3.2) -----------------------------------------------------
    def stage_for(self, length: float) -> int:
        for i, s in enumerate(self.stages):
            if length < s.hi:
                return i
        return len(self.stages) - 1

    def route(self, req_id: int, length: float, *,
              cached_tokens: float = 0.0,
              prefix_digest: Optional[int] = None,
              slo_class: str = "standard") -> int:
        """Pure placement decision for one arrival.

        Cache-aware routing (DESIGN.md §Prefix cache): the length that
        matters is the UNCACHED one — a 30K prompt whose first 28K tokens
        are resident somewhere is a short request, so stage selection uses
        ``length - cached_tokens`` (reservations on the chosen backend
        still cover true length). Within the stage, dispatch tie-breaks
        toward instances advertising the request's prefix-head digest, so
        repeat prefixes land where their blocks already live; the stage RR
        counter advances either way, keeping placement deterministic.

        SLO-aware dispatch (DESIGN.md §SLO scheduling): interactive
        arrivals pick the least-queued instance of the candidate set —
        their TTFT deadline cannot absorb a deep queue RR might assign —
        while standard/batch keep the RR rotation that spreads prefix
        diversity."""
        if self.cfg.policy == "round-robin":
            c = self._rr.get(_RR_GLOBAL, 0)
            self._rr[_RR_GLOBAL] = c + 1
            iid = self._order[c % len(self._order)]
        elif self.cfg.policy == "least-loaded":
            iid = min(self._order, key=lambda i: self.instances[i].load())
        else:
            si = self.stage_for(max(length - cached_tokens, 1.0))
            ids = self.stages[si].instance_ids
            c = self._rr.get(si, 0)
            self._rr[si] = c + 1
            if prefix_digest is not None:
                warm = [i for i in ids
                        if prefix_digest in self.instances[i].prefix_digests()]
                if warm:
                    ids = warm
            if priority_of(slo_class) == 0 and len(ids) > 1:
                iid = min(ids,
                          key=lambda i: (self.instances[i].queued_tokens(), i))
            else:
                iid = ids[c % len(ids)]
        self.decisions.append(("route", req_id, iid))
        return iid

    def submit(self, ref: Any, req_id: int, length: float, *,
               cached_tokens: float = 0.0,
               prefix_digest: Optional[int] = None,
               slo_class: str = "standard") -> int:
        """Route an arrival and hand it to the backend."""
        iid = self.route(req_id, length, cached_tokens=cached_tokens,
                         prefix_digest=prefix_digest, slo_class=slo_class)
        self.ops.dispatch(ref, iid)
        return iid

    # ---- growth-triggered handover (§3.2) -----------------------------------
    def on_instance_iteration(self, inst_id: int) -> None:
        """Offer every request that outgrew its stage to the next stage."""
        if self.cfg.policy != "cascade":
            return
        si = self.stage_of_instance[inst_id]
        hi = self.stages[si].hi
        if hi == float("inf"):
            return
        for rv in self.instances[inst_id].requests():
            if rv.length >= hi and rv.req_id not in self._pending:
                nxt = min(si + 1, len(self.stages) - 1)
                self._offer(inst_id, rv, self.stages[nxt].instance_ids)

    def handover_all(self) -> None:
        for iid in self._order:
            self.on_instance_iteration(iid)

    def begin_tick(self) -> None:
        """Step-synchronous drivers: reset the per-tick migration budget."""
        self._tick_started = 0

    def _tick_ok(self) -> bool:
        return (self.cfg.max_migrations_per_tick <= 0
                or self._tick_started < self.cfg.max_migrations_per_tick)

    # ---- bid-ask negotiation (§4.4) -----------------------------------------
    def _offer(self, src_id: int, rv: ReqView,
               candidate_ids: Sequence[int]) -> None:
        sender = self.senders[src_id]
        mig = MigRequest(rv.req_id, int(rv.length), src_id,
                         slo_priority=priority_of(rv.slo_class))
        sender.offer(mig)
        self._pending[rv.req_id] = (rv.ref, src_id)
        cands = [self.instances[i] for i in candidate_ids
                 if i != src_id and self.instances[i].can_accept(rv.ref)]
        if self.cfg.balancing == "rr":
            # Fig.-16 ablation: hand over round-robin, no negotiation
            c = self._rr.get(_RR_HANDOVER, 0)
            self._rr[_RR_HANDOVER] = c + 1
            rid = cands[c % len(cands)].id if cands else None
        else:
            bids = [Bid(c.id, c.load(),
                        self.receivers[c.id].earliest_start(),
                        int(self.rng.integers(0, 1 << 30)))
                    for c in cands]
            rid = select_receiver(bids)
        if rid is None:
            sender.drop(mig.req_id)
            self._pending.pop(rv.req_id, None)
            return
        self.receivers[rid].win(mig)
        self._pump(rid)

    # ---- receiver pull loop -------------------------------------------------
    def _sender_busy(self, src_id: int) -> bool:
        return self.senders[src_id].transmitting is not None

    def _pump(self, rid: int) -> None:
        recv = self.receivers[rid]
        self._unwedge(recv)
        if recv.waiting_for is not None:
            # §4.4 starvation: this receiver is committed to the starved
            # request and next_pull stays blocked until it lands — so the
            # pump must try that transfer directly (the sender's
            # starved-first gate admits it as soon as it is free);
            # otherwise sender and receiver deadlock on each other
            req_id = recv.waiting_for
            mig = recv.take(req_id)          # clears the block
            if mig is None:
                return
            if not self._begin_transfer(mig, rid):
                recv.win(mig)
                recv.waiting_for = req_id    # still blocked: sender busy
            return
        while True:
            mig, starved = recv.next_pull(self._sender_busy)
            if starved is not None:
                entry = self._pending.get(starved)
                if entry is not None:
                    self.senders[entry[1]].mark_starved(starved)
            if mig is None:
                return
            if not self._begin_transfer(mig, rid):
                recv.win(mig)          # put back; retry on next pump
                return

    def pump_all(self) -> None:
        for rid in self._order:
            if len(self.receivers[rid]):
                self._pump(rid)

    def _unwedge(self, recv: ReceiverState) -> None:
        """A receiver blocked on a starved request stays blocked until that
        request transfers — but the request may instead have *finished* on
        its source. Drop such stale blocks so the pull loop keeps flowing."""
        req_id = recv.waiting_for
        if req_id is None:
            return
        entry = self._pending.get(req_id)
        if entry is None:
            recv.take(req_id)          # finalized elsewhere: drop the win
            return
        ref, src_id = entry
        if not self.instances[src_id].has_request(ref):
            self.senders[src_id].drop(req_id)
            self._pending.pop(req_id, None)
            recv.take(req_id)

    def _begin_transfer(self, mig: MigRequest, dst_id: int) -> bool:
        """Returns True when the pull was consumed (transfer started or the
        offer was stale), False when the receiver should retry later."""
        entry = self._pending.get(mig.req_id)
        if entry is None:
            return True                # already finalized elsewhere
        ref, src_id = entry
        src = self.instances[src_id]
        dst = self.instances[dst_id]
        sender = self.senders[src_id]
        if not src.has_request(ref):   # finished before the transfer began
            sender.drop(mig.req_id)
            self._pending.pop(mig.req_id, None)
            return True
        if not sender.can_transmit(mig.req_id):
            return False
        # §5 flow control: stay on the source unless the receiver can admit
        # the request right now and the migration budget allows the move
        if not self._tick_ok() or not dst.can_accept(ref):
            return False
        sender.begin(mig.req_id)
        self._tick_started += 1
        status = self.ops.start_migration(ref, src_id, dst_id)
        if status == MIG_FAILED:
            sender.abort(mig.req_id)
            self._tick_started -= 1
            return False
        assert status in (MIG_STARTED, MIG_COMPLETED), status
        self.decisions.append(("migrate", mig.req_id, src_id, dst_id))
        self._dst_of[mig.req_id] = dst_id
        if status == MIG_COMPLETED:
            self._finalize(mig.req_id, arrived=True)
        return True

    def migration_finished(self, req_id: int, arrived: bool = True) -> None:
        """Async backends report a transfer's end here: ``arrived`` tells
        whether the request landed on the receiver, or the move was
        dropped because the request finished mid-flight."""
        dst_id = self._finalize(req_id, arrived)
        if dst_id is not None:
            self._pump(dst_id)

    def _finalize(self, req_id: int, arrived: bool) -> Optional[int]:
        dst_id = self._dst_of.pop(req_id, None)
        entry = self._pending.pop(req_id, None)
        if entry is not None:
            src_id = entry[1]
            self.senders[src_id].finish(req_id)
            if dst_id is not None and arrived:
                key = (self.stage_of_instance[src_id],
                       self.stage_of_instance[dst_id])
                self.migrations += 1
                self.migrations_by_stage[key] = \
                    self.migrations_by_stage.get(key, 0) + 1
        if dst_id is not None:
            self.receivers[dst_id].complete(req_id)
        return dst_id

    # ---- intra-stage rebalancing (§4.4) -------------------------------------
    def balance(self) -> None:
        if self.cfg.policy != "cascade" or self.cfg.balancing != "full":
            return
        for stage in self.stages:
            ids = stage.instance_ids
            if len(ids) < 2:
                continue
            loads = {i: self.instances[i].load() for i in ids}
            for i in ids:
                peers = [l for j, l in loads.items() if j != i]
                if not is_overloaded(loads[i], peers):
                    continue
                cands = [rv for rv in self.instances[i].requests()
                         if rv.req_id not in self._pending]
                if not cands:
                    continue
                # memory-aware AND SLO-aware: among the migratable
                # requests, move the largest KV footprint of the LOWEST
                # service class first (batch before standard before
                # interactive) — rebalancing should never add transfer
                # latency to a tight-deadline request while batch work is
                # available to move
                victim = max(cands, key=lambda rv: (priority_of(rv.slo_class),
                                                    rv.length))
                self._offer(i, victim, [j for j in ids if j != i])

    # ---- boundary refinement (§4.3, Fig. 15) --------------------------------
    def refine(self) -> None:
        if self.cfg.policy != "cascade" or self.cfg.refinement == "none":
            return
        if self.cfg.refinement == "adaptive" and self.qoe is None:
            return
        for bi in range(len(self.stages) - 1):
            own = [rv for i in self.stages[bi].instance_ids
                   for rv in self.instances[i].request_view()]
            succ = [self.instances[i].request_view()
                    for i in self.stages[bi + 1].instance_ids]
            if self.cfg.refinement == "adaptive":
                b = self.refiners[bi].refine(own, succ)
            else:
                merged = own + [r for s in succ for r in s]
                if len(merged) < self.refiners[bi].min_requests:
                    continue
                if self.cfg.refinement == "quantity":
                    b = quantity_based_split(merged)
                else:
                    b = memory_based_split(merged)
                self.refiners[bi].boundary = b
            # keep boundaries monotone across stages
            lo = self.stages[bi].lo
            hi_next = self.stages[bi + 1].hi
            b = max(float(b), lo + 1.0)
            if hi_next != float("inf"):
                b = min(b, hi_next - 1.0)
            self.stages[bi].hi = b
            self.stages[bi + 1].lo = b
            self.decisions.append(("boundary", bi, b))
            self.ops.set_boundary(bi, b)
