"""Decentralized bid-ask load (re)balancing (paper §4.4).

Market-style pairwise negotiation: an overloaded *sender* asks; candidate
*receivers* bid with (current load, earliest transmission start time); the
sender filters out the higher-load half, keeps the three earliest starters,
and takes the first reply. Won requests sit in the receiver's priority
queue (priority = sender load); a starvation counter triggers sender-side
backpressure after ``starvation_threshold`` failed pulls.

The protocol is implemented as pure decision functions + small state
machines so the discrete-event simulator and the real in-process server
drive the same code.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

OVERLOAD_FACTOR = 1.25          # §4.4: 25% above stage average triggers
STARVATION_THRESHOLD = 3
KEEP_EARLIEST = 3


@dataclasses.dataclass(frozen=True)
class Bid:
    receiver_id: int
    load: float                  # receiver's current load
    earliest_start: float        # buffered work / measured throughput
    reply_order: int             # arrival order of the reply


@dataclasses.dataclass
class MigRequest:
    req_id: int
    seq_len: int                 # tokens to transfer (KV volume)
    src: int
    priority: float = 0.0        # sender load at ask time
    dst: Optional[int] = None
    # SLO class priority (repro.sched.slo: 0=interactive .. 2=batch).
    # Receivers pull lower values first so an interactive migration is
    # never parked behind a batch transfer of higher sender load.
    slo_priority: int = 1


def select_receiver(bids: Sequence[Bid]) -> Optional[int]:
    """§4.4 selection: drop the higher-load half, keep the 3 earliest
    transmission starts, pick the first replier."""
    if not bids:
        return None
    by_load = sorted(bids, key=lambda b: (b.load, b.reply_order))
    keep = by_load[:max(1, (len(by_load) + 1) // 2)]
    by_start = sorted(keep, key=lambda b: (b.earliest_start, b.reply_order))
    finalists = by_start[:KEEP_EARLIEST]
    return min(finalists, key=lambda b: b.reply_order).receiver_id


def is_overloaded(own_load: float, peer_loads: Sequence[float],
                  factor: float = OVERLOAD_FACTOR) -> bool:
    """Overloaded-outlier test: load ≥ factor × stage average."""
    loads = list(peer_loads) + [own_load]
    avg = sum(loads) / len(loads)
    return avg > 0 and own_load >= factor * avg


class SenderState:
    """Buffers requests awaiting migration; at most one in flight."""

    def __init__(self, instance_id: int):
        self.instance_id = instance_id
        self.buffer: Dict[int, MigRequest] = {}
        self.transmitting: Optional[int] = None
        self.starved: List[int] = []      # receiver-flagged, send-next queue

    def load(self) -> float:
        """Piggybacked on asks; also the priority receivers queue with."""
        return float(sum(r.seq_len for r in self.buffer.values()))

    def offer(self, req: MigRequest) -> MigRequest:
        req.priority = self.load() + req.seq_len
        self.buffer[req.req_id] = req
        return req

    def can_transmit(self, req_id: int) -> bool:
        if self.transmitting is not None:
            return False
        if self.starved and req_id != self.starved[0]:
            return False              # backpressure: starved request first
        return req_id in self.buffer

    def begin(self, req_id: int) -> MigRequest:
        assert self.can_transmit(req_id)
        self.transmitting = req_id
        if self.starved and self.starved[0] == req_id:
            self.starved.pop(0)
        return self.buffer[req_id]

    def finish(self, req_id: int) -> None:
        assert self.transmitting == req_id
        self.transmitting = None
        self.buffer.pop(req_id, None)

    def abort(self, req_id: int) -> None:
        """Roll back a ``begin`` whose transfer never started; the request
        stays buffered for a later retry."""
        assert self.transmitting == req_id
        self.transmitting = None

    def mark_starved(self, req_id: int) -> None:
        if req_id in self.buffer and req_id not in self.starved:
            self.starved.append(req_id)

    def drop(self, req_id: int) -> None:
        """Remove a request that will never transmit (finished or evicted
        before its transfer began). Clearing it from ``starved`` matters:
        a stale head entry would block every other transmission."""
        self.buffer.pop(req_id, None)
        if req_id in self.starved:
            self.starved.remove(req_id)


class ReceiverState:
    """Priority queue of won requests; pulls highest-priority first."""

    def __init__(self, instance_id: int, throughput: float = 1.0):
        self.instance_id = instance_id
        self.throughput = max(throughput, 1e-9)
        self._heap: List[Tuple[int, float, int, int, MigRequest]] = []
        self._tie = itertools.count()
        self.fails: Dict[int, int] = {}
        self.waiting_for: Optional[int] = None   # starvation: block on req

    def buffered_tokens(self) -> float:
        return float(sum(item[-1].seq_len for item in self._heap))

    def earliest_start(self) -> float:
        """Bid payload: buffered work / measured throughput."""
        return self.buffered_tokens() / self.throughput

    def win(self, req: MigRequest) -> None:
        req.dst = self.instance_id
        heapq.heappush(self._heap, (req.slo_priority, -req.priority,
                                    req.req_id, next(self._tie), req))

    def __len__(self) -> int:
        return len(self._heap)

    def next_pull(self, sender_busy) -> Tuple[Optional[MigRequest], Optional[int]]:
        """Dequeue the highest-priority transferable request.

        ``sender_busy(src_id)``: whether that sender is mid-transfer.
        Returns (request to start now | None, starved req_id to notify | None).
        Skipped requests accumulate failures; past the threshold the
        receiver blocks and notifies the sender (§4.4 starvation rule).
        """
        if self.waiting_for is not None:
            return None, None
        skipped = []
        starved: Optional[int] = None
        chosen: Optional[MigRequest] = None
        while self._heap:
            item = heapq.heappop(self._heap)
            req = item[-1]
            if not sender_busy(req.src):
                chosen = req
                break
            self.fails[req.req_id] = self.fails.get(req.req_id, 0) + 1
            if self.fails[req.req_id] > STARVATION_THRESHOLD and starved is None:
                starved = req.req_id
                self.waiting_for = req.req_id
                skipped.append(item)
                break
            skipped.append(item)
        for item in skipped:
            heapq.heappush(self._heap, item)
        return chosen, starved

    def take(self, req_id: int) -> Optional[MigRequest]:
        """Remove a specific request (starvation hand-off arriving)."""
        for i, item in enumerate(self._heap):
            if item[-1].req_id == req_id:
                self._heap.pop(i)
                heapq.heapify(self._heap)
                if self.waiting_for == req_id:
                    self.waiting_for = None
                self.fails.pop(req_id, None)
                return item[-1]
        return None

    def complete(self, req_id: int) -> None:
        self.fails.pop(req_id, None)
        if self.waiting_for == req_id:
            self.waiting_for = None

    def drain(self) -> List[MigRequest]:
        """Empty the queue (receiver died): every won offer is returned
        so the caller can unwind the matching sender state, and all
        starvation bookkeeping resets with the instance."""
        out = [item[-1] for item in self._heap]
        self._heap.clear()
        self.fails.clear()
        self.waiting_for = None
        return out
