"""Backend-agnostic CascadeInfer control plane (paper §3–§5).

One implementation of the paper's scheduling mechanisms — length routing,
growth-triggered handover with bid-ask negotiation, intra-stage
rebalancing, boundary refinement, §5 flow control — driven by pluggable
backends through a tiny protocol:

  * :class:`~repro.control.protocol.InstanceView` — what the core reads
    from a serving instance (load, free/used/queued tokens, live requests,
    admission check);
  * :class:`~repro.control.protocol.ClusterOps` — what the core asks the
    backend to do (dispatch an arrival, move KV, observe boundary edits);
  * :class:`~repro.control.plane.ControlPlane` — the scheduling core.

Drivers: ``repro.sim.cluster.CascadePolicy`` (discrete-event timing,
simulated transfers) and ``repro.serving.server.MILSServer``
(step-synchronous ticks, real KV migration between JAX engines).
"""
from repro.control.bidask import (Bid, MigRequest, ReceiverState,  # noqa: F401
                                  SenderState, is_overloaded,
                                  select_receiver)
from repro.control.faults import (HEALTH_ALIVE, HEALTH_DEAD,  # noqa: F401
                                  HEALTH_SUSPECT, XFER_LOST, XFER_OK,
                                  XFER_STALL, BackoffPolicy, FaultInjector,
                                  FaultSpec)
from repro.control.plane import (ControlConfig, ControlPlane,  # noqa: F401
                                 StageState)
from repro.control.protocol import (MIG_COMPLETED, MIG_FAILED,  # noqa: F401
                                    MIG_STARTED, ClusterOps, InstanceView,
                                    ReqView)
from repro.control.refinement import (BoundaryRefiner,  # noqa: F401
                                      memory_based_split,
                                      quantity_based_split)
