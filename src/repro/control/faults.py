"""Deterministic fault model for multi-instance serving (DESIGN.md
§Fault tolerance).

Three pieces, shared verbatim by the discrete-event simulator and the
real step-synchronous server so chaos runs stay lockstep-comparable:

  * :class:`FaultSpec` — a frozen, seeded description of what goes wrong
    in a run: scripted instance crashes/rejoins, per-transfer loss/stall
    probabilities, per-instance slowdown factors. Time points are in the
    *driver's* clock (sim seconds or server steps) — the spec itself is
    clock-free data.
  * :class:`FaultInjector` — turns the spec into concrete decisions.
    Per-transfer outcomes are keyed by ``hash(seed, req_id, attempt)``,
    NOT by a sequential RNG draw: both backends start the same transfers
    in the same per-request order (that is what decision-log parity
    already guarantees), so the k-th transfer attempt of request r gets
    the same fate in both worlds regardless of how unrelated events
    interleave.
  * :class:`BackoffPolicy` — the capped exponential retry schedule the
    control plane applies to failed migrations (receiver refusal, wire
    timeout, receiver death). Delays are measured in *pump rounds* (the
    plane's only notion of retry time); after ``max_retries`` failures
    the request is permanently banned from migrating and completes on
    its source.

Health states live here too so drivers and the plane agree on the
vocabulary without importing each other.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Optional, Tuple

# Instance health (plane-side liveness tracking; DESIGN.md §Fault
# tolerance). alive -> suspect after ``suspect_after`` heartbeat-free
# time units, suspect -> dead after ``dead_after``; any heartbeat
# restores alive (dead -> alive is a rejoin).
HEALTH_ALIVE = "alive"
HEALTH_SUSPECT = "suspect"
HEALTH_DEAD = "dead"

# FaultInjector per-transfer outcomes
XFER_OK = "ok"
XFER_LOST = "lost"       # never delivers; discovered by the deadline
XFER_STALL = "stall"     # delivers late; the deadline usually fires first


@dataclasses.dataclass(frozen=True)
class BackoffPolicy:
    """Capped exponential backoff for failed migration attempts.

    ``delay(n)`` is how many pump rounds to wait after the n-th failure
    (1-based): base, base*mult, ... capped at ``cap``. After
    ``max_retries`` failures the request is banned from migrating for
    the rest of its life — the strict no-spin bound the regression test
    asserts (total attempts <= max_retries + 1)."""
    max_retries: int = 6
    base: float = 1.0
    multiplier: float = 2.0
    cap: float = 32.0

    def delay(self, fails: int) -> float:
        return min(self.base * self.multiplier ** max(fails - 1, 0),
                   self.cap)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Seeded description of a chaos run (clock-free data; times are in
    the driver's own units — sim seconds or server steps)."""
    seed: int = 0
    # scripted instance deaths/revivals: ((instance_id, at_time), ...)
    crashes: Tuple[Tuple[int, float], ...] = ()
    rejoins: Tuple[Tuple[int, float], ...] = ()
    # correlated (rack-style) failures: ((instance_ids...), at_time) kills
    # every listed instance in the SAME tick — power/switch domains where
    # deaths are not independent. Expanded into per-instance crashes by
    # ``all_crashes``; drivers iterate that, never ``crashes`` directly.
    racks: Tuple[Tuple[Tuple[int, ...], float], ...] = ()
    # per-transfer-attempt wire faults
    transfer_loss_p: float = 0.0
    transfer_stall_p: float = 0.0
    # slow-instance degradation: ((instance_id, slowdown_factor >= 1), ...)
    slowdowns: Tuple[Tuple[int, float], ...] = ()

    @property
    def all_crashes(self) -> Tuple[Tuple[int, float], ...]:
        """Per-instance crash schedule with rack events expanded:
        independent ``crashes`` first, then each rack's members in listed
        order (drivers that push events in sequence keep a deterministic
        same-tick order)."""
        out = list(self.crashes)
        for ids, t in self.racks:
            out.extend((int(i), float(t)) for i in ids)
        return tuple(out)


def _unit_hash(*vals) -> float:
    """Deterministic uniform [0, 1) from a tuple of values — sha256, not
    Python's randomized ``hash``, so sim and server (and re-runs) agree."""
    h = hashlib.sha256("|".join(str(v) for v in vals).encode()).digest()
    return int.from_bytes(h[:8], "big") / 2.0 ** 64


class FaultInjector:
    """Concrete fault decisions for one run of one backend.

    Both backends construct their own injector from the SAME spec; the
    counter-free hashing keying per-transfer outcomes on (req_id,
    attempt#) makes their decisions identical as long as their transfer
    sequences match — which decision-log parity guarantees."""

    def __init__(self, spec: FaultSpec):
        self.spec = spec
        self._attempts: Dict[int, int] = {}     # req_id -> transfers started

    def crash_time(self, instance_id: int) -> Optional[float]:
        for iid, t in self.spec.all_crashes:
            if iid == instance_id:
                return float(t)
        return None

    def rejoin_time(self, instance_id: int) -> Optional[float]:
        for iid, t in self.spec.rejoins:
            if iid == instance_id:
                return float(t)
        return None

    def slowdown(self, instance_id: int) -> float:
        for iid, f in self.spec.slowdowns:
            if iid == instance_id:
                return max(float(f), 1.0)
        return 1.0

    def transfer_event(self, req_id: int) -> str:
        """Fate of request ``req_id``'s next transfer attempt:
        XFER_OK | XFER_LOST | XFER_STALL. Increments the per-request
        attempt counter, so retries re-draw (a lost first attempt does
        not doom every retry unless loss_p == 1)."""
        k = self._attempts.get(req_id, 0)
        self._attempts[req_id] = k + 1
        loss = self.spec.transfer_loss_p
        stall = self.spec.transfer_stall_p
        if loss <= 0.0 and stall <= 0.0:
            return XFER_OK
        u = _unit_hash(self.spec.seed, "xfer", req_id, k)
        if u < loss:
            return XFER_LOST
        if u < loss + stall:
            return XFER_STALL
        return XFER_OK
