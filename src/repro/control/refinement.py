"""Adaptive range refinement (paper §4.3).

Each instance periodically re-derives its downstream boundary from live
request lengths: merge its own active lengths with the *average* successor
set (union of successor requests divided evenly by successor count, the
same sorted every-n-th division as §4.2), scan all split points of the
sorted merged list for

    b = argmin_i  Q^{R[:i]} + Q^{R[i:]}

and take R[b] as the new boundary. Stability optimizations reproduced:
EMA smoothing, low-traffic freeze (< ``min_requests``), planner-seeded
initial boundary.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.qoe import NUM_FEATURES, QoEModel


def divide_evenly(sorted_vals: np.ndarray, n: int) -> np.ndarray:
    """Footnote-1 set division S/n: starting from the n/2-th element,
    pick every n-th — a representative subset of |S|/n elements."""
    if n <= 1:
        return sorted_vals
    return sorted_vals[n // 2::n]


def _prefix_features(I: np.ndarray, L: np.ndarray) -> np.ndarray:
    """cumF[i] = features of R[:i]; rows [nb+1, 5]."""
    n = len(I)
    cum = np.zeros((n + 1, NUM_FEATURES))
    cum[1:, 1] = np.arange(1, n + 1)
    cum[1:, 2] = np.cumsum(I)
    cum[1:, 3] = np.cumsum(I * I)
    cum[1:, 4] = np.cumsum(L)
    cum[:, 0] = 1.0
    return cum


def optimal_split(requests: Sequence[Tuple[float, float]],
                  qoe: QoEModel) -> Tuple[int, float]:
    """requests: (input_len, current_len) pairs. Returns (split index b,
    boundary length R[b]) minimizing Q^{R[:i]} + Q^{R[i:]} over the
    length-sorted list."""
    arr = np.asarray(requests, np.float64)
    order = np.argsort(arr[:, 1], kind="stable")
    I = arr[order, 0]
    L = arr[order, 1]
    n = len(I)
    cum = _prefix_features(I, L)
    total = cum[n]
    best_q, best_i = np.inf, 0
    for i in range(n + 1):
        left = cum[i]
        right = total - cum[i]
        right[0] = 1.0
        q = qoe.batch_q_from_F(left) + qoe.batch_q_from_F(right)
        if q < best_q:
            best_q, best_i = q, i
    boundary = L[min(best_i, n - 1)] if n else 0.0
    return best_i, float(boundary)


@dataclasses.dataclass
class BoundaryRefiner:
    """Per-instance boundary state machine (one per stage boundary)."""
    qoe: QoEModel
    boundary: float                  # seeded from the offline plan (§4.3)
    ema: float = 0.3                 # smoothing weight for the new sample
    min_requests: int = 5            # low-traffic freeze threshold
    history: List[float] = dataclasses.field(default_factory=list)

    def refine(self, own: Sequence[Tuple[float, float]],
               successors: Sequence[Sequence[Tuple[float, float]]]) -> float:
        """own: this instance's (I, L) pairs; successors: one list per
        successor instance. Returns the (possibly unchanged) boundary."""
        merged = list(own)
        if successors:
            # union of successor requests divided evenly by successor count
            all_succ = sorted((tuple(r) for s in successors for r in s),
                              key=lambda r: r[1])
            share = divide_evenly(np.asarray(all_succ, np.float64).reshape(
                -1, 2) if all_succ else np.zeros((0, 2)), len(successors))
            merged.extend((float(a), float(b)) for a, b in share)
        if len(merged) < self.min_requests:      # freeze under low traffic
            self.history.append(self.boundary)
            return self.boundary
        _, raw = optimal_split(merged, self.qoe)
        self.boundary = (1 - self.ema) * self.boundary + self.ema * raw
        self.history.append(self.boundary)
        return self.boundary


# --- naïve baselines for the Fig.-15 ablation -----------------------------
def quantity_based_split(requests: Sequence[Tuple[float, float]]) -> float:
    """Balance the *number* of requests per side."""
    L = np.sort(np.asarray([r[1] for r in requests], np.float64))
    if not len(L):
        return 0.0
    return float(L[len(L) // 2])


def memory_based_split(requests: Sequence[Tuple[float, float]]) -> float:
    """Balance per-side memory (Σ current length ≈ KV bytes)."""
    L = np.sort(np.asarray([r[1] for r in requests], np.float64))
    if not len(L):
        return 0.0
    cum = np.cumsum(L)
    i = int(np.searchsorted(cum, cum[-1] / 2))
    return float(L[min(i, len(L) - 1)])
