"""GQA attention with RoPE / M-RoPE, full and sliding-window variants,
prefill and single-token decode against a preallocated KV cache.

Shapes follow the serving convention:
  activations  x        [B, T, D]
  kv cache     k, v     [B, S, H_kv, Dh]   (ring buffer of size W when
                                            sliding_window > 0)
The decode step writes ONE token at ``pos`` and attends over the cache —
this is what ``serve_step`` lowers in the multi-pod dry-run.
"""
from __future__ import annotations

import math
import os
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, dense_init, psum_if_tp

NEG_INF = -1e30

# Paged decode attention backends (DESIGN.md §Decode hot path):
#   dense — XLA gather of pool[block_tables] + masked SDPA. Materializes a
#           [B, NBT·BS, Hkv, Dh] copy per layer per step; CPU/debug fallback.
#   grid  — Pallas kernel, grid (B, Hkv, NBT): no gather, but every request
#           pays max-NBT grid steps (skipped blocks still cost a grid step).
#   flat  — Pallas kernel over a flat work list of Σ_b ceil(L_b/BS) items:
#           no gather AND no per-request padding at the grid level.
#   fused — Pallas kernel over ONE tagged work list covering decode rows
#           AND prefill chunks of a mixed iteration: single launch per
#           layer per step (DESIGN.md §Fused mixed-iteration attention).
PAGED_BACKENDS = ("dense", "grid", "flat", "fused")

# KV block-pool storage layouts (DESIGN.md §Quantized KV blocks):
#   bf16 — the model dtype, full-width rows.
#   int8 — symmetric per-(block, position, kv-head) int8 with f32 row
#          scales; quantize-on-write, dequant in-register inside the
#          flash core. Supported by the "fused" and "dense" backends.
KV_DTYPES = ("bf16", "int8")


def resolve_paged_backend(backend: Optional[str] = None):
    """(backend, interpret) for this process. Explicit arg wins, then the
    REPRO_PAGED_ATTN env var, then auto: the fused Pallas kernel on TPU,
    the dense XLA path elsewhere (Pallas off-TPU would need interpret
    mode, which is for validation, not speed). Asking for a kernel
    backend off-TPU gets interpret=True so it still runs."""
    choice = backend or os.environ.get("REPRO_PAGED_ATTN", "auto")
    on_tpu = jax.default_backend() == "tpu"
    if choice == "auto":
        choice = "fused" if on_tpu else "dense"
    assert choice in PAGED_BACKENDS, f"unknown paged backend {choice!r}"
    return choice, (choice != "dense" and not on_tpu)


# --------------------------------------------------------------------------
# Rotary embeddings
# --------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x [B, T, H, Dh]; positions [B, T] (int)."""
    freqs = rope_freqs(x.shape[-1], theta)                       # [Dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs    # [B, T, Dh/2]
    cos, sin = jnp.cos(angles)[:, :, None, :], jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections=(2, 3, 3)):
    """Qwen2-VL M-RoPE: head_dim/2 frequency slots split into (t, h, w)
    sections, each rotated by its own position stream.

    x [B, T, H, Dh]; positions3 [B, T, 3] (temporal, height, width ids —
    identical streams for pure text).  [arXiv:2409.12191]
    """
    dh = x.shape[-1]
    half = dh // 2
    freqs = rope_freqs(dh, theta)                                # [half]
    total = sum(sections)
    bounds = []
    start = 0
    for s in sections:
        n = (half * s) // total
        bounds.append((start, start + n))
        start += n
    bounds[-1] = (bounds[-1][0], half)  # absorb rounding into last section
    pos = positions3.astype(jnp.float32)                         # [B, T, 3]
    angle_parts = []
    for i, (lo, hi) in enumerate(bounds):
        angle_parts.append(pos[..., i:i + 1] * freqs[lo:hi])     # [B, T, hi-lo]
    angles = jnp.concatenate(angle_parts, axis=-1)               # [B, T, half]
    cos, sin = jnp.cos(angles)[:, :, None, :], jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig, cross: bool = False):
    d, h, hk, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 8)
    p = {
        "wq": dense_init(ks[0], (d, h * dh), cfg.dtype),
        "wk": dense_init(ks[1], (d, hk * dh), cfg.dtype),
        "wv": dense_init(ks[2], (d, hk * dh), cfg.dtype),
        "wo": dense_init(ks[3], (h * dh, d), cfg.dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * dh,), cfg.dtype)
        p["bk"] = jnp.zeros((hk * dh,), cfg.dtype)
        p["bv"] = jnp.zeros((hk * dh,), cfg.dtype)
    return p


def _project_qkv(p, cfg: ModelConfig, x):
    B, T, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    # head counts come from the projection widths, NOT cfg: under serving
    # tensor parallelism (DESIGN.md §Sharded serving) the local wq/wk/wv
    # shards hold H/TP and Hkv/TP heads, and the contiguous output-dim
    # split keeps each shard's q heads aligned with its own kv heads (GQA
    # group size G = H/Hkv is shard-invariant).
    dh = cfg.head_dim
    h, hk = q.shape[-1] // dh, k.shape[-1] // dh
    return (q.reshape(B, T, h, dh), k.reshape(B, T, hk, dh),
            v.reshape(B, T, hk, dh))


# --------------------------------------------------------------------------
# Core SDPA (GQA, masked) — the XLA path. jnp.einsum lets GSPMD shard the
# KV sequence axis for context-parallel long decode.
# --------------------------------------------------------------------------
def _gqa_sdpa(q, k, v, mask):
    """q [B,Tq,H,Dh]; k,v [B,S,Hkv,Dh]; mask broadcastable to
    [B, Hkv, G, Tq, S] (pass 5-d masks; None = attend everything).

    K/V stay in their storage dtype — f32 accumulation comes from
    ``preferred_element_type`` so the (multi-GiB in decode) cache is never
    materialized as an f32 copy; scores/softmax still run in f32.
    """
    B, Tq, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Tq, Hkv, G, Dh)
    scores = jnp.einsum("bthgd,bshd->bhgts", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(Dh).astype(jnp.float32)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgts,bshd->bthgd", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Tq, H, Dh).astype(q.dtype)


def _causal_mask(Tq: int, S: int, q_offset, window: int = 0):
    """[1, 1, 1, Tq, S] boolean; True = attend. q position i (global
    q_offset + i) may see kv position j <= its own; window limits lookback."""
    qpos = q_offset + jnp.arange(Tq)[:, None]
    kpos = jnp.arange(S)[None, :]
    m = kpos <= qpos
    if window:
        m &= kpos > qpos - window
    return m[None, None, None]


# --------------------------------------------------------------------------
# Memory-bounded flash attention (XLA path): double lax.scan over q / kv
# blocks with online softmax. This is what long-sequence prefill/train
# lower to on the production mesh — peak temp is O(BQ·BK) per chip instead
# of O(T·S). (The Pallas kernel is the TPU-executed equivalent; this is
# the pjit-shardable formulation. Causal block pruning is NOT applied —
# the grid is static — so HLO FLOPs count ~2× the causal minimum; the
# roofline's useful_flops_ratio surfaces that.)
# --------------------------------------------------------------------------
FLASH_THRESHOLD = 2048 * 2048   # T·S above which prefill uses the scan path


def flash_attention_xla(q, k, v, *, causal: bool = True, window: int = 0,
                        block_q: int = 512, block_k: int = 1024):
    """q [B,T,H,Dh]; k,v [B,S,Hkv,Dh] -> [B,T,H,Dh]."""
    B, T, H, Dh = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    bq, bk = min(block_q, T), min(block_k, S)
    nq, nk = -(-T // bq), -(-S // bk)
    Tp, Sp = nq * bq, nk * bk
    qf = jnp.pad(q, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    kf = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    vf = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    qg = qf.reshape(B, nq, bq, Hkv, G, Dh).astype(jnp.float32)
    kg = kf.reshape(B, nk, bk, Hkv, Dh).astype(jnp.float32)
    vg = vf.reshape(B, nk, bk, Hkv, Dh).astype(jnp.float32)
    scale = 1.0 / math.sqrt(Dh)

    def q_step(_, qi):
        qblk, i = qi                      # [B,bq,Hkv,G,Dh], scalar
        qpos = i * bq + jnp.arange(bq)

        @jax.checkpoint   # backward recomputes p per block (flash-style):
        def kv_step(carry, kvj):          # else AD saves every [bq,bk] tile
            m, l, acc = carry
            kblk, vblk, j = kvj
            kpos = j * bk + jnp.arange(bk)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk) * scale
            mask = kpos[None, :] < S      # padding
            if causal:
                mask = mask & (kpos[None, :] <= qpos[:, None])
            if window:
                mask = mask & (kpos[None, :] > qpos[:, None] - window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vblk)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, bq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, bq, Dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.swapaxes(kg, 0, 1), jnp.swapaxes(vg, 0, 1),
             jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]   # [B,Hkv,G,bq,Dh]
        return None, out

    _, outs = jax.lax.scan(jax.checkpoint(q_step), None,
                           (jnp.swapaxes(qg, 0, 1), jnp.arange(nq)))
    # outs [nq, B, Hkv, G, bq, Dh] -> [B, T, H, Dh]
    out = jnp.moveaxis(outs, 0, 1).transpose(0, 1, 4, 2, 3, 5)
    out = out.reshape(B, Tp, H, Dh)[:, :T]
    return out.astype(q.dtype)


# --------------------------------------------------------------------------
# Prefill: full self-attention over the prompt, returns the populated cache.
# --------------------------------------------------------------------------
def attention_prefill(p, cfg: ModelConfig, x, positions, *, mrope_positions=None):
    q, k, v = _project_qkv(p, cfg, x)
    if cfg.use_mrope:
        q = apply_mrope(q, mrope_positions, cfg.rope_theta)
        k = apply_mrope(k, mrope_positions, cfg.rope_theta)
    elif not cfg.learned_pos:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    T = x.shape[1]
    B = x.shape[0]
    if T * T > FLASH_THRESHOLD:
        out = flash_attention_xla(q, k, v, causal=True,
                                  window=cfg.sliding_window)
    else:
        mask = _causal_mask(T, T, 0, cfg.sliding_window)
        out = _gqa_sdpa(q, k, v, mask)
    return psum_if_tp(out.reshape(B, T, -1) @ p["wo"], cfg), (k, v)


# --------------------------------------------------------------------------
# Decode: one token vs. a preallocated cache.
# --------------------------------------------------------------------------
class KVCache(NamedTuple):
    k: jnp.ndarray  # [B, S, Hkv, Dh]
    v: jnp.ndarray


class QuantKVCache(NamedTuple):
    """int8 paged block pool (DESIGN.md §Quantized KV blocks): K/V rows are
    symmetric int8 over the head dim with f32 per-(block, position,
    kv-head) scales — (Dh + 4)/(2·Dh) of the bf16 bytes, ≈ 1.94× resident
    requests at Dh = 128. A pytree like :class:`KVCache`, so the generic
    block gather/scatter/migration helpers work unchanged."""
    k: jnp.ndarray        # [NB, BS, Hkv, Dh] int8
    v: jnp.ndarray
    k_scale: jnp.ndarray  # [NB, BS, Hkv] f32
    v_scale: jnp.ndarray


def quantize_kv(x):
    """Symmetric int8 quantization over the last (head) axis:
    ``x ≈ int8 * scale`` with ``scale = amax/127`` per leading index.
    Returns ``(int8 values, f32 scales [x.shape[:-1]])``."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def scatter_pool(pool_l, blk, off, k, v):
    """Write new K/V rows into one layer's pool slice at physical
    ``(blk, off)`` — quantize-on-write when the pool is int8. ``blk``/
    ``off`` are int32 of any matching shape S; ``k``/``v`` are [*S, Hkv,
    Dh] in compute dtype."""
    if isinstance(pool_l, QuantKVCache):
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        return QuantKVCache(pool_l.k.at[blk, off].set(kq),
                            pool_l.v.at[blk, off].set(vq),
                            pool_l.k_scale.at[blk, off].set(ks),
                            pool_l.v_scale.at[blk, off].set(vs))
    return KVCache(pool_l.k.at[blk, off].set(k.astype(pool_l.k.dtype)),
                   pool_l.v.at[blk, off].set(v.astype(pool_l.v.dtype)))


def _pool_scales(pool_l):
    """(k_scale, v_scale) kernel operands — (None, None) for bf16 pools."""
    if isinstance(pool_l, QuantKVCache):
        return pool_l.k_scale, pool_l.v_scale
    return None, None


def _gather_dequant(pool_l, block_tables):
    """Dense-path gather of a request-contiguous [B, NBT·BS, Hkv, Dh]
    view, dequantized to f32 when the pool is int8."""
    k_seq = paged_gather(pool_l.k, block_tables)
    v_seq = paged_gather(pool_l.v, block_tables)
    if isinstance(pool_l, QuantKVCache):
        ks = paged_gather(pool_l.k_scale, block_tables)   # [B, S, Hkv]
        vs = paged_gather(pool_l.v_scale, block_tables)
        k_seq = k_seq.astype(jnp.float32) * ks[..., None]
        v_seq = v_seq.astype(jnp.float32) * vs[..., None]
    return k_seq, v_seq


def quantize_piece(piece):
    """Contiguous full-precision KV piece (:class:`KVCache`, leaves
    ``[..., Hkv, Dh]``) → its :class:`QuantKVCache` twin, for writing into
    an int8 pool. Zero-padding commutes: padded rows quantize to int8 0
    with scale 0, which dequantize back to exact zeros."""
    kq, ks = quantize_kv(piece.k)
    vq, vs = quantize_kv(piece.v)
    return QuantKVCache(kq, vq, ks, vs)


def dequantize_piece(piece, dtype):
    """:class:`QuantKVCache` piece → contiguous full-precision
    :class:`KVCache` in ``dtype``. Migration exports cross this, so the
    wire format stays the full-width layout and mixed bf16/int8 clusters
    interoperate (DESIGN.md §Migration wire format)."""
    return KVCache(
        (piece.k.astype(jnp.float32) * piece.k_scale[..., None]).astype(dtype),
        (piece.v.astype(jnp.float32) * piece.v_scale[..., None]).astype(dtype))


def _check_kv_backend(pool_l, attn_backend: str):
    if isinstance(pool_l, QuantKVCache) and attn_backend in ("grid", "flat"):
        raise ValueError(
            f"int8 KV pools need the 'fused' or 'dense' backend, "
            f"got {attn_backend!r}")


def attention_decode(p, cfg: ModelConfig, x, cache: KVCache, pos,
                     *, mrope_positions=None):
    """x [B, 1, D]; pos [B] int32 — number of tokens already in the cache.

    Writes the new token's K/V at ``pos`` (ring index ``pos % W`` when
    sliding) and attends over valid positions. Returns (out, new_cache).
    """
    B = x.shape[0]
    q, k, v = _project_qkv(p, cfg, x)           # q [B,1,H,Dh]; k,v [B,1,Hkv,Dh]
    if cfg.use_mrope:
        mp = (mrope_positions if mrope_positions is not None
              else jnp.broadcast_to(pos[:, None, None], (B, 1, 3)))
        q = apply_mrope(q, mp, cfg.rope_theta)
        k = apply_mrope(k, mp, cfg.rope_theta)
    elif not cfg.learned_pos:
        pp = pos[:, None]
        q = apply_rope(q, pp, cfg.rope_theta)
        k = apply_rope(k, pp, cfg.rope_theta)

    S = cache.k.shape[1]
    W = cfg.sliding_window
    write_idx = (pos % W) if W else jnp.minimum(pos, S - 1)

    def write(buf, new):
        def one(b, n, i):
            return jax.lax.dynamic_update_slice(b, n, (i, 0, 0))
        out = jax.vmap(one)(buf, new, write_idx)
        if cfg.kv_cache_spec is not None:
            # pin the scatter result to the cache layout: GSPMD then
            # reshards the 1-token operand, not the multi-GiB cache
            out = jax.lax.with_sharding_constraint(out, cfg.kv_cache_spec)
        return out

    new_k = write(cache.k, k)
    new_v = write(cache.v, v)

    kpos = jnp.arange(S)[None, :]                               # [1, S]
    if W:
        # ring buffer: slot j holds absolute position p where p % W == j and
        # p <= pos; valid iff pos - W < p <= pos  <=> slot written recently.
        abs_pos = kpos + ((pos[:, None] - kpos) // W) * W        # latest write
        valid = (abs_pos >= 0) & (abs_pos >= pos[:, None] - W + 1) \
                & (abs_pos <= pos[:, None])
        mask = valid[:, None, None, None, :]
    else:
        mask = (kpos <= pos[:, None])[:, None, None, None, :]
    out = _gqa_sdpa(q, new_k, new_v, mask)
    return psum_if_tp(out.reshape(B, 1, -1) @ p["wo"], cfg), \
        KVCache(new_k, new_v)


# --------------------------------------------------------------------------
# Paged decode: one token vs. a global block pool + per-request block table.
# --------------------------------------------------------------------------
def paged_gather(pool, block_tables):
    """pool [NB, BS, Hkv, Dh]; block_tables [B, NBT] int32 ->
    contiguous per-request view [B, NBT*BS, Hkv, Dh]. Rows past a
    request's length come from padding table entries and must be masked
    by the caller."""
    B, NBT = block_tables.shape
    g = pool[block_tables]                       # [B, NBT, BS, Hkv, Dh]
    return g.reshape(B, NBT * pool.shape[1], *pool.shape[2:])


def attention_decode_paged(p, cfg: ModelConfig, x, pool_l: KVCache,
                           block_tables, pos, *, mrope_positions=None,
                           attn_backend: str = "dense",
                           attn_interpret: bool = False,
                           attn_num_work: Optional[int] = None):
    """Block-table variant of :func:`attention_decode`.

    x [B, 1, D]; pool_l leaves [NB, BS, Hkv, Dh] — ONE layer's slice of the
    engine's global block pool; block_tables [B, NBT] int32 physical block
    ids (padded rows arbitrary); pos [B] int32 tokens already cached
    (``pos = -1`` marks a dead batch slot: its write lands in the padding
    row of its table and its attention length is 0).

    Writes the new token's K/V at physical ``(table[pos//BS], pos%BS)``
    and attends over the request's blocks only. Requests never share
    blocks, so the batched scatter has no duplicate indices. Full
    attention only — the sliding-window ring layout keeps the monolithic
    path (as do ssm/rwkv recurrent states).

    ``attn_backend`` (static — the serving engine bakes it in at jit
    time, see :func:`resolve_paged_backend`) picks how the attention
    itself runs. The kernel backends ("grid" / "flat") stream pool blocks
    HBM→VMEM by table indirection and never materialize the old
    ``[B, NBT·BS, Hkv, Dh]`` per-layer gather; "flat" additionally
    flattens the grid to ``attn_num_work`` (>= Σ_b ceil(L_b/BS)) work
    items so short requests stop paying the batch-max block count.
    """
    assert not cfg.sliding_window, "paged decode is full-attention only"
    B = x.shape[0]
    q, k, v = _project_qkv(p, cfg, x)           # q [B,1,H,Dh]; k,v [B,1,Hkv,Dh]
    if cfg.use_mrope:
        mp = (mrope_positions if mrope_positions is not None
              else jnp.broadcast_to(pos[:, None, None], (B, 1, 3)))
        q = apply_mrope(q, mp, cfg.rope_theta)
        k = apply_mrope(k, mp, cfg.rope_theta)
    elif not cfg.learned_pos:
        pp = pos[:, None]
        q = apply_rope(q, pp, cfg.rope_theta)
        k = apply_rope(k, pp, cfg.rope_theta)

    _check_kv_backend(pool_l, attn_backend)
    BS = pool_l.k.shape[1]
    blk = jnp.take_along_axis(block_tables, (pos // BS)[:, None], axis=1)[:, 0]
    off = pos % BS
    new_pool = scatter_pool(pool_l, blk, off, k[:, 0], v[:, 0])

    if attn_backend == "fused":
        # one-launch mixed kernel degenerates to all-decode tags at C = 1;
        # ctx = pos, seg = 1 (dead slots: total = 0 -> zero work items)
        from repro.kernels.mixed_attention import paged_mixed_attention
        ks, vs = _pool_scales(new_pool)
        o = paged_mixed_attention(
            q, new_pool.k, new_pool.v, block_tables, pos,
            jnp.ones_like(pos), jnp.zeros_like(pos), ks, vs,
            num_work=attn_num_work, interpret=attn_interpret)
        out = o.astype(q.dtype)                  # [B, 1, H, Dh]
    elif attn_backend != "dense":
        # Pallas path: the pool stays put; the kernel chases the block
        # table. lengths = pos + 1 (dead slots: 0 -> zero work items).
        from repro.kernels.decode_attention import (
            paged_decode_attention, paged_decode_attention_flat)
        lengths = pos + 1
        if attn_backend == "flat":
            o = paged_decode_attention_flat(
                q[:, 0], new_pool.k, new_pool.v, block_tables, lengths,
                num_work=attn_num_work, interpret=attn_interpret)
        else:
            o = paged_decode_attention(
                q[:, 0], new_pool.k, new_pool.v, block_tables, lengths,
                interpret=attn_interpret)
        out = o[:, None].astype(q.dtype)         # [B, 1, H, Dh]
    else:
        k_seq, v_seq = _gather_dequant(new_pool, block_tables)
        kpos = jnp.arange(k_seq.shape[1])[None, :]
        mask = (kpos <= pos[:, None])[:, None, None, None, :]
        out = _gqa_sdpa(q, k_seq, v_seq, mask)
    return psum_if_tp(out.reshape(B, 1, -1) @ p["wo"], cfg), new_pool


def attention_prefill_chunk_paged(p, cfg: ModelConfig, x, pool_l: KVCache,
                                  block_tables, ctx_len, chunk_len,
                                  *, mrope_positions=None,
                                  attn_backend: str = "dense",
                                  attn_interpret: bool = False):
    """Chunked prefill against the paged pool (DESIGN.md §Chunked prefill).

    x [B, C, D] — B prompt chunks of C tokens (rows past ``chunk_len``
    are padding); pool_l leaves [NB, BS, Hkv, Dh] — ONE layer's slice of
    the global block pool; block_tables [B, NBT] int32 covering at least
    ``ceil((ctx_len + C)/BS)`` rows (the tail padded with a garbage
    block, so padding-row writes never touch live data); ctx_len [B] (or
    scalar) int32 tokens already written for each chunk's request;
    chunk_len [B] (or scalar) int32 real tokens in each chunk.

    Writes the chunk's K/V into the pool at logical positions
    ``ctx..ctx+C-1`` (RoPE applied at the true global positions), then
    attends each query causally over its own chunk **plus the previously
    written context**, read through the block table — so a partial prompt
    lives in the same pool as decode state and later chunks/decodes see
    exactly the rows earlier chunks wrote. Returns (out [B, C, D],
    new pool); output rows past ``chunk_len`` are garbage (the caller
    keeps only the last real position's logits).
    """
    assert not cfg.sliding_window, "paged prefill is full-attention only"
    B, C, _ = x.shape
    ctx = jnp.broadcast_to(jnp.asarray(ctx_len, jnp.int32).reshape(-1), (B,))
    clen = jnp.broadcast_to(jnp.asarray(chunk_len, jnp.int32).reshape(-1),
                            (B,))
    positions = ctx[:, None] + jnp.arange(C, dtype=jnp.int32)[None]  # [B, C]
    q, k, v = _project_qkv(p, cfg, x)
    if cfg.use_mrope:
        mp = (mrope_positions if mrope_positions is not None
              else jnp.broadcast_to(positions[..., None], (B, C, 3)))
        q = apply_mrope(q, mp, cfg.rope_theta)
        k = apply_mrope(k, mp, cfg.rope_theta)
    elif not cfg.learned_pos:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    _check_kv_backend(pool_l, attn_backend)
    BS = pool_l.k.shape[1]
    blk = jnp.take_along_axis(block_tables, positions // BS, axis=1)  # [B, C]
    off = positions % BS
    # chunk positions are distinct per request and requests never share
    # blocks, so the batched scatter has no duplicate (blk, off) pairs
    new_pool = scatter_pool(pool_l, blk, off, k, v)

    if attn_backend == "fused":
        # one-launch mixed kernel with all-chunk tags
        from repro.kernels.mixed_attention import paged_mixed_attention
        ks, vs = _pool_scales(new_pool)
        out = paged_mixed_attention(
            q, new_pool.k, new_pool.v, block_tables, ctx, clen,
            jnp.ones_like(ctx), ks, vs, interpret=attn_interpret)
        out = out.astype(q.dtype)
    elif attn_backend != "dense":
        # Pallas path: the pool stays in HBM; the flat work-list kernel
        # chases the block table (cost ∝ chunk × context blocks)
        from repro.kernels.prefill_attention import paged_prefill_attention
        out = paged_prefill_attention(q, new_pool.k, new_pool.v,
                                      block_tables, ctx, clen,
                                      interpret=attn_interpret)
        out = out.astype(q.dtype)
    else:
        k_seq, v_seq = _gather_dequant(new_pool, block_tables)
        kpos = jnp.arange(k_seq.shape[1])[None, None, :]        # [1, 1, S]
        mask = (kpos <= positions[:, :, None])[:, None, None]   # [B,1,1,C,S]
        out = _gqa_sdpa(q, k_seq, v_seq, mask)
    return psum_if_tp(out.reshape(B, C, -1) @ p["wo"], cfg), new_pool


def attention_mixed_paged(p, cfg: ModelConfig, x_dec, x_ck, pool_l,
                          bt_dec, bt_ck, pos, ctx_len, chunk_len, *,
                          attn_backend: str = "fused",
                          attn_interpret: bool = False,
                          attn_num_work: Optional[int] = None):
    """ONE fused attention launch for a whole mixed iteration: the decode
    batch advances one token while prompt chunks prefill beside it
    (DESIGN.md §Fused mixed-iteration attention).

    x_dec [Bd, 1, D] — the decode batch (``pos = -1`` marks dead slots);
    x_ck  [Bp, C, D] — the prefill chunks (rows past ``chunk_len`` are
    padding); pool_l — ONE layer's pool slice (:class:`KVCache` or
    :class:`QuantKVCache`); bt_dec [Bd, NBT] / bt_ck [Bp, NBT'] block
    tables (padded to a common width here); pos [Bd] tokens already
    cached per decode slot; ctx_len/chunk_len [Bp] as in
    :func:`attention_prefill_chunk_paged`.

    Projection/RoPE/wo stay per-half — padding decode tokens through the
    chunk width would inflate the MXU work C× — and only the attention
    itself runs as one tagged work list: decode segments (tag 0,
    ctx = pos, seg = 1) interleaved with chunk segments (tag 1). Returns
    ``(out_dec [Bd, 1, D], out_ck [Bp, C, D], new_pool)``.
    """
    assert not cfg.sliding_window, "paged mixed step is full-attention only"
    assert not cfg.use_mrope, "paged mixed step: RoPE / learned-pos only"
    _check_kv_backend(pool_l, attn_backend)
    Bd = x_dec.shape[0]
    Bp, C, _ = x_ck.shape
    ctx = jnp.broadcast_to(jnp.asarray(ctx_len, jnp.int32).reshape(-1), (Bp,))
    clen = jnp.broadcast_to(jnp.asarray(chunk_len, jnp.int32).reshape(-1),
                            (Bp,))
    positions = ctx[:, None] + jnp.arange(C, dtype=jnp.int32)[None]  # [Bp, C]

    qd, kd, vd = _project_qkv(p, cfg, x_dec)
    qc, kc, vc = _project_qkv(p, cfg, x_ck)
    if not cfg.learned_pos:
        qd = apply_rope(qd, pos[:, None], cfg.rope_theta)
        kd = apply_rope(kd, pos[:, None], cfg.rope_theta)
        qc = apply_rope(qc, positions, cfg.rope_theta)
        kc = apply_rope(kc, positions, cfg.rope_theta)

    BS = pool_l.k.shape[1]
    blk_d = jnp.take_along_axis(bt_dec, (pos // BS)[:, None], axis=1)[:, 0]
    blk_c = jnp.take_along_axis(bt_ck, positions // BS, axis=1)
    pool1 = scatter_pool(pool_l, blk_d, pos % BS, kd[:, 0], vd[:, 0])
    new_pool = scatter_pool(pool1, blk_c, positions % BS, kc, vc)

    ctx_all = jnp.concatenate([pos, ctx])
    slen_all = jnp.concatenate([jnp.ones_like(pos), clen])

    if attn_backend == "fused":
        from repro.kernels.mixed_attention import paged_mixed_attention
        # decode q rides in row 0 of a chunk-wide tile; block tables pad
        # to a common width (padded entries are only reached clamped, on
        # work items the total guard skips)
        NBT = max(bt_dec.shape[1], bt_ck.shape[1])
        bt_all = jnp.concatenate([
            jnp.pad(bt_dec, ((0, 0), (0, NBT - bt_dec.shape[1]))),
            jnp.pad(bt_ck, ((0, 0), (0, NBT - bt_ck.shape[1])))])
        q_all = jnp.concatenate([
            jnp.pad(qd, ((0, 0), (0, C - 1), (0, 0), (0, 0))), qc])
        tags = jnp.concatenate([jnp.zeros_like(pos), jnp.ones_like(ctx)])
        ks, vs = _pool_scales(new_pool)
        o = paged_mixed_attention(
            q_all, new_pool.k, new_pool.v, bt_all, ctx_all, slen_all, tags,
            ks, vs, num_work=attn_num_work, interpret=attn_interpret)
        o = o.astype(qd.dtype)
        out_d, out_c = o[:Bd, :1], o[Bd:]
    else:
        # dense bit-parity reference: the same two-gather SDPA halves the
        # separate-kernel path runs (CPU/debug fallback)
        kd_seq, vd_seq = _gather_dequant(new_pool, bt_dec)
        kpos = jnp.arange(kd_seq.shape[1])[None, :]
        mask = (kpos <= pos[:, None])[:, None, None, None, :]
        out_d = _gqa_sdpa(qd, kd_seq, vd_seq, mask)
        kc_seq, vc_seq = _gather_dequant(new_pool, bt_ck)
        kpos = jnp.arange(kc_seq.shape[1])[None, None, :]
        mask = (kpos <= positions[:, :, None])[:, None, None]
        out_c = _gqa_sdpa(qc, kc_seq, vc_seq, mask)
    return (psum_if_tp(out_d.reshape(Bd, 1, -1) @ p["wo"], cfg),
            psum_if_tp(out_c.reshape(Bp, C, -1) @ p["wo"], cfg), new_pool)


def make_paged_pool(cfg: ModelConfig, num_blocks: int, block_size: int,
                    dtype=None, kv_dtype: str = "bf16"):
    """Zeroed global block pool for ONE layer: [NB, BS, Hkv, Dh].
    ``kv_dtype="int8"`` returns the quantized layout (zero scales, so
    garbage blocks dequantize to exact zeros)."""
    assert kv_dtype in KV_DTYPES, kv_dtype
    shape = (num_blocks, block_size, cfg.num_kv_heads, cfg.head_dim)
    if kv_dtype == "int8":
        sshape = shape[:-1]
        return QuantKVCache(jnp.zeros(shape, jnp.int8),
                            jnp.zeros(shape, jnp.int8),
                            jnp.zeros(sshape, jnp.float32),
                            jnp.zeros(sshape, jnp.float32))
    dt = dtype or cfg.dtype
    return KVCache(jnp.zeros(shape, dt), jnp.zeros(shape, dt))


# --------------------------------------------------------------------------
# Cross-attention (whisper decoder): KV precomputed from encoder output.
# --------------------------------------------------------------------------
def cross_attention(p, cfg: ModelConfig, x, enc_kv: KVCache):
    B, T, _ = x.shape
    h, dh = cfg.num_heads, cfg.head_dim
    q = (x @ p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(B, T, h, dh)
    out = _gqa_sdpa(q, enc_kv.k, enc_kv.v, None)
    return out.reshape(B, T, -1) @ p["wo"]


def encode_cross_kv(p, cfg: ModelConfig, enc_out):
    B, S, _ = enc_out.shape
    hk, dh = cfg.num_kv_heads, cfg.head_dim
    k = enc_out @ p["wk"]
    v = enc_out @ p["wv"]
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    return KVCache(k.reshape(B, S, hk, dh), v.reshape(B, S, hk, dh))


def make_cache(cfg: ModelConfig, batch: int, seq: int, dtype=None) -> KVCache:
    """Preallocate a zeroed cache (ring of size window when sliding)."""
    S = min(seq, cfg.sliding_window) if cfg.sliding_window else seq
    dt = dtype or cfg.dtype
    shape = (batch, S, cfg.num_kv_heads, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dt), jnp.zeros(shape, dt))
