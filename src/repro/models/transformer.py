"""Decoder-only transformer stack (dense GQA / MoE / VLM flavors).

Per-layer weights are stacked on a leading ``L`` axis and the layer loop is
``jax.lax.scan`` — fast compiles at 48+ layers and remat-friendly. The same
block code serves train (full-sequence), prefill (returns KV cache) and
decode (one token against the cache).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.attention import KVCache
from repro.models.common import (ModelConfig, embed_init, rms_norm,
                                 dense_init, maybe_shard_activations)
from repro.models.mlp import ffn, init_ffn, init_moe, moe


# --------------------------------------------------------------------------
# Init
# --------------------------------------------------------------------------
def init_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    p = {
        "ln_attn": jnp.ones((cfg.d_model,), cfg.dtype),
        "ln_mlp": jnp.ones((cfg.d_model,), cfg.dtype),
        "attn": attn.init_attention(ks[0], cfg),
    }
    if cfg.num_experts:
        p["moe"] = init_moe(ks[1], cfg)
        if cfg.dense_residual:  # arctic: parallel dense FFN
            p["ffn"] = init_ffn(ks[2], cfg)
            p["ln_res"] = jnp.ones((cfg.d_model,), cfg.dtype)
    else:
        p["ffn"] = init_ffn(ks[1], cfg)
    return p


def init_decoder(key, cfg: ModelConfig):
    ks = jax.random.split(key, cfg.num_layers + 3)
    layers = [init_block(ks[i], cfg) for i in range(cfg.num_layers)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    p = {
        "embed": embed_init(ks[-3], (cfg.vocab_size, cfg.d_model), cfg.dtype),
        "layers": stacked,
        "ln_f": jnp.ones((cfg.d_model,), cfg.dtype),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(ks[-2], (cfg.d_model, cfg.vocab_size), cfg.dtype)
    return p


# --------------------------------------------------------------------------
# Block forward (shared by all modes)
# --------------------------------------------------------------------------
def _mlp_part(pl, cfg: ModelConfig, x):
    """Returns (mlp_out, aux)."""
    h = rms_norm(x, pl["ln_mlp"], cfg.norm_eps)
    if cfg.num_experts:
        out, aux = moe(pl["moe"], cfg, h, cfg.moe_impl)
        if cfg.dense_residual:
            out = out + ffn(pl["ffn"], cfg, rms_norm(x, pl["ln_res"], cfg.norm_eps))
        return out, aux
    return ffn(pl["ffn"], cfg, h), jnp.float32(0.0)


def block_full(pl, cfg: ModelConfig, x, positions, mrope_positions=None):
    """Full-sequence pass (train / prefill). Returns (x, cache_l, aux)."""
    h = rms_norm(x, pl["ln_attn"], cfg.norm_eps)
    a, (k, v) = attn.attention_prefill(pl["attn"], cfg, h, positions,
                                       mrope_positions=mrope_positions)
    x = x + a
    m, aux = _mlp_part(pl, cfg, x)
    return x + m, KVCache(k, v), aux


def block_decode(pl, cfg: ModelConfig, x, cache_l: KVCache, pos,
                 mrope_positions=None):
    h = rms_norm(x, pl["ln_attn"], cfg.norm_eps)
    a, new_cache = attn.attention_decode(pl["attn"], cfg, h, cache_l, pos,
                                         mrope_positions=mrope_positions)
    x = x + a
    m, aux = _mlp_part(pl, cfg, x)
    return x + m, new_cache, aux


# --------------------------------------------------------------------------
# Embedding in/out
# --------------------------------------------------------------------------
def embed_tokens(p, cfg: ModelConfig, tokens, vision_embeds=None,
                 vision_mask=None):
    if cfg.tp_axis is not None:
        # vocab-sharded lookup (DESIGN.md §Sharded serving): each shard
        # holds V/TP contiguous embedding rows; out-of-range ids read a
        # clamped row, are zeroed, and the psum assembles the one real
        # row — exact, because exactly one shard contributes non-zeros.
        vloc = p["embed"].shape[0]
        idx = jax.lax.axis_index(cfg.tp_axis)
        local = tokens - idx * vloc
        ok = (local >= 0) & (local < vloc)
        x = jnp.where(ok[..., None],
                      p["embed"][jnp.clip(local, 0, vloc - 1)], 0)
        x = jax.lax.psum(x, cfg.tp_axis)
    else:
        x = p["embed"][tokens]
    if vision_embeds is not None and vision_mask is not None:
        # place the precomputed patch embeddings (VLM stub frontend) at the
        # masked positions, in order.
        B, T, D = x.shape
        idx = jnp.cumsum(vision_mask.astype(jnp.int32), axis=1) - 1
        idx = jnp.clip(idx, 0, vision_embeds.shape[1] - 1)
        gathered = jnp.take_along_axis(vision_embeds, idx[..., None], axis=1)
        x = jnp.where(vision_mask[..., None], gathered.astype(x.dtype), x)
    return x


def unembed(p, cfg: ModelConfig, x):
    w = p["embed"].T if cfg.tie_embeddings else p["unembed"]
    logits = x @ w
    if cfg.tp_axis is not None:
        # each shard computed V/TP logit columns (tied embeddings shard V
        # on dim 0, so the transpose lines up); the all-gather makes the
        # full vocab visible on every shard — argmax sampling then runs
        # replicated INSIDE the jitted step, keeping the one-d2h-per-step
        # discipline (DESIGN.md §Sharded serving).
        logits = jax.lax.all_gather(logits, cfg.tp_axis,
                                    axis=logits.ndim - 1, tiled=True)
    return logits


# --------------------------------------------------------------------------
# Full-stack passes
# --------------------------------------------------------------------------
def forward_full(p, cfg: ModelConfig, tokens, *, vision_embeds=None,
                 vision_mask=None, mrope_positions=None, return_cache=False,
                 remat: bool = False, last_only: bool = False,
                 last_index=None):
    """Train / prefill pass. Returns (logits, cache|None, aux).

    ``last_index`` (traced scalar) unembeds ONLY position ``last_index``
    — the bucketed-prefill path, where the prompt is padded to a pow2
    length and the true last token sits mid-sequence. Causality makes the
    K/V rows and logits at positions < true length independent of the
    padding tail."""
    x = embed_tokens(p, cfg, tokens, vision_embeds, vision_mask)
    B, T = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    if cfg.use_mrope and mrope_positions is None:
        mrope_positions = jnp.broadcast_to(positions[..., None], (B, T, 3))

    def body(carry, pl):
        x, aux = carry
        x = maybe_shard_activations(x, cfg)
        x, cache_l, a = block_full(pl, cfg, x, positions, mrope_positions)
        return (x, aux + a), cache_l if return_cache else 0

    body_fn = jax.checkpoint(body) if remat else body
    (x, aux), caches = jax.lax.scan(body_fn, (x, jnp.float32(0.0)), p["layers"])
    x = rms_norm(x, p["ln_f"], cfg.norm_eps)
    if last_index is not None:    # bucketed prefill: true last position
        x = jax.lax.dynamic_slice_in_dim(x, last_index, 1, axis=1)
    elif last_only:   # serving prefill needs next-token logits only
        x = x[:, -1:]
    logits = unembed(p, cfg, x)
    return logits, (caches if return_cache else None), aux


def forward_decode(p, cfg: ModelConfig, token, cache: KVCache, pos,
                   *, mrope_positions=None):
    """token [B] int32; cache leaves [L, B, S, Hkv, Dh]; pos [B] int32.
    Returns (logits [B, V], new_cache)."""
    x = embed_tokens(p, cfg, token[:, None])
    if cfg.use_mrope and mrope_positions is None:
        B = token.shape[0]
        mrope_positions = jnp.broadcast_to(pos[:, None, None], (B, 1, 3))

    def body(x, layer):
        pl, cache_l = layer
        x, new_cache_l, _ = block_decode(pl, cfg, x, cache_l, pos,
                                         mrope_positions)
        return x, new_cache_l

    x, new_cache = jax.lax.scan(body, x, (p["layers"], cache))
    x = rms_norm(x, p["ln_f"], cfg.norm_eps)
    return unembed(p, cfg, x)[:, 0], new_cache


def block_decode_paged(pl, cfg: ModelConfig, x, pool_l: KVCache,
                       block_tables, pos, mrope_positions=None,
                       attn_backend: str = "dense",
                       attn_interpret: bool = False,
                       attn_num_work=None):
    h = rms_norm(x, pl["ln_attn"], cfg.norm_eps)
    a, new_pool = attn.attention_decode_paged(pl["attn"], cfg, h, pool_l,
                                              block_tables, pos,
                                              mrope_positions=mrope_positions,
                                              attn_backend=attn_backend,
                                              attn_interpret=attn_interpret,
                                              attn_num_work=attn_num_work)
    x = x + a
    m, aux = _mlp_part(pl, cfg, x)
    return x + m, new_pool, aux


def forward_decode_paged(p, cfg: ModelConfig, token, pool: KVCache,
                         block_tables, pos, *, mrope_positions=None,
                         attn_backend: str = "dense",
                         attn_interpret: bool = False,
                         attn_num_work=None):
    """token [B] int32; pool leaves [L, NB, BS, Hkv, Dh] (global block
    pool); block_tables [B, NBT] int32; pos [B] int32 (-1 = dead slot).
    Returns (logits [B, V], new_pool). The attn_* knobs are static
    backend selectors (DESIGN.md §Decode hot path), baked in by the
    engine via functools.partial before jit."""
    x = embed_tokens(p, cfg, token[:, None])
    if cfg.use_mrope and mrope_positions is None:
        B = token.shape[0]
        mrope_positions = jnp.broadcast_to(pos[:, None, None], (B, 1, 3))

    def body(x, layer):
        pl, pool_l = layer
        x, new_pool_l, _ = block_decode_paged(pl, cfg, x, pool_l,
                                              block_tables, pos,
                                              mrope_positions,
                                              attn_backend=attn_backend,
                                              attn_interpret=attn_interpret,
                                              attn_num_work=attn_num_work)
        return x, new_pool_l

    x, new_pool = jax.lax.scan(body, x, (p["layers"], pool))
    x = rms_norm(x, p["ln_f"], cfg.norm_eps)
    return unembed(p, cfg, x)[:, 0], new_pool


def block_prefill_chunk(pl, cfg: ModelConfig, x, pool_l: KVCache,
                        block_tables, ctx_len, chunk_len,
                        mrope_positions=None, attn_backend: str = "dense",
                        attn_interpret: bool = False):
    h = rms_norm(x, pl["ln_attn"], cfg.norm_eps)
    a, new_pool = attn.attention_prefill_chunk_paged(
        pl["attn"], cfg, h, pool_l, block_tables, ctx_len, chunk_len,
        mrope_positions=mrope_positions, attn_backend=attn_backend,
        attn_interpret=attn_interpret)
    x = x + a
    m, aux = _mlp_part(pl, cfg, x)
    return x + m, new_pool, aux


def forward_prefill_chunk(p, cfg: ModelConfig, tokens, pool: KVCache,
                          block_tables, ctx_len, chunk_len, *,
                          mrope_positions=None, attn_backend: str = "dense",
                          attn_interpret: bool = False):
    """One prompt *chunk* through the stack against the paged pool
    (DESIGN.md §Chunked prefill): tokens [B, C] int32 (rows past
    ``chunk_len`` are padding), pool leaves [L, NB, BS, Hkv, Dh],
    block_tables [B, NBT], ctx_len / chunk_len traced int32 scalars (or
    [B]). Every layer writes the chunk's K/V into its pool slice and
    attends over the written context + chunk, so calling this
    chunk-by-chunk reproduces the whole-prompt prefill's cache rows and
    next-token logits exactly. Returns (last-real-token logits [B, V],
    new pool)."""
    x = embed_tokens(p, cfg, tokens)
    B, C = tokens.shape
    ctx = jnp.broadcast_to(jnp.asarray(ctx_len, jnp.int32).reshape(-1), (B,))
    if cfg.use_mrope and mrope_positions is None:
        positions = ctx[:, None] + jnp.arange(C, dtype=jnp.int32)[None]
        mrope_positions = jnp.broadcast_to(positions[..., None], (B, C, 3))

    def body(x, layer):
        pl_, pool_l = layer
        x, new_pool_l, _ = block_prefill_chunk(
            pl_, cfg, x, pool_l, block_tables, ctx_len, chunk_len,
            mrope_positions, attn_backend=attn_backend,
            attn_interpret=attn_interpret)
        return x, new_pool_l

    x, new_pool = jax.lax.scan(body, x, (p["layers"], pool))
    x = rms_norm(x, p["ln_f"], cfg.norm_eps)
    # each chunk's last REAL position — on the prompt's final chunk this
    # is the request's first-token distribution
    clen = jnp.broadcast_to(jnp.asarray(chunk_len, jnp.int32).reshape(-1),
                            (B,))
    x = jnp.take_along_axis(x, (clen - 1)[:, None, None], axis=1)
    return unembed(p, cfg, x)[:, 0], new_pool


def block_mixed(pl, cfg: ModelConfig, x_dec, x_ck, pool_l, bt_dec, bt_ck,
                pos, ctx_len, chunk_len, attn_backend: str = "fused",
                attn_interpret: bool = False, attn_num_work=None):
    hd = rms_norm(x_dec, pl["ln_attn"], cfg.norm_eps)
    hc = rms_norm(x_ck, pl["ln_attn"], cfg.norm_eps)
    ad, ac, new_pool = attn.attention_mixed_paged(
        pl["attn"], cfg, hd, hc, pool_l, bt_dec, bt_ck, pos, ctx_len,
        chunk_len, attn_backend=attn_backend, attn_interpret=attn_interpret,
        attn_num_work=attn_num_work)
    x_dec = x_dec + ad
    x_ck = x_ck + ac
    md, aux_d = _mlp_part(pl, cfg, x_dec)
    mc, aux_c = _mlp_part(pl, cfg, x_ck)
    return x_dec + md, x_ck + mc, new_pool, aux_d + aux_c


def forward_mixed(p, cfg: ModelConfig, dec_token, ck_tokens, pool,
                  bt_dec, bt_ck, pos, ctx_len, chunk_len, *,
                  attn_backend: str = "fused", attn_interpret: bool = False,
                  attn_num_work=None):
    """One whole MIXED iteration through the stack: the decode batch
    (``dec_token [Bd]``, ``pos [Bd]``, -1 = dead slot) advances one token
    while prompt chunks (``ck_tokens [Bp, C]``, ``ctx_len``/``chunk_len``)
    prefill beside it — each layer runs ONE fused attention launch over
    the tagged decode+chunk work list (DESIGN.md §Fused mixed-iteration
    attention). Activations stay per-half through embed/QKV/MLP so decode
    tokens never pay the chunk width C in linear work. Returns
    ``(dec_logits [Bd, V], ck_logits [Bp, V], new_pool)`` — ck_logits at
    each chunk's last real position, as in :func:`forward_prefill_chunk`.
    """
    x_dec = embed_tokens(p, cfg, dec_token[:, None])
    x_ck = embed_tokens(p, cfg, ck_tokens)
    Bp, C = ck_tokens.shape

    def body(carry, layer):
        x_dec, x_ck = carry
        pl_, pool_l = layer
        x_dec, x_ck, new_pool_l, _ = block_mixed(
            pl_, cfg, x_dec, x_ck, pool_l, bt_dec, bt_ck, pos, ctx_len,
            chunk_len, attn_backend=attn_backend,
            attn_interpret=attn_interpret, attn_num_work=attn_num_work)
        return (x_dec, x_ck), new_pool_l

    (x_dec, x_ck), new_pool = jax.lax.scan(body, (x_dec, x_ck),
                                           (p["layers"], pool))
    x_dec = rms_norm(x_dec, p["ln_f"], cfg.norm_eps)
    x_ck = rms_norm(x_ck, p["ln_f"], cfg.norm_eps)
    clen = jnp.broadcast_to(jnp.asarray(chunk_len, jnp.int32).reshape(-1),
                            (Bp,))
    x_ck = jnp.take_along_axis(x_ck, (clen - 1)[:, None, None], axis=1)
    return (unembed(p, cfg, x_dec)[:, 0], unembed(p, cfg, x_ck)[:, 0],
            new_pool)


def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                     dtype=None, kv_dtype: str = "bf16"):
    """Global paged KV pool: leaves [L, NB, BS, Hkv, Dh] (DESIGN.md
    §Block pool) — int8 rows + f32 [L, NB, BS, Hkv] scales when
    ``kv_dtype="int8"`` (§Quantized KV blocks). Blocks are owned by
    requests via the engine's BlockAllocator; the model never sees
    ownership, only block tables."""
    assert not cfg.sliding_window, "paged cache is full-attention only"
    assert kv_dtype in attn.KV_DTYPES, kv_dtype
    shape = (cfg.num_layers, num_blocks, block_size, cfg.num_kv_heads,
             cfg.head_dim)
    if kv_dtype == "int8":
        sshape = shape[:-1]
        return attn.QuantKVCache(jnp.zeros(shape, jnp.int8),
                                 jnp.zeros(shape, jnp.int8),
                                 jnp.zeros(sshape, jnp.float32),
                                 jnp.zeros(sshape, jnp.float32))
    dt = dtype or cfg.dtype
    return KVCache(jnp.zeros(shape, dt), jnp.zeros(shape, dt))


def init_cache(cfg: ModelConfig, batch: int, seq: int, dtype=None) -> KVCache:
    S = min(seq, cfg.sliding_window) if cfg.sliding_window else seq
    dt = dtype or cfg.dtype
    shape = (cfg.num_layers, batch, S, cfg.num_kv_heads, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dt), jnp.zeros(shape, dt))
