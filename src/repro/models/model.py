"""Unified Model API over the zoo.

Every family exposes the same four entry points, so the trainer, the
serving engine, and the multi-pod dry-run treat architectures uniformly:

    model = build_model(cfg)
    params = model.init(rng)
    loss, aux = model.loss(params, batch)                  # train_4k
    logits, cache = model.prefill(params, batch, cache_len)  # prefill_32k
    logits, cache = model.decode_step(params, cache, token, pos)  # decode_*

``batch`` is a dict; family-specific extras (audio/vision stub embeddings,
M-RoPE position ids) ride along in it. Caches are opaque pytrees.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import mamba2, rwkv6, transformer, whisper
from repro.models.attention import KVCache
from repro.models.common import ModelConfig, softmax_xent


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[..., Any]
    loss: Callable[..., Any]            # (params, batch) -> (loss, aux)
    prefill: Callable[..., Any]         # (params, batch, cache_len) -> (logits, cache)
    decode_step: Callable[..., Any]     # (params, cache, token, pos) -> (logits, cache)
    init_cache: Callable[..., Any]      # (batch_size, seq) -> cache
    # Paged (block-table) serving path — decoder-only full-attention
    # families; None elsewhere (ssm/rwkv recurrent state and sliding-window
    # ring buffers keep the monolithic layout).
    #   init_paged_cache(num_blocks, block_size) -> pool [L, NB, BS, Hkv, Dh]
    #   decode_step_paged(params, pool, token, block_tables, pos)
    #       -> (logits, pool)
    init_paged_cache: Optional[Callable[..., Any]] = None
    decode_step_paged: Optional[Callable[..., Any]] = None
    # Bucketed prefill (device-resident engines): tokens padded to a pow2
    # bucket, true_len a traced scalar — one compile per bucket instead of
    # one per distinct prompt length.
    #   prefill_bucketed(params, batch, true_len)
    #       -> (last-token logits [B, V], prompt-cache piece [L, B, P, ...])
    prefill_bucketed: Optional[Callable[..., Any]] = None
    # Chunked paged prefill (DESIGN.md §Chunked prefill): one prompt chunk
    # written + attended against the paged pool, so the engine can pack
    # prompt chunks into decode iterations instead of freezing the batch
    # for a whole long prompt.
    #   prefill_chunk(params, pool, tokens, block_tables, ctx_len,
    #                 chunk_len, *, attn_backend, attn_interpret)
    #       -> (last-real-token logits [B, V], new pool)
    prefill_chunk: Optional[Callable[..., Any]] = None
    # Fused mixed iteration (DESIGN.md §Fused mixed-iteration attention):
    # the decode batch and the prefill chunks of one engine step through
    # the stack with ONE attention launch per layer.
    #   mixed_step(params, pool, dec_token, ck_tokens, bt_dec, bt_ck, pos,
    #              ctx_len, chunk_len, *, attn_backend, attn_interpret,
    #              attn_num_work)
    #       -> (dec_logits [Bd, V], ck_logits [Bp, V], new pool)
    mixed_step: Optional[Callable[..., Any]] = None

    @property
    def supports_paged(self) -> bool:
        return self.decode_step_paged is not None


def _relay_kv(cache_pref: KVCache, cfg: ModelConfig, cache_len: int) -> KVCache:
    """Prompt-length per-layer KV [L,B,T,H,D] -> preallocated decode buffer
    [L,B,W,H,D] with ring layout (slot = abs position % W when sliding)."""
    L, B, T = cache_pref.k.shape[:3]
    W = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
    take = min(T, W)
    idx = jnp.arange(T - take, T) % W

    def relay(k):
        buf = jnp.zeros((L, B, W) + k.shape[3:], k.dtype)
        return buf.at[:, :, idx].set(k[:, :, T - take:])

    return KVCache(relay(cache_pref.k), relay(cache_pref.v))


# --------------------------------------------------------------------------
# Decoder-only family (dense / MoE / VLM)
# --------------------------------------------------------------------------
def _decoder_model(cfg: ModelConfig) -> Model:
    def init(rng):
        return transformer.init_decoder(rng, cfg)

    def loss(params, batch, remat: bool = False):
        tokens = batch["tokens"]
        logits, _, aux = transformer.forward_full(
            params, cfg, tokens,
            vision_embeds=batch.get("vision_embeds"),
            vision_mask=batch.get("vision_mask"),
            mrope_positions=batch.get("mrope_positions"),
            remat=remat)
        mask = batch.get("loss_mask")
        xe = softmax_xent(logits[:, :-1], tokens[:, 1:],
                          None if mask is None else mask[:, 1:])
        return xe + aux, {"xent": xe, "aux": aux}

    def prefill(params, batch, cache_len: Optional[int] = None):
        tokens = batch["tokens"]
        logits, caches, _ = transformer.forward_full(
            params, cfg, tokens,
            vision_embeds=batch.get("vision_embeds"),
            vision_mask=batch.get("vision_mask"),
            mrope_positions=batch.get("mrope_positions"),
            return_cache=True, last_only=True)
        cache = _relay_kv(caches, cfg, cache_len or tokens.shape[1])
        return logits[:, -1], cache

    def decode_step(params, cache, token, pos, **extras):
        return transformer.forward_decode(params, cfg, token, cache, pos,
                                          **extras)

    def init_cache(batch_size: int, seq: int):
        return transformer.init_cache(cfg, batch_size, seq)

    if cfg.sliding_window:
        # ring-buffer cache layout is incompatible with block tables;
        # such configs serve through the monolithic fallback
        return Model(cfg, init, loss, prefill, decode_step, init_cache)

    def decode_step_paged(params, pool, token, block_tables, pos, **extras):
        return transformer.forward_decode_paged(params, cfg, token, pool,
                                                block_tables, pos, **extras)

    def init_paged_cache(num_blocks: int, block_size: int,
                         kv_dtype: str = "bf16"):
        return transformer.init_paged_cache(cfg, num_blocks, block_size,
                                            kv_dtype=kv_dtype)

    def prefill_bucketed(params, batch, true_len):
        tokens = batch["tokens"]
        logits, caches, _ = transformer.forward_full(
            params, cfg, tokens,
            vision_embeds=batch.get("vision_embeds"),
            vision_mask=batch.get("vision_mask"),
            mrope_positions=batch.get("mrope_positions"),
            return_cache=True, last_index=true_len - 1)
        return logits[:, 0], caches

    def prefill_chunk(params, pool, tokens, block_tables, ctx_len,
                      chunk_len, *, attn_backend: str = "dense",
                      attn_interpret: bool = False):
        return transformer.forward_prefill_chunk(
            params, cfg, tokens, pool, block_tables, ctx_len, chunk_len,
            attn_backend=attn_backend, attn_interpret=attn_interpret)

    def mixed_step(params, pool, dec_token, ck_tokens, bt_dec, bt_ck, pos,
                   ctx_len, chunk_len, *, attn_backend: str = "fused",
                   attn_interpret: bool = False, attn_num_work=None):
        return transformer.forward_mixed(
            params, cfg, dec_token, ck_tokens, pool, bt_dec, bt_ck, pos,
            ctx_len, chunk_len, attn_backend=attn_backend,
            attn_interpret=attn_interpret, attn_num_work=attn_num_work)

    return Model(cfg, init, loss, prefill, decode_step, init_cache,
                 init_paged_cache=init_paged_cache,
                 decode_step_paged=decode_step_paged,
                 prefill_bucketed=prefill_bucketed,
                 prefill_chunk=prefill_chunk,
                 mixed_step=mixed_step)


# --------------------------------------------------------------------------
# RWKV6
# --------------------------------------------------------------------------
def _rwkv_model(cfg: ModelConfig) -> Model:
    def init(rng):
        return rwkv6.init_model(rng, cfg)

    def loss(params, batch, remat: bool = False):
        tokens = batch["tokens"]
        logits, _, _ = rwkv6.forward_full(params, cfg, tokens, remat=remat)
        mask = batch.get("loss_mask")
        xe = softmax_xent(logits[:, :-1], tokens[:, 1:],
                          None if mask is None else mask[:, 1:])
        return xe, {"xent": xe}

    def prefill(params, batch, cache_len: Optional[int] = None):
        return rwkv6.prefill(params, cfg, batch["tokens"])

    def decode_step(params, cache, token, pos, **extras):
        return rwkv6.forward_decode(params, cfg, token, cache, pos)

    def init_cache(batch_size: int, seq: int):
        return rwkv6.init_state(cfg, batch_size)

    return Model(cfg, init, loss, prefill, decode_step, init_cache)


# --------------------------------------------------------------------------
# Zamba2 hybrid
# --------------------------------------------------------------------------
def _zamba_model(cfg: ModelConfig) -> Model:
    def init(rng):
        return mamba2.init_zamba(rng, cfg)

    def loss(params, batch, remat: bool = False):
        tokens = batch["tokens"]
        logits, _, _ = mamba2.forward_full(params, cfg, tokens, remat=remat)
        mask = batch.get("loss_mask")
        xe = softmax_xent(logits[:, :-1], tokens[:, 1:],
                          None if mask is None else mask[:, 1:])
        return xe, {"xent": xe}

    def prefill(params, batch, cache_len: Optional[int] = None):
        return mamba2.prefill(params, cfg, batch["tokens"], cache_len)

    def decode_step(params, cache, token, pos, **extras):
        return mamba2.forward_decode(params, cfg, token, cache, pos)

    def init_cache(batch_size: int, seq: int):
        return mamba2.init_state(cfg, batch_size, seq)

    return Model(cfg, init, loss, prefill, decode_step, init_cache)


# --------------------------------------------------------------------------
# Whisper (enc-dec)
# --------------------------------------------------------------------------
def _whisper_model(cfg: ModelConfig) -> Model:
    def init(rng):
        return whisper.init_model(rng, cfg)

    def loss(params, batch, remat: bool = False):
        tokens = batch["tokens"]
        logits, _, _ = whisper.forward_full(params, cfg, tokens,
                                            batch["audio_embeds"], remat=remat)
        mask = batch.get("loss_mask")
        xe = softmax_xent(logits[:, :-1], tokens[:, 1:],
                          None if mask is None else mask[:, 1:])
        return xe, {"xent": xe}

    def prefill(params, batch, cache_len: Optional[int] = None):
        tokens = batch["tokens"]
        logits, caches, _ = whisper.forward_full(
            params, cfg, tokens, batch["audio_embeds"], return_cache=True,
            last_only=True)
        self_kv = _relay_kv(caches.self_kv, cfg,
                            cache_len or tokens.shape[1])
        return logits[:, -1], whisper.WhisperCache(self_kv, caches.cross_kv)

    def decode_step(params, cache, token, pos, **extras):
        return whisper.forward_decode(params, cfg, token, cache, pos)

    def init_cache(batch_size: int, seq: int):
        return whisper.init_cache(cfg, batch_size, seq)

    return Model(cfg, init, loss, prefill, decode_step, init_cache)


# --------------------------------------------------------------------------
# Factory
# --------------------------------------------------------------------------
def build_model(cfg: ModelConfig) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        return _decoder_model(cfg)
    if cfg.family == "ssm":
        return _rwkv_model(cfg)
    if cfg.family == "hybrid":
        return _zamba_model(cfg)
    if cfg.family == "encdec":
        return _whisper_model(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")


def synthetic_batch(cfg: ModelConfig, batch: int, seq: int, rng=None,
                    np_seed: int = 0) -> Dict[str, jnp.ndarray]:
    """A runnable (CPU) batch with the right extras for the family."""
    import numpy as np
    r = np.random.default_rng(np_seed)
    out: Dict[str, Any] = {
        "tokens": jnp.asarray(
            r.integers(0, cfg.vocab_size, (batch, seq), dtype=np.int32)),
    }
    if cfg.family == "encdec":
        out["audio_embeds"] = jnp.asarray(
            r.normal(0, 1, (batch, cfg.encoder_seq, cfg.d_model)), cfg.dtype)
    if cfg.family == "vlm":
        n_patch = max(1, seq // 4)
        vm = np.zeros((batch, seq), bool)
        vm[:, :n_patch] = True
        out["vision_embeds"] = jnp.asarray(
            r.normal(0, 1, (batch, n_patch, cfg.d_model)), cfg.dtype)
        out["vision_mask"] = jnp.asarray(vm)
        # M-RoPE ids: vision patches share t=0 with (h, w) grid; text runs on
        tpos = np.zeros((batch, seq, 3), np.int32)
        side = max(1, int(np.sqrt(n_patch)))
        for i in range(n_patch):
            tpos[:, i] = (0, i // side, i % side)
        for i in range(n_patch, seq):
            t = i - n_patch + 1
            tpos[:, i] = (t, t, t)
        out["mrope_positions"] = jnp.asarray(tpos)
    return out
