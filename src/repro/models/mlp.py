"""Feed-forward layers: SwiGLU / GELU dense FFN and token-choice MoE.

Two MoE dispatch implementations:

  * ``dense``  — every token runs every expert, masked combine. Exact,
    dropless, trivial to verify; used for CPU smoke tests and the real
    in-process serving engine (expert counts are tiny there).
  * ``gshard`` — capacity-based one-hot dispatch/combine einsums
    (GShard / Switch formulation). Active-expert FLOPs only; the expert
    axis shards cleanly under GSPMD (all-to-all), which is what the
    multi-pod dry-run and roofline need at 128 experts.

Router load-balance auxiliary loss follows Switch Transformer.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, act_fn, dense_init, psum_if_tp


# --------------------------------------------------------------------------
# Dense FFN
# --------------------------------------------------------------------------
def init_ffn(key, cfg: ModelConfig, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        return {
            "w_gate": dense_init(ks[0], (d, f), cfg.dtype),
            "w_up": dense_init(ks[1], (d, f), cfg.dtype),
            "w_down": dense_init(ks[2], (f, d), cfg.dtype),
        }
    return {
        "w_up": dense_init(ks[0], (d, f), cfg.dtype),
        "b_up": jnp.zeros((f,), cfg.dtype),
        "w_down": dense_init(ks[1], (f, d), cfg.dtype),
        "b_down": jnp.zeros((d,), cfg.dtype),
    }


def ffn(p, cfg: ModelConfig, x):
    a = act_fn(cfg.act)
    if "w_gate" in p:
        return psum_if_tp(
            (a(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"], cfg)
    # gelu path: b_up is F-sharded like w_up's output, so it adds
    # pre-reduce; b_down is replicated and must add exactly once — AFTER
    # the psum over the F-contraction partials.
    return psum_if_tp(a(x @ p["w_up"] + p["b_up"]) @ p["w_down"], cfg) \
        + p["b_down"]


# --------------------------------------------------------------------------
# MoE
# --------------------------------------------------------------------------
def init_moe(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "w_gate": dense_init(ks[1], (e, d, f), cfg.dtype),
        "w_up": dense_init(ks[2], (e, d, f), cfg.dtype),
        "w_down": dense_init(ks[3], (e, f, d), cfg.dtype),
    }


def _route(p, cfg: ModelConfig, xf):
    """xf [N, D] -> (probs [N,E], topw [N,K], topi [N,K], aux scalar)."""
    E, K = cfg.num_experts, cfg.experts_per_token
    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, K)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32)           # [N, K, E]
    frac = jnp.mean(onehot.sum(1), axis=0)                        # tokens/expert
    prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac * prob) * cfg.router_aux_coef
    return probs, topw, topi, aux


def _experts(p, cfg: ModelConfig, xe):
    """Batched expert FFN. xe [E, ..., D] -> [E, ..., D]. Under serving
    tensor parallelism the per-expert FFN dim F is the sharded axis
    (every shard holds all experts, F/TP wide — the router stays
    replicated), so the w_down contraction is a partial sum."""
    a = act_fn(cfg.act)
    h = jnp.einsum("e...d,edf->e...f", xe, p["w_gate"])
    u = jnp.einsum("e...d,edf->e...f", xe, p["w_up"])
    return psum_if_tp(
        jnp.einsum("e...f,efd->e...d", a(h) * u, p["w_down"]), cfg)


def moe_dense(p, cfg: ModelConfig, x):
    """Exact dropless MoE by running all experts on all tokens."""
    B, T, D = x.shape
    E = cfg.num_experts
    xf = x.reshape(B * T, D)
    _, topw, topi, aux = _route(p, cfg, xf)
    combine = jnp.einsum("nk,nke->ne", topw,
                         jax.nn.one_hot(topi, E, dtype=jnp.float32))
    y = _experts(p, cfg, jnp.broadcast_to(xf, (E,) + xf.shape))   # [E, N, D]
    out = jnp.einsum("end,ne->nd", y.astype(jnp.float32), combine)
    return out.reshape(B, T, D).astype(x.dtype), aux


def moe_gshard(p, cfg: ModelConfig, x, capacity_factor: float = 1.25):
    """Capacity-based dispatch. x [G, S, D] with G = batch groups (sharded
    on data under pjit); tokens above per-group expert capacity are dropped
    with their combine weight (GShard semantics)."""
    G, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    C = max(1, math.ceil(S * K / E * capacity_factor))

    xf = x.reshape(G * S, D)
    _, topw, topi, aux = _route(p, cfg, xf)
    topw = topw.reshape(G, S, K)
    topi = topi.reshape(G, S, K)

    # slot of each (token, k) pair within its expert, per group
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32)           # [G,S,K,E]
    flat = onehot.reshape(G, S * K, E)
    prior = jnp.cumsum(flat, axis=1) - flat                       # earlier pairs
    slot = jnp.einsum("gpe,gpe->gp", prior,
                      flat).reshape(G, S, K).astype(jnp.int32)

    # combine mask [G,S,E,C]: sum over K of weight * onehot(expert)*onehot(slot)
    combine = jnp.zeros((G, S, E, C), jnp.float32)
    for k in range(K):
        keep = (slot[..., k] < C).astype(jnp.float32) * topw[..., k]
        slot_oh = jax.nn.one_hot(jnp.minimum(slot[..., k], C - 1), C,
                                 dtype=jnp.float32)               # [G,S,C]
        combine = combine + (keep[..., None, None]
                             * onehot[:, :, k, :, None] * slot_oh[:, :, None, :])
    dispatch = (combine > 0).astype(x.dtype)

    xe = jnp.einsum("gsec,gsd->egcd", dispatch, x)                # [E,G,C,D]
    y = _experts(p, cfg, xe)                                      # [E,G,C,D]
    out = jnp.einsum("egcd,gsec->gsd", y.astype(jnp.float32), combine)
    return out.astype(x.dtype), aux


def moe(p, cfg: ModelConfig, x, impl: str | None = None):
    impl = impl or getattr(cfg, "moe_impl", "dense")
    if impl == "dense":
        return moe_dense(p, cfg, x)
    if impl == "gshard":
        return moe_gshard(p, cfg, x)
    raise ValueError(f"unknown moe impl {impl!r}")
