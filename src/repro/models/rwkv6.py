"""RWKV-6 "Finch" — attention-free RNN with data-dependent decay.
[arXiv:2404.05892]

Faithful pieces: token-shift mixing, per-channel data-dependent decay
``w_t = exp(-exp(w0 + lora(x)))``, per-head matrix-valued state
``S_t = diag(w_t) S_{t-1} + k_t v_t^T``, bonus ``u`` on the current token,
squared-ReLU channel mix. Simplification (noted in DESIGN.md): static
token-shift interpolation weights instead of the v6 dynamic ddlerp — the
decay (the part that matters for serving cost) stays fully data-dependent.

Decode is O(1) per token: the whole point of including this arch —
CascadeInfer's length-heterogeneity tax vanishes for it (DESIGN §4).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.common import (ModelConfig, dense_init, embed_init,
                                 rms_norm, maybe_shard_activations)

LORA_R = 32


def _heads(cfg: ModelConfig):
    K = cfg.ssm_head_dim or 64
    H = cfg.d_model // K
    return H, K


def init_layer(key, cfg: ModelConfig):
    D = cfg.d_model
    H, K = _heads(cfg)
    ks = jax.random.split(key, 12)
    return {
        "ln_att": jnp.ones((D,), cfg.dtype),
        "ln_ffn": jnp.ones((D,), cfg.dtype),
        # token-shift mixes (static lerp weights in [0,1])
        "mu_r": jnp.full((D,), 0.5, cfg.dtype),
        "mu_k": jnp.full((D,), 0.5, cfg.dtype),
        "mu_v": jnp.full((D,), 0.5, cfg.dtype),
        "mu_w": jnp.full((D,), 0.5, cfg.dtype),
        "mu_g": jnp.full((D,), 0.5, cfg.dtype),
        "w_r": dense_init(ks[0], (D, D), cfg.dtype),
        "w_k": dense_init(ks[1], (D, D), cfg.dtype),
        "w_v": dense_init(ks[2], (D, D), cfg.dtype),
        "w_g": dense_init(ks[3], (D, D), cfg.dtype),
        "w_o": dense_init(ks[4], (D, D), cfg.dtype),
        # data-dependent decay LoRA: w = exp(-exp(w0 + tanh(x A) B))
        "decay_w0": jnp.full((D,), -6.0, cfg.dtype),
        "decay_A": dense_init(ks[5], (D, LORA_R), cfg.dtype),
        "decay_B": dense_init(ks[6], (LORA_R, D), cfg.dtype, scale=0.1),
        "bonus_u": jnp.zeros((H, K), cfg.dtype),
        "ln_x": jnp.ones((D,), cfg.dtype),  # per-head group norm weight
        # channel mix
        "mu_ck": jnp.full((D,), 0.5, cfg.dtype),
        "mu_cr": jnp.full((D,), 0.5, cfg.dtype),
        "cw_k": dense_init(ks[7], (D, cfg.d_ff), cfg.dtype),
        "cw_v": dense_init(ks[8], (cfg.d_ff, D), cfg.dtype),
        "cw_r": dense_init(ks[9], (D, D), cfg.dtype),
    }


def init_model(key, cfg: ModelConfig):
    ks = jax.random.split(key, cfg.num_layers + 3)
    layers = [init_layer(ks[i], cfg) for i in range(cfg.num_layers)]
    return {
        "embed": embed_init(ks[-3], (cfg.vocab_size, cfg.d_model), cfg.dtype),
        "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *layers),
        "ln_f": jnp.ones((cfg.d_model,), cfg.dtype),
        "unembed": dense_init(ks[-2], (cfg.d_model, cfg.vocab_size), cfg.dtype),
    }


def _decay(pl, xw):
    return jnp.exp(-jnp.exp(
        (pl["decay_w0"].astype(jnp.float32)
         + jnp.tanh(xw.astype(jnp.float32) @ pl["decay_A"].astype(jnp.float32))
         @ pl["decay_B"].astype(jnp.float32))))


def _group_norm(x, weight, H, K, eps=1e-5):
    """Per-head LayerNorm on [..., H, K] flattened to [..., D]."""
    shp = x.shape
    x = x.reshape(shp[:-1] + (H, K)).astype(jnp.float32)
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x.reshape(shp) * weight.astype(jnp.float32))


def time_mix_step(pl, cfg: ModelConfig, x, x_prev, S):
    """One token. x [B, D]; S [B, H, K, K]; returns (out, S')."""
    H, K = _heads(cfg)
    B, D = x.shape
    lerp = lambda mu: x + (x_prev - x) * mu
    r = (lerp(pl["mu_r"]) @ pl["w_r"]).reshape(B, H, K)
    k = (lerp(pl["mu_k"]) @ pl["w_k"]).reshape(B, H, K)
    v = (lerp(pl["mu_v"]) @ pl["w_v"]).reshape(B, H, K)
    g = jax.nn.silu(lerp(pl["mu_g"]) @ pl["w_g"])
    w = _decay(pl, lerp(pl["mu_w"])).reshape(B, H, K)             # f32

    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    u = pl["bonus_u"].astype(jnp.float32)
    kv = jnp.einsum("bhk,bhv->bhkv", kf, vf)
    y = jnp.einsum("bhk,bhkv->bhv", rf, S + u[None, :, :, None] * kv)
    S = w[..., None] * S + kv
    y = _group_norm(y.reshape(B, D), pl["ln_x"], H, K)
    return ((y * g.astype(jnp.float32)) @ pl["w_o"].astype(jnp.float32)
            ).astype(x.dtype), S


def time_mix_seq(pl, cfg: ModelConfig, x, S0=None, x_prev0=None,
                 return_state: bool = False):
    """Full sequence. x [B, T, D] -> [B, T, D].

    TPU-structured: the token-shift lerps and ALL projections run as
    full-sequence matmuls OUTSIDE the scan (MXU-sized work, correctly
    counted by cost analysis); only the O(H·K²) recurrence stays
    sequential."""
    H, K = _heads(cfg)
    B, T, D = x.shape
    xp = _shift(x)
    if x_prev0 is not None:                      # decode-state handoff
        xp = xp.at[:, 0].set(x_prev0)
    lerp = lambda mu: x + (xp - x) * mu
    r = (lerp(pl["mu_r"]) @ pl["w_r"]).reshape(B, T, H, K)
    k = (lerp(pl["mu_k"]) @ pl["w_k"]).reshape(B, T, H, K)
    v = (lerp(pl["mu_v"]) @ pl["w_v"]).reshape(B, T, H, K)
    g = jax.nn.silu(lerp(pl["mu_g"]) @ pl["w_g"])
    w = _decay(pl, lerp(pl["mu_w"])).reshape(B, T, H, K)          # f32
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    u = pl["bonus_u"].astype(jnp.float32)

    if S0 is None:
        S0 = jnp.zeros((B, H, K, K), jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp                    # [B,H,K] each
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S = wt[..., None] * S + kv
        return S, y

    xs = tuple(jnp.swapaxes(a, 0, 1) for a in (rf, kf, vf, w))
    S, ys = jax.lax.scan(step, S0, xs)
    y = jnp.swapaxes(ys, 0, 1).reshape(B, T, D)                   # f32
    y = _group_norm(y, pl["ln_x"], H, K)
    out = ((y * g.astype(jnp.float32))
           @ pl["w_o"].astype(jnp.float32)).astype(x.dtype)
    if return_state:
        return out, S, x[:, -1]
    return out


def channel_mix(pl, cfg: ModelConfig, x, x_prev):
    """x, x_prev [.., D] (x_prev = token-shifted input)."""
    xk = x + (x_prev - x) * pl["mu_ck"]
    xr = x + (x_prev - x) * pl["mu_cr"]
    k = jnp.square(jax.nn.relu(xk @ pl["cw_k"]))
    return jax.nn.sigmoid(xr @ pl["cw_r"]) * (k @ pl["cw_v"])


def _shift(x):
    """[B, T, D] -> previous token (zeros at t=0)."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def layer_seq(pl, cfg: ModelConfig, x):
    h = rms_norm(x, pl["ln_att"], cfg.norm_eps)
    x = x + time_mix_seq(pl, cfg, h)
    h = rms_norm(x, pl["ln_ffn"], cfg.norm_eps)
    return x + channel_mix(pl, cfg, h, _shift(h))


def forward_full(p, cfg: ModelConfig, tokens, remat: bool = False):
    x = p["embed"][tokens]

    def body(x, pl):
        x = maybe_shard_activations(x, cfg)
        return layer_seq(pl, cfg, x), 0

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, p["layers"])
    x = rms_norm(x, p["ln_f"], cfg.norm_eps)
    return x @ p["unembed"], None, jnp.float32(0.0)


# --------------------------------------------------------------------------
# Decode: O(1) recurrent state per layer
# --------------------------------------------------------------------------
def init_state(cfg: ModelConfig, batch: int) -> Dict[str, jnp.ndarray]:
    H, K = _heads(cfg)
    L, D = cfg.num_layers, cfg.d_model
    return {
        "S": jnp.zeros((L, batch, H, K, K), jnp.float32),
        "att_prev": jnp.zeros((L, batch, D), cfg.dtype),
        "ffn_prev": jnp.zeros((L, batch, D), cfg.dtype),
    }


def forward_decode(p, cfg: ModelConfig, token, state, pos=None):
    """token [B] -> (logits [B, V], state')."""
    x = p["embed"][token]

    def body(x, layer):
        pl, S, att_prev, ffn_prev = layer
        h = rms_norm(x, pl["ln_att"], cfg.norm_eps)
        y, S = time_mix_step(pl, cfg, h, att_prev, S)
        x = x + y
        h2 = rms_norm(x, pl["ln_ffn"], cfg.norm_eps)
        x = x + channel_mix(pl, cfg, h2, ffn_prev)
        return x, (S, h, h2)

    x, (S, att_prev, ffn_prev) = jax.lax.scan(
        body, x, (p["layers"], state["S"], state["att_prev"], state["ffn_prev"]))
    x = rms_norm(x, p["ln_f"], cfg.norm_eps)
    return x @ p["unembed"], {"S": S, "att_prev": att_prev, "ffn_prev": ffn_prev}


def prefill(p, cfg: ModelConfig, tokens):
    """Run the prompt and return (last_logits, decode state)."""
    x = p["embed"][tokens]

    def body(x, pl):
        h = rms_norm(x, pl["ln_att"], cfg.norm_eps)
        y, S, att_prev = time_mix_seq(pl, cfg, h, return_state=True)
        x = x + y
        h2 = rms_norm(x, pl["ln_ffn"], cfg.norm_eps)
        x = x + channel_mix(pl, cfg, h2, _shift(h2))
        return x, (S, att_prev, h2[:, -1])

    x, (S, att_prev, ffn_prev) = jax.lax.scan(body, x, p["layers"])
    x = rms_norm(x, p["ln_f"], cfg.norm_eps)
    logits = x[:, -1] @ p["unembed"]
    return logits, {"S": S, "att_prev": att_prev, "ffn_prev": ffn_prev}
