"""Whisper-large-v3 transformer backbone (encoder-decoder). [arXiv:2212.04356]

Per the assignment, the modality frontend (mel-spectrogram + conv feature
extractor) is a STUB: inputs are precomputed frame embeddings
``audio_embeds [B, encoder_seq, d_model]``. Everything downstream — the
32-layer encoder, 32-layer decoder with self- + cross-attention, learned
positions — is implemented.

Decode: self-attention KV cache grows per token; cross-attention KV is
computed once from the encoder output at prefill and stays fixed.
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.attention import KVCache
from repro.models.common import (ModelConfig, dense_init, embed_init,
                                 layer_norm, maybe_shard_activations)
from repro.models.mlp import ffn, init_ffn


def _ln(key_unused, d, dtype):
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def init_enc_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    return {
        "ln1": _ln(None, cfg.d_model, cfg.dtype),
        "ln2": _ln(None, cfg.d_model, cfg.dtype),
        "attn": attn.init_attention(ks[0], cfg),
        "ffn": init_ffn(ks[1], cfg),
    }


def init_dec_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    return {
        "ln1": _ln(None, cfg.d_model, cfg.dtype),
        "ln2": _ln(None, cfg.d_model, cfg.dtype),
        "ln3": _ln(None, cfg.d_model, cfg.dtype),
        "self_attn": attn.init_attention(ks[0], cfg),
        "cross_attn": attn.init_attention(ks[1], cfg),
        "ffn": init_ffn(ks[2], cfg),
    }


def init_model(key, cfg: ModelConfig):
    ks = jax.random.split(key, cfg.encoder_layers + cfg.num_layers + 4)
    enc = [init_enc_layer(ks[i], cfg) for i in range(cfg.encoder_layers)]
    dec = [init_dec_layer(ks[cfg.encoder_layers + i], cfg)
           for i in range(cfg.num_layers)]
    return {
        "enc_pos": embed_init(ks[-4], (cfg.encoder_seq, cfg.d_model), cfg.dtype),
        "enc_layers": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "enc_ln": _ln(None, cfg.d_model, cfg.dtype),
        "embed": embed_init(ks[-3], (cfg.vocab_size, cfg.d_model), cfg.dtype),
        "dec_pos": embed_init(ks[-2], (cfg.max_position, cfg.d_model), cfg.dtype),
        "dec_layers": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "dec_ln": _ln(None, cfg.d_model, cfg.dtype),
    }


def encode(p, cfg: ModelConfig, audio_embeds):
    """audio_embeds [B, S_enc, D] (stub conv frontend output)."""
    x = audio_embeds + p["enc_pos"][None, :audio_embeds.shape[1]]

    def body(x, pl):
        h = layer_norm(x, pl["ln1"]["w"], pl["ln1"]["b"], cfg.norm_eps)
        B, T, _ = h.shape
        pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
        # bidirectional: no mask, learned positions (no rope)
        q, k, v = attn._project_qkv(pl["attn"], cfg, h)
        a = attn._gqa_sdpa(q, k, v, None).reshape(B, T, -1) @ pl["attn"]["wo"]
        x = x + a
        h = layer_norm(x, pl["ln2"]["w"], pl["ln2"]["b"], cfg.norm_eps)
        return x + ffn(pl["ffn"], cfg, h), 0

    x, _ = jax.lax.scan(body, x, p["enc_layers"])
    return layer_norm(x, p["enc_ln"]["w"], p["enc_ln"]["b"], cfg.norm_eps)


class WhisperCache(NamedTuple):
    self_kv: KVCache      # [L, B, S_dec, Hkv, Dh]
    cross_kv: KVCache     # [L, B, S_enc, Hkv, Dh]


def _dec_block_full(pl, cfg, x, positions, cross_kv):
    h = layer_norm(x, pl["ln1"]["w"], pl["ln1"]["b"], cfg.norm_eps)
    a, kv = attn.attention_prefill(pl["self_attn"], cfg, h, positions)
    kv = KVCache(*kv)
    x = x + a
    h = layer_norm(x, pl["ln2"]["w"], pl["ln2"]["b"], cfg.norm_eps)
    x = x + attn.cross_attention(pl["cross_attn"], cfg, h, cross_kv)
    h = layer_norm(x, pl["ln3"]["w"], pl["ln3"]["b"], cfg.norm_eps)
    return x + ffn(pl["ffn"], cfg, h), kv


def forward_full(p, cfg: ModelConfig, tokens, audio_embeds,
                 return_cache: bool = False, remat: bool = False,
                 last_only: bool = False):
    """Teacher-forced decoder pass. Returns (logits, cache|None, aux)."""
    enc = encode(p, cfg, audio_embeds)
    B, T = tokens.shape
    x = p["embed"][tokens] + p["dec_pos"][None, :T]
    positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))

    def body(x, pl):
        x = maybe_shard_activations(x, cfg)
        cross_kv = attn.encode_cross_kv(pl["cross_attn"], cfg, enc)
        x, kv = _dec_block_full(pl, cfg, x, positions, cross_kv)
        return x, (kv, cross_kv) if return_cache else 0

    body_fn = jax.checkpoint(body) if remat else body
    x, caches = jax.lax.scan(body_fn, x, p["dec_layers"])
    x = layer_norm(x, p["dec_ln"]["w"], p["dec_ln"]["b"], cfg.norm_eps)
    if last_only:   # serving prefill needs next-token logits only
        x = x[:, -1:]
    logits = x @ p["embed"].T  # whisper ties decoder embedding
    if return_cache:
        self_kv, cross_kv = caches
        return logits, WhisperCache(self_kv, cross_kv), jnp.float32(0.0)
    return logits, None, jnp.float32(0.0)


def init_cache(cfg: ModelConfig, batch: int, dec_seq: int,
               enc_seq: int | None = None) -> WhisperCache:
    L = cfg.num_layers
    Se = enc_seq or cfg.encoder_seq
    shape_s = (L, batch, dec_seq, cfg.num_kv_heads, cfg.head_dim)
    shape_c = (L, batch, Se, cfg.num_kv_heads, cfg.head_dim)
    z = lambda s: jnp.zeros(s, cfg.dtype)
    return WhisperCache(KVCache(z(shape_s), z(shape_s)),
                        KVCache(z(shape_c), z(shape_c)))


def forward_decode(p, cfg: ModelConfig, token, cache: WhisperCache, pos):
    """token [B]; pos [B] — decoder tokens already generated."""
    B = token.shape[0]
    x = p["embed"][token][:, None] + p["dec_pos"][pos][:, None]

    def body(x, layer):
        pl, self_kv, cross_kv = layer
        h = layer_norm(x, pl["ln1"]["w"], pl["ln1"]["b"], cfg.norm_eps)
        a, new_kv = attn.attention_decode(pl["self_attn"], cfg, h, self_kv, pos)
        x = x + a
        h = layer_norm(x, pl["ln2"]["w"], pl["ln2"]["b"], cfg.norm_eps)
        x = x + attn.cross_attention(pl["cross_attn"], cfg, h, cross_kv)
        h = layer_norm(x, pl["ln3"]["w"], pl["ln3"]["b"], cfg.norm_eps)
        x = x + ffn(pl["ffn"], cfg, h)
        return x, new_kv

    x, new_self = jax.lax.scan(body, x, (p["dec_layers"], cache.self_kv,
                                         cache.cross_kv))
    x = layer_norm(x, p["dec_ln"]["w"], p["dec_ln"]["b"], cfg.norm_eps)
    logits = (x @ p["embed"].T)[:, 0]
    return logits, WhisperCache(new_self, cache.cross_kv)
