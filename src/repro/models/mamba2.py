"""Mamba2 (SSD) blocks and the Zamba2 hybrid stack. [arXiv:2411.15242]

Mamba2 head-structured state space: per head h, state S ∈ R^{P×N},
  S_t = exp(dt_t·A_h)·S_{t-1} + dt_t·(x_t ⊗ B_t),   y_t = S_t·C_t + D_h·x_t
with scalar A per head, short causal conv on (x, B, C), gated RMSNorm out.

Zamba2: a backbone of Mamba2 blocks with ONE shared attention+MLP block
applied every ``attn_every`` layers (weights reused at every site, each
site keeps its own KV cache). Simplification vs. the released model (noted
in DESIGN.md): the shared block consumes the hidden state directly instead
of concat(hidden, embedding) + per-site projector.

Decode state is O(1) in sequence length for the Mamba part; only the
shared-attention sites carry a KV cache — the hybrid's heterogeneity tax
is scaled by the attention fraction (DESIGN §4).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.attention import KVCache
from repro.models.common import (ModelConfig, dense_init, embed_init,
                                 rms_norm, maybe_shard_activations)
from repro.models.mlp import ffn, init_ffn

CONV_K = 4
EXPAND = 2


def dims(cfg: ModelConfig):
    d_inner = EXPAND * cfg.d_model
    P = cfg.ssm_head_dim
    H = d_inner // P
    N = cfg.ssm_state
    return d_inner, H, P, N


# --------------------------------------------------------------------------
# Mamba2 block params
# --------------------------------------------------------------------------
def init_mamba_block(key, cfg: ModelConfig):
    D = cfg.d_model
    d_inner, H, P, N = dims(cfg)
    conv_dim = d_inner + 2 * N  # x, B, C share the conv
    ks = jax.random.split(key, 4)
    return {
        "ln": jnp.ones((D,), cfg.dtype),
        "w_in": dense_init(ks[0], (D, 2 * d_inner + 2 * N + H), cfg.dtype),
        "conv_w": dense_init(ks[1], (CONV_K, conv_dim), cfg.dtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "ln_gate": jnp.ones((d_inner,), cfg.dtype),
        "w_out": dense_init(ks[2], (d_inner, D), cfg.dtype),
    }


def _split_proj(cfg: ModelConfig, zxbcdt):
    d_inner, H, P, N = dims(cfg)
    z, x, B, C, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N],
        axis=-1)
    return z, x, B, C, dt


def _conv_seq(pl, xbc):
    """Causal depthwise conv over time. xbc [B, T, Cd]."""
    w = pl["conv_w"].astype(jnp.float32)                          # [K, Cd]
    pad = jnp.pad(xbc.astype(jnp.float32), ((0, 0), (CONV_K - 1, 0), (0, 0)))
    out = sum(pad[:, k:k + xbc.shape[1]] * w[k] for k in range(CONV_K))
    return jax.nn.silu(out + pl["conv_b"].astype(jnp.float32)).astype(xbc.dtype)


def _ssd_scan(pl, cfg: ModelConfig, x, B, C, dt, S0=None):
    """Sequential SSD over time. x [B,T,d_inner]; B,C [B,T,N]; dt [B,T,H].
    Returns (y [B,T,d_inner], final state [B,H,P,N])."""
    d_inner, H, P, N = dims(cfg)
    Bb, T, _ = x.shape
    xh = x.reshape(Bb, T, H, P).astype(jnp.float32)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + pl["dt_bias"])  # [B,T,H]
    A = -jnp.exp(pl["A_log"])                                      # [H]
    decay = jnp.exp(dtf * A)                                       # [B,T,H]
    Bf, Cf = B.astype(jnp.float32), C.astype(jnp.float32)
    S = S0 if S0 is not None else jnp.zeros((Bb, H, P, N), jnp.float32)

    def step(S, inp):
        xt, Bt, Ct, dct, dtt = inp          # [B,H,P],[B,N],[B,N],[B,H],[B,H]
        S = dct[..., None, None] * S + (dtt[..., None, None]
                                        * xt[..., None] * Bt[:, None, None, :])
        y = jnp.einsum("bhpn,bn->bhp", S, Ct)
        return S, y

    xs = (jnp.swapaxes(xh, 0, 1), jnp.swapaxes(Bf, 0, 1),
          jnp.swapaxes(Cf, 0, 1), jnp.swapaxes(decay, 0, 1),
          jnp.swapaxes(dtf, 0, 1))
    S, ys = jax.lax.scan(step, S, xs)
    y = jnp.swapaxes(ys, 0, 1)                                     # [B,T,H,P]
    y = y + pl["D_skip"][None, None, :, None] * xh
    return y.reshape(Bb, T, d_inner), S


def _ssd_chunked(pl, cfg: ModelConfig, x, B, C, dt, S0=None,
                 chunk: int = 128):
    """Chunk-parallel SSD (the actual Mamba2 algorithm): within a chunk the
    scalar-per-head decays form a 1-semiseparable matrix computed with
    matmuls; only the T/chunk inter-chunk state recurrence is sequential.
    Numerically identical to ``_ssd_scan`` (tested); AD saves one state
    per CHUNK instead of per token — the zamba2 train-memory fix.
    """
    d_inner, H, P, N = dims(cfg)
    Bb, T, _ = x.shape
    assert T % chunk == 0, (T, chunk)
    nc, Ck = T // chunk, chunk
    xh = x.reshape(Bb, T, H, P).astype(jnp.float32)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + pl["dt_bias"])  # [B,T,H]
    A = -jnp.exp(pl["A_log"])                                      # [H]
    la = dtf * A                                                   # log decay
    xdt = xh * dtf[..., None]                                      # [B,T,H,P]
    Bf = B.astype(jnp.float32).reshape(Bb, nc, Ck, N)
    Cf = C.astype(jnp.float32).reshape(Bb, nc, Ck, N)
    xdt_c = xdt.reshape(Bb, nc, Ck, H, P)
    cl = jnp.cumsum(la.reshape(Bb, nc, Ck, H), axis=2)             # [B,nc,Ck,H]
    mask = jnp.tril(jnp.ones((Ck, Ck), bool))                      # s <= t
    S = S0 if S0 is not None else jnp.zeros((Bb, H, P, N), jnp.float32)

    def chunk_body(S, inp):
        xc, Bc, Cc, clc = inp          # [B,Ck,H,P],[B,Ck,N],[B,Ck,N],[B,Ck,H]
        # intra-chunk: y_t += Σ_{s<=t} exp(cl_t - cl_s) (C_t·B_s) xdt_s
        M = jnp.exp(clc[:, :, None, :] - clc[:, None, :, :])       # [B,t,s,H]
        M = jnp.where(mask[None, :, :, None], M, 0.0)
        CB = jnp.einsum("btn,bsn->bts", Cc, Bc)
        y = jnp.einsum("bts,btsh,bshp->bthp", CB, M, xc)
        # inter-chunk: carry-in state decayed to position t
        y = y + jnp.einsum("bth,btn,bhpn->bthp", jnp.exp(clc), Cc, S)
        # state update to chunk end
        cl_last = clc[:, -1]                                       # [B,H]
        S_add = jnp.einsum("bsh,bshp,bsn->bhpn",
                           jnp.exp(cl_last[:, None] - clc), xc, Bc)
        S = jnp.exp(cl_last)[..., None, None] * S + S_add
        return S, y

    xs = tuple(jnp.swapaxes(a, 0, 1) for a in (xdt_c, Bf, Cf, cl))
    S, ys = jax.lax.scan(chunk_body, S, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bb, T, H, P)
    y = y + pl["D_skip"][None, None, :, None] * xh
    return y.reshape(Bb, T, d_inner), S


def _ssd(pl, cfg: ModelConfig, x, B, C, dt, S0=None):
    """Dispatch: chunked when enabled and the length divides."""
    chunk = getattr(cfg, "ssm_chunk", 0)
    if chunk and x.shape[1] % chunk == 0 and x.shape[1] >= chunk:
        return _ssd_chunked(pl, cfg, x, B, C, dt, S0, chunk)
    return _ssd_scan(pl, cfg, x, B, C, dt, S0)


def mamba_seq(pl, cfg: ModelConfig, x, return_state: bool = False):
    """Full-sequence Mamba2 block. x [B,T,D] -> [B,T,D] (+ decode states)."""
    h = rms_norm(x, pl["ln"], cfg.norm_eps)
    z, xs, B, C, dt = _split_proj(cfg, h @ pl["w_in"])
    xbc_raw = jnp.concatenate([xs, B, C], axis=-1)
    xbc = _conv_seq(pl, xbc_raw)
    d_inner, _, _, N = dims(cfg)
    xs, B, C = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)
    y, S = _ssd(pl, cfg, xs, B, C, dt)
    y = _gated_out(pl, cfg, y, z)
    if return_state:
        pad = jnp.pad(xbc_raw, ((0, 0), (CONV_K - 1, 0), (0, 0)))
        conv_state = pad[:, -(CONV_K - 1):] if CONV_K > 1 else pad[:, :0]
        return x + y, conv_state, S
    return x + y


def _gated_out(pl, cfg: ModelConfig, y, z):
    y = rms_norm(y.astype(cfg.dtype) * jax.nn.silu(z), pl["ln_gate"],
                 cfg.norm_eps)
    return y @ pl["w_out"]


def mamba_step(pl, cfg: ModelConfig, x, conv_state, S):
    """One decode token. x [B,D]; conv_state [B,K-1,Cd]; S [B,H,P,N]."""
    d_inner, H, P, N = dims(cfg)
    h = rms_norm(x, pl["ln"], cfg.norm_eps)
    z, xs, B, C, dt = _split_proj(cfg, h @ pl["w_in"])
    xbc = jnp.concatenate([xs, B, C], axis=-1)                     # [B, Cd]
    window = jnp.concatenate([conv_state, xbc[:, None]], axis=1)   # [B,K,Cd]
    w = pl["conv_w"].astype(jnp.float32)
    conv = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w)
    conv = jax.nn.silu(conv + pl["conv_b"].astype(jnp.float32))
    xs, B, C = jnp.split(conv.astype(x.dtype), [d_inner, d_inner + N], axis=-1)

    y, S = _ssd_scan(pl, cfg, xs[:, None], B[:, None], C[:, None],
                     dt[:, None], S0=S)
    y = _gated_out(pl, cfg, y[:, 0], z)
    return x + y, window[:, 1:], S


# --------------------------------------------------------------------------
# Zamba2 hybrid stack
# --------------------------------------------------------------------------
def init_zamba(key, cfg: ModelConfig):
    assert cfg.attn_every and cfg.num_layers % cfg.attn_every == 0
    groups = cfg.num_layers // cfg.attn_every
    ks = jax.random.split(key, cfg.num_layers + 4)
    blocks = [init_mamba_block(ks[i], cfg) for i in range(cfg.num_layers)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    # reshape leading axis L -> [groups, attn_every]
    stacked = jax.tree.map(
        lambda a: a.reshape((groups, cfg.attn_every) + a.shape[1:]), stacked)
    shared = {
        "ln_attn": jnp.ones((cfg.d_model,), cfg.dtype),
        "ln_mlp": jnp.ones((cfg.d_model,), cfg.dtype),
        "attn": attn.init_attention(ks[-4], cfg),
        "ffn": init_ffn(ks[-3], cfg),
    }
    return {
        "embed": embed_init(ks[-2], (cfg.vocab_size, cfg.d_model), cfg.dtype),
        "mamba": stacked,
        "shared": shared,
        "ln_f": jnp.ones((cfg.d_model,), cfg.dtype),
        "unembed": dense_init(ks[-1], (cfg.d_model, cfg.vocab_size), cfg.dtype),
    }


def _shared_full(ps, cfg, x, positions):
    h = rms_norm(x, ps["ln_attn"], cfg.norm_eps)
    a, kv = attn.attention_prefill(ps["attn"], cfg, h, positions)
    x = x + a
    x = x + ffn(ps["ffn"], cfg, rms_norm(x, ps["ln_mlp"], cfg.norm_eps))
    return x, KVCache(*kv)


def _shared_decode(ps, cfg, x, cache_site: KVCache, pos):
    h = rms_norm(x, ps["ln_attn"], cfg.norm_eps)
    a, new_cache = attn.attention_decode(ps["attn"], cfg, h, cache_site, pos)
    x = x + a
    x = x + ffn(ps["ffn"], cfg, rms_norm(x, ps["ln_mlp"], cfg.norm_eps))
    return x, new_cache


def forward_full(p, cfg: ModelConfig, tokens, remat: bool = False,
                 return_cache: bool = False):
    x = p["embed"][tokens]
    B, T = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))

    def group(x, mamba_group):
        def inner(x, pl):
            x = maybe_shard_activations(x, cfg)
            return mamba_seq(pl, cfg, x), 0
        inner_fn = jax.checkpoint(inner) if remat else inner
        x, _ = jax.lax.scan(inner_fn, x, mamba_group)
        x, kv = _shared_full(p["shared"], cfg, x, positions)
        return x, kv if return_cache else 0

    x, kvs = jax.lax.scan(group, x, p["mamba"])
    x = rms_norm(x, p["ln_f"], cfg.norm_eps)
    return x @ p["unembed"], (kvs if return_cache else None), jnp.float32(0.0)


def init_state(cfg: ModelConfig, batch: int, seq: int) -> Dict:
    d_inner, H, P, N = dims(cfg)
    conv_dim = d_inner + 2 * N
    groups = cfg.num_layers // cfg.attn_every
    S = min(seq, cfg.sliding_window) if cfg.sliding_window else seq
    return {
        "conv": jnp.zeros((groups, cfg.attn_every, batch, CONV_K - 1, conv_dim),
                          cfg.dtype),
        "ssm": jnp.zeros((groups, cfg.attn_every, batch, H, P, N), jnp.float32),
        "kv": KVCache(
            jnp.zeros((groups, batch, S, cfg.num_kv_heads, cfg.head_dim),
                      cfg.dtype),
            jnp.zeros((groups, batch, S, cfg.num_kv_heads, cfg.head_dim),
                      cfg.dtype)),
    }


def prefill(p, cfg: ModelConfig, tokens, cache_len: int | None = None):
    """Run the prompt, return (last_logits, decode state dict).

    The attention KV cache is re-laid into a preallocated buffer of
    ``cache_len`` (default: prompt length) so decode can append."""
    B, T = tokens.shape
    S = cache_len or T
    x = p["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))

    def group(x, mamba_group):
        def inner(x, pl):
            x, conv, Ss = mamba_seq(pl, cfg, x, return_state=True)
            return x, (conv, Ss)

        x, (conv_g, ssm_g) = jax.lax.scan(inner, x, mamba_group)
        x, kv = _shared_full(p["shared"], cfg, x, positions)
        return x, (conv_g, ssm_g, kv)

    x, (conv, ssm, kvs) = jax.lax.scan(group, x, p["mamba"])
    x = rms_norm(x, p["ln_f"], cfg.norm_eps)
    logits = x[:, -1] @ p["unembed"]

    # re-lay prompt KV into the preallocated decode buffer
    W = min(S, cfg.sliding_window) if cfg.sliding_window else S
    G = cfg.num_layers // cfg.attn_every

    def relay(k):  # [G, B, T, Hkv, Dh] -> [G, B, W, Hkv, Dh]
        buf = jnp.zeros((G, B, W, cfg.num_kv_heads, cfg.head_dim), k.dtype)
        take = min(T, W)
        # slot = absolute position % W (ring-buffer layout used by decode)
        idx = jnp.arange(T - take, T) % W
        return buf.at[:, :, idx].set(k[:, :, T - take:])

    state = {"conv": conv, "ssm": ssm,
             "kv": KVCache(relay(kvs.k), relay(kvs.v))}
    return logits, state


def forward_decode(p, cfg: ModelConfig, token, state, pos):
    """token [B]; pos [B] — tokens already in the attention cache."""
    x = p["embed"][token]

    def group(x, inp):
        mamba_group, conv_g, ssm_g, kv_g = inp

        def inner(x, layer):
            pl, conv, S = layer
            x, conv, S = mamba_step(pl, cfg, x, conv, S)
            return x, (conv, S)

        x, (conv_g, ssm_g) = jax.lax.scan(inner, x, (mamba_group, conv_g, ssm_g))
        x2, kv_g = _shared_decode(p["shared"], cfg, x[:, None], kv_g, pos)
        return x2[:, 0], (conv_g, ssm_g, kv_g)

    x, (conv, ssm, kv) = jax.lax.scan(
        group, x, (p["mamba"], state["conv"], state["ssm"], state["kv"]))
    x = rms_norm(x, p["ln_f"], cfg.norm_eps)
    logits = x @ p["unembed"]
    return logits, {"conv": conv, "ssm": ssm, "kv": kv}
