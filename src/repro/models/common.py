"""Shared model components: config, norms, embeddings, initializers.

All models in the zoo are pure-functional JAX: parameters are pytrees of
jnp arrays, every forward is a plain function. Layers are stacked for
``jax.lax.scan`` (leading ``num_layers`` axis on every per-layer weight)
so deep configs (48-54 layers) compile quickly and remat cleanly.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

Params = Any  # pytree of jnp.ndarray


# --------------------------------------------------------------------------
# Config
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config per assigned architecture (src/repro/configs/)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int          # 0 for attention-free (rwkv6)
    num_kv_heads: int
    d_ff: int               # dense FFN dim (per-expert dim for MoE)
    vocab_size: int
    head_dim: int = 0       # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    dense_residual: bool = False   # arctic: dense FFN in parallel with MoE
    router_aux_coef: float = 0.01  # load-balance loss coefficient
    moe_impl: str = "dense"        # dense (exact, CPU) | gshard (distributed)

    # --- positional / attention flavor ---
    rope_theta: float = 10_000.0
    use_mrope: bool = False        # qwen2-vl 3-section rope
    qkv_bias: bool = False
    sliding_window: int = 0        # 0 = full attention; >0 = window size
    learned_pos: bool = False      # whisper decoder
    max_position: int = 131_072

    # --- SSM / hybrid ---
    ssm_state: int = 0             # mamba2 N
    ssm_head_dim: int = 64         # mamba2 P
    ssm_chunk: int = 0             # 0 = sequential scan; >0 = chunked SSD
    attn_every: int = 0            # zamba2: shared attn each N ssm blocks

    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0           # frames after the (stubbed) conv frontend

    # --- numerics / impl ---
    act: str = "swiglu"            # swiglu | gelu
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: Any = jnp.float32
    attention_impl: str = "xla"    # xla | pallas (decode path only)
    # sequence-parallel activation sharding between blocks (§Perf lever):
    # (batch_axes, seq_axis) mesh-axis names, e.g. (("pod","data"), "model").
    # None = off (paper-faithful baseline). Needs an active mesh context.
    act_shard: Any = None
    # decode KV-cache layout hint (§Perf lever): PartitionSpec for
    # [B, S, Hkv, Dh] applied to the updated cache inside serve_step —
    # pins the scatter output so GSPMD reshards the 1-token operand
    # instead of round-tripping the multi-GiB cache. None = off.
    kv_cache_spec: Any = None
    # serving tensor parallelism (DESIGN.md §Sharded serving): mesh axis
    # name the forward runs under via shard_map. When set, every weight
    # matrix is the LOCAL shard (q/kv heads, FFN dim, vocab split over
    # the axis) and the forward inserts the manual collectives: psum
    # after wo / w_down contractions, masked-embed psum, logits
    # all-gather. None = single-device (no collectives traced).
    tp_axis: Optional[str] = None
    source: str = ""               # citation bracket from the assignment

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def reduced(self, **over) -> "ModelConfig":
        """Smoke-test variant: <=2 layers, d_model<=256, <=4 experts."""
        small = dict(
            num_layers=2,
            d_model=min(self.d_model, 256),
            num_heads=min(self.num_heads, 4) if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=64 if self.num_heads else 0,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 16) if self.encoder_seq else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else self.ssm_head_dim,
            attn_every=2 if self.attn_every else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            max_position=4096,
            name=self.name + "-reduced",
        )
        small.update(over)
        return dataclasses.replace(self, **small)


# --------------------------------------------------------------------------
# Initializers (shape-only friendly: everything goes through jax.random so
# jax.eval_shape(init, rng) gives ShapeDtypeStructs without allocation).
# --------------------------------------------------------------------------
def dense_init(key, shape, dtype, scale: float = 1.0):
    fan_in = shape[0] if len(shape) >= 2 else max(shape[-1], 1)
    std = scale / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def psum_if_tp(x, cfg: "ModelConfig"):
    """All-reduce a partial activation over the serving tensor-parallel
    axis — identity when ``cfg.tp_axis`` is unset, so single-device
    forwards trace exactly as before (DESIGN.md §Sharded serving)."""
    if cfg.tp_axis is None:
        return x
    return jax.lax.psum(x, cfg.tp_axis)


def maybe_shard_activations(x, cfg: "ModelConfig"):
    """Sequence-parallel constraint on inter-block activations [B, T, D]
    (Megatron SP): seq dim sharded on the tensor axis between blocks, so
    remat residual stacks shrink by the model-axis size."""
    if cfg.act_shard is None:
        return x
    batch_axes, seq_axis = cfg.act_shard
    spec = jax.sharding.PartitionSpec(batch_axes, seq_axis,
                                      *([None] * (x.ndim - 2)))
    return jax.lax.with_sharding_constraint(x, spec)


# --------------------------------------------------------------------------
# Normalization
# --------------------------------------------------------------------------
def rms_norm(x, weight, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------
# Activations
# --------------------------------------------------------------------------
def act_fn(name: str):
    if name == "swiglu":
        return jax.nn.silu
    if name == "gelu":
        return partial(jax.nn.gelu, approximate=True)
    raise ValueError(f"unknown activation {name!r}")


# --------------------------------------------------------------------------
# Losses
# --------------------------------------------------------------------------
def softmax_xent(logits, labels, mask=None):
    """Mean next-token cross-entropy. logits [..., V], labels int [...].

    The gold logit is extracted with an iota-compare mask-sum rather than
    ``take_along_axis``: a gather along a model-sharded vocab axis forces
    GSPMD to replicate the logits (and scatter in backward), while the
    mask-sum fuses elementwise and keeps the vocab dim sharded.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0),
                   axis=-1)
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
