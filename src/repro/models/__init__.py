from repro.models.common import ModelConfig
from repro.models.model import Model, build_model, synthetic_batch

__all__ = ["ModelConfig", "Model", "build_model", "synthetic_batch"]
