"""qwen3-moe-30b-a3b — 128-expert top-8 MoE. [hf:Qwen/Qwen3-30B-A3B]"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        d_ff=768,                 # per-expert FFN dim
        vocab_size=151936,
        num_experts=128,
        experts_per_token=8,
        rope_theta=1_000_000.0,
        source="[hf:Qwen/Qwen3-30B-A3B]",
    )
