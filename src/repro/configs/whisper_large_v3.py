"""whisper-large-v3 — enc-dec audio backbone, conv frontend stubbed.
[arXiv:2212.04356]"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        family="encdec",
        num_layers=32,            # decoder
        encoder_layers=32,
        d_model=1280,
        num_heads=20,
        num_kv_heads=20,          # GQA kv=20 (== MHA)
        d_ff=5120,
        vocab_size=51866,
        encoder_seq=1500,         # 30 s audio after conv frontend (stub)
        max_position=448,         # whisper decoder position table
        learned_pos=True,
        act="gelu",
        source="[arXiv:2212.04356]",
    )
