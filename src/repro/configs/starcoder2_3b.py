"""starcoder2-3b — dense GQA + RoPE code model. [arXiv:2402.19173]"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b",
        family="dense",
        num_layers=30,
        d_model=3072,
        num_heads=24,
        num_kv_heads=2,
        d_ff=12288,
        vocab_size=49152,
        rope_theta=100_000.0,
        act="gelu",
        source="[arXiv:2402.19173]",
    )
