"""arctic-480b — 128-expert top-2 MoE with parallel dense residual FFN.
[hf:Snowflake/snowflake-arctic-base]"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b",
        family="moe",
        num_layers=35,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        d_ff=4864,                # per-expert and dense-residual FFN dim
        vocab_size=32000,
        num_experts=128,
        experts_per_token=2,
        dense_residual=True,
        source="[hf:Snowflake/snowflake-arctic-base]",
    )
