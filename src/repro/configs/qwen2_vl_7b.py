"""qwen2-vl-7b — VLM backbone with M-RoPE; ViT frontend stubbed.
[arXiv:2409.12191]"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b",
        family="vlm",
        num_layers=28,
        d_model=3584,
        num_heads=28,
        num_kv_heads=4,
        d_ff=18944,
        vocab_size=152064,
        use_mrope=True,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        source="[arXiv:2409.12191]",
    )
