"""qwen2.5-14b — dense GQA with QKV bias. [hf:Qwen/Qwen2.5-0.5B]"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b",
        family="dense",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        d_ff=13824,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        source="[hf:Qwen/Qwen2.5-0.5B]",
    )
