"""Architecture config registry.

Every assigned architecture has one module here defining ``config()`` with
the exact assignment specs (source cited in ``ModelConfig.source``).
Reduced smoke variants come from ``cfg.reduced()``.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.common import ModelConfig

ARCHS: List[str] = [
    "whisper-large-v3",
    "qwen3-moe-30b-a3b",
    "starcoder2-3b",
    "qwen2-vl-7b",
    "rwkv6-7b",
    "minitron-8b",
    "smollm-360m",
    "zamba2-2.7b",
    "arctic-480b",
    "qwen2.5-14b",
    # the paper's own evaluation model (Llama-3.2-3B, §6.1)
    "llama3.2-3b",
]


def _module_name(arch: str) -> str:
    return "repro.configs." + arch.replace("-", "_").replace(".", "_")


def get_config(arch: str, **overrides) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCHS}")
    cfg = importlib.import_module(_module_name(arch)).config()
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}
