"""minitron-8b — width-pruned Nemotron, dense GQA. [arXiv:2407.14679]"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b",
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=16384,
        vocab_size=256000,
        source="[arXiv:2407.14679]",
    )
