"""rwkv6-7b "Finch" — attention-free, data-dependent decay.
[arXiv:2404.05892]"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b",
        family="ssm",
        num_layers=32,
        d_model=4096,
        num_heads=0,              # attention-free
        num_kv_heads=0,
        d_ff=14336,
        vocab_size=65536,
        ssm_head_dim=64,          # 64 rwkv heads of dim 64
        ssm_state=64,
        source="[arXiv:2404.05892]",
    )
