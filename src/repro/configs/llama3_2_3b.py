"""llama3.2-3b — the paper's own evaluation model (§6.1). [arXiv paper]"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b",
        family="dense",
        num_layers=28,
        d_model=3072,
        num_heads=24,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=128256,
        rope_theta=500_000.0,
        source="[arXiv:2407.21783 / paper §6.1]",
    )
