"""zamba2-2.7b — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242]"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        num_layers=54,            # mamba2 blocks
        d_model=2560,
        num_heads=32,             # shared attention block (MHA, kv=32)
        num_kv_heads=32,
        head_dim=80,
        d_ff=10240,
        vocab_size=32000,
        ssm_state=64,
        ssm_head_dim=64,
        attn_every=6,             # shared block applied 9 times
        source="[arXiv:2411.15242]",
    )
