"""smollm-360m — llama-arch small model. [hf:HuggingFaceTB/SmolLM-135M]"""
from repro.models.common import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m",
        family="dense",
        num_layers=32,
        d_model=960,
        num_heads=15,
        num_kv_heads=5,
        d_ff=2560,
        vocab_size=49152,
        source="[hf:HuggingFaceTB/SmolLM-135M]",
    )
