"""Bucketed workload statistics for pipeline planning (paper §4.2).

Sequence-length space is cut into exponentially growing tiers (the paper's
first DP optimization — O(log L) candidate cut points). Each request
(input I, output O) sweeps lengths [I, I+O) during decode; it contributes
to every bucket its trajectory crosses, weighted by residency fraction, so
bucket-range QoE features F = [1, n, ΣI, ΣI², ΣL] come from O(1) prefix
sums.

``cross[j]`` counts requests whose trajectory straddles edge j — the
volume behind the inter-stage migration cost c_{l'}.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np

from repro.core.qoe import NUM_FEATURES


def exp_bucket_edges(max_len: int, first: int = 128,
                     growth: float = 2.0) -> np.ndarray:
    """[0, first, first·g, …, ≥ max_len] — O(log L) edges."""
    edges = [0.0, float(first)]
    while edges[-1] < max_len:
        edges.append(edges[-1] * growth)
    return np.asarray(edges)


@dataclasses.dataclass
class WorkloadStats:
    edges: np.ndarray          # [nb+1] bucket boundaries (lengths)
    acc: np.ndarray            # [nb, 5] per-bucket feature accumulators
    cross: np.ndarray          # [nb+1] trajectory crossings per edge
    num_requests: int

    @property
    def nb(self) -> int:
        return len(self.edges) - 1

    # cumulative feature table: cum[j] = Σ acc[:j]
    def __post_init__(self):
        self._cum = np.concatenate(
            [np.zeros((1, NUM_FEATURES)), np.cumsum(self.acc, axis=0)], axis=0)

    def range_features(self, j_lo: int, j_hi: int) -> np.ndarray:
        """F for bucket range [j_lo, j_hi) (edge indices)."""
        F = self._cum[j_hi] - self._cum[j_lo]
        F[0] = 1.0
        return F

    def edge_crossings(self, j: int) -> float:
        return float(self.cross[j])


def build_stats(requests: Sequence[Tuple[int, int]],
                edges: np.ndarray) -> WorkloadStats:
    """requests: iterable of (input_len I, output_len O)."""
    edges = np.asarray(edges, np.float64)
    nb = len(edges) - 1
    acc = np.zeros((nb, NUM_FEATURES))
    cross = np.zeros(nb + 1)
    for I, O in requests:
        I = float(I)
        O = max(float(O), 1.0)
        f = I + O
        lo = np.searchsorted(edges, I, side="right") - 1
        hi = np.searchsorted(edges, f, side="left")
        for j in range(max(lo, 0), min(hi, nb)):
            a, b = edges[j], edges[j + 1]
            seg_lo, seg_hi = max(I, a), min(f, b)
            overlap = seg_hi - seg_lo
            if overlap <= 0:
                continue
            w = overlap / O                      # residency fraction
            l_rep = 0.5 * (seg_lo + seg_hi)      # mean length in bucket
            acc[j] += [0.0, w, w * I, w * I * I, w * l_rep]
        # edge crossings: I < edge < I+O
        j1 = np.searchsorted(edges, I, side="right")
        j2 = np.searchsorted(edges, f, side="left")
        cross[j1:j2 + 1] += (edges[j1:j2 + 1] > I) & (edges[j1:j2 + 1] < f)
    return WorkloadStats(edges=edges, acc=acc, cross=cross,
                         num_requests=len(requests))
