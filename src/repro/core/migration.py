"""Live KV-cache migration (paper §5, adapting Llumnix's mechanism).

Multi-round live migration: while the source keeps decoding, round k
copies the KV written since round k−1 started; rounds shrink geometrically
until the residual is below ``stop_threshold`` tokens, then a brief
stop-and-copy finishes the hand-off. A per-instance concurrency cap
(3 transfers) and skip-if-no-idle-slot flow control are enforced by the
``MigrationManager``.

Two consumers:
  * the discrete-event simulator uses ``plan_live_migration`` timings;
  * the real in-process server moves actual KV pytrees with
    ``slice_kv_batch`` / ``merge_kv_batch`` (device-to-device copies —
    this container's stand-in for cudaMemcpyPeerAsync / RDMA).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

MAX_CONCURRENT = 3            # §5: strict concurrency limit
STOP_THRESHOLD = 256          # tokens left -> stop-and-copy
MAX_ROUNDS = 8


@dataclasses.dataclass(frozen=True)
class MigrationTiming:
    total_s: float            # wall time from start to ownership flip
    stall_s: float            # source decode stall (final round only)
    rounds: int
    bytes_moved: float


def plan_live_migration(tokens: float, decode_tok_per_s: float,
                        bytes_per_token: float, bandwidth: float,
                        stop_threshold: int = STOP_THRESHOLD) -> MigrationTiming:
    """Timing of a multi-round live migration of ``tokens`` KV tokens."""
    bw_tok = bandwidth / max(bytes_per_token, 1e-9)    # tokens/s on the wire
    remaining = float(tokens)
    total = 0.0
    moved = 0.0
    rounds = 0
    while remaining > stop_threshold and rounds < MAX_ROUNDS:
        t = remaining / bw_tok
        total += t
        moved += remaining
        # decode continued during the round: new residual to copy
        remaining = decode_tok_per_s * t
        rounds += 1
    stall = remaining / bw_tok                         # stop-and-copy
    total += stall
    moved += remaining
    return MigrationTiming(total_s=total, stall_s=stall, rounds=rounds + 1,
                           bytes_moved=moved * bytes_per_token)


class MigrationManager:
    """Concurrency + flow control for one instance's outbound transfers."""

    def __init__(self, max_concurrent: int = MAX_CONCURRENT):
        self.max_concurrent = max_concurrent
        self.active: Dict[int, float] = {}     # req_id -> finish time (sim)

    def can_start(self, target_has_idle_slot: bool) -> bool:
        # §5: skip migration entirely if the target has no idle cache slot;
        # requests above the concurrency cap stay on the source.
        return target_has_idle_slot and len(self.active) < self.max_concurrent

    def start(self, req_id: int, finish_time: float) -> None:
        assert len(self.active) < self.max_concurrent
        self.active[req_id] = finish_time

    def finish(self, req_id: int) -> None:
        self.active.pop(req_id, None)


# --------------------------------------------------------------------------
# Real KV movement for the in-process multi-engine server
# --------------------------------------------------------------------------
def slice_kv_batch(cache, index: int):
    """Extract request ``index``'s KV slice from a batched cache pytree.
    Cache leaves are [L, B, S, ...] (or [B, ...] for recurrent states with
    leading layer axes folded) — we slice the batch axis (axis 1 for
    [L, B, ...] leaves, axis 0 otherwise is not used here)."""
    return jax.tree.map(lambda a: a[:, index:index + 1], cache)


def merge_kv_batch(cache, piece, index: int):
    """Write a sliced KV piece into slot ``index`` of a batched cache."""
    def put(a, p):
        return jax.lax.dynamic_update_slice_in_dim(a, p.astype(a.dtype),
                                                   index, axis=1)
    return jax.tree.map(put, cache, piece)


def gather_kv_blocks(pool, block_ids):
    """Extract a request's physical blocks from a paged pool.

    Pool leaves are [L, NB, BS, ...]; ``block_ids`` is the request's block
    table (ordered logical->physical). Returns leaves [L, nb, BS, ...] —
    the migration wire format for the paged engine (DESIGN.md §Migration):
    bytes moved scale with ceil(length/BS)·BS, not max_seq.
    """
    idx = jnp.asarray(block_ids, jnp.int32)
    return jax.tree.map(lambda a: a[:, idx], pool)


def scatter_kv_blocks(pool, piece, block_ids):
    """Write a gathered piece (leaves [L, nb, BS, ...]) into freshly
    allocated blocks of the destination pool."""
    idx = jnp.asarray(block_ids, jnp.int32)

    def put(a, p):
        return a.at[:, idx].set(p.astype(a.dtype))
    return jax.tree.map(put, pool, piece)


def kv_bytes(cache) -> float:
    return float(sum(a.size * a.dtype.itemsize
                     for a in jax.tree.leaves(cache)))
