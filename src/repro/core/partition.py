"""Length-aware stage partition (paper §4.2).

DP over (stages s, instances e, cut point l):

    f[s,e,l] = min_{e',l'}  f[s-1,e',l'] + (e-e')·Q^{n_{l',l}/(e-e')} + c_{l'}

Three solvers:
  * ``full_dp``       — the exact recursion over exponential buckets,
                        O(E² · S · nb²) with O(1) prefix-sum features.
  * ``two_phase``     — the paper's optimized heuristic: a 1-instance-per-
                        stage chain DP (O(E·nb²)), then greedy adjacent-stage
                        merges by max positive merge gain.
  * ``naive_cost_estimate`` — operation count of the unbucketed O(E³L²) DP
                        (for the §6.5 "51 hours vs 0.06 s" table).

Even division of a request set among m instances scales every extensive
feature by 1/m (the paper's sorted every-m-th-element division — footnote 1).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional

import numpy as np

from repro.core.qoe import QoEModel
from repro.core.workload_stats import WorkloadStats

INF = float("inf")


@dataclasses.dataclass(frozen=True)
class Stage:
    lo: float              # serving range [lo, hi)
    hi: float
    num_instances: int


@dataclasses.dataclass
class PipelinePlan:
    stages: List[Stage]
    quality: float

    def stage_for_length(self, length: float) -> int:
        """Earliest stage whose range covers ``length`` (§3.2 routing)."""
        for i, st in enumerate(self.stages):
            if length < st.hi:
                return i
        return len(self.stages) - 1

    @property
    def num_instances(self) -> int:
        return sum(s.num_instances for s in self.stages)

    def boundaries(self) -> List[float]:
        return [s.hi for s in self.stages[:-1]]


def _stage_q(stats: WorkloadStats, qoe: QoEModel, j_lo: int, j_hi: int,
             m: int) -> float:
    """(e−e')·Q^{n/(e−e')}: m instances evenly sharing bucket range."""
    F = stats.range_features(j_lo, j_hi)
    if F[1] <= 0:
        return 0.0
    return m * qoe.batch_q_from_F(F / m)


def _cut_cost(stats: WorkloadStats, j: int, kv_bytes_per_token: float,
              bandwidth: float, weight: float = 1.0) -> float:
    """c_{l'}: volume of sequence fragments straddling the cut / bandwidth."""
    if j == 0 or j >= len(stats.edges):
        return 0.0
    tokens = stats.edge_crossings(j) * stats.edges[j]
    return weight * tokens * kv_bytes_per_token / bandwidth


def full_dp(stats: WorkloadStats, E: int, qoe: QoEModel, *,
            kv_bytes_per_token: float = 2e5, bandwidth: float = 25e9,
            max_stages: Optional[int] = None) -> PipelinePlan:
    nb = stats.nb
    S = min(max_stages or E, E)
    # f[s][e][l]: best quality, s stages, e instances, covering buckets [0, l)
    f = np.full((S + 1, E + 1, nb + 1), INF)
    arg = np.full((S + 1, E + 1, nb + 1, 2), -1, dtype=np.int64)
    f[0, 0, 0] = 0.0
    for s in range(1, S + 1):
        for e in range(s, E + 1):
            for l in range(s, nb + 1):
                best, be, bl = INF, -1, -1
                for e_prev in range(s - 1, e):
                    m = e - e_prev
                    for l_prev in range(s - 1, l):
                        prev = f[s - 1, e_prev, l_prev]
                        if prev == INF:
                            continue
                        q = _stage_q(stats, qoe, l_prev, l, m)
                        c = _cut_cost(stats, l_prev, kv_bytes_per_token,
                                      bandwidth)
                        val = prev + q + c
                        if val < best:
                            best, be, bl = val, e_prev, l_prev
                f[s, e, l] = best
                arg[s, e, l] = (be, bl)
    # best over all stage counts with all E instances, full length coverage
    s_best = int(np.argmin(f[1:, E, nb])) + 1
    quality = float(f[s_best, E, nb])
    # backtrack
    stages: List[Stage] = []
    s, e, l = s_best, E, nb
    while s > 0:
        e_prev, l_prev = arg[s, e, l]
        stages.append(Stage(lo=float(stats.edges[l_prev]),
                            hi=float(stats.edges[l]) if l < nb else INF,
                            num_instances=e - e_prev))
        s, e, l = s - 1, int(e_prev), int(l_prev)
    stages.reverse()
    stages[-1] = dataclasses.replace(stages[-1], hi=INF)
    return PipelinePlan(stages=stages, quality=quality)


def _chain_dp(stats: WorkloadStats, E: int, qoe: QoEModel,
              kv_bytes_per_token: float, bandwidth: float) -> List[Stage]:
    """Phase 1: exactly one instance per stage, E stages."""
    nb = stats.nb
    f = np.full((E + 1, nb + 1), INF)
    arg = np.full((E + 1, nb + 1), -1, dtype=np.int64)
    f[0, 0] = 0.0
    for s in range(1, E + 1):
        for l in range(s, nb + 1):
            best, bl = INF, -1
            for l_prev in range(s - 1, l):
                prev = f[s - 1, l_prev]
                if prev == INF:
                    continue
                val = (prev + _stage_q(stats, qoe, l_prev, l, 1)
                       + _cut_cost(stats, l_prev, kv_bytes_per_token,
                                   bandwidth))
                if val < best:
                    best, bl = val, l_prev
            f[s, l] = best
            arg[s, l] = bl
    stages: List[Stage] = []
    s, l = E, nb
    while s > 0:
        l_prev = int(arg[s, l])
        stages.append(Stage(float(stats.edges[l_prev]),
                            float(stats.edges[l]) if l < nb else INF, 1))
        s, l = s - 1, l_prev
    stages.reverse()
    return stages


def two_phase(stats: WorkloadStats, E: int, qoe: QoEModel, *,
              kv_bytes_per_token: float = 2e5,
              bandwidth: float = 25e9) -> PipelinePlan:
    """Paper's optimized solver: chain DP + greedy adjacent merges."""
    stages = _chain_dp(stats, E, qoe, kv_bytes_per_token, bandwidth)
    edges = list(stats.edges)

    def jdx(x: float) -> int:
        if x == INF:
            return stats.nb
        return int(np.searchsorted(stats.edges, x))

    def stage_cost(st: Stage) -> float:
        return _stage_q(stats, qoe, jdx(st.lo), jdx(st.hi), st.num_instances)

    def boundary_cost(st: Stage) -> float:
        return _cut_cost(stats, jdx(st.lo), kv_bytes_per_token, bandwidth)

    while len(stages) > 1:
        # merge gain for each adjacent pair (naive O(E) scan per §4.2)
        best_gain, best_i = 0.0, -1
        for i in range(len(stages) - 1):
            a, b = stages[i], stages[i + 1]
            before = stage_cost(a) + stage_cost(b) + boundary_cost(b)
            merged = Stage(a.lo, b.hi, a.num_instances + b.num_instances)
            gain = before - stage_cost(merged)
            if gain > best_gain:
                best_gain, best_i = gain, i
        if best_i < 0:
            break
        a, b = stages[best_i], stages[best_i + 1]
        stages[best_i:best_i + 2] = [
            Stage(a.lo, b.hi, a.num_instances + b.num_instances)]

    total = sum(stage_cost(s) for s in stages)
    total += sum(boundary_cost(s) for s in stages[1:])
    return PipelinePlan(stages=stages, quality=total)


def naive_cost_estimate(E: int, max_len: int) -> float:
    """Operation count of the unbucketed O(E³·L²) DP (§6.5 table)."""
    return float(E) ** 3 * float(max_len) ** 2
