"""Moved to ``repro.control.refinement`` (the backend-agnostic
control-plane package); this shim keeps the historical import path
working."""
from repro.control.refinement import (BoundaryRefiner,  # noqa: F401
                                      divide_evenly, memory_based_split,
                                      optimal_split, quantity_based_split)
