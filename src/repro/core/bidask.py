"""Moved to ``repro.control.bidask`` (the backend-agnostic control-plane
package); this shim keeps the historical import path working."""
from repro.control.bidask import (KEEP_EARLIEST, OVERLOAD_FACTOR,  # noqa: F401
                                  STARVATION_THRESHOLD, Bid, MigRequest,
                                  ReceiverState, SenderState, is_overloaded,
                                  select_receiver)
