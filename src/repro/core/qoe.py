"""QoE model (paper §4.1).

Per-request quality under a steady batch:
    Q = Σ_k D_k F_k,  F = [1, n, ΣI_i, ΣI_i², ΣL_i]
(normalized latency — end-to-end latency / output length). Batch QoE is
Q^B = n · Q₁ (Eq. 1).

Fitting follows §4.1: profile (length-bucket × batch-size) runs keeping B
requests in flight, extract each request's normalized latency and its
average batch loads F_k, then least-squares D against F. The profiling
*source* in this repo is the discrete-event simulator (whose ground-truth
cost function includes the kernel-derived heterogeneity tax the QoE model
deliberately does NOT know about — same model/reality separation as the
paper's fitted model vs. the real GPU).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

NUM_FEATURES = 5


def batch_features(inputs: Sequence[float], lengths: Sequence[float],
                   weights: Sequence[float] | None = None) -> np.ndarray:
    """F = [1, n, ΣI, ΣI², ΣL] for a request set (optionally weighted —
    weights are residency fractions when sets are built from trajectories)."""
    I = np.asarray(inputs, np.float64)
    L = np.asarray(lengths, np.float64)
    w = np.ones_like(I) if weights is None else np.asarray(weights, np.float64)
    return np.array([1.0, w.sum(), (w * I).sum(), (w * I * I).sum(),
                     (w * L).sum()])


@dataclasses.dataclass
class QoEModel:
    D: np.ndarray  # [5]

    def request_q(self, F: np.ndarray) -> float:
        """Normalized latency of one request under batch loads F."""
        return float(self.D @ F)

    def batch_q(self, inputs, lengths, weights=None) -> float:
        """Q^B = n · Q₁ (Eq. 1). Empty set -> 0."""
        F = batch_features(inputs, lengths, weights)
        n = F[1]
        if n <= 0:
            return 0.0
        return n * self.request_q(F)

    def batch_q_from_F(self, F: np.ndarray) -> float:
        n = F[1]
        if n <= 0:
            return 0.0
        return n * float(self.D @ F)

    def save(self, path: str) -> None:
        np.save(path, self.D)

    @classmethod
    def load(cls, path: str) -> "QoEModel":
        return cls(np.load(path))


def fit_qoe(F_samples: np.ndarray, Q_samples: np.ndarray,
            ridge: float = 1e-8, nonneg: bool = True) -> QoEModel:
    """Least-squares fit of D (§4.1):  argmin Σ_j (Q^(j) − Σ_k D_k F_k^(j))².

    F_samples [N, 5]; Q_samples [N]. A whisper of ridge keeps the normal
    equations well-posed when a profiling sweep leaves features collinear
    (e.g. fixed batch size makes F1 constant). With ``nonneg`` the fit is
    projected onto D ≥ 0 via an active-set loop — all five coefficients are
    physically nonnegative costs, and collinear ΣI/ΣL columns otherwise
    trade sign freely.
    """
    F = np.asarray(F_samples, np.float64)
    Q = np.asarray(Q_samples, np.float64)
    # column scaling for conditioning (I² reaches 1e10 at 100k lengths)
    scale = np.maximum(np.abs(F).max(axis=0), 1e-12)
    Fs = F / scale
    k = F.shape[1]
    active = np.ones(k, bool)
    for _ in range(k + 1):
        A = Fs[:, active].T @ Fs[:, active] + ridge * np.eye(active.sum())
        b = Fs[:, active].T @ Q
        sol = np.linalg.solve(A, b)
        if not nonneg or (sol >= 0).all():
            break
        idx = np.flatnonzero(active)
        active[idx[sol < 0]] = False
        if not active.any():
            sol = np.zeros(0)
            break
    D = np.zeros(k)
    D[active] = sol
    if nonneg:
        D = np.maximum(D, 0.0)
    return QoEModel(D / scale)


def relative_errors(model: QoEModel, F_samples: np.ndarray,
                    Q_samples: np.ndarray) -> np.ndarray:
    """Per-request relative prediction error (paper Fig. 13 metric)."""
    pred = np.asarray(F_samples, np.float64) @ model.D
    Q = np.asarray(Q_samples, np.float64)
    return (pred - Q) / np.maximum(np.abs(Q), 1e-12)


def static_baseline_errors(F_samples: np.ndarray,
                           Q_samples: np.ndarray) -> np.ndarray:
    """The paper's Fig.-13 baseline: always predict the global mean."""
    Q = np.asarray(Q_samples, np.float64)
    return (Q.mean() - Q) / np.maximum(np.abs(Q), 1e-12)
