"""AdamW + cosine/linear-warmup schedule, implemented natively (no optax
dependency). State is a pytree shaped like params — shards identically
under pjit (ZeRO-1-style sharding is applied at the launch layer).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    grad_clip: float = 1.0


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init_adamw(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState,
                 params) -> Tuple[Any, AdamWState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    new_p, new_m, new_v = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        np_, nm, nv = upd(g, m, v, p)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    params = jax.tree.unflatten(tdef, new_p)
    st = AdamWState(step=step, mu=jax.tree.unflatten(tdef, new_m),
                    nu=jax.tree.unflatten(tdef, new_v))
    return params, st, {"lr": lr, "grad_norm": gnorm}
