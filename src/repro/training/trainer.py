"""Training loop: jit-compiled AdamW step, periodic checkpointing,
loss/metric logging. Used by the end-to-end example (train a ~100M model
for a few hundred steps) and by the per-arch train smoke tests; the
distributed variant lives in launch/train.py (same step function under
pjit shardings).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.training.checkpoint import save_checkpoint
from repro.training.data import DataConfig, TokenStream
from repro.training.optimizer import (AdamWConfig, AdamWState, adamw_update,
                                      init_adamw)


@dataclasses.dataclass
class TrainConfig:
    steps: int = 200
    log_every: int = 20
    ckpt_every: int = 0              # 0 = only at end
    ckpt_path: Optional[str] = None
    remat: bool = False
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    remat: bool = False) -> Callable:
    def train_step(params, opt_state: AdamWState, batch):
        def loss_fn(p):
            loss, aux = model.loss(p, batch, remat=remat)
            return loss, aux
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state, info = adamw_update(opt_cfg, grads, opt_state,
                                               params)
        metrics = {"loss": loss, **info}
        return params, opt_state, metrics
    return train_step


def train(model: Model, params, data: TokenStream,
          cfg: TrainConfig) -> Dict[str, List[float]]:
    opt_state = init_adamw(params)
    step_fn = jax.jit(make_train_step(model, cfg.opt, cfg.remat))
    history: Dict[str, List[float]] = {"loss": [], "lr": [], "grad_norm": []}
    it = iter(data)
    t0 = time.time()
    for step in range(1, cfg.steps + 1):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        if step % cfg.log_every == 0 or step == cfg.steps:
            loss = float(m["loss"])
            history["loss"].append(loss)
            history["lr"].append(float(m["lr"]))
            history["grad_norm"].append(float(m["grad_norm"]))
            rate = step / (time.time() - t0)
            print(f"step {step:5d}  loss {loss:.4f}  "
                  f"lr {float(m['lr']):.2e}  "
                  f"gnorm {float(m['grad_norm']):.3f}  {rate:.2f} it/s")
        if cfg.ckpt_path and cfg.ckpt_every and step % cfg.ckpt_every == 0:
            save_checkpoint(cfg.ckpt_path, params, step)
    if cfg.ckpt_path:
        save_checkpoint(cfg.ckpt_path, params, cfg.steps)
    history["params"] = params          # type: ignore[assignment]
    return history
