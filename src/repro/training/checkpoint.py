"""Checkpointing: pytree <-> .npz with slash-joined key paths.

Restores onto the existing tree structure (shape/dtype checked), so it
round-trips params, optimizer state, and caches alike.
"""
from __future__ import annotations

import os
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save_checkpoint(path: str, tree: Any, step: int | None = None) -> None:
    flat = _flatten(tree)
    if step is not None:
        flat["__step__"] = np.asarray(step)
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)


def load_checkpoint(path: str, like: Any) -> tuple[Any, int | None]:
    """Restore into the structure of ``like``; returns (tree, step)."""
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    step = int(flat.pop("__step__")) if "__step__" in flat else None
    paths_leaves, tdef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in paths_leaves:
        key = "/".join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key!r}")
        arr = flat[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(tdef, leaves), step
