"""Token data pipeline: deterministic synthetic LM streams.

A Zipf-distributed unigram stream with injected bigram structure — learnable
by a small model in a few hundred steps (loss drops measurably), which is
what the end-to-end training example asserts. Batches come out as the
``Model.loss`` batch dict for the arch's family (audio/vision stubs filled
with deterministic pseudo-embeddings).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np

from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch_size: int = 8
    seq_len: int = 128
    seed: int = 0
    zipf_a: float = 1.3


class TokenStream:
    """Infinite deterministic batch iterator."""

    def __init__(self, cfg: ModelConfig, data: DataConfig):
        self.cfg = cfg
        self.data = data
        self._rng = np.random.default_rng(data.seed)
        V = cfg.vocab_size
        # fixed random bigram successor table => learnable structure
        table_rng = np.random.default_rng(12345)
        self._succ = table_rng.integers(0, V, V)

    def _sample_tokens(self, B: int, T: int) -> np.ndarray:
        V = self.cfg.vocab_size
        z = self._rng.zipf(self.data.zipf_a, (B, T)) % V
        out = z.astype(np.int64)
        # 60% of positions follow the bigram table (signal); rest noise
        follow = self._rng.random((B, T)) < 0.6
        for t in range(1, T):
            out[:, t] = np.where(follow[:, t], self._succ[out[:, t - 1]],
                                 out[:, t])
        return out.astype(np.int32)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        B, T = self.data.batch_size, self.data.seq_len
        batch: Dict[str, np.ndarray] = {"tokens": self._sample_tokens(B, T)}
        cfg = self.cfg
        if cfg.family == "encdec":
            e_rng = np.random.default_rng(self.data.seed + 7)
            batch["audio_embeds"] = e_rng.normal(
                0, 1, (B, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        if cfg.family == "vlm":
            n_patch = max(1, T // 8)
            vm = np.zeros((B, T), bool)
            vm[:, :n_patch] = True
            e_rng = np.random.default_rng(self.data.seed + 13)
            batch["vision_embeds"] = e_rng.normal(
                0, 1, (B, n_patch, cfg.d_model)).astype(np.float32)
            batch["vision_mask"] = vm
        return batch
