"""Block-level cost model for the decode-attention kernel on TPU.

This is the analytic bridge between the kernel and the rest of the system:
  * the Fig.-2 benchmark uses it to quantify the heterogeneity tax,
  * the simulator's ground-truth iteration cost is calibrated from it,
  * §Perf napkin math reads straight off these terms.

Model (per decode iteration, per chip):
  padded backend:  blocks(b) = ceil(S_pad / BS) for every request
  ragged backend:  blocks(b) = ceil(L_b / BS) compute + skip-overhead

Each KV block costs DMA ``2·BS·Dh·bytes / HBM_bw`` (K and V streamed
HBM→VMEM) and MXU ``2·2·G·BS·Dh / peak`` FLOP-time; decode attention has
arithmetic intensity ≈ G (<< ridge point), so the DMA term dominates and a
block's wall time is max(dma, mxu) ≈ dma — which is why wasted *padded*
blocks hurt exactly in proportion to their count, matching the paper's
observation that heterogeneity, not raw FLOPs, sets the iteration time.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

# TPU v5e constants (assignment-specified)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
SKIP_OVERHEAD_S = 2e-7       # per skipped grid step (scalar branch + DMA mgmt)
LAUNCH_OVERHEAD_S = 2e-6     # per EXTRA kernel launch beyond the first
                             # (dispatch + grid setup + scalar prefetch)
HOST_STAGING_BW = 16e9       # bytes/s host<->device staging (PCIe-class
                             # link the multi-tier KV demote/promote copies
                             # ride — DESIGN.md §Multi-tier KV)
PROMOTE_TOKEN_COST = 0.25    # routing price of one host-tier cached token:
                             # the h2d copy is ~4x cheaper than recomputing
                             # the token's prefill, so a host hit is priced
                             # as a quarter-length prompt tail


def h2d_block_time_s(block_bytes: float) -> float:
    """Wall time to stage ONE KV block across the host link (either
    direction — demote d2h and promote h2d ride the same staging path):
    a launch-sized dispatch overhead plus the payload at staging
    bandwidth."""
    return LAUNCH_OVERHEAD_S + float(block_bytes) / HOST_STAGING_BW


def promote_cost_tokens(n_blocks: int, block_size: int) -> float:
    """Token-equivalent ROUTING price of promoting ``n_blocks`` host-tier
    blocks: a host hit is cheaper than recompute but not free, so
    routing's effective length charges ``uncached_tail + this`` instead
    of treating the hit like a device hit. Pure and deterministic — the
    real server and the simulator call it with identical inputs, which
    is what keeps their decision logs in lockstep (DESIGN.md §Multi-tier
    KV)."""
    return PROMOTE_TOKEN_COST * float(n_blocks) * float(block_size)


def kv_bytes_per_elem(kv_dtype: str, head_dim: int) -> float:
    """HBM bytes per stored KV element. int8 carries one f32 scale per
    (position, kv-head) row amortized over the head dim —
    ``(Dh + 4)/Dh`` bytes, ≈ 1.94× denser than bf16 at Dh = 128
    (DESIGN.md §Quantized KV blocks)."""
    if kv_dtype == "int8":
        return 1.0 + 4.0 / head_dim
    assert kv_dtype == "bf16", kv_dtype
    return 2.0


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    num_q_heads: int
    num_kv_heads: int
    head_dim: int
    kv_bytes: float = 2.0      # bf16 cache; kv_bytes_per_elem for int8
    block_s: int = 512


def block_time_s(spec: AttnSpec) -> float:
    """Wall time of one (kv-head, kv-block) grid step."""
    g = spec.num_q_heads // spec.num_kv_heads
    dma = 2 * spec.block_s * spec.head_dim * spec.kv_bytes / HBM_BW
    mxu = 2 * 2 * g * spec.block_s * spec.head_dim / PEAK_FLOPS
    return max(dma, mxu)


def padded_blocks(lengths: Sequence[int], block_s: int,
                  pad_to: int | None = None) -> int:
    """Grid steps a padded (paper-faithful) backend executes per kv head."""
    if not len(lengths):
        return 0
    s_pad = pad_to if pad_to is not None else max(lengths)
    return len(lengths) * math.ceil(max(s_pad, 1) / block_s)


def ragged_blocks(lengths: Sequence[int], block_s: int) -> int:
    """Compute blocks a ragged backend executes per kv head."""
    return sum(math.ceil(max(l, 1) / block_s) for l in lengths)


def pow2_bucket(n: int) -> int:
    """Smallest power of two >= n (bucketing policy shared by the flat
    grid, the engine's table width, and prefill prompt padding)."""
    p = 1
    while p < n:
        p *= 2
    return p


def flat_grid_blocks(lengths: Sequence[int], block_s: int,
                     bucketed: bool = True) -> int:
    """Grid steps the work-flattened backend executes per kv head: the
    real Σ_b ceil(L_b/BS) work items, padded to a pow2 bucket (padding
    items skip compute but still take a grid step — the flat analogue of
    SKIP_OVERHEAD_S, bounded at < 2x by the bucketing)."""
    n = ragged_blocks(lengths, block_s)
    return pow2_bucket(n) if (bucketed and n) else n


def decode_attn_time_flat_s(lengths: Sequence[int], spec: AttnSpec) -> float:
    """Decode-attention wall time for the work-flattened grid: unlike the
    ragged (B, Hkv, NBT) grid, no request pays another's block count — the
    only overhead is the pow2 bucket's padding tail."""
    if not len(lengths):
        return 0.0
    comp = ragged_blocks(lengths, spec.block_s)
    skipped = flat_grid_blocks(lengths, spec.block_s) - comp
    return spec.num_kv_heads * (comp * block_time_s(spec)
                                + skipped * SKIP_OVERHEAD_S)


def decode_attn_time_s(lengths: Sequence[int], spec: AttnSpec,
                       ragged: bool = False,
                       pad_to: int | None = None) -> float:
    """Decode-attention wall time for one iteration over a batch."""
    if not len(lengths):
        return 0.0
    t_blk = block_time_s(spec)
    full = padded_blocks(lengths, spec.block_s, pad_to)
    if not ragged:
        return spec.num_kv_heads * full * t_blk
    comp = ragged_blocks(lengths, spec.block_s)
    skipped = full - comp
    return spec.num_kv_heads * (comp * t_blk + skipped * SKIP_OVERHEAD_S)


# --------------------------------------------------------------------------
# Chunked prefill + mixed iterations (DESIGN.md §Chunked prefill) — the
# analytic mirror of kernels/prefill_attention.paged_prefill_attention and
# the engine's token-budgeted mixed step; sim/costmodel builds its ground
# truth from these instead of its own I² formula.
# --------------------------------------------------------------------------
def prefill_chunk_blocks(chunk: int, ctx: int, block_s: int) -> int:
    """Grid steps (per kv head) the chunked-prefill kernel runs for one
    chunk: every block of the written context plus the chunk itself."""
    return math.ceil(max(ctx + chunk, 1) / block_s)


def prefill_chunk_flops(chunk: int, ctx: int, spec: AttnSpec) -> float:
    """Attention MXU FLOPs of ONE prompt chunk at one layer: score + PV
    matmuls of ``chunk`` queries against the written context plus the
    (block-causally pruned) own chunk. Summing over a prompt's chunks
    recovers the causal whole-prompt count ≈ 2·H·Dh·I², so a single
    chunk=I call prices the monolithic prefill too — one formula, every
    granularity."""
    own = (chunk + spec.block_s) / 2.0        # causal prune within the chunk
    return 4.0 * spec.num_q_heads * spec.head_dim * chunk * (ctx + own)


def prefill_flops(input_len: int, spec: AttnSpec,
                  cached_tokens: int = 0) -> float:
    """Attention MXU FLOPs one layer spends prefilling a prompt of which
    ``cached_tokens`` leading tokens are already resident in the prefix
    cache (DESIGN.md §Prefix cache): only the uncached tail runs, as one
    logical chunk attending to the cached context plus itself. With
    ``cached_tokens=0`` this is the whole-prompt causal count."""
    cached = min(int(cached_tokens), max(int(input_len) - 1, 0))
    return prefill_chunk_flops(int(input_len) - cached, cached, spec)


def prefill_flops_skipped(input_len: int, cached_tokens: int,
                          spec: AttnSpec) -> float:
    """FLOPs a warm prefill never runs vs. a cold one — the benchmark's
    prefill-FLOPs-skipped counter (`benchmarks/bench_prefix_cache.py`)."""
    return (prefill_flops(input_len, spec)
            - prefill_flops(input_len, spec, cached_tokens))


def prefill_chunk_attn_time_s(chunk: int, ctx: int, spec: AttnSpec) -> float:
    """Wall time of one chunk's paged-prefill attention: DMA of the
    context blocks (HBM→VMEM, per kv head) vs. the chunk's MXU time —
    compute-bound for real chunk sizes, DMA-bound when a tiny chunk drags
    a huge context (which is why the engine packs chunks to a budget)."""
    blocks = prefill_chunk_blocks(chunk, ctx, spec.block_s)
    dma = (spec.num_kv_heads * blocks
           * 2 * spec.block_s * spec.head_dim * spec.kv_bytes / HBM_BW)
    mxu = prefill_chunk_flops(chunk, ctx, spec) / PEAK_FLOPS
    return max(dma, mxu)


def fused_grid_items(chunks: Sequence[tuple], decode_lengths: Sequence[int],
                     block_s: int) -> int:
    """Grid steps (per kv head) of the FUSED mixed-iteration work list:
    pow2 bucket of the decode rows' real blocks PLUS pow2 bucket of the
    chunk blocks. The engine buckets the two halves independently rather
    than pow2(dec+ck) — a single bucket can overshoot the pair (e.g.
    9+8 → 32 vs 16+8), which would let the merged grid pay MORE padding
    than the two kernels it replaces; with split buckets the padding tail
    is identical by construction and fusing saves exactly the extra
    launch (DESIGN.md §Fused mixed-iteration attention)."""
    dec = ragged_blocks(decode_lengths, block_s)
    ck = sum(prefill_chunk_blocks(int(c), int(x), block_s)
             for c, x in chunks)
    return ((pow2_bucket(dec) if dec else 0)
            + (pow2_bucket(ck) if ck else 0))


def mixed_iter_time_s(chunks: Sequence[tuple], decode_lengths: Sequence[int],
                      spec: AttnSpec, *,
                      decode_backend: str = "flat") -> float:
    """Attention wall time of one token-budgeted MIXED iteration: the
    decode batch plus every packed prompt chunk ``(chunk_len, ctx_len)``
    — the analytic mirror of the engine's fused step (decode burst +
    chunked prefill, one device round-trip). ``decode_backend`` picks the
    decode term's kernel model (``fused`` | ``flat`` | ``ragged`` |
    ``padded``) so a chunked-vs-monolithic comparison can hold the decode
    backend fixed and attribute only the prefill difference to chunking.

    ``fused`` prices the single tagged work list: one launch carrying the
    same decode + chunk padding tails the separate kernels pad. The
    separate backends pay the chunk grid's own padding tail PLUS the
    extra chunk-batch launch (``LAUNCH_OVERHEAD_S``)."""
    if decode_backend == "fused":
        comp_dec = ragged_blocks(decode_lengths, spec.block_s)
        comp_ck = sum(prefill_chunk_blocks(int(c), int(x), spec.block_s)
                      for c, x in chunks)
        skipped = max(fused_grid_items(chunks, decode_lengths, spec.block_s)
                      - comp_dec - comp_ck, 0)
        t = spec.num_kv_heads * (comp_dec * block_time_s(spec)
                                 + skipped * SKIP_OVERHEAD_S)
        for chunk, ctx in chunks:
            t += prefill_chunk_attn_time_s(int(chunk), int(ctx), spec)
        return t
    if decode_backend == "flat":
        t = decode_attn_time_flat_s(decode_lengths, spec)
    else:
        t = decode_attn_time_s(decode_lengths, spec,
                               ragged=(decode_backend == "ragged"))
    for chunk, ctx in chunks:
        t += prefill_chunk_attn_time_s(int(chunk), int(ctx), spec)
    if len(chunks):
        ck = sum(prefill_chunk_blocks(int(c), int(x), spec.block_s)
                 for c, x in chunks)
        skipped_ck = pow2_bucket(ck) - ck
        t += (spec.num_kv_heads * skipped_ck * SKIP_OVERHEAD_S
              + LAUNCH_OVERHEAD_S)  # the separate chunk-batch launch
    return t


def allreduce_time_s(payload_bytes: float, num_devices: int) -> float:
    """Ring all-reduce wall time over ``num_devices`` chips on the ICI:
    each chip moves ``2·(n-1)/n`` of the payload through one link
    (reduce-scatter + all-gather). n <= 1 is free — the tensor-parallel
    cost terms call this unconditionally (DESIGN.md §Sharded serving)."""
    n = int(num_devices)
    if n <= 1 or payload_bytes <= 0:
        return 0.0
    return 2.0 * (n - 1) / n * float(payload_bytes) / ICI_BW


def heterogeneity_tax(lengths: Sequence[int], spec: AttnSpec) -> float:
    """Fraction of padded-backend time wasted vs. a length-homogeneous
    batch with the same total token count (the paper's Fig.-2 metric)."""
    if not len(lengths):
        return 0.0
    hetero = decode_attn_time_s(lengths, spec, ragged=False)
    mean = sum(lengths) / len(lengths)
    homog = decode_attn_time_s([mean] * len(lengths), spec, ragged=False)
    return hetero / max(homog, 1e-12)
