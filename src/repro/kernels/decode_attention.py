"""Flash-decode GQA attention — Pallas TPU kernel.

This is the serving hot spot the paper's scheduling is built around: decode
attention over a (possibly heterogeneous) batch of KV caches.

TPU adaptation of the paper's SM-block analysis (DESIGN.md §2): the grid is
``(B, Hkv, S/BS)`` and TPU grid steps execute *sequentially* per core, so a
batch padded to its longest member burns ``Σ_b (ceil(maxL/BS) − ceil(L_b/BS))``
wasted block iterations — the TPU restatement of inter-SM imbalance.

Two layouts, three modes, same numerics:
  * ``ragged=False`` (paper-faithful backend): every KV block is fetched and
    computed, out-of-range positions masked — cost ∝ B · ceil(S/BS).
  * ``ragged=True`` (beyond-paper): per-request length scalars are prefetched
    (SMEM) and fully-masked blocks skip the MXU work via ``pl.when`` —
    cost ∝ Σ_b ceil(L_b/BS) plus a small per-skipped-block grid overhead.
  * ``paged_decode_attention``: same ragged skip, but KV lives in a global
    block *pool* ``[NB, BS, Hkv, Dh]`` and each request's blocks are chased
    through a prefetched block table — the serving engine's layout
    (DESIGN.md §Block pool), no per-request padding or copies at all.

Block design for v5e: BS=512 KV rows × Dh=128 lanes (bf16 tile 16×128
aligned, MXU contraction dim 128); the per-(b,hkv) working set is
q [G,128] + k,v [512,128] ≈ 0.26 MB ≪ 16 MB VMEM, leaving room for
double-buffered DMA of the next KV block. Accumulators (m, l, acc) live in
VMEM scratch that persists across the sequential KV-block grid dimension.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# the flash core and work-list builder live in kernels.ops (shared with the
# chunked-prefill and fused mixed-iteration kernels); flat_work_list is
# re-exported here for backward compatibility
from repro.kernels.ops import (NEG_INF, _flash_block_update, _flash_finish,
                               _flash_init, flat_work_list)

__all__ = ["decode_attention", "paged_decode_attention",
           "paged_decode_attention_flat", "flat_work_list"]

DEFAULT_BLOCK = 512


def _decode_kernel(lengths_ref,          # scalar prefetch [B]
                   q_ref,                # [1, 1, G, Dh]
                   k_ref, v_ref,         # [1, BS, 1, Dh]
                   o_ref,                # [1, 1, G, Dh]
                   m_ref, l_ref, acc_ref,  # VMEM scratch
                   *, block_s: int, ragged: bool):
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    pl.when(j == 0)(lambda: _flash_init(m_ref, l_ref, acc_ref))

    length = lengths_ref[b]
    start = j * block_s

    def _compute():
        _flash_block_update(q_ref, k_ref, v_ref, m_ref, l_ref, acc_ref,
                            start, length)

    if ragged:
        # skip the MXU work for blocks entirely beyond this request's length
        pl.when(start < length)(_compute)
    else:
        _compute()

    pl.when(j == nj - 1)(lambda: _flash_finish(o_ref, l_ref, acc_ref))


def _paged_decode_kernel(lengths_ref,        # scalar prefetch [B]
                         bt_ref,             # scalar prefetch [B, NBT]
                         q_ref,              # [1, 1, G, Dh]
                         k_ref, v_ref,       # [1, BS, 1, Dh] (one phys block)
                         o_ref,              # [1, 1, G, Dh]
                         m_ref, l_ref, acc_ref,  # VMEM scratch
                         *, block_s: int):
    """Block-table decode attention: grid step (b, h, j) DMAs *physical*
    block ``bt_ref[b, j]`` (resolved by the index maps below, before the
    body runs — scalar prefetch) holding logical KV rows
    ``[j·BS, (j+1)·BS)`` of request ``b``. Blocks at or beyond the request's
    length are pure padding (tables are padded with block 0) and skip the
    MXU work entirely, so cost is ∝ Σ_b ceil(L_b/BS) — the paged engine
    never pays for another request's length (DESIGN.md §Kernel grid)."""
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    pl.when(j == 0)(lambda: _flash_init(m_ref, l_ref, acc_ref))

    length = lengths_ref[b]
    start = j * block_s
    pl.when(start < length)(
        lambda: _flash_block_update(q_ref, k_ref, v_ref, m_ref, l_ref,
                                    acc_ref, start, length))
    pl.when(j == nj - 1)(lambda: _flash_finish(o_ref, l_ref, acc_ref))


def _flat_paged_kernel(wreq_ref, wblk_ref,   # scalar prefetch [W], [W]
                       lengths_ref,          # scalar prefetch [B]
                       bt_ref,               # scalar prefetch [B, NBT]
                       q_ref,                # [1, 1, G, Dh]
                       k_ref, v_ref,         # [1, BS, 1, Dh] (one phys block)
                       o_ref,                # [1, 1, G, Dh]
                       m_ref, l_ref, acc_ref,  # VMEM scratch
                       *, block_s: int):
    """Work-flattened paged decode attention: grid step (h, w) processes
    flat work item ``w`` = (request ``wreq[w]``, logical block ``wblk[w]``).
    The work list is exactly the Σ_b ceil(L_b/BS) real blocks (sorted by
    request, blocks in order) padded to a static bucket, so — unlike the
    (B, Hkv, NBT) grid — short requests never burn skipped grid steps up
    to the batch max NBT.

    Request boundaries are detected from the prefetched work list itself:
    the accumulators re-init on the first item of a request and the output
    row is written on its last. Padding items alias the *last* real
    request with sentinel block index NBT (so ``start >= length`` skips
    the MXU work, the accumulators are untouched, and the final write is
    an idempotent re-write of that request's row — never a new row)."""
    w = pl.program_id(1)
    nw = pl.num_programs(1)
    b = wreq_ref[w]
    j = wblk_ref[w]
    prev_b = wreq_ref[jnp.maximum(w - 1, 0)]
    next_b = wreq_ref[jnp.minimum(w + 1, nw - 1)]
    first = (w == 0) | (prev_b != b)
    last = (w == nw - 1) | (next_b != b)

    pl.when(first)(lambda: _flash_init(m_ref, l_ref, acc_ref))

    length = lengths_ref[b]
    start = j * block_s
    pl.when(start < length)(
        lambda: _flash_block_update(q_ref, k_ref, v_ref, m_ref, l_ref,
                                    acc_ref, start, length))
    pl.when(last)(lambda: _flash_finish(o_ref, l_ref, acc_ref))


@functools.partial(jax.jit, static_argnames=("num_work", "interpret"))
def paged_decode_attention_flat(q, k_pool, v_pool, block_tables, lengths, *,
                                num_work: Optional[int] = None,
                                interpret: bool = False):
    """Work-flattened variant of :func:`paged_decode_attention`.

    Same operands, same numerics, different grid: ``(Hkv, num_work)``
    where ``num_work`` is a **static** bucket >= Σ_b ceil(L_b/BS) (callers
    round up to a power of two so recompiles stay O(log total-work); None
    falls back to the worst case B·NBT). The old grid executes
    ``B · Hkv · NBT`` steps and relies on ``pl.when`` to skip the padded
    tail of every short request; this grid executes ``Hkv · num_work``
    steps total — the heterogeneity tax is gone at the grid level, not
    just at the MXU level (DESIGN.md §Decode hot path).
    """
    B, H, Dh = q.shape
    NB, BS, Hkv = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    G = H // Hkv
    NBT = block_tables.shape[1]
    assert H % Hkv == 0, (H, Hkv)
    assert NBT >= 1
    W = num_work if num_work is not None else B * NBT
    assert W >= 1
    qg = q.reshape(B, Hkv, G, Dh)
    work_req, work_blk = flat_work_list(lengths, NBT, BS, W)

    grid = (Hkv, W)
    kernel = functools.partial(_flat_paged_kernel, block_s=BS)

    def q_map(h, w, wreq, wblk, lens, bt):
        del wblk, lens, bt
        return (wreq[w], h, 0, 0)

    def kv_map(h, w, wreq, wblk, lens, bt):
        del lens
        # padding items carry block index NBT; clamp for the table lookup —
        # whatever block it DMAs is skipped by the kernel's length guard
        return (bt[wreq[w], jnp.minimum(wblk[w], NBT - 1)], 0, h, 0)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, Dh), q_map),
                pl.BlockSpec((1, BS, 1, Dh), kv_map),
                pl.BlockSpec((1, BS, 1, Dh), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, G, Dh), q_map),
            scratch_shapes=[
                pltpu.VMEM((G, 128), jnp.float32),   # m (lane-replicated)
                pltpu.VMEM((G, 128), jnp.float32),   # l
                pltpu.VMEM((G, Dh), jnp.float32),    # acc
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dh), q.dtype),
        interpret=interpret,
    )(work_req, work_blk, lengths, block_tables, qg, k_pool, v_pool)
    return out.reshape(B, H, Dh)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths, *,
                           interpret: bool = False):
    """Decode attention over a paged KV pool.

    q            [B, H, Dh]              — one query token per request
    k/v_pool     [NB, BS, Hkv, Dh]       — global physical block pool
    block_tables [B, NBT] int32          — physical block id per logical
                                           block; rows past a request's
                                           ceil(L_b/BS) blocks are padding
    lengths      [B] int32               — valid tokens per request
    returns      [B, H, Dh]

    TPU mapping: both scalars are prefetched (SMEM) so the KV BlockSpec
    index maps can chase the block table — grid step (b, h, j) DMAs
    physical block ``block_tables[b, j]`` from HBM while step j−1 computes
    (standard double-buffered sequential grid). Fully padded steps skip
    the MXU via ``pl.when``; the paged pool means no request is ever
    padded to another's length, so the grid cost is Σ_b ceil(L_b/BS).
    """
    B, H, Dh = q.shape
    NB, BS, Hkv = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    G = H // Hkv
    NBT = block_tables.shape[1]
    assert H % Hkv == 0, (H, Hkv)
    qg = q.reshape(B, Hkv, G, Dh)

    grid = (B, Hkv, NBT)
    kernel = functools.partial(_paged_decode_kernel, block_s=BS)

    def kv_map(b, h, j, lens, bt):
        del lens
        return (bt[b, j], 0, h, 0)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, Dh), lambda b, h, j, *pf: (b, h, 0, 0)),
                pl.BlockSpec((1, BS, 1, Dh), kv_map),
                pl.BlockSpec((1, BS, 1, Dh), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, G, Dh),
                                   lambda b, h, j, *pf: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 128), jnp.float32),   # m (lane-replicated)
                pltpu.VMEM((G, 128), jnp.float32),   # l
                pltpu.VMEM((G, Dh), jnp.float32),    # acc
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dh), q.dtype),
        interpret=interpret,
    )(lengths, block_tables, qg, k_pool, v_pool)
    return out.reshape(B, H, Dh)


@functools.partial(jax.jit, static_argnames=("block_s", "ragged", "interpret"))
def decode_attention(q, k, v, lengths, *, block_s: int = DEFAULT_BLOCK,
                     ragged: bool = False, interpret: bool = False):
    """q [B, H, Dh]; k, v [B, S, Hkv, Dh]; lengths [B] int32 -> [B, H, Dh].

    ``interpret=True`` runs the kernel body in Python on CPU (used for all
    validation in this repo); on a real TPU leave it False.
    """
    B, H, Dh = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    assert H % Hkv == 0, (H, Hkv)
    # monolithic caches come in any size: clamp the block to the sequence
    # and pad the sequence up to a whole number of blocks (padded rows are
    # masked by the length guard, which never exceeds S)
    block_s = min(block_s, S)
    nj = -(-S // block_s)
    if nj * block_s != S:
        pad = ((0, 0), (0, nj * block_s - S), (0, 0), (0, 0))
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    qg = q.reshape(B, Hkv, G, Dh)

    grid = (B, Hkv, nj)
    kernel = functools.partial(_decode_kernel, block_s=block_s, ragged=ragged)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, Dh), lambda b, h, j, *prefetch: (b, h, 0, 0)),
                pl.BlockSpec((1, block_s, 1, Dh), lambda b, h, j, *prefetch: (b, j, h, 0)),
                pl.BlockSpec((1, block_s, 1, Dh), lambda b, h, j, *prefetch: (b, j, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, Dh), lambda b, h, j, *prefetch: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 128), jnp.float32),   # m (lane-replicated)
                pltpu.VMEM((G, 128), jnp.float32),   # l
                pltpu.VMEM((G, Dh), jnp.float32),    # acc
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dh), q.dtype),
        interpret=interpret,
    )(lengths, qg, k, v)
    return out.reshape(B, H, Dh)
