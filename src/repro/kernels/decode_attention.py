"""Flash-decode GQA attention — Pallas TPU kernel.

This is the serving hot spot the paper's scheduling is built around: decode
attention over a (possibly heterogeneous) batch of KV caches.

TPU adaptation of the paper's SM-block analysis (DESIGN.md §2): the grid is
``(B, Hkv, S/BS)`` and TPU grid steps execute *sequentially* per core, so a
batch padded to its longest member burns ``Σ_b (ceil(maxL/BS) − ceil(L_b/BS))``
wasted block iterations — the TPU restatement of inter-SM imbalance.

Two layouts, three modes, same numerics:
  * ``ragged=False`` (paper-faithful backend): every KV block is fetched and
    computed, out-of-range positions masked — cost ∝ B · ceil(S/BS).
  * ``ragged=True`` (beyond-paper): per-request length scalars are prefetched
    (SMEM) and fully-masked blocks skip the MXU work via ``pl.when`` —
    cost ∝ Σ_b ceil(L_b/BS) plus a small per-skipped-block grid overhead.
  * ``paged_decode_attention``: same ragged skip, but KV lives in a global
    block *pool* ``[NB, BS, Hkv, Dh]`` and each request's blocks are chased
    through a prefetched block table — the serving engine's layout
    (DESIGN.md §Block pool), no per-request padding or copies at all.

Block design for v5e: BS=512 KV rows × Dh=128 lanes (bf16 tile 16×128
aligned, MXU contraction dim 128); the per-(b,hkv) working set is
q [G,128] + k,v [512,128] ≈ 0.26 MB ≪ 16 MB VMEM, leaving room for
double-buffered DMA of the next KV block. Accumulators (m, l, acc) live in
VMEM scratch that persists across the sequential KV-block grid dimension.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK = 512


def _decode_kernel(lengths_ref,          # scalar prefetch [B]
                   q_ref,                # [1, 1, G, Dh]
                   k_ref, v_ref,         # [1, BS, 1, Dh]
                   o_ref,                # [1, 1, G, Dh]
                   m_ref, l_ref, acc_ref,  # VMEM scratch
                   *, block_s: int, ragged: bool):
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths_ref[b]
    start = j * block_s

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)             # [G, Dh]
        k = k_ref[0, :, 0].astype(jnp.float32)          # [BS, Dh]
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [G, BS]
        s = s / math.sqrt(q.shape[-1])
        idx = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(idx < length, s, NEG_INF)

        m_prev = m_ref[:, 0]                            # [G]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])                 # [G, BS]
        l_new = l_ref[:, 0] * alpha + p.sum(axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    if ragged:
        # skip the MXU work for blocks entirely beyond this request's length
        pl.when(start < length)(_compute)
    else:
        _compute()

    @pl.when(j == nj - 1)
    def _finish():
        l = l_ref[:, 0]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def _paged_decode_kernel(lengths_ref,        # scalar prefetch [B]
                         bt_ref,             # scalar prefetch [B, NBT]
                         q_ref,              # [1, 1, G, Dh]
                         k_ref, v_ref,       # [1, BS, 1, Dh] (one phys block)
                         o_ref,              # [1, 1, G, Dh]
                         m_ref, l_ref, acc_ref,  # VMEM scratch
                         *, block_s: int):
    """Block-table decode attention: grid step (b, h, j) DMAs *physical*
    block ``bt_ref[b, j]`` (resolved by the index maps below, before the
    body runs — scalar prefetch) holding logical KV rows
    ``[j·BS, (j+1)·BS)`` of request ``b``. Blocks at or beyond the request's
    length are pure padding (tables are padded with block 0) and skip the
    MXU work entirely, so cost is ∝ Σ_b ceil(L_b/BS) — the paged engine
    never pays for another request's length (DESIGN.md §Kernel grid)."""
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths_ref[b]
    start = j * block_s

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)             # [G, Dh]
        k = k_ref[0, :, 0].astype(jnp.float32)          # [BS, Dh]
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [G, BS]
        s = s / math.sqrt(q.shape[-1])
        idx = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(idx < length, s, NEG_INF)

        m_prev = m_ref[:, 0]                            # [G]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])                 # [G, BS]
        l_new = l_ref[:, 0] * alpha + p.sum(axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    pl.when(start < length)(_compute)

    @pl.when(j == nj - 1)
    def _finish():
        l = l_ref[:, 0]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths, *,
                           interpret: bool = False):
    """Decode attention over a paged KV pool.

    q            [B, H, Dh]              — one query token per request
    k/v_pool     [NB, BS, Hkv, Dh]       — global physical block pool
    block_tables [B, NBT] int32          — physical block id per logical
                                           block; rows past a request's
                                           ceil(L_b/BS) blocks are padding
    lengths      [B] int32               — valid tokens per request
    returns      [B, H, Dh]

    TPU mapping: both scalars are prefetched (SMEM) so the KV BlockSpec
    index maps can chase the block table — grid step (b, h, j) DMAs
    physical block ``block_tables[b, j]`` from HBM while step j−1 computes
    (standard double-buffered sequential grid). Fully padded steps skip
    the MXU via ``pl.when``; the paged pool means no request is ever
    padded to another's length, so the grid cost is Σ_b ceil(L_b/BS).
    """
    B, H, Dh = q.shape
    NB, BS, Hkv = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    G = H // Hkv
    NBT = block_tables.shape[1]
    assert H % Hkv == 0, (H, Hkv)
    qg = q.reshape(B, Hkv, G, Dh)

    grid = (B, Hkv, NBT)
    kernel = functools.partial(_paged_decode_kernel, block_s=BS)

    def kv_map(b, h, j, lens, bt):
        del lens
        return (bt[b, j], 0, h, 0)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, Dh), lambda b, h, j, *pf: (b, h, 0, 0)),
                pl.BlockSpec((1, BS, 1, Dh), kv_map),
                pl.BlockSpec((1, BS, 1, Dh), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, G, Dh),
                                   lambda b, h, j, *pf: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 128), jnp.float32),   # m (lane-replicated)
                pltpu.VMEM((G, 128), jnp.float32),   # l
                pltpu.VMEM((G, Dh), jnp.float32),    # acc
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dh), q.dtype),
        interpret=interpret,
    )(lengths, block_tables, qg, k_pool, v_pool)
    return out.reshape(B, H, Dh)


@functools.partial(jax.jit, static_argnames=("block_s", "ragged", "interpret"))
def decode_attention(q, k, v, lengths, *, block_s: int = DEFAULT_BLOCK,
                     ragged: bool = False, interpret: bool = False):
    """q [B, H, Dh]; k, v [B, S, Hkv, Dh]; lengths [B] int32 -> [B, H, Dh].

    ``interpret=True`` runs the kernel body in Python on CPU (used for all
    validation in this repo); on a real TPU leave it False.
    """
    B, H, Dh = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    assert H % Hkv == 0 and S % block_s == 0, (H, Hkv, S, block_s)
    nj = S // block_s
    qg = q.reshape(B, Hkv, G, Dh)

    grid = (B, Hkv, nj)
    kernel = functools.partial(_decode_kernel, block_s=block_s, ragged=ragged)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, Dh), lambda b, h, j, *prefetch: (b, h, 0, 0)),
                pl.BlockSpec((1, block_s, 1, Dh), lambda b, h, j, *prefetch: (b, j, h, 0)),
                pl.BlockSpec((1, block_s, 1, Dh), lambda b, h, j, *prefetch: (b, j, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, Dh), lambda b, h, j, *prefetch: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 128), jnp.float32),   # m (lane-replicated)
                pltpu.VMEM((G, 128), jnp.float32),   # l
                pltpu.VMEM((G, Dh), jnp.float32),    # acc
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dh), q.dtype),
        interpret=interpret,
    )(lengths, qg, k, v)
    return out.reshape(B, H, Dh)
