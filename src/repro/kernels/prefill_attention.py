"""Prefill attention — Pallas TPU kernels.

Two kernels:

  * :func:`prefill_attention` — whole-prompt causal flash attention over a
    contiguous ``[B, T, ...]`` batch. Standard flash tiling: grid
    ``(B, Hkv, Tq/BQ, S/BK)`` with online-softmax accumulation over the
    innermost (sequential) KV dimension and causal block pruning — upper-
    triangular KV blocks are skipped entirely (``pl.when``), halving
    compute. Used by the monolithic (non-paged) serving path and training.

  * :func:`paged_prefill_attention` — **chunked** prefill over the paged
    KV pool (DESIGN.md §Chunked prefill): a query chunk ``[C]`` of one
    request attends causally to its own chunk plus all previously written
    context, read block-by-block from the pool through a scalar-prefetched
    block table. The grid is a flat work list like
    ``paged_decode_attention_flat`` — cost ∝ chunk × ceil(L_ctx/BS) — so
    serving engines can pack prompt chunks *into* decode iterations
    instead of freezing the batch for a whole long prompt. (The paper's
    §2.1 baseline isolates prefill into dedicated compute-bound
    iterations; chunked prefill is what removes that head-of-line block.)

Block design: q tile [BQ·G, 128], kv tile [BK, 128]; BQ=BK=256 keeps the
working set ≈ (256·G + 2·256) · 128 · 2 B ≲ 1 MB in VMEM with MXU-aligned
contraction dims.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import (NEG_INF, _flash_block_update, _flash_finish,
                               _flash_init, flat_work_list)


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _prefill_kernel(lengths_ref, q_ref, k_ref, v_ref, o_ref,
                    m_ref, l_ref, acc_ref, *, bq: int, bk: int):
    b = pl.program_id(0)
    i = pl.program_id(2)   # q block
    j = pl.program_id(3)   # kv block
    nj = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths_ref[b]
    q_start = i * bq
    kv_start = j * bk

    @pl.when((kv_start <= q_start + bq - 1) & (kv_start < length))
    def _compute():
        G, Dh = q_ref.shape[3], q_ref.shape[4]
        q = q_ref[0, 0].astype(jnp.float32).reshape(bq * G, Dh)
        k = k_ref[0, :, 0].astype(jnp.float32)              # [BK, Dh]
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
        s = s / math.sqrt(Dh)                               # [BQ*G, BK]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // G
        kpos = kv_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where((kpos <= qpos) & (kpos < length), s, NEG_INF)

        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_ref[:, 0] * alpha + p.sum(axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(j == nj - 1)
    def _finish():
        G, Dh = o_ref.shape[3], o_ref.shape[4]
        l = l_ref[:, 0]
        safe = jnp.where(l == 0.0, 1.0, l)
        out = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)
        o_ref[0, 0] = out.reshape(o_ref.shape[2], G, Dh)


@functools.partial(jax.jit,
                   static_argnames=("block_q", "block_k", "interpret"))
def prefill_attention(q, k, v, lengths=None, *, block_q: int = 256,
                      block_k: int = 256, interpret: bool = False):
    """q [B, T, H, Dh]; k, v [B, T, Hkv, Dh] -> [B, T, H, Dh] (causal).

    ``T`` need not be a multiple of the tile sizes: the operands are
    padded internally up to the block multiple and the pad tail is masked
    (kv rows by the ``lengths`` guard, q rows by trimming the output), so
    callers never pre-pad just to satisfy the kernel.
    """
    B, T, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    if lengths is None:
        lengths = jnp.full((B,), T, jnp.int32)
    # pad the sequence to a multiple of both tile sizes; padded kv rows sit
    # at positions >= length (masked in-kernel), padded q rows are trimmed
    block_q = min(block_q, _round_up(T, 8))
    block_k = min(block_k, _round_up(T, 8))
    tile = block_q * block_k // math.gcd(block_q, block_k)
    Tp = _round_up(T, tile)
    if Tp != T:
        pad = ((0, 0), (0, Tp - T), (0, 0), (0, 0))
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    lengths = jnp.minimum(lengths, T)
    # [B, Hkv, T, G, Dh] so a q tile is contiguous rows per kv head
    qg = q.reshape(B, Tp, Hkv, G, Dh).transpose(0, 2, 1, 3, 4)

    grid = (B, Hkv, Tp // block_q, Tp // block_k)
    kernel = functools.partial(_prefill_kernel, bq=block_q, bk=block_k)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, block_q, G, Dh),
                             lambda b, h, i, j, *p: (b, h, i, 0, 0)),
                pl.BlockSpec((1, block_k, 1, Dh),
                             lambda b, h, i, j, *p: (b, j, h, 0)),
                pl.BlockSpec((1, block_k, 1, Dh),
                             lambda b, h, i, j, *p: (b, j, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, block_q, G, Dh),
                                   lambda b, h, i, j, *p: (b, h, i, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((block_q * G, 128), jnp.float32),
                pltpu.VMEM((block_q * G, 128), jnp.float32),
                pltpu.VMEM((block_q * G, Dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, Tp, G, Dh), q.dtype),
        interpret=interpret,
    )(lengths, qg, k, v)
    return out.transpose(0, 2, 1, 3, 4).reshape(B, Tp, H, Dh)[:, :T]


# --------------------------------------------------------------------------
# Chunked prefill over the paged pool (DESIGN.md §Chunked prefill)
# --------------------------------------------------------------------------
def _paged_prefill_kernel(wreq_ref, wblk_ref,    # scalar prefetch [W], [W]
                          ctx_ref, clen_ref,     # scalar prefetch [B], [B]
                          bt_ref,                # scalar prefetch [B, NBT]
                          q_ref,                 # [1, 1, C, G, Dh]
                          k_ref, v_ref,          # [1, BS, 1, Dh] (one block)
                          o_ref,                 # [1, 1, C, G, Dh]
                          m_ref, l_ref, acc_ref,   # VMEM scratch
                          *, block_s: int):
    """Flat-work-list chunked prefill: grid step (h, w) processes work item
    ``w`` = (chunk ``wreq[w]``, logical KV block ``wblk[w]``) — the C
    queries of that chunk against ONE physical pool block holding logical
    rows ``[j·BS, (j+1)·BS)`` of the chunk's request. The work list is the
    Σ_c ceil((ctx_c + clen_c)/BS) real blocks (chunk-major, blocks in
    order) padded to a static bucket; chunk boundaries re-init the
    accumulators and the output row is written on a chunk's last item,
    exactly like ``_flat_paged_kernel``. Causality: query row i (global
    position ctx + i) sees kv position kpos <= ctx + i, so the chunk
    attends to its full written context plus itself, never to unwritten
    pool rows."""
    w = pl.program_id(1)
    nw = pl.num_programs(1)
    c = wreq_ref[w]
    j = wblk_ref[w]
    prev_c = wreq_ref[jnp.maximum(w - 1, 0)]
    next_c = wreq_ref[jnp.minimum(w + 1, nw - 1)]
    first = (w == 0) | (prev_c != c)
    last = (w == nw - 1) | (next_c != c)

    pl.when(first)(lambda: _flash_init(m_ref, l_ref, acc_ref))

    ctx = ctx_ref[c]
    total = ctx + clen_ref[c]
    start = j * block_s

    def _compute():
        G = q_ref.shape[3]
        rows = q_ref.shape[2] * G                           # C·G
        # per-row global query position (row r is chunk token r // G),
        # kept 2-d ([rows, 1], broadcastable) — TPU iota must be >= 2-d
        qpos = ctx + jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0) // G
        _flash_block_update(q_ref, k_ref, v_ref, m_ref, l_ref, acc_ref,
                            start, total, qpos=qpos)

    pl.when(start < total)(_compute)
    pl.when(last)(lambda: _flash_finish(o_ref, l_ref, acc_ref))


@functools.partial(jax.jit, static_argnames=("num_work", "interpret"))
def paged_prefill_attention(q, k_pool, v_pool, block_tables, ctx_lens,
                            chunk_lens, *, num_work: Optional[int] = None,
                            interpret: bool = False):
    """Chunked causal prefill attention over a paged KV pool.

    q            [B, C, H, Dh]        — B prompt *chunks*, C queries each
                                        (rows past ``chunk_lens[b]`` are
                                        padding; their output is garbage
                                        and must be ignored by the caller)
    k/v_pool     [NB, BS, Hkv, Dh]    — global block pool. The chunk's own
                                        K/V must ALREADY be scattered into
                                        its blocks (positions ctx..ctx+C)
                                        before this call — partial prompts
                                        live in the pool like decode state
    block_tables [B, NBT] int32       — per-chunk block table covering at
                                        least ceil((ctx+C)/BS) rows
    ctx_lens     [B] int32            — tokens written BEFORE this chunk
    chunk_lens   [B] int32            — real tokens in this chunk
    returns      [B, C, H, Dh]

    Grid ``(Hkv, num_work)`` over the flat (chunk, logical-block) work
    list of Σ_b ceil((ctx_b + chunk_b)/BS) real items — the chunked-
    prefill analogue of :func:`paged_decode_attention_flat`: each work
    item is one [C·G, BS] MXU tile against one pool block, so the cost is
    chunk × context blocks and a chunk never pays another chunk's context
    length. ``num_work`` is a static bucket (callers round to a power of
    two; None = the worst case B·NBT).
    """
    B, C, H, Dh = q.shape
    BS, Hkv = k_pool.shape[1], k_pool.shape[2]
    G = H // Hkv
    NBT = block_tables.shape[1]
    assert H % Hkv == 0, (H, Hkv)
    W = num_work if num_work is not None else B * NBT
    assert W >= 1
    qg = q.reshape(B, C, Hkv, G, Dh).transpose(0, 2, 1, 3, 4)
    totals = (ctx_lens + chunk_lens).astype(jnp.int32)
    work_req, work_blk = flat_work_list(totals, NBT, BS, W)

    grid = (Hkv, W)
    kernel = functools.partial(_paged_prefill_kernel, block_s=BS)

    def q_map(h, w, wreq, wblk, ctx, clen, bt):
        del wblk, ctx, clen, bt
        return (wreq[w], h, 0, 0, 0)

    def kv_map(h, w, wreq, wblk, ctx, clen, bt):
        del ctx, clen
        # padding items carry block index NBT; clamp for the table lookup —
        # whatever block it DMAs is skipped by the kernel's total guard
        return (bt[wreq[w], jnp.minimum(wblk[w], NBT - 1)], 0, h, 0)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, C, G, Dh), q_map),
                pl.BlockSpec((1, BS, 1, Dh), kv_map),
                pl.BlockSpec((1, BS, 1, Dh), kv_map),
            ],
            out_specs=pl.BlockSpec((1, 1, C, G, Dh), q_map),
            scratch_shapes=[
                pltpu.VMEM((C * G, 128), jnp.float32),   # m (lane-replicated)
                pltpu.VMEM((C * G, 128), jnp.float32),   # l
                pltpu.VMEM((C * G, Dh), jnp.float32),    # acc
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, C, G, Dh), q.dtype),
        interpret=interpret,
    )(work_req, work_blk, ctx_lens.astype(jnp.int32),
      chunk_lens.astype(jnp.int32), block_tables, qg, k_pool, v_pool)
    return out.transpose(0, 2, 1, 3, 4).reshape(B, C, H, Dh)
