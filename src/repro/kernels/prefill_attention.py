"""Causal flash-attention prefill — Pallas TPU kernel.

The paper isolates prefill into dedicated compute-bound iterations (§2.1);
this kernel is that iteration's hot spot. Standard flash tiling:
grid ``(B, Hkv, Tq/BQ, S/BK)`` with online-softmax accumulation over the
innermost (sequential) KV dimension and causal block pruning — upper-
triangular KV blocks are skipped entirely (``pl.when``), halving compute.

Block design: q tile [BQ·G, 128], kv tile [BK, 128]; BQ=BK=256 keeps the
working set ≈ (256·G + 2·256) · 128 · 2 B ≲ 1 MB in VMEM with MXU-aligned
contraction dims.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _prefill_kernel(lengths_ref, q_ref, k_ref, v_ref, o_ref,
                    m_ref, l_ref, acc_ref, *, bq: int, bk: int):
    b = pl.program_id(0)
    i = pl.program_id(2)   # q block
    j = pl.program_id(3)   # kv block
    nj = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths_ref[b]
    q_start = i * bq
    kv_start = j * bk

    @pl.when((kv_start <= q_start + bq - 1) & (kv_start < length))
    def _compute():
        G, Dh = q_ref.shape[3], q_ref.shape[4]
        q = q_ref[0, 0].astype(jnp.float32).reshape(bq * G, Dh)
        k = k_ref[0, :, 0].astype(jnp.float32)              # [BK, Dh]
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
        s = s / math.sqrt(Dh)                               # [BQ*G, BK]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // G
        kpos = kv_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where((kpos <= qpos) & (kpos < length), s, NEG_INF)

        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_ref[:, 0] * alpha + p.sum(axis=-1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(j == nj - 1)
    def _finish():
        G, Dh = o_ref.shape[3], o_ref.shape[4]
        l = l_ref[:, 0]
        safe = jnp.where(l == 0.0, 1.0, l)
        out = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)
        o_ref[0, 0] = out.reshape(o_ref.shape[2], G, Dh)


@functools.partial(jax.jit,
                   static_argnames=("block_q", "block_k", "interpret"))
def prefill_attention(q, k, v, lengths=None, *, block_q: int = 256,
                      block_k: int = 256, interpret: bool = False):
    """q [B, T, H, Dh]; k, v [B, T, Hkv, Dh] -> [B, T, H, Dh] (causal)."""
    B, T, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    assert T % block_q == 0 and T % block_k == 0, (T, block_q, block_k)
    if lengths is None:
        lengths = jnp.full((B,), T, jnp.int32)
    # [B, Hkv, T, G, Dh] so a q tile is contiguous rows per kv head
    qg = q.reshape(B, T, Hkv, G, Dh).transpose(0, 2, 1, 3, 4)

    grid = (B, Hkv, T // block_q, T // block_k)
    kernel = functools.partial(_prefill_kernel, bq=block_q, bk=block_k)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, block_q, G, Dh),
                             lambda b, h, i, j, *p: (b, h, i, 0, 0)),
                pl.BlockSpec((1, block_k, 1, Dh),
                             lambda b, h, i, j, *p: (b, j, h, 0)),
                pl.BlockSpec((1, block_k, 1, Dh),
                             lambda b, h, i, j, *p: (b, j, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, block_q, G, Dh),
                                   lambda b, h, i, j, *p: (b, h, i, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((block_q * G, 128), jnp.float32),
                pltpu.VMEM((block_q * G, 128), jnp.float32),
                pltpu.VMEM((block_q * G, Dh), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, T, G, Dh), q.dtype),
        interpret=interpret,
    )(lengths, qg, k, v)
    return out.transpose(0, 2, 1, 3, 4).reshape(B, T, H, Dh)
