"""Fused mixed-iteration attention — ONE Pallas launch per mixed step.

PR 4's mixed iterations still issue two flat-grid launches per layer: the
decode work list (``paged_decode_attention_flat``) and the prefill-chunk
work list (``paged_prefill_attention``). Each pays its own pow2 padding
and launch overhead — exactly the double cost ROADMAP item 2 targets.

:func:`paged_mixed_attention` packs *all* (segment, logical-block) items
of a mixed iteration into a single scalar-prefetched work list: a
*segment* is either a decode row (qlen = 1, ``tag = 0``) or a prefill
chunk (qlen = chunk, ``tag = 1``), interleaved freely. One grid
``(Hkv, W)`` where ``W >= Σ_s ceil((ctx_s + seg_s)/BS)`` is the caller's
static work bucket. The engine picks ``W = pow2(decode items) +
pow2(chunk items)`` — split buckets, because a single pow2 of the sum
can overshoot the pair (9+8 → 32 vs 16+8) and make the merged grid pad
MORE than the two kernels it replaces; split, the padding tail matches
the separate launches exactly and fusion's win is the saved launch.

Work-list layout (DESIGN.md §Fused mixed-iteration attention): segment
``s`` contributes ``ceil(total_s/BS)`` consecutive items where
``total_s = ctx_s + seg_s`` (for decode, ctx = L−1 and seg = 1, so
total = L — a decode row IS a chunk of length 1). Tag encoding is a
prefetched int32 vector indexed by segment: 0 → narrow [G, BS] update on
the q tile's first chunk row, 1 → full [C·G, BS] causally-masked update.
The same garbage-block/sentinel discipline as the other flat grids
applies: padding items alias the last real segment with block index NBT,
the ``start < total`` guard skips them, and the final write is an
idempotent re-write of that segment's row.

Quantized KV (``k_scale``/``v_scale`` given): the pool is int8 with f32
per-(block, position, kv-head) scales; blocks are dequantized in-register
inside the shared flash core, so HBM DMA moves ~half the bytes
(DESIGN.md §Quantized KV blocks).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import (_flash_block_update, _flash_finish,
                               _flash_init, flat_work_list)


def _mixed_kernel(wreq_ref, wblk_ref,     # scalar prefetch [W], [W]
                  tags_ref,               # scalar prefetch [B]
                  ctx_ref, slen_ref,      # scalar prefetch [B], [B]
                  bt_ref,                 # scalar prefetch [B, NBT]
                  q_ref,                  # [1, 1, C, G, Dh]
                  k_ref, v_ref,           # [1, BS, 1, Dh] (one phys block)
                  *rest,                  # (+ks,vs if quantized) o, scratch
                  block_s: int, quantized: bool):
    """Grid step (h, w): flat work item ``w`` = (segment ``wreq[w]``,
    logical KV block ``wblk[w]``) against ONE physical pool block. Segment
    boundaries re-init the accumulators / write the output row exactly
    like ``_flat_paged_kernel``; the per-segment tag picks the decode or
    chunk compute shape against the SAME scratch and KV DMA."""
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        ks_ref = vs_ref = None
        o_ref, m_ref, l_ref, acc_ref = rest
    w = pl.program_id(1)
    nw = pl.num_programs(1)
    s = wreq_ref[w]
    j = wblk_ref[w]
    prev_s = wreq_ref[jnp.maximum(w - 1, 0)]
    next_s = wreq_ref[jnp.minimum(w + 1, nw - 1)]
    first = (w == 0) | (prev_s != s)
    last = (w == nw - 1) | (next_s != s)

    pl.when(first)(lambda: _flash_init(m_ref, l_ref, acc_ref))

    ctx = ctx_ref[s]
    total = ctx + slen_ref[s]
    start = j * block_s
    is_chunk = tags_ref[s] == 1
    if quantized:
        k_scale = ks_ref[0, :, 0].reshape(-1, 1)    # [BS, 1]
        v_scale = vs_ref[0, :, 0].reshape(-1, 1)
    else:
        k_scale = v_scale = None

    def _chunk():
        G = q_ref.shape[3]
        rows = q_ref.shape[2] * G                   # C·G
        # per-row global query position (row r is chunk token r // G),
        # kept 2-d ([rows, 1], broadcastable) — TPU iota must be >= 2-d
        qpos = ctx + jax.lax.broadcasted_iota(jnp.int32, (rows, 1), 0) // G
        _flash_block_update(q_ref, k_ref, v_ref, m_ref, l_ref, acc_ref,
                            start, total, qpos=qpos,
                            k_scale=k_scale, v_scale=v_scale)

    def _decode():
        # qlen = 1: only the first chunk row of the q tile is live, so pay
        # a [G, BS] MXU tile instead of [C·G, BS]; the decode length mask
        # (idx < total, total = L) IS the causal mask at qpos = L−1
        _flash_block_update(q_ref, k_ref, v_ref, m_ref, l_ref, acc_ref,
                            start, total, k_scale=k_scale, v_scale=v_scale,
                            rows=q_ref.shape[3])

    def _compute():
        pl.when(is_chunk)(_chunk)
        pl.when(jnp.logical_not(is_chunk))(_decode)

    pl.when(start < total)(_compute)
    pl.when(last)(lambda: _flash_finish(o_ref, l_ref, acc_ref))


@functools.partial(jax.jit, static_argnames=("num_work", "interpret"))
def paged_mixed_attention(q, k_pool, v_pool, block_tables, ctx_lens,
                          seg_lens, tags, k_scale=None, v_scale=None, *,
                          num_work: Optional[int] = None,
                          interpret: bool = False):
    """Fused mixed-iteration attention over a paged KV pool.

    q            [B, C, H, Dh]     — B *segments*, C query rows each. A
                                     chunk segment uses rows [0, seg) and
                                     a decode segment row 0 only; rows
                                     past ``seg_lens[s]`` are padding
                                     whose output the caller must ignore
    k/v_pool     [NB, BS, Hkv, Dh] — global block pool (bf16/f32, or int8
                                     with ``k_scale``/``v_scale`` given).
                                     Every segment's own K/V must ALREADY
                                     be scattered before this call
    block_tables [B, NBT] int32    — per-segment block table covering at
                                     least ceil((ctx+seg)/BS) rows
    ctx_lens     [B] int32         — tokens before this segment's queries
                                     (decode: L−1; chunk: written context)
    seg_lens     [B] int32         — query rows (decode: 1; chunk: clen)
    tags         [B] int32         — 0 = decode row, 1 = prefill chunk
    k/v_scale    [NB, BS, Hkv] f32 — per-(block, position, kv-head) int8
                                     dequant scales (both or neither)
    returns      [B, C, H, Dh]

    Grid ``(Hkv, num_work)`` over the flat (segment, logical-block) work
    list of Σ_s ceil((ctx_s + seg_s)/BS) real items — ONE launch covers
    the whole mixed iteration. ``num_work`` is a static bucket (callers
    round to a power of two; None = the worst case B·NBT).
    """
    B, C, H, Dh = q.shape
    BS, Hkv = k_pool.shape[1], k_pool.shape[2]
    G = H // Hkv
    NBT = block_tables.shape[1]
    assert H % Hkv == 0, (H, Hkv)
    assert (k_scale is None) == (v_scale is None)
    quantized = k_scale is not None
    W = num_work if num_work is not None else B * NBT
    assert W >= 1
    qg = q.reshape(B, C, Hkv, G, Dh).transpose(0, 2, 1, 3, 4)
    totals = (ctx_lens + seg_lens).astype(jnp.int32)
    work_req, work_blk = flat_work_list(totals, NBT, BS, W)

    grid = (Hkv, W)
    kernel = functools.partial(_mixed_kernel, block_s=BS,
                               quantized=quantized)

    def q_map(h, w, wreq, wblk, tags, ctx, slen, bt):
        del wblk, tags, ctx, slen, bt
        return (wreq[w], h, 0, 0, 0)

    def kv_map(h, w, wreq, wblk, tags, ctx, slen, bt):
        del tags, ctx, slen
        # padding items carry block index NBT; clamp for the table lookup —
        # whatever block it DMAs is skipped by the kernel's total guard
        return (bt[wreq[w], jnp.minimum(wblk[w], NBT - 1)], 0, h, 0)

    def scale_map(h, w, wreq, wblk, tags, ctx, slen, bt):
        del tags, ctx, slen
        return (bt[wreq[w], jnp.minimum(wblk[w], NBT - 1)], 0, h)

    in_specs = [
        pl.BlockSpec((1, 1, C, G, Dh), q_map),
        pl.BlockSpec((1, BS, 1, Dh), kv_map),
        pl.BlockSpec((1, BS, 1, Dh), kv_map),
    ]
    operands = [qg, k_pool, v_pool]
    if quantized:
        in_specs += [pl.BlockSpec((1, BS, 1), scale_map),
                     pl.BlockSpec((1, BS, 1), scale_map)]
        operands += [k_scale, v_scale]

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=6,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, 1, C, G, Dh), q_map),
            scratch_shapes=[
                pltpu.VMEM((C * G, 128), jnp.float32),   # m (lane-replicated)
                pltpu.VMEM((C * G, 128), jnp.float32),   # l
                pltpu.VMEM((C * G, Dh), jnp.float32),    # acc
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, C, G, Dh), q.dtype),
        interpret=interpret,
    )(work_req, work_blk, tags.astype(jnp.int32), ctx_lens.astype(jnp.int32),
      seg_lens.astype(jnp.int32), block_tables, *operands)
    return out.transpose(0, 2, 1, 3, 4).reshape(B, C, H, Dh)
