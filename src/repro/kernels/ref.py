"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth for every kernel sweep test —
straightforward masked softmax attention with no tiling tricks.
"""
from __future__ import annotations

import jax.numpy as jnp
import jax

NEG_INF = -1e30


def decode_attention_ref(q, k, v, lengths):
    """Single-token GQA decode attention.

    q        [B, H, Dh]     — one query token per request
    k, v     [B, S, Hkv, Dh] — KV cache (padded to S)
    lengths  [B] int32       — valid cache length per request
    returns  [B, H, Dh]
    """
    B, H, Dh = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, Dh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg, kf) / jnp.sqrt(Dh)
    mask = jnp.arange(S)[None, None, None, :] < lengths[:, None, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", w, vf)
    return out.reshape(B, H, Dh).astype(q.dtype)


def prefill_attention_ref(q, k, v, lengths=None):
    """Causal full-sequence GQA attention (flash-prefill oracle).

    q [B, T, H, Dh]; k, v [B, T, Hkv, Dh]; lengths [B] optional padding.
    """
    B, T, H, Dh = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, T, Hkv, G, Dh).astype(jnp.float32)
    scores = jnp.einsum("bthgd,bshd->bhgts", qg, k.astype(jnp.float32))
    scores = scores / jnp.sqrt(Dh)
    causal = jnp.arange(T)[:, None] >= jnp.arange(T)[None, :]
    mask = causal[None, None, None]
    if lengths is not None:
        valid = jnp.arange(T)[None, :] < lengths[:, None]           # [B, S]
        mask = mask & valid[:, None, None, None, :]
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgts,bshd->bthgd", w, v.astype(jnp.float32))
    return out.reshape(B, T, H, Dh).astype(q.dtype)
