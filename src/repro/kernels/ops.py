"""Jitted public wrappers for the Pallas kernels.

On this CPU container the kernels run in ``interpret=True`` (Python
execution of the kernel body) — numerics are identical to TPU. The
``backend`` argument lets callers (engine, tests) pick:

  * ``"xla"``     — pure-jnp reference (fast on CPU, default here)
  * ``"pallas"``  — the TPU kernel (interpret on CPU, compiled on TPU)
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode_pallas
from repro.kernels.prefill_attention import prefill_attention as _prefill_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def decode_attention(q, k, v, lengths, *, backend: str = "xla",
                     ragged: bool = False, block_s: int = 512):
    if backend == "xla":
        return ref.decode_attention_ref(q, k, v, lengths)
    if backend == "pallas":
        return _decode_pallas(q, k, v, lengths, block_s=block_s,
                              ragged=ragged, interpret=not _on_tpu())
    raise ValueError(f"unknown backend {backend!r}")


def prefill_attention(q, k, v, lengths=None, *, backend: str = "xla",
                      block_q: int = 256, block_k: int = 256):
    if backend == "xla":
        return ref.prefill_attention_ref(q, k, v, lengths)
    if backend == "pallas":
        return _prefill_pallas(q, k, v, lengths, block_q=block_q,
                               block_k=block_k, interpret=not _on_tpu())
    raise ValueError(f"unknown backend {backend!r}")
