"""Jitted public wrappers for the Pallas kernels, plus the ONE shared
flash-attention inner core every paged kernel builds on.

On this CPU container the kernels run in ``interpret=True`` (Python
execution of the kernel body) — numerics are identical to TPU. The
``backend`` argument lets callers (engine, tests) pick:

  * ``"xla"``     — pure-jnp reference (fast on CPU, default here)
  * ``"pallas"``  — the TPU kernel (interpret on CPU, compiled on TPU)

The ``_flash_*`` helpers below are the online-softmax KV-block core shared
by the decode, chunked-prefill, AND fused mixed-iteration kernels — one
implementation, imported by all three (no cross-module private imports).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# --------------------------------------------------------------------------
# Shared flash-attention core (decode / chunked prefill / fused mixed)
# --------------------------------------------------------------------------
def _flash_block_update(q_ref, k_ref, v_ref, m_ref, l_ref, acc_ref,
                        start, length, qpos=None, k_scale=None, v_scale=None,
                        rows=None):
    """ONE online-softmax KV-block step, shared by the decode kernels, the
    chunked-prefill kernel AND the fused mixed-iteration kernel: the q
    tile (trailing dims flattened to [rows, Dh] — [G, Dh] for decode,
    [C·G, Dh] for a prefill chunk) vs. this grid step's KV block
    [BS, Dh], masked at ``length``, accumulated into the persistent
    (m, l, acc) scratch.

    ``qpos`` (per-row global query positions) additionally applies the
    causal ``kv <= q`` mask of chunked prefill; decode's single query row
    needs none. ``k_scale``/``v_scale`` ([BS, 1], f32) dequantize an int8
    KV block in-register — the pool stays int8 in HBM, so DMA bytes halve
    (DESIGN.md §Quantized KV blocks). ``rows`` (static) restricts the
    update to the FIRST ``rows`` scratch rows reading the q tile's first
    chunk row only — the fused kernel's tagged decode items use it to pay
    a [G, BS] matmul instead of the chunk tile's [C·G, BS]."""
    if rows is None:
        q = q_ref[0, 0].astype(jnp.float32).reshape(-1, q_ref.shape[-1])
        sl = slice(None)
    else:
        # tagged decode item inside a chunk-shaped tile: first chunk row
        q = q_ref[0, 0, 0].astype(jnp.float32).reshape(rows,
                                                       q_ref.shape[-1])
        sl = slice(0, rows)
    k = k_ref[0, :, 0].astype(jnp.float32)          # [BS, Dh]
    v = v_ref[0, :, 0].astype(jnp.float32)
    if k_scale is not None:
        k = k * k_scale                             # [BS, 1] row scales
        v = v * v_scale
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [rows, BS]
    s = s / math.sqrt(q.shape[-1])
    idx = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    keep = idx < length
    if qpos is not None:                 # qpos broadcastable to [rows, BS]
        keep &= idx <= qpos
    s = jnp.where(keep, s, NEG_INF)

    m_prev = m_ref[sl, 0]                           # [rows]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])                 # [rows, BS]
    l_new = l_ref[sl, 0] * alpha + p.sum(axis=-1)
    acc_ref[sl, :] = (acc_ref[sl, :] * alpha[:, None]
                      + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
    m_ref[sl, :] = jnp.broadcast_to(m_new[:, None], (q.shape[0],
                                                     m_ref.shape[1]))
    l_ref[sl, :] = jnp.broadcast_to(l_new[:, None], (q.shape[0],
                                                     l_ref.shape[1]))


def _flash_init(m_ref, l_ref, acc_ref):
    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)


def _flash_finish(o_ref, l_ref, acc_ref):
    l = l_ref[:, 0]
    safe = jnp.where(l == 0.0, 1.0, l)
    out = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)
    o_ref[0, 0] = out.reshape(o_ref.shape[2:])   # [G,Dh] / prefill [C,G,Dh]


def flat_work_list(lengths, nbt: int, block_s: int, num_work: int):
    """Flat (request, logical block) work list for the flattened grids —
    pure jnp, so the serving engine builds it on device every step.

    Items ``[0, Σ_b ceil(L_b/BS))`` enumerate every request's real blocks
    (request-major, blocks in order); the tail up to ``num_work`` is
    padding aliasing the last request with ``nbt`` (one past the table) as
    its block index, which the kernels' ``start < length`` guard always
    skips. Caller guarantees ``num_work >= Σ_b ceil(L_b/BS)``.
    Returns int32 ``(work_req [num_work], work_blk [num_work])``."""
    B = lengths.shape[0]
    nb = jnp.maximum(-(-lengths // block_s), 0).astype(jnp.int32)
    offs = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(nb)])
    total = offs[-1]
    w = jnp.arange(num_work, dtype=jnp.int32)
    b = jnp.clip(jnp.searchsorted(offs, w, side="right") - 1, 0, B - 1)
    b = b.astype(jnp.int32)
    j = w - offs[b]
    # last request with any real work (argmax of reversed has-work mask);
    # padding must alias it so the output index map never leaves its row
    last_b = (B - 1 - jnp.argmax((nb > 0)[::-1])).astype(jnp.int32)
    pad = w >= total
    return (jnp.where(pad, last_b, b),
            jnp.where(pad, jnp.int32(nbt), j))


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def decode_attention(q, k, v, lengths, *, backend: str = "xla",
                     ragged: bool = False, block_s: int = 512):
    from repro.kernels import ref
    if backend == "xla":
        return ref.decode_attention_ref(q, k, v, lengths)
    if backend == "pallas":
        from repro.kernels.decode_attention import (
            decode_attention as _decode_pallas)
        return _decode_pallas(q, k, v, lengths, block_s=block_s,
                              ragged=ragged, interpret=not _on_tpu())
    raise ValueError(f"unknown backend {backend!r}")


def prefill_attention(q, k, v, lengths=None, *, backend: str = "xla",
                      block_q: int = 256, block_k: int = 256):
    from repro.kernels import ref
    if backend == "xla":
        return ref.prefill_attention_ref(q, k, v, lengths)
    if backend == "pallas":
        from repro.kernels.prefill_attention import (
            prefill_attention as _prefill_pallas)
        return _prefill_pallas(q, k, v, lengths, block_q=block_q,
                               block_k=block_k, interpret=not _on_tpu())
    raise ValueError(f"unknown backend {backend!r}")
