"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run forces 512 host devices and must do
so before any jax initialization).
"""
from __future__ import annotations

import jax


def _axis_types_kwarg(n: int) -> dict:
    # jax.sharding.AxisType landed after 0.4.x; Auto is that era's default
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n} if at is not None else {}


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kwarg(len(axes)))


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over the locally available devices (tests)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh(
        (n // model, model), ("data", "model"), **_axis_types_kwarg(2))


def make_tp_mesh(tp: int) -> jax.sharding.Mesh:
    """1-D serving mesh: the first ``tp`` local devices on a single
    'model' axis (DESIGN.md §Sharded serving). Each tensor-parallel
    Engine owns one of these; a cluster of engines with different ``tp``
    is a set of disjoint meshes over one host's devices."""
    n = len(jax.devices())
    assert 1 <= tp <= n, f"tp={tp} needs {tp} devices, have {n}"
    return jax.make_mesh((tp,), ("model",), **_axis_types_kwarg(1),
                         devices=jax.devices()[:tp])


def batch_axes(mesh: jax.sharding.Mesh):
    """The (super-)axis batch shards over: ('pod','data') when a pod axis
    exists, else ('data',)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
