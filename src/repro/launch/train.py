"""Distributed training launcher.

Runs real training steps under pjit with the production sharding rules on
whatever devices exist (1 CPU here; the same code path drives the 16×16
mesh — the multi-pod dry-run proves those shardings compile).

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --reduced --steps 20 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.shardings import batch_shardings, param_shardings, replicated
from repro.models import build_model
from repro.training.data import DataConfig, TokenStream
from repro.training.optimizer import (AdamWConfig, AdamWState, adamw_update,
                                      init_adamw)
from repro.training.trainer import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    mesh = make_host_mesh(model=args.model_parallel)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps)
    step_fn = make_train_step(model, opt_cfg)

    with mesh:
        params = jax.jit(
            model.init,
            out_shardings=param_shardings(
                jax.eval_shape(model.init, jax.random.PRNGKey(0)), mesh),
        )(jax.random.PRNGKey(0))
        opt_state = init_adamw(params)
        stream = TokenStream(cfg, DataConfig(batch_size=args.batch,
                                             seq_len=args.seq))
        jitted = jax.jit(step_fn)
        it = iter(stream)
        for step in range(1, args.steps + 1):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            params, opt_state, m = jitted(params, opt_state, batch)
            if step % max(args.steps // 10, 1) == 0 or step == 1:
                print(f"step {step:4d} loss {float(m['loss']):.4f} "
                      f"lr {float(m['lr']):.2e}")
    if args.ckpt:
        from repro.training.checkpoint import save_checkpoint
        save_checkpoint(args.ckpt, params, args.steps)
        print("saved", args.ckpt)


if __name__ == "__main__":
    main()
