"""GSPMD sharding rules for every architecture family.

Name-based rules map parameter pytree paths to PartitionSpecs: tensor-
parallel weights shard on ``model`` (attention heads / FFN dim / expert
axis), batch shards on ``('pod','data')``, decode KV caches shard batch on
``data`` and heads (or head_dim when head count doesn't divide) on
``model``; ``long_500k`` context-parallel decode shards the cache
*sequence* axis on ``data``.

Every rule is divisibility-guarded — jax rejects non-divisible shardings —
falling back to replication for that dim.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import batch_axes
from repro.models.common import ModelConfig

# (path regex, dim index from the END to shard on "model")
PARAM_RULES: Sequence[Tuple[str, int]] = (
    (r"(^|/)embed$", 2),                 # [V, D] -> shard V
    (r"(^|/)unembed$", 1),               # [D, V] -> shard V
    (r"moe/router$", -1),                # replicated (tiny, f32)
    (r"moe/w_(gate|up|down)$", 3),       # [L, E, D, F] -> expert parallel
    (r"attn/w[qkv]$", 1),
    (r"attn/b[qkv]$", 1),
    (r"attn/wo$", 2),
    (r"ffn/w_(gate|up)$", 1),
    (r"ffn/b_up$", 1),
    (r"ffn/w_down$", 2),
    # rwkv6
    (r"(^|/)w_[rkvg]$", 1),
    (r"(^|/)w_o$", 2),
    (r"(^|/)cw_[kr]$", 1),
    (r"(^|/)cw_v$", 2),
    # zamba2 mamba blocks
    (r"mamba/w_in$", 1),
    (r"mamba/conv_w$", 1),
    (r"mamba/conv_b$", 1),
    (r"mamba/ln_gate$", 1),
    (r"mamba/w_out$", 2),
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_spec(path: str, shape: Tuple[int, ...], model_size: int,
               *, expert_2d: bool = False, data_size: int = 0) -> P:
    for pat, dim_from_end in PARAM_RULES:
        if re.search(pat, path):
            if dim_from_end < 0:
                return P()
            d = len(shape) - dim_from_end
            spec: list = [None] * len(shape)
            if 0 <= d < len(shape) and shape[d] % model_size == 0:
                spec[d] = "model"
            if expert_2d and re.search(r"moe/w_(gate|up|down)$", path):
                # §Perf beyond-paper: experts on 'model' AND the FFN dim on
                # 'data' — per-chip expert weights shrink by the data size
                ffn_d = len(shape) - (1 if path.endswith(("w_gate", "w_up"))
                                      else 2)
                if (spec[ffn_d] is None and data_size
                        and shape[ffn_d] % data_size == 0):
                    spec[ffn_d] = "data"
            if all(a is None for a in spec):
                return P()
            return P(*spec)
    return P()


def param_shardings(param_shapes, mesh, *, expert_2d: bool = False) -> Any:
    model_size = mesh.shape["model"]
    data_size = mesh.shape.get("data", 1)
    flat, tdef = jax.tree_util.tree_flatten_with_path(param_shapes)
    specs = [NamedSharding(mesh,
                           param_spec(_path_str(p), tuple(l.shape),
                                      model_size, expert_2d=expert_2d,
                                      data_size=data_size))
             for p, l in flat]
    return jax.tree_util.tree_unflatten(tdef, specs)


def zero1_shardings(param_shapes, mesh, base: Any = None) -> Any:
    """ZeRO-1 (§Perf beyond-paper): optimizer mu/nu additionally shard
    their largest replicated dim over 'data'. Params keep ``base``."""
    model_size = mesh.shape["model"]
    data_size = mesh.shape.get("data", 1)
    flat, tdef = jax.tree_util.tree_flatten_with_path(param_shapes)
    specs = []
    for p, leaf in flat:
        spec = list(param_spec(_path_str(p), tuple(leaf.shape), model_size))
        spec += [None] * (len(leaf.shape) - len(spec))
        # shard the largest still-replicated dim on 'data'
        cands = [(dim, i) for i, (dim, ax) in
                 enumerate(zip(leaf.shape, spec))
                 if ax is None and dim % data_size == 0 and dim >= data_size]
        if cands:
            _, i = max(cands)
            spec[i] = "data"
        specs.append(NamedSharding(mesh, P(*spec)))
    return jax.tree_util.tree_unflatten(tdef, specs)


# --------------------------------------------------------------------------
# Batch / cache shardings
# --------------------------------------------------------------------------
def _guard(shape, spec_list, mesh) -> P:
    """Drop sharded dims that don't divide."""
    out = []
    for dim, ax in zip(shape, spec_list):
        if ax is None:
            out.append(None)
            continue
        size = int(np.prod([mesh.shape[a] for a in
                            (ax if isinstance(ax, tuple) else (ax,))]))
        out.append(ax if dim % size == 0 else None)
    return P(*out)


def batch_shardings(batch_shapes, mesh) -> Any:
    """Shard dim 0 (global batch) of every input on ('pod','data')."""
    ba = batch_axes(mesh)

    def one(leaf):
        spec = [ba] + [None] * (len(leaf.shape) - 1)
        return NamedSharding(mesh, _guard(leaf.shape, spec, mesh))

    return jax.tree.map(one, batch_shapes)


def cache_shardings(cache_shapes, mesh, *, batch_size: int,
                    cache_seq: int, context_parallel: bool = False,
                    seq_on_model: bool = False) -> Any:
    """Decode KV/state-cache sharding.

    Axes are located by SIZE, not position (cache layouts differ per
    family): the batch axis is the first non-leading dim equal to
    ``batch_size``; the sequence axis is the first dim equal to
    ``cache_seq``. Strategy:
      * batch -> 'data' (normal decode),
      * ``context_parallel`` (long_500k, B=1): sequence -> 'data' instead,
      * a 'model'-divisible later dim (heads, else head_dim) -> 'model'.
    """
    data_size = mesh.shape["data"]
    model_size = mesh.shape["model"]

    def one(leaf):
        shape = leaf.shape
        r = len(shape)
        spec: list = [None] * r
        data_ax = None
        if context_parallel:
            for i, d in enumerate(shape):
                if d == cache_seq and d % data_size == 0:
                    data_ax = i
                    break
        else:
            for i in range(1, r):
                if shape[i] == batch_size and shape[i] % data_size == 0:
                    data_ax = i
                    break
        if data_ax is not None:
            spec[data_ax] = "data"
        # model axis preference: heads (conflict-free GQA) > sequence
        # (partial-softmax stats are tiny — §Perf) > head_dim (forces a
        # cache-sized all-gather for the QK contraction; naive baseline
        # fallback). ``seq_on_model`` enables the sequence option.
        start = (data_ax + 1) if data_ax is not None else 1
        non_seq = [i for i in range(start, r) if spec[i] is None
                   and shape[i] != cache_seq]
        heads = [i for i in non_seq if i < r - 1]
        seq = ([i for i in range(start, r) if spec[i] is None
                and shape[i] == cache_seq] if seq_on_model else [])
        final = [i for i in non_seq if i == r - 1]
        for i in heads + seq + final:
            if shape[i] % model_size == 0:
                spec[i] = "model"
                break
        return NamedSharding(mesh, _guard(shape, spec, mesh))

    return jax.tree.map(one, cache_shapes)


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# --------------------------------------------------------------------------
# Serving tensor parallelism (DESIGN.md §Sharded serving)
# --------------------------------------------------------------------------
# The training PARAM_RULES already express the serving TP layout for every
# dense decoder weight: wq/wk/wv/b* split the head dim, wo splits its
# H·Dh contraction dim, ffn w_gate/w_up/b_up split F, w_down splits its F
# contraction dim, embed splits V (rows) and unembed splits V (columns) —
# exactly the manual-collective contract the tp_axis forwards implement.
# Only MoE differs: training shards the EXPERT axis (all-to-all dispatch),
# while the serving engine keeps every expert on every shard and splits
# the per-expert FFN dim F (router replicated) so moe_dense needs just
# one psum after the w_down contraction.
_SERVING_OVERRIDES: Sequence[Tuple[str, int]] = (
    (r"moe/router$", -1),                # replicated
    (r"moe/w_(gate|up)$", 1),            # [L, E, D, F] -> split F
    (r"moe/w_down$", 2),                 # [L, E, F, D] -> split F
)


def serving_param_spec(path: str, shape: Tuple[int, ...],
                       model_size: int) -> P:
    """PartitionSpec of one serving parameter under tensor parallelism.
    Non-divisible dims replicate (the Engine asserts divisibility of the
    dims that MUST split — kv heads and vocab)."""
    for pat, dim_from_end in _SERVING_OVERRIDES:
        if re.search(pat, path):
            if dim_from_end < 0:
                return P()
            d = len(shape) - dim_from_end
            spec: list = [None] * len(shape)
            if 0 <= d < len(shape) and shape[d] % model_size == 0:
                spec[d] = "model"
            return P(*spec) if any(spec) else P()
    return param_spec(path, shape, model_size)


def serving_param_spec_tree(params, tp: int) -> Any:
    """PartitionSpec pytree for a serving param tree at TP size ``tp``."""
    flat, tdef = jax.tree_util.tree_flatten_with_path(params)
    specs = [serving_param_spec(_path_str(p), tuple(l.shape), tp)
             for p, l in flat]
    return jax.tree_util.tree_unflatten(tdef, specs)


def pool_spec_tree(pool) -> Any:
    """PartitionSpec pytree for a paged KV pool (or a contiguous KV
    piece): the kv-head axis — dim 3 of [L, NB, BS, Hkv, Dh] rows and of
    [L, NB, BS, Hkv] int8 scales — shards on 'model'; block ids, work
    lists and every other axis stay replicated, so the allocator, prefix
    index and migration bookkeeping never see the mesh."""
    def one(leaf):
        nd = len(leaf.shape)
        assert nd >= 4, f"pool leaf rank {nd} < 4"
        spec = [None] * nd
        spec[3] = "model"
        return P(*spec)
    return jax.tree.map(one, pool)
