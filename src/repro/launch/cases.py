"""Dry-run case construction: (architecture × input shape) -> a jit-able
step function + ShapeDtypeStruct inputs + shardings.

Input shapes (assignment):
    train_4k     seq 4096,   global batch 256   -> train_step
    prefill_32k  seq 32768,  global batch 32    -> prefill
    decode_32k   seq 32768,  global batch 128   -> serve_step (1 new token)
    long_500k    seq 524288, global batch 1     -> serve_step, sub-quadratic

Family adjustments (DESIGN §4):
  * long_500k gives full-attention families a sliding-window (8192)
    variant; whisper skips long_500k (448-position decoder, no 500k story);
    rwkv6 (O(1) state) and zamba2 run their native decode.
  * whisper's decoder position table is extended to the exercised decode
    length (synthetic but shape-faithful).
  * MoE archs lower the GShard capacity-dispatch path (expert-parallel
    all-to-all) instead of the dense-verification path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import batch_axes
from repro.launch.shardings import (batch_shardings, cache_shardings,
                                    param_shardings, replicated,
                                    zero1_shardings)
from repro.models import build_model
from repro.models.common import ModelConfig
from repro.training.optimizer import AdamWConfig, AdamWState, init_adamw
from repro.models.model import Model

SHAPES: Dict[str, Dict[str, int]] = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind=0),
    "prefill_32k": dict(seq_len=32_768, global_batch=32, kind=1),
    "decode_32k": dict(seq_len=32_768, global_batch=128, kind=2),
    "long_500k": dict(seq_len=524_288, global_batch=1, kind=2),
}

SLIDING_WINDOW_LONG = 8192


def shape_kind(shape_name: str) -> str:
    return {0: "train", 1: "prefill", 2: "decode"}[SHAPES[shape_name]["kind"]]


def skip_reason(arch: str, shape_name: str) -> Optional[str]:
    if arch == "whisper-large-v3" and shape_name == "long_500k":
        return ("enc-dec audio decoder is position-capped (448); no 500k "
                "decode story (DESIGN §4)")
    return None


def adjusted_config(arch: str, shape_name: str,
                    dtype=jnp.bfloat16) -> ModelConfig:
    import dataclasses as dc
    cfg = get_config(arch, dtype=dtype)
    over: Dict[str, Any] = {}
    if cfg.num_experts:
        over["moe_impl"] = "gshard"
    if shape_name == "long_500k" and cfg.family in ("dense", "moe", "vlm"):
        over["sliding_window"] = SLIDING_WINDOW_LONG
    if cfg.family == "encdec":
        # extend the decoder position table to the exercised length
        seq = SHAPES[shape_name]["seq_len"]
        if shape_name != "train_4k":
            over["max_position"] = max(cfg.max_position, seq + 1)
        else:
            over["max_position"] = max(cfg.max_position, 4096 + 1)
    if over:
        cfg = dc.replace(cfg, **over)
    return cfg


@dataclasses.dataclass
class DryrunCase:
    arch: str
    shape_name: str
    cfg: ModelConfig
    fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    model: Model


def _token_batch_shapes(cfg: ModelConfig, B: int, T: int) -> Dict[str, Any]:
    sh: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((B, T), jnp.int32)}
    if cfg.family == "encdec":
        sh["audio_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    if cfg.family == "vlm":
        n_patch = max(1, T // 8)
        sh["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, n_patch, cfg.d_model), cfg.dtype)
        sh["vision_mask"] = jax.ShapeDtypeStruct((B, T), jnp.bool_)
        sh["mrope_positions"] = jax.ShapeDtypeStruct((B, T, 3), jnp.int32)
    return sh


def input_specs(arch: str, shape_name: str = "train_4k"):
    """ShapeDtypeStruct stand-ins for every model input of the given
    (arch, shape) — weak-type-correct, shardable, no device allocation
    (the brief's ``input_specs()`` entry point; build_case composes these
    with params/cache shapes and shardings)."""
    cfg = adjusted_config(arch, shape_name)
    sp = SHAPES[shape_name]
    B, T = sp["global_batch"], sp["seq_len"]
    if shape_kind(shape_name) == "decode":
        return {"token": jax.ShapeDtypeStruct((B,), jnp.int32),
                "pos": jax.ShapeDtypeStruct((B,), jnp.int32)}
    return _token_batch_shapes(cfg, B, T)


def build_case(arch: str, shape_name: str, mesh,
               optimized: bool = False) -> DryrunCase:
    """``optimized=False`` is the paper-faithful/naive baseline;
    ``optimized=True`` enables the beyond-paper §Perf levers:
      * sequence-parallel activation sharding (train/prefill),
      * ZeRO-1 optimizer-state sharding over 'data' (train),
      * 2D expert sharding (MoE: experts on 'model', FFN dim on 'data'),
      * pinned KV-cache layout on the decode scatter (decode shapes).
    """
    import dataclasses as dc

    from jax.sharding import NamedSharding, PartitionSpec as P

    reason = skip_reason(arch, shape_name)
    if reason is not None:
        raise ValueError(f"skipped: {reason}")
    cfg = adjusted_config(arch, shape_name)
    sp = SHAPES[shape_name]
    B, T = sp["global_batch"], sp["seq_len"]
    kind = shape_kind(shape_name)
    ba = batch_axes(mesh)

    if optimized and kind == "train":
        # sequence-parallel pays off where remat stacks residuals; in
        # prefill it only added resharding (measured regression — §Perf)
        cfg = dc.replace(cfg, act_shard=(ba, "model"))
    if optimized and cfg.family == "hybrid" and kind in ("train", "prefill"):
        # chunked SSD: per-chunk (not per-token) AD state residuals
        cfg = dc.replace(cfg, ssm_chunk=128)

    model = build_model(cfg)
    param_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    # 2D expert sharding: always pays in decode (weight streaming is the
    # wall); in train/prefill it trades data-axis partial-sum collectives
    # for memory, so only use it when weights otherwise can't fit
    # (measured: qwen3-moe prefill regressed 0.59x with it always-on).
    expert_2d = False
    if optimized and cfg.num_experts:
        if kind == "decode":
            expert_2d = True
        else:
            from repro.sim.costmodel import profile_from_config
            w_chip = 2.0 * profile_from_config(cfg).params_total \
                / mesh.shape["model"]
            expert_2d = w_chip > 8 * 2**30
    p_shard = param_shardings(param_shapes, mesh, expert_2d=expert_2d)

    if kind == "train":
        batch_shapes = _token_batch_shapes(cfg, B, T)
        b_shard = batch_shardings(batch_shapes, mesh)
        opt_shapes = jax.eval_shape(init_adamw, param_shapes)
        if optimized:   # ZeRO-1: mu/nu also sharded over 'data'
            z = zero1_shardings(param_shapes, mesh)
            o_shard = AdamWState(step=replicated(mesh), mu=z, nu=z)
        else:           # optimizer state shards like params
            o_shard = AdamWState(step=replicated(mesh), mu=p_shard,
                                 nu=p_shard)
        from repro.training.trainer import make_train_step
        step = make_train_step(model, AdamWConfig(), remat=True)
        return DryrunCase(arch, shape_name, cfg, step,
                          (param_shapes, opt_shapes, batch_shapes),
                          (p_shard, o_shard, b_shard), model)

    if kind == "prefill":
        batch_shapes = _token_batch_shapes(cfg, B, T)
        b_shard = batch_shardings(batch_shapes, mesh)

        def prefill_step(params, batch):
            return model.prefill(params, batch, cache_len=T)

        return DryrunCase(arch, shape_name, cfg, prefill_step,
                          (param_shapes, batch_shapes),
                          (p_shard, b_shard), model)

    # decode: one new token against a cache of T tokens
    cache_shapes = jax.eval_shape(lambda: model.init_cache(B, T))
    context_parallel = (B == 1)
    c_shard = cache_shardings(
        cache_shapes, mesh, batch_size=B,
        cache_seq=(min(T, cfg.sliding_window) if cfg.sliding_window else T),
        context_parallel=context_parallel, seq_on_model=optimized)
    if optimized and cfg.num_heads:
        # pin the per-layer [B,S,H,D] cache layout inside serve_step: the
        # leading (layer/group) axis of the stored cache is scanned away
        S_eff = min(T, cfg.sliding_window) if cfg.sliding_window else T
        kv_spec = None
        for sh, sd in zip(jax.tree.leaves(cache_shapes),
                          jax.tree.leaves(c_shard)):
            if len(sh.shape) == 5 and sh.shape[2] == S_eff:
                kv_spec = P(*sd.spec[1:])
                break
        if kv_spec is not None:
            cfg = dc.replace(cfg, kv_cache_spec=kv_spec)
            model = build_model(cfg)
    tok = jax.ShapeDtypeStruct((B,), jnp.int32)
    pos = jax.ShapeDtypeStruct((B,), jnp.int32)
    tp_shard = NamedSharding(
        mesh, P(ba) if (B % _axes_size(mesh, ba) == 0 and B > 1) else P())

    def serve_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    return DryrunCase(arch, shape_name, cfg, serve_step,
                      (param_shapes, cache_shapes, tok, pos),
                      (p_shard, c_shard, tp_shard, tp_shard), model)


def _axes_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
