"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch × shape × mesh), all PER-CHIP seconds (jax returns
the per-partition SPMD module, so ``cost_analysis`` numbers are already
per device):

  compute    = FLOPs_per_chip / peak_FLOP/s
  memory     = bytes_per_chip / HBM_bw
  collective = Σ_ops wire_factor(op) · operand_bytes_per_chip / link_bw

**Scan-body caveat (measured and corrected):** XLA's ``cost_analysis``
counts a ``while``-loop body ONCE, not × trip count — our models loop
layers (and SSM time steps) with ``lax.scan``, so raw HLO FLOPs/bytes
under-count by ~num_layers. We therefore report BOTH:
  * ``flops_hlo`` / ``bytes_hlo`` — raw cost_analysis numbers,
  * analytic structural terms (exact matmul/attention FLOP formulas per
    arch × shape; weight-streaming + KV-traffic byte floors), which the
    roofline terms use:   compute = analytic FLOPs,
                          memory  = max(bytes_hlo, analytic floor).
Collectives: instances inside while-body computations are multiplied by
the layer trip count (they execute once per layer).

Collective bytes are parsed from the optimized HLO (operand shapes
resolved through a defs table) with ring-algorithm wire factors
(all-reduce 2×, all-gather counts its gathered result, reduce-scatter /
all-to-all / collective-permute 1× operand).

MODEL_FLOPS uses 6·N·D for training and 2·N·D for inference forward
passes (N = active params for MoE); the ratio MODEL_FLOPS / analytic
FLOPs flags attention/dispatch/remat overhead beyond the matmul core.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

# assignment hardware constants (TPU v5e)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_WIRE_FACTOR = {
    "all-reduce": 2.0,          # ring: reduce-scatter + all-gather
    "all-gather": 1.0,          # counted on its (gathered) result
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(\w[\w]*)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"%?([\w\.\-]+)\s*=\s*([^\s]+)\s+([\w\-]+)")


def _shape_bytes(dtype: str, dims: str) -> float:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0.0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return float(n * b)


def _first_shape_bytes(typestr: str) -> float:
    """Total bytes of a (possibly tuple) HLO type string."""
    return sum(_shape_bytes(dt, dims)
               for dt, dims in _SHAPE_RE.findall(typestr))


def parse_collectives(hlo_text: str, body_multiplier: int = 1) -> List[Dict]:
    """Per-collective records: op kind, operand bytes, result bytes,
    multiplicity. Collectives inside while-body computations execute once
    per loop iteration; ``body_multiplier`` (the layer trip count) is
    applied to those."""
    defs: Dict[str, float] = {}
    records: List[Dict] = []
    # pass 1: defs table (name -> result bytes)
    for line in hlo_text.splitlines():
        m = re.match(r"\s*%?([\w\.\-]+)\s*=\s*((?:\([^)]*\))|(?:[^\s]+))\s",
                     line)
        if m:
            defs[m.group(1)] = _first_shape_bytes(m.group(2))
    # pass 2: collectives, tracking the enclosing computation
    current_comp = ""
    for line in hlo_text.splitlines():
        comp = re.match(r"\s*%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*\{", line)
        if comp is None:
            comp = re.match(r"\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s+\([^)]*\)"
                            r"\s*->", line)
        if comp:
            current_comp = comp.group(1)
        m = re.match(
            r"\s*%?([\w\.\-]+)\s*=\s*((?:\([^)]*\))|(?:[^\s]+))\s+"
            r"([\w\-]+)\(([^)]*)\)", line)
        if not m:
            continue
        name, typestr, op, operands = m.groups()
        kind = next((c for c in _COLLECTIVES if op.startswith(c)), None)
        if kind is None:
            continue
        result_bytes = _first_shape_bytes(typestr)
        # operand bytes: inline shapes if present, else defs lookup
        inline = _SHAPE_RE.findall(operands)
        if inline:
            op_bytes = sum(_shape_bytes(dt, dims) for dt, dims in inline)
        else:
            op_bytes = sum(defs.get(o.strip().lstrip("%"), 0.0)
                           for o in operands.split(",") if o.strip())
        in_body = ("while" in current_comp or "body" in current_comp
                   or "region" in current_comp)
        records.append(dict(op=kind, name=name, operand_bytes=op_bytes,
                            result_bytes=result_bytes,
                            mult=body_multiplier if in_body else 1))
    return records


def collective_wire_bytes(records: List[Dict]) -> float:
    total = 0.0
    for r in records:
        f = _WIRE_FACTOR[r["op"]]
        base = r["result_bytes"] if r["op"] == "all-gather" \
            else r["operand_bytes"]
        if base == 0.0:
            base = max(r["operand_bytes"], r["result_bytes"])
        total += f * base * r.get("mult", 1)
    return total


# --------------------------------------------------------------------------
# Analytic structural terms (exact formulas; correct across scan bodies)
# --------------------------------------------------------------------------
def analytic_flops_global(cfg, shape_name: str, seq: int,
                          batch: int) -> float:
    """Executed FLOPs (global): matmul core + attention + recurrence.
    Matches what the lowered program actually computes — e.g. the flash
    XLA path computes full (non-causal-pruned) T×S score blocks, and MoE
    gshard dispatch einsums are included."""
    from repro.sim.costmodel import profile_from_config
    prof = profile_from_config(cfg)
    N = prof.params                       # active params (incl. embeddings)
    L = cfg.num_layers
    H, Dh = cfg.num_heads, cfg.head_dim
    attn_layers = (L // cfg.attn_every) if cfg.attn_every else L
    fwd_mult = {"train_4k": 3.0, "prefill_32k": 1.0}.get(shape_name, 1.0)

    if shape_name in ("train_4k", "prefill_32k"):
        tokens = batch * seq
        core = 2.0 * N * tokens
        attn = 0.0
        if H:
            # flash XLA path: full T×S QK^T + PV, 2 matmuls, grouped heads
            attn = attn_layers * 4.0 * batch * seq * seq * H * Dh
        if cfg.family == "ssm":           # rwkv recurrence ~6·H·K² / tok
            Hr = cfg.d_model // (cfg.ssm_head_dim or 64)
            K = cfg.ssm_head_dim or 64
            attn += L * 6.0 * tokens * Hr * K * K
        if cfg.family == "hybrid":        # mamba SSD ~5·H·P·N / tok
            d_inner = 2 * cfg.d_model
            Hm = d_inner // cfg.ssm_head_dim
            attn += L * 5.0 * tokens * Hm * cfg.ssm_head_dim * cfg.ssm_state
        if cfg.family == "encdec":        # encoder self-attn + cross KV
            Se = cfg.encoder_seq
            enc_attn = cfg.encoder_layers * 4.0 * batch * Se * Se * H * Dh
            cross = L * 4.0 * batch * seq * Se * H * Dh
            attn += enc_attn + cross
        return fwd_mult * (core + attn)

    # decode: one token per request against a cache
    core = 2.0 * N * batch
    S_eff = min(seq, cfg.sliding_window) if cfg.sliding_window else seq
    attn = 0.0
    if H:
        attn = attn_layers * 4.0 * batch * S_eff * H * Dh
    if cfg.family == "ssm":
        Hr = cfg.d_model // (cfg.ssm_head_dim or 64)
        K = cfg.ssm_head_dim or 64
        attn += L * 6.0 * batch * Hr * K * K
    if cfg.family == "hybrid":
        d_inner = 2 * cfg.d_model
        Hm = d_inner // cfg.ssm_head_dim
        attn += L * 5.0 * batch * Hm * cfg.ssm_head_dim * cfg.ssm_state
    if cfg.family == "encdec":
        attn += L * 4.0 * batch * cfg.encoder_seq * H * Dh   # cross-attn
    return core + attn


def analytic_bytes_per_chip(cfg, shape_name: str, seq: int, batch: int,
                            model_axis: int, data_axis: int) -> float:
    """HBM-traffic floor per chip: weights streamed once per step (or 3×
    for train: fwd read + grad write + opt update r/w ≈ 3 param passes in
    bf16 + f32 opt state r/w), plus KV/activation traffic."""
    from repro.sim.costmodel import profile_from_config
    prof = profile_from_config(cfg)
    w_chip = 2.0 * prof.params_total / model_axis            # bf16 weights
    if shape_name == "train_4k":
        # fwd+bwd weight reads ×2, grad write, adam mu/nu f32 r/w
        opt = 2 * 4.0 * prof.params_total / model_axis
        act = 2.0 * cfg.d_model * batch * seq / data_axis * cfg.num_layers
        return 3 * w_chip + 2 * opt + act
    if shape_name == "prefill_32k":
        act = 2.0 * cfg.d_model * batch * seq / data_axis * cfg.num_layers
        return w_chip + act
    # decode: weights once + full KV cache read (sharded on data × model)
    S_eff = min(seq, cfg.sliding_window) if cfg.sliding_window else seq
    kv = prof.kv_bytes_per_token * S_eff * batch   # total, all layers
    return w_chip + kv / (data_axis * model_axis)


def analytic_min_bytes(cfg, shape_name: str, seq: int, batch: int,
                       mesh_shape: Dict[str, int]) -> float:
    model_axis = mesh_shape.get("model", 1)
    data_axis = (mesh_shape.get("data", 1) * mesh_shape.get("pod", 1))
    return analytic_bytes_per_chip(cfg, shape_name, seq, batch,
                                   model_axis, data_axis)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops_per_chip: float                 # analytic (scan-corrected)
    bytes_per_chip: float                 # max(hlo, analytic floor)
    collective_bytes_per_chip: float      # while-body multiplied
    num_chips: int
    model_flops_global: float
    flops_hlo_per_chip: float = 0.0       # raw cost_analysis (body-once)
    bytes_hlo_per_chip: float = 0.0
    n_collectives: int = 0
    temp_bytes_per_chip: float = 0.0
    arg_bytes_per_chip: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_chip / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL (matmul-core) FLOPs over executed FLOPs — attention,
        MoE dispatch, non-causal flash waste show up here."""
        exec_global = self.flops_per_chip * self.num_chips
        return self.model_flops_global / max(exec_global, 1e-30)

    def row(self) -> Dict:
        return dict(arch=self.arch, shape=self.shape, mesh=self.mesh,
                    t_compute=self.t_compute, t_memory=self.t_memory,
                    t_collective=self.t_collective, dominant=self.dominant,
                    flops_per_chip=self.flops_per_chip,
                    flops_hlo_per_chip=self.flops_hlo_per_chip,
                    bytes_per_chip=self.bytes_per_chip,
                    bytes_hlo_per_chip=self.bytes_hlo_per_chip,
                    coll_bytes_per_chip=self.collective_bytes_per_chip,
                    model_flops=self.model_flops_global,
                    useful_ratio=self.useful_flops_ratio,
                    n_collectives=self.n_collectives,
                    temp_bytes_per_chip=self.temp_bytes_per_chip,
                    arg_bytes_per_chip=self.arg_bytes_per_chip)


def model_flops(cfg, shape_name: str, seq: int, batch: int) -> float:
    """6·N·D train / 2·N·D inference (N = active params, D = tokens)."""
    from repro.sim.costmodel import profile_from_config
    n_active = profile_from_config(cfg).params
    if shape_name == "train_4k":
        return 6.0 * n_active * seq * batch
    if shape_name == "prefill_32k":
        return 2.0 * n_active * seq * batch
    return 2.0 * n_active * batch          # decode: one token per request
