import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input shape) on the production meshes and extract the
roofline terms from the compiled artifact.

The two lines ABOVE the docstring must run before any jax import — jax
locks the device count at first init. This flag is set ONLY here (smoke
tests and benches see 1 device).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out results/dryrun.json
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import ARCHS  # noqa: E402
from repro.launch.cases import SHAPES, build_case, skip_reason  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import (Roofline, collective_wire_bytes,  # noqa: E402
                                   model_flops, parse_collectives)

ASSIGNED = [a for a in ARCHS if a != "llama3.2-3b"]


def run_case(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True, optimized: bool = False) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    reason = skip_reason(arch, shape_name)
    if reason:
        return dict(arch=arch, shape=shape_name, mesh=mesh_name,
                    status="skipped", reason=reason)
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        case = build_case(arch, shape_name, mesh, optimized=optimized)
        with mesh:
            jitted = jax.jit(case.fn, in_shardings=case.in_shardings)
            lowered = jitted.lower(*case.args)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        sp = SHAPES[shape_name]
        colls = parse_collectives(hlo,
                                  body_multiplier=case.cfg.num_layers)
        wire = collective_wire_bytes(colls)
        from repro.launch.roofline import (analytic_flops_global,
                                           analytic_min_bytes)
        flops_an = analytic_flops_global(case.cfg, shape_name,
                                         sp["seq_len"], sp["global_batch"])
        bytes_floor = analytic_min_bytes(case.cfg, shape_name,
                                         sp["seq_len"], sp["global_batch"],
                                         dict(mesh.shape))
        hlo_bytes = float(cost.get("bytes accessed", 0.0))
        rl = Roofline(
            arch=arch, shape=shape_name, mesh=mesh_name,
            flops_per_chip=flops_an / mesh.size,
            bytes_per_chip=max(hlo_bytes, bytes_floor),
            collective_bytes_per_chip=wire,
            num_chips=mesh.size,
            model_flops_global=model_flops(case.cfg, shape_name,
                                           sp["seq_len"], sp["global_batch"]),
            flops_hlo_per_chip=float(cost.get("flops", 0.0)),
            bytes_hlo_per_chip=hlo_bytes,
            n_collectives=len(colls),
            temp_bytes_per_chip=float(mem.temp_size_in_bytes),
            arg_bytes_per_chip=float(mem.argument_size_in_bytes),
        )
        row = rl.row()
        row.update(status="ok", optimized=optimized,
                   t_lower=t_lower, t_compile=t_compile,
                   output_bytes=float(mem.output_size_in_bytes))
        if verbose:
            print(f"[ok] {arch:22s} {shape_name:12s} {mesh_name:8s} "
                  f"comp={rl.t_compute:.3e}s mem={rl.t_memory:.3e}s "
                  f"coll={rl.t_collective:.3e}s dom={rl.dominant:10s} "
                  f"args/chip={rl.arg_bytes_per_chip/2**30:.2f}GiB "
                  f"temp/chip={rl.temp_bytes_per_chip/2**30:.2f}GiB "
                  f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)",
                  flush=True)
        return row
    except Exception as e:  # noqa: BLE001 — report, don't abort the sweep
        if verbose:
            print(f"[FAIL] {arch} {shape_name} {mesh_name}: {e}", flush=True)
            traceback.print_exc()
        return dict(arch=arch, shape=shape_name, mesh=mesh_name,
                    status="fail", error=f"{type(e).__name__}: {e}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="")
    ap.add_argument("--opt", action="store_true",
                    help="enable beyond-paper sharding optimizations")
    args = ap.parse_args()

    archs = ASSIGNED if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    rows = []
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                rows.append(run_case(arch, shape, mp,
                                     optimized=args.opt))
    ok = sum(1 for r in rows if r["status"] == "ok")
    skip = sum(1 for r in rows if r["status"] == "skipped")
    fail = sum(1 for r in rows if r["status"] == "fail")
    print(f"\n== dry-run: {ok} ok / {skip} skipped / {fail} FAILED "
          f"of {len(rows)}")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
        print("wrote", args.out)
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
