"""Serving launcher: a CascadeInfer MILS cluster over real JAX engines.

Replays a `sim/workload.py` trace open-loop against the real engines —
the same arrival process the discrete-event simulator consumes — through
the shared control plane (`repro.control`).

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --engines 4 --requests 12
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.core.partition import PipelinePlan, Stage
from repro.core.qoe import QoEModel
from repro.models import build_model
from repro.sched import assign_classes, parse_class_mix
from repro.serving.server import (MILSServer, ServerConfig,
                                  requests_from_trace)
from repro.sim.workload import WorkloadSpec, generate


def default_plan(num_engines: int, max_seq: int) -> PipelinePlan:
    """Two length stages splitting the engine pool (bootstrapping plan;
    production planning uses core.partition on profiled stats)."""
    if num_engines == 1:
        return PipelinePlan([Stage(0.0, float("inf"), 1)], 0.0)
    half = num_engines // 2
    return PipelinePlan(
        [Stage(0.0, max_seq / 4, num_engines - half),
         Stage(max_seq / 4, float("inf"), half)], 0.0)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--engines", type=int, default=4)
    ap.add_argument("--tp", default="1",
                    help="tensor-parallel degree per engine: a single "
                         "int ('2') shards every engine over that many "
                         "devices, or a comma list ('2,1,1,1') for a "
                         "heterogeneous cluster (DESIGN.md §Sharded "
                         "serving; needs XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N or "
                         "real devices)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--policy", default="cascade",
                    choices=["cascade", "round-robin", "least-loaded"])
    ap.add_argument("--refinement", default="adaptive",
                    choices=["adaptive", "quantity", "memory", "none"])
    ap.add_argument("--balancing", default="full",
                    choices=["full", "inter-stage", "rr"])
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-slots", type=int, default=3)
    ap.add_argument("--attn-backend", default=None,
                    choices=["dense", "grid", "flat", "fused"],
                    help="paged attention backend (default: auto — the "
                         "fused mixed-iteration kernel on TPU, dense XLA "
                         "elsewhere; see DESIGN.md §Decode hot path and "
                         "§Fused mixed-iteration attention)")
    ap.add_argument("--kv-dtype", default="bf16",
                    choices=["bf16", "int8"],
                    help="paged KV block pool dtype — int8 halves KV "
                         "bytes (~2x resident requests; needs the fused "
                         "or dense backend; DESIGN.md §Quantized KV "
                         "blocks)")
    ap.add_argument("--host-loop", action="store_true",
                    help="use the legacy host-driven engine step loop")
    ap.add_argument("--prefill-budget", type=int, default=None,
                    help="prompt-chunk tokens packed per mixed iteration "
                         "(DESIGN.md §Chunked prefill; default 256)")
    ap.add_argument("--no-chunked-prefill", action="store_true",
                    help="monolithic whole-prompt prefill (the §2.1 "
                         "head-of-line baseline)")
    ap.add_argument("--prefix-cache", dest="prefix_cache",
                    action="store_true", default=True,
                    help="refcounted prefix-shared KV pool (DESIGN.md "
                         "§Prefix cache; the default)")
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false",
                    help="disable prefix sharing — the bit-parity "
                         "legacy allocator path")
    ap.add_argument("--host-kv-budget", type=int, default=4096,
                    help="host-RAM KV tier capacity in tokens per engine "
                         "(DESIGN.md §Multi-tier KV): evicted prefix "
                         "chains demote here instead of dropping, and "
                         "hits promote back asynchronously. 0 reproduces "
                         "the drop-on-reclaim allocator bit-exactly "
                         "(default: a conservative 4096)")
    ap.add_argument("--no-kv-tiering", dest="host_kv_budget",
                    action="store_const", const=0,
                    help="disable the host KV tier (same as "
                         "--host-kv-budget 0)")
    ap.add_argument("--arrival-rate", type=float, default=2.0,
                    help="workload arrivals/s, replayed at 1 step/s")
    ap.add_argument("--slo-class-mix", default=None,
                    help="SLO service-class mix for the replayed trace, "
                         "e.g. 'interactive:0.5,standard:0.3,batch:0.2' "
                         "(classes: repro.sched.SLO_CLASSES; default: "
                         "all standard)")
    ap.add_argument("--preemption", dest="preemption",
                    action="store_true", default=True,
                    help="SLO-tiered preemptive scheduling (DESIGN.md "
                         "§SLO scheduling; the default)")
    ap.add_argument("--no-preemption", dest="preemption",
                    action="store_false",
                    help="disable preemption — bit-parity FCFS queues")
    ap.add_argument("--slo-scale", type=float, default=1.0,
                    help="SLO-scale sweep knob (paper §6.4)")
    ap.add_argument("--slo-time-scale", type=float, default=1.0,
                    help="engine steps per abstract SLO second")
    ap.add_argument("--crash", action="append", default=[],
                    metavar="ENGINE:STEP",
                    help="chaos: kill engine ENGINE at step STEP "
                         "(repeatable; DESIGN.md §Fault tolerance)")
    ap.add_argument("--rejoin", action="append", default=[],
                    metavar="ENGINE:STEP",
                    help="chaos: revive a crashed engine at step STEP "
                         "(fresh state; its old residents were already "
                         "re-dispatched)")
    ap.add_argument("--transfer-loss-p", type=float, default=0.0,
                    help="chaos: probability a migration transfer is "
                         "lost on the wire (rolled back after timeout)")
    ap.add_argument("--transfer-stall-p", type=float, default=0.0,
                    help="chaos: probability a transfer stalls past its "
                         "deadline (delivered late, treated as lost)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the deterministic fault injector")
    ap.add_argument("--migration-timeout-steps", type=int, default=4,
                    help="steps before an in-flight transfer is rolled "
                         "back to its sender")
    ap.add_argument("--dead-after-steps", type=int, default=6,
                    help="heartbeat-free steps before an engine is "
                         "declared dead and its residents re-dispatched")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    def _events(specs):
        return tuple((int(e), float(s)) for e, s in
                     (item.split(":", 1) for item in specs))

    faults = None
    if args.crash or args.rejoin or args.transfer_loss_p > 0 \
            or args.transfer_stall_p > 0:
        from repro.control.faults import FaultSpec
        faults = FaultSpec(seed=args.fault_seed,
                           crashes=_events(args.crash),
                           rejoins=_events(args.rejoin),
                           transfer_loss_p=args.transfer_loss_p,
                           transfer_stall_p=args.transfer_stall_p)

    tp = ([int(x) for x in args.tp.split(",")] if "," in args.tp
          else int(args.tp))
    tps = tp if isinstance(tp, list) else [tp] * args.engines
    if any(t > 1 for t in tps):
        assert not args.host_loop, "--tp > 1 needs the device-resident loop"
        need = max(tps)
        assert len(jax.devices()) >= need, (
            f"--tp {args.tp} needs {need} devices, have "
            f"{len(jax.devices())} (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need} for CPU)")

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    plan = default_plan(args.engines, args.max_seq)
    qoe = QoEModel(np.array([1e-3, 1e-4, 1e-6, 0.0, 1e-6]))
    srv = MILSServer(model, params, plan, qoe,
                     ServerConfig(policy=args.policy,
                                  refinement=args.refinement,
                                  balancing=args.balancing, seed=args.seed,
                                  preemption=args.preemption,
                                  slo_scale=args.slo_scale,
                                  slo_time_scale=args.slo_time_scale,
                                  faults=faults,
                                  migration_timeout_steps=
                                  args.migration_timeout_steps,
                                  dead_after_steps=args.dead_after_steps,
                                  host_kv_budget=(args.host_kv_budget
                                                  if args.prefix_cache
                                                  else 0)),
                     tp=tp,
                     max_slots=args.max_slots, max_seq=args.max_seq,
                     attn_backend=args.attn_backend,
                     kv_dtype=args.kv_dtype,
                     device_resident=False if args.host_loop else None,
                     prefill_token_budget=args.prefill_budget,
                     chunked_prefill=(False if args.no_chunked_prefill
                                      else None),
                     prefix_cache=args.prefix_cache)
    # the same ShareGPT-shaped trace the simulator runs, arrival times
    # mapped to server steps, lengths capped to the reduced model
    spec = WorkloadSpec(rate=args.arrival_rate,
                        duration=args.requests / args.arrival_rate,
                        seed=args.seed)
    trace = generate(spec)[:args.requests]
    if args.slo_class_mix:
        mix = parse_class_mix(args.slo_class_mix)
        classes = assign_classes(len(trace),
                                 mix, np.random.default_rng(args.seed))
        trace = [dataclasses.replace(r, slo_class=c)
                 for r, c in zip(trace, classes)]
    for req, step in requests_from_trace(trace, vocab_size=cfg.vocab_size,
                                         max_seq=args.max_seq,
                                         seed=args.seed):
        srv.submit_at(req, step)
    srv.run(max_steps=100 * args.requests)
    print("summary:", {k: round(v, 2) if isinstance(v, float) else v
                       for k, v in srv.summary().items()})
    print("stage bounds:", srv.stage_bounds)


if __name__ == "__main__":
    main()
