"""Serving launcher: a CascadeInfer MILS cluster over real JAX engines.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --engines 4 --requests 12
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core.partition import PipelinePlan, Stage
from repro.core.qoe import QoEModel
from repro.models import build_model
from repro.serving.request import ServeRequest
from repro.serving.server import MILSServer, ServerConfig


def default_plan(num_engines: int, max_seq: int) -> PipelinePlan:
    """Two length stages splitting the engine pool (bootstrapping plan;
    production planning uses core.partition on profiled stats)."""
    if num_engines == 1:
        return PipelinePlan([Stage(0.0, float("inf"), 1)], 0.0)
    half = num_engines // 2
    return PipelinePlan(
        [Stage(0.0, max_seq / 4, num_engines - half),
         Stage(max_seq / 4, float("inf"), half)], 0.0)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--engines", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--policy", default="cascade",
                    choices=["cascade", "round-robin", "least-loaded"])
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-slots", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    plan = default_plan(args.engines, args.max_seq)
    qoe = QoEModel(np.array([1e-3, 1e-4, 1e-6, 0.0, 1e-6]))
    srv = MILSServer(model, params, plan, qoe,
                     ServerConfig(policy=args.policy, seed=args.seed),
                     max_slots=args.max_slots, max_seq=args.max_seq)
    rng = np.random.default_rng(args.seed)
    reqs = [ServeRequest(i,
                         rng.integers(0, cfg.vocab_size,
                                      int(rng.integers(8, args.max_seq // 3))
                                      ).astype(np.int32),
                         int(rng.integers(8, args.max_seq // 2)))
            for i in range(args.requests)]
    srv.run(reqs, max_steps=50 * args.requests)
    print("summary:", srv.summary())
    print("stage bounds:", srv.stage_bounds)


if __name__ == "__main__":
    main()
