"""Single-instance JAX inference engine: block-granular paged KV cache +
continuous batching (the vLLM-role component of DESIGN §3; layouts and
invariants in DESIGN.md).

Two cache layouts behind one scheduling surface:

  * **paged** (default for full-attention decoder families): a global block
    pool with leaves ``[L, num_blocks, block_size, Hkv, Dh]`` plus a
    per-request block table, managed by ``BlockAllocator``. Admission gates
    on worst-case *block reservations* (``ceil(min(prompt+max_new,
    max_seq)/BS)``), physical blocks are allocated incrementally as the
    sequence grows, and a 16-token request pins 16 tokens of cache — not a
    ``max_seq`` slab. ``free_tokens()`` can never go negative.
  * **monolithic** fallback (ssm/rwkv recurrent state, sliding-window ring
    buffers): preallocated ``[L, slots, S_max, ...]`` slab, one slot per
    request, with the same reservation-based admission accounting.

Every ``step()`` is one **mixed** continuous-batching iteration (DESIGN.md
§Chunked prefill): pack up to ``prefill_token_budget`` prompt-chunk tokens
(resuming partial prompts oldest-first, then admitting FCFS) alongside the
full decode batch, then advance every fully-prefilled request by one
token with a single batched decode. Chunk K/V is scattered into freshly
allocated pool blocks, so partial prompts live in the same pool as decode
state; a long prompt therefore never freezes decoding for more than one
iteration (the §2.1 head-of-line block this engine used to have —
``chunked_prefill=False`` keeps that whole-prompt baseline). Migration
exports a request's KV trimmed to its actual written length (paged: a
gather of its blocks; mid-prefill: the ``ctx_done`` rows, resumed on the
receiver) — the wire format is the same contiguous ``[L, 1, length, ...]``
piece for both layouts, so mixed clusters interoperate (DESIGN.md
§Migration wire format).

**Device-resident decode hot loop** (paged engines, the default —
DESIGN.md §Decode hot path): block tables, slot lengths, and last tokens
live as device arrays (pow2-capped width growth), sampling is a fused
on-device argmax over the whole ``max_slots``-wide batch, and every
``step()`` performs exactly ONE device→host transfer — the sampled
tokens, routed through :func:`d2h` so tests can count it. ``step(burst=n)``
fuses up to ``n`` consecutive iterations into one ``lax.scan``
micro-batch (the fusion never crosses a count/capacity finish boundary,
so continuous-batching admission is not delayed). Prompt prefills are
padded to pow2 buckets so compiles stay O(log max_seq), not O(distinct
prompt lengths). ``device_resident=False`` keeps the original host-driven
loop — the bit-parity reference.
"""
from __future__ import annotations

import dataclasses
import functools
import weakref
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.migration import (gather_kv_blocks, kv_bytes,
                                  scatter_kv_blocks)
from repro.kernels.cost import pow2_bucket
from repro.launch.mesh import make_tp_mesh
from repro.launch.shardings import pool_spec_tree, serving_param_spec_tree
from repro.models.attention import (QuantKVCache, dequantize_piece,
                                    quantize_piece, resolve_paged_backend)
from repro.models.model import Model, build_model
from repro.sched.policy import park_or_recompute
from repro.sched.slo import (aging_promotion, insert_sorted, priority_of,
                             queue_key, tpot_hopeless)
from repro.serving.block_pool import (BlockAllocator, blocks_for, chain_hash,
                                      prompt_chain)
from repro.serving.request import ServeRequest, State

DEFAULT_BLOCK_SIZE = 16
# Per-iteration prompt-chunk token budget of the mixed scheduler
# (DESIGN.md §Chunked prefill): every step packs up to this many prompt
# tokens (oldest request first) alongside the full decode batch, so a
# long prompt can never freeze decoding for more than one iteration.
DEFAULT_PREFILL_BUDGET = 256

# Running count of device->host synchronizations performed by all engines
# in this process (bench_decode_hotloop reads it; tests monkeypatch d2h).
D2H_CALLS = 0

# Weakrefs to every engine ever constructed in this process. The test
# suite's drain-leak fixture walks this after each test and asserts no
# engine is left holding reservations or parked requests (crashed
# engines are skipped via their `_faulted` flag).
_LIVE_ENGINES: List["weakref.ref"] = []


def d2h(x) -> np.ndarray:
    """The engine's ONLY device→host synchronization point. Every token
    that reaches Python crosses here, so `D2H_CALLS` (and a test shim
    monkeypatching this function) measures host round-trips exactly."""
    global D2H_CALLS
    D2H_CALLS += 1
    return np.asarray(x)


# Running count of attention-bearing device calls (jitted forwards that
# execute attention kernels) issued by all engines in this process. Launch
# counters INSIDE a jitted function only tick at trace time, so the
# one-launch-per-mixed-step contract is asserted here instead: every such
# forward is routed through :func:`attn_call` (the launch-count twin of
# :func:`d2h`), and a fused mixed step makes exactly ONE call where the
# separate-kernel path makes two (chunk batch + decode burst).
ATTN_CALLS = 0


def attn_call(fn, *args, **kwargs):
    """Issue one attention-bearing device call (and count it)."""
    global ATTN_CALLS
    ATTN_CALLS += 1
    return fn(*args, **kwargs)


_next_pow2 = pow2_bucket     # ONE bucketing policy (kernels/cost.py)


def _pow2_floor(n: int) -> int:
    assert n >= 1
    return 1 << (n.bit_length() - 1)


@dataclasses.dataclass
class _Parked:
    """A park-preempted request: off its batch slot, KV blocks (and the
    covering reservation) intact. ``_unpark`` restores it into any free
    slot with bit-identical continuation (DESIGN.md §SLO scheduling)."""
    req: ServeRequest
    table: List[int]
    shared: int          # shared prefix-head blocks (released owned=False)
    rblocks: int         # reservation units the request still holds
    slot_len: int


class Engine:
    def __init__(self, engine_id: int, model: Model, params, *,
                 max_slots: int = 8, max_seq: int = 512,
                 token_budget: Optional[int] = None,
                 paged: Optional[bool] = None,
                 block_size: int = DEFAULT_BLOCK_SIZE,
                 device_resident: Optional[bool] = None,
                 attn_backend: Optional[str] = None,
                 prefill_token_budget: Optional[int] = None,
                 chunked_prefill: Optional[bool] = None,
                 prefix_cache: Optional[bool] = None,
                 kv_dtype: str = "bf16",
                 host_kv_budget: int = 0,
                 preemption: Optional[bool] = None,
                 slo_time_scale: float = 1.0,
                 tp: int = 1):
        assert model.cfg.family in ("dense", "moe", "vlm", "ssm"), \
            "engine supports decoder-only families"
        assert kv_dtype in ("bf16", "int8"), kv_dtype
        # Serving tensor parallelism (DESIGN.md §Sharded serving): tp > 1
        # rebuilds the model with the manual-collective tp_axis, pins
        # params + pool to a 1-D 'model' mesh over the first ``tp`` local
        # devices, and runs every attention-bearing jit through shard_map.
        # Only the pool's kv-head axis is sharded — the allocator, prefix
        # index, block tables and migration wire format never see the mesh.
        self.tp = int(tp)
        if self.tp > 1:
            cfg = model.cfg
            assert model.supports_paged and paged is not False, \
                "tensor-parallel serving needs the paged block pool"
            assert device_resident is not False, \
                "tensor-parallel serving needs the device-resident loop"
            assert cfg.num_kv_heads % self.tp == 0, \
                f"kv heads {cfg.num_kv_heads} not divisible by tp={self.tp}"
            assert cfg.num_heads % self.tp == 0, \
                f"heads {cfg.num_heads} not divisible by tp={self.tp}"
            assert cfg.vocab_size % self.tp == 0, \
                f"vocab {cfg.vocab_size} not divisible by tp={self.tp}"
            assert cfg.d_ff % self.tp == 0, \
                f"d_ff {cfg.d_ff} not divisible by tp={self.tp}"
            model = build_model(dataclasses.replace(cfg, tp_axis="model"))
            self.mesh = make_tp_mesh(self.tp)
            self._pspec = serving_param_spec_tree(params, self.tp)
            params = jax.device_put(params, jax.tree.map(
                lambda s: NamedSharding(self.mesh, s), self._pspec))
        self.id = engine_id
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.token_budget = token_budget or max_slots * max_seq
        self.paged = model.supports_paged if paged is None else paged
        if self.paged:
            assert model.supports_paged, \
                f"{model.cfg.name} ({model.cfg.family}) has no paged path"
            self.block_size = block_size
            # ``token_budget`` is the PER-DEVICE pool budget: each shard
            # holds Hkv/tp heads of every block, so a tp-engine owns tp×
            # the blocks (and resident tokens) at equal per-device bytes.
            self.num_blocks = (self.token_budget * self.tp) // block_size
            assert self.num_blocks > 0, \
                f"token_budget {self.token_budget} < one block ({block_size})"
            # capacity is block-granular: tokens that don't fill a block
            # can't back any request (mirrors sim.Instance)
            self.token_budget = self.num_blocks * block_size
            # host-RAM KV tier (DESIGN.md §Multi-tier KV): reclaimed
            # cached chains demote to a capacity-bounded host store
            # instead of dying; 0 keeps the drop-on-reclaim behavior
            # bit-exactly
            self.host_kv_budget = int(host_kv_budget or 0)
            self.allocator = BlockAllocator(
                self.num_blocks, block_size,
                host_blocks=self.host_kv_budget // block_size)
            if self.allocator.host_tier_enabled:
                self.allocator.set_demote_fetch(self._demote_snapshot)
            # +1 garbage block (id num_blocks, never allocated): dead batch
            # slots and padded table rows write/read there by construction,
            # so the fixed-shape device loop cannot corrupt live blocks
            self.garbage_block = self.num_blocks
            self.kv_dtype = kv_dtype
            if kv_dtype == "int8":
                # int8 pools halve KV bytes, so the same token_budget holds
                # nearly 2x the blocks (DESIGN.md §Quantized KV blocks);
                # quantized rows are only readable by the fused kernel and
                # the dense gather
                self.cache = model.init_paged_cache(self.num_blocks + 1,
                                                    block_size,
                                                    kv_dtype=kv_dtype)
            else:
                self.cache = model.init_paged_cache(self.num_blocks + 1,
                                                    block_size)
            if self.tp > 1:
                self._pool_spec = pool_spec_tree(self.cache)
                self.cache = jax.device_put(self.cache, jax.tree.map(
                    lambda s: NamedSharding(self.mesh, s), self._pool_spec))
            self.block_tables: List[List[int]] = [[] for _ in range(max_slots)]
            self._bytes_per_block = kv_bytes(self.cache) / (self.num_blocks + 1)
            self.device_resident = (device_resident
                                    if device_resident is not None else True)
            self.attn_backend, self.attn_interpret = \
                resolve_paged_backend(attn_backend)
            if kv_dtype == "int8":
                assert self.attn_backend in ("fused", "dense"), \
                    "int8 KV needs the 'fused' or 'dense' attention backend"
            if self.device_resident:
                assert model.prefill_bucketed is not None, \
                    "device-resident loop needs Model.prefill_bucketed"
                self._nbt_cap = 1               # device table width (pow2)
                self._dev_bt = jnp.full((max_slots, 1), self.garbage_block,
                                        jnp.int32)
                self._dev_len = jnp.zeros((max_slots,), jnp.int32)
                self._dev_tok = jnp.zeros((max_slots,), jnp.int32)
                self._burst_fns: Dict[Tuple[int, int], Callable] = {}
                self._mixed_fns: Dict[int, Callable] = {}
                if self.tp > 1:
                    # bucketed prefill returns a contiguous KV piece
                    # [L, B, P, Hkv, Dh] — kv heads sharded like the pool
                    self._prefill_bucketed = jax.jit(self._smap(
                        model.prefill_bucketed, (self._pspec, P(), P()),
                        (P(), P(None, None, None, "model", None))))
                else:
                    self._prefill_bucketed = jax.jit(model.prefill_bucketed)
                self._pending_first: List[Tuple[ServeRequest, jnp.ndarray]] = []
            else:
                # the host loop honors the backend too (attn_num_work
                # stays None -> the flat wrapper's B·NBT worst case)
                self._decode_paged = jax.jit(functools.partial(
                    model.decode_step_paged,
                    attn_backend=self.attn_backend,
                    attn_interpret=self.attn_interpret))
        else:
            assert kv_dtype == "bf16", \
                "quantized KV needs the paged block pool"
            self.block_size = 0
            self.device_resident = False
            self.cache = model.init_cache(max_slots, max_seq)
            self._bytes_per_slot = kv_bytes(self.cache) / max_slots
            self._decode = jax.jit(model.decode_step)
        # Chunked paged prefill (DESIGN.md §Chunked prefill): on by default
        # wherever the model supports it; chunked_prefill=False keeps the
        # whole-prompt path (the monolithic-prefill baseline).
        chunk_ok = self.paged and model.prefill_chunk is not None
        self.chunked_prefill = (chunk_ok if chunked_prefill is None
                                else chunked_prefill)
        self.prefill_token_budget = (prefill_token_budget
                                     or DEFAULT_PREFILL_BUDGET)
        self._prefill_order: List[int] = []   # slots mid-prefill, oldest 1st
        if self.chunked_prefill:
            assert chunk_ok, \
                f"{model.cfg.name}: chunked prefill needs a paged engine " \
                "and Model.prefill_chunk"
            ck = functools.partial(model.prefill_chunk,
                                   attn_backend=self.attn_backend,
                                   attn_interpret=self.attn_interpret)
            if self.tp > 1:
                ck = self._smap(ck, (self._pspec, self._pool_spec,
                                     P(), P(), P(), P()),
                                (P(), self._pool_spec))
            self._prefill_chunk = jax.jit(ck)
        # Fused mixed iterations (DESIGN.md §Fused mixed-iteration
        # attention): when the backend is "fused" and the model has a
        # mixed_step, the device loop runs the decode batch AND the step's
        # prompt chunks through ONE attention-bearing device call (one
        # kernel launch per layer). Otherwise mixed steps stay two calls —
        # the bit-parity separate-kernel reference.
        self.fused_mixed = bool(
            self.chunked_prefill and self.device_resident
            and self.attn_backend == "fused"
            and getattr(model, "mixed_step", None) is not None)
        # Refcounted prefix cache (DESIGN.md §Prefix cache): admission
        # shares already-resident full prompt blocks and starts chunked
        # prefill at ctx_done = cached_tokens, so a warm request skips the
        # cached blocks' prefill work entirely. Needs the chunked paged
        # path (warm starts resume mid-prompt); prefix_cache=False is the
        # bit-parity legacy path.
        self.prefix_cache = (self.chunked_prefill if prefix_cache is None
                             else bool(prefix_cache and self.chunked_prefill))
        if self.paged:
            self._slot_rblocks = [0] * max_slots   # reserved blocks per slot
            self._slot_shared = [0] * max_slots    # shared table-head blocks
        self.slot_len = np.zeros(max_slots, np.int32)       # tokens in cache
        self.slots: List[Optional[ServeRequest]] = [None] * max_slots
        self.slot_reserved = np.zeros(max_slots, np.int64)  # worst-case tokens
        self.waiting: Deque[ServeRequest] = deque()
        # SLO-tiered preemptive scheduling (DESIGN.md §SLO scheduling &
        # preemption): off by default on direct construction — the
        # bit-parity FCFS legacy path. When on, the waiting queue is kept
        # sorted by repro.sched.slo.queue_key and a blocked higher-class
        # request may park (slot shortage) or recompute-preempt (memory
        # shortage) the lowest-class resident decode.
        self.slo_sched = bool(preemption)
        self.slo_time_scale = float(slo_time_scale)
        self.parked: List[_Parked] = []
        self._seq = 0                # submission tie-break for queue_key
        self.preemptions = 0         # victim pauses (park + recompute)
        self.preempt_recomputes = 0  # victims whose KV was dropped
        self.resumes = 0             # park restores + recompute completions
        # TPOT-deadline admission (DESIGN.md §SLO scheduling): resumed
        # decodes whose TPOT is already unrecoverable never preempt
        # healthy traffic — counted here (once per request) against
        # attainment instead
        self.tpot_skipped = 0
        self._tpot_hopeless_ids: set = set()
        self.steps = 0
        self.tokens_out = 0
        self.peak_kv_bytes = 0.0
        # prefill cost counters (bench_prefix_cache reads them): block-work
        # actually run by prefill (Σ per chunk ceil((ctx+clen)/BS) — the
        # grid-step mirror) vs. prompt tokens served straight from the
        # prefix index. A warm identical prompt shows up as a collapsed
        # prefill_work_blocks and a matching cached_prompt_tokens_total.
        self.prefill_work_blocks = 0
        self.prefill_tokens_done = 0
        self.cached_prompt_tokens_total = 0
        # multi-tier KV counters (DESIGN.md §Multi-tier KV): blocks
        # promoted from the host tier back onto device at admission
        self.promoted_blocks_total = 0
        # last decode's grid accounting (bench_decode_hotloop reads it):
        # flat_items = work items the flat grid runs (pow2 bucket),
        # real_items = Σ_b ceil(L_b/BS), padded_items = B·max_b ceil(L_b/BS)
        self.last_grid: Dict[str, int] = {}
        self._prefill = jax.jit(model.prefill,
                                static_argnames=("cache_len",))
        _LIVE_ENGINES.append(weakref.ref(self))

    # ---- serving tensor parallelism (DESIGN.md §Sharded serving) ----------
    def _smap(self, fn, in_specs, out_specs):
        """shard_map a forward over this engine's 1-D 'model' mesh.
        ``check_rep=False``: block tables / work lists are replicated by
        construction and the psum sites live inside the model."""
        return shard_map(fn, self.mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)

    def _localize_piece(self, piece):
        """Adopt a migration piece gathered on ANOTHER engine's mesh: pull
        it to host and re-place it under this engine's sharding (plain
        device arrays for tp=1). Same-mesh pieces pass through untouched.
        The host copy is migration traffic — accounted by the cluster's
        byte ledger, not the step's d2h discipline."""
        leaves = jax.tree_util.tree_leaves(piece)
        if not leaves or not hasattr(leaves[0], "sharding"):
            return piece
        here = jax.tree_util.tree_leaves(self.cache)[0].sharding
        if leaves[0].sharding.device_set == here.device_set:
            return piece
        host = jax.tree.map(np.asarray, piece)
        if self.tp > 1:
            return jax.device_put(host, jax.tree.map(
                lambda s: NamedSharding(self.mesh, s),
                pool_spec_tree(piece)))
        return jax.tree.map(jnp.asarray, host)

    # ---- drain-time leak check (DESIGN.md §Fault tolerance) ---------------
    def check_drained(self, strict: bool = True) -> None:
        """Assert this engine holds no request state. ``strict`` also
        requires the queues to be empty (a post-run server drain);
        non-strict only checks that ALLOCATOR state matches the resident
        requests — the invariant conftest runs after every test, where
        engines may legitimately still hold live requests."""
        if strict:
            assert all(r is None for r in self.slots), \
                f"engine {self.id}: undrained slots"
            assert not self.waiting, f"engine {self.id}: undrained queue"
            assert not self.parked, f"engine {self.id}: undrained parked"
            assert not self._prefill_order, \
                f"engine {self.id}: dangling prefill order"
        if self.paged:
            self.allocator.check_invariants()
            if strict and not any(self.slots) and not self.parked:
                self.allocator.check_drained()
        elif strict:
            assert int(self.slot_reserved.sum()) == 0, \
                f"engine {self.id}: leaked slot reservations"
            assert int(self.slot_len.sum()) == 0, \
                f"engine {self.id}: leaked slot lengths"

    def shutdown(self) -> None:
        """End-of-life check + release: asserts the engine drained clean,
        then drops its device buffers."""
        self.check_drained(strict=True)
        self.cache = None
        if self.paged:
            self.block_tables = [[] for _ in self.block_tables]

    # ---- load views --------------------------------------------------------
    def active(self) -> List[ServeRequest]:
        return [r for r in self.slots if r is not None]

    def used_tokens(self) -> int:
        """Tokens of cache memory actually pinned by running requests.
        Paged: allocated blocks × block size; monolithic: live cache rows.
        (Waiting prompts hold no cache — they are reported by
        ``queued_tokens``/``load`` instead, so admission and the free
        budget agree on one definition.)"""
        if self.paged:
            return self.allocator.allocated_tokens()
        return int(self.slot_len.sum())

    def reserved_tokens(self) -> int:
        """Worst-case committed footprint of all admitted requests —
        what admission gates on (never exceeds the budget)."""
        if self.paged:
            return self.allocator.reserved_blocks * self.block_size
        return int(self.slot_reserved.sum())

    def queued_tokens(self) -> int:
        """UN-PREFILLED, UNCACHED prompt tokens: whole waiting prompts
        (minus their prefix-cache hit, estimated at submit) plus the
        not-yet-written remainder of requests mid-chunked-prefill. The
        written part of a partial prompt is already pinned cache and shows
        up in ``used_tokens`` — one token never counts twice, and a warm
        30K prompt whose first 28K tokens are resident queues as the
        short request it effectively is (DESIGN.md §Prefix cache)."""
        q = sum(r.prefill_target_len - r.cached_tokens for r in self.waiting)
        q += sum(r.prefill_target_len - r.ctx_done
                 for r in self.active() if r.prefilling)
        return int(q)

    def free_tokens(self) -> int:
        """Unpinned cache budget; the admission invariant keeps this >= 0."""
        return self.token_budget - self.used_tokens()

    def load(self) -> float:
        """Scheduling pressure: pinned cache + queued prompt tokens."""
        return float(self.used_tokens() + self.queued_tokens())

    def kv_bytes_pinned(self) -> float:
        """Cache bytes pinned right now (paged: allocated blocks;
        monolithic: occupied max_seq slabs)."""
        if self.paged:
            return self.allocator.allocated_blocks * self._bytes_per_block
        return sum(1 for r in self.slots if r is not None) \
            * self._bytes_per_slot

    def has_idle_slot(self) -> bool:
        return any(r is None for r in self.slots)

    def request_view(self) -> List[Tuple[float, float]]:
        return [(float(len(r.prompt)), float(r.length)) for r in self.active()]

    # ---- prefix cache (DESIGN.md §Prefix cache) ------------------------------
    def _prompt_digests(self, prompt) -> List[int]:
        """Chain digests of the prompt's full blocks, capped at
        ``(len-1)//BS`` so even a fully-cached identical prompt still
        prefill-computes >= 1 token (the first output token needs the last
        position's logits)."""
        return prompt_chain(prompt, self.block_size,
                            limit=(len(prompt) - 1) // self.block_size)

    def _req_digests(self, req: ServeRequest) -> List[int]:
        """Per-request digest memo: the prompt is immutable, so its sha1
        chain is computed ONCE per block size — not per hint probe, per
        submit, and per admission re-check of the waiting-queue head."""
        cache = req.prefix_digests_memo
        if cache is None or cache[0] != self.block_size:
            cache = (self.block_size, self._prompt_digests(req.prompt))
            req.prefix_digests_memo = cache
        return cache[1]

    def _cached_chain(self, req: ServeRequest) -> List[int]:
        """Longest resident block chain for this prompt ([] when the
        cache is off or cold)."""
        if not self.prefix_cache:
            return []
        return self.allocator.lookup(self._req_digests(req))

    def _tiered_chain(self, req: ServeRequest):
        """(device block ids, host digest continuation) — the two-tier
        chain hit admission consumes: device blocks are shared for free,
        host digests are promoted at a copy cost (DESIGN.md §Multi-tier
        KV)."""
        if not self.prefix_cache:
            return [], []
        return self.allocator.lookup_tiered(self._req_digests(req))

    def prefix_hint(self, req: ServeRequest):
        """(head_digest, cached_tokens, promote_blocks) for dispatch: the
        digest of the prompt's first full block (None for sub-block
        prompts), the tokens resident here across BOTH tiers, and how
        many of those blocks are host-resident (routing prices their
        promote copy — DESIGN.md §Multi-tier KV). The digest is
        content-derived, so it is identical across engines for the same
        prompt."""
        if not self.prefix_cache or len(req.prompt) <= self.block_size:
            return None, 0, 0
        digests = self._req_digests(req)
        dev, host = self.allocator.lookup_tiered(digests)
        return digests[0], (len(dev) + len(host)) * self.block_size, len(host)

    def prefix_digests(self) -> frozenset:
        """Head digests of every cached chain (either tier) — the compact
        advertisement within-stage dispatch tie-breaks on."""
        if not self.paged or not self.prefix_cache:
            return frozenset()
        return (self.allocator.head_digests()
                | self.allocator.host_head_digests())

    def tiered_digests(self) -> Dict[int, str]:
        """Head digest -> tier tag ('device' | 'host'). The control
        plane's warm filter prefers device-warm instances — a host hit
        still beats recompute but pays the promote copy (DESIGN.md
        §Multi-tier KV)."""
        if not self.paged or not self.prefix_cache:
            return {}
        out = {h: "device" for h in self.allocator.head_digests()}
        for h in self.allocator.host_head_digests():
            out.setdefault(h, "host")
        return out

    # ---- multi-tier KV (DESIGN.md §Multi-tier KV) ----------------------------
    def _demote_snapshot(self, block_id: int):
        """Payload fetch the allocator calls when reclaiming a cached
        block with the host tier on: an ASYNC device-side slice of the
        block ([L, 1, BS, ...]; int8 pools carry their scale leaves in
        the same pytree). Dispatch order guarantees the copy reads the
        block BEFORE the allocation that triggered the reclaim overwrites
        it; the host transfer itself happens at ``_flush_demotes`` — off
        the decode hot loop, after the step's single d2h."""
        return jax.tree.map(lambda a: a[:, block_id:block_id + 1],
                            self.cache)

    def _flush_demotes(self) -> None:
        """Materialize this step's demoted payloads to host numpy. NOT
        routed through :func:`d2h` on purpose: the step's one-d2h
        contract is about the decode hot loop's sync token; these copies
        were dispatched earlier and drain here, overlapped with the
        iteration that evicted them."""
        if self.paged and self.allocator.host_tier_enabled:
            self.allocator.host_materialize(
                lambda p: jax.tree.map(np.asarray, p))

    def _promote_blocks(self, req: ServeRequest, shared: List[int],
                        promo: List[int]) -> List[int]:
        """Promote a host-tier chain continuation onto device: allocate
        owned blocks (covered by the request's admission reservation),
        scatter all payloads in ONE async device call — the h2d copy
        overlaps the current mixed iteration; the request only
        chunk-prefills its truly-uncached tail afterwards — and
        re-publish each digest with its chain links restored."""
        # pop payloads BEFORE allocating: the allocation may reclaim (and
        # demote) other device blocks, and the resulting host-capacity
        # pressure must never evict the very entries being promoted
        payloads = [self.allocator.host_pop(h) for h in promo]
        ids = self.allocator.allocate(len(promo))
        piece = jax.tree.map(lambda *ps: jnp.concatenate(
            [jnp.asarray(p) for p in ps], axis=1), *payloads)
        self.cache = scatter_kv_blocks(self.cache, piece, ids)
        digests = self._req_digests(req)
        d0 = len(shared)
        for j, (b, h) in enumerate(zip(ids, promo)):
            parent = digests[d0 + j - 1] if d0 + j > 0 else 0
            self.allocator.publish(b, h, head=(d0 + j == 0), parent=parent)
        self.promoted_blocks_total += len(ids)
        return ids

    @property
    def cache_demotions(self) -> int:
        return self.allocator.cache_demotions if self.paged else 0

    @property
    def cache_drops(self) -> int:
        return self.allocator.cache_drops if self.paged else 0

    @property
    def cache_promotions(self) -> int:
        return self.allocator.cache_promotions if self.paged else 0

    def _publish_prompt(self, req: ServeRequest, slot: int) -> None:
        """Prefill finished: publish the prompt's FULL blocks into the
        prefix index (first writer wins; the partial tail block — which
        generation keeps writing — is never published). Extends the
        request's digest memo instead of re-hashing the prompt: the
        capped lookup chain misses at most the final full block
        (prompts whose length is an exact block multiple)."""
        table = self.block_tables[slot]
        digests = list(self._req_digests(req))
        n_full = len(req.prompt) // self.block_size
        if len(digests) < n_full:           # len(prompt) % BS == 0
            parent = digests[-1] if digests else 0
            start = len(digests) * self.block_size
            digests.append(chain_hash(
                parent, req.prompt[start:start + self.block_size]))
        for j, h in enumerate(digests[:n_full]):
            self.allocator.publish(table[j], h, head=(j == 0),
                                   parent=digests[j - 1] if j else 0)

    # ---- intake -------------------------------------------------------------
    def submit(self, req: ServeRequest) -> None:
        req.state = State.WAITING
        # prefix-hit hint for queued_tokens/load while the request waits
        # (refreshed authoritatively at admission) — both tiers count: a
        # host-resident chain still spares the queue its prefill work
        if self.paged and self.prefix_cache:
            dev, host = self._tiered_chain(req)
            req.cached_tokens = (len(dev) + len(host)) * self.block_size
        else:
            req.cached_tokens = 0
        if self.slo_sched:
            self._seq += 1
            req.sched_key = queue_key(req.slo_class, req.arrival_step,
                                      self._worst_tokens(req), self._seq,
                                      time_scale=self.slo_time_scale)
            insert_sorted(self.waiting, req)
        else:
            self.waiting.append(req)

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slots):
            if r is None:
                return i
        return None

    def _worst_tokens(self, req: ServeRequest) -> int:
        """Upper bound on this request's final cache length: generation
        stops at max_new_tokens or when the cache hits max_seq."""
        return min(len(req.prompt) + req.max_new_tokens, self.max_seq)

    def can_accept(self, req: ServeRequest) -> bool:
        """Slot + worst-case budget check (used for admission AND inbound
        migration, so both paths — and the server's receiver picking —
        share one accounting definition)."""
        if self._free_slot() is None or len(req.prompt) + 1 > self.max_seq:
            return False
        if req.state is State.RUNNING:
            # inbound migration: the remaining generation must fit this
            # engine's max_seq — rejecting here (and not only in
            # import_request) keeps _pick_receiver from choosing a
            # receiver that would refuse the import after the KV gather
            remaining = max(req.max_new_tokens - len(req.generated), 0)
            if req.length + remaining > self.max_seq:
                return False
        if self.paged:
            # admission reserves only the uncached tail: resident prefix
            # blocks are shared, not re-allocated — but sharing a PARKED
            # (refcount-0) chain revives it into cached_live, so the gate
            # charges that revival too or `reserved + cached_live` could
            # overshoot num_blocks. Migrated-in (RUNNING) requests
            # re-import as private, so they reserve true length.
            need = blocks_for(self._worst_tokens(req), self.block_size)
            if req.state is not State.RUNNING:
                chain = self._cached_chain(req)
                need += self.allocator.revival_cost(chain) - len(chain)
            return self.allocator.can_reserve(need)
        return self.reserved_tokens() + self._worst_tokens(req) \
            <= self.token_budget

    def _admit(self) -> List[ServeRequest]:
        """Admit FCFS while capacity lasts. Prompts that can NEVER fit this
        engine are failed (rejected=True) instead of wedging the queue —
        matching sim.Instance's documented semantics."""
        admitted = []
        if self.slo_sched:
            self._age_waiting()
            self._resume_ready()
        while self.waiting:
            req = self.waiting[0]
            if len(req.prompt) + 1 > self.max_seq:
                self.waiting.popleft()
                req.rejected = True
                req.state = State.FINISHED
                req.first_token_step = self.steps
                req.finish_step = self.steps
                admitted.append(req)
                continue
            if not self.can_accept(req):
                if self.slo_sched and self._preempt_for(req):
                    continue
                break
            slot = self._free_slot()
            self.waiting.popleft()
            self._prefill_into_slot(req, slot)
            admitted.append(req)
        if self.slo_sched:
            self._resume_ready()
        return admitted

    def _reserve(self, req: ServeRequest, slot: int,
                 cached_blocks: int = 0) -> None:
        worst = self._worst_tokens(req)
        if self.paged:
            rb = blocks_for(worst, self.block_size) - cached_blocks
            self.allocator.reserve(rb)
            self._slot_rblocks[slot] = rb
        self.slot_reserved[slot] = worst

    # ---- device-mirror helpers (paged + device_resident) ---------------------
    def _ensure_nbt_cap(self, need: int) -> None:
        """Grow the device block-table width to a pow2 >= need (capped at
        the max_seq block count) — O(log max_seq) recompiles total."""
        if need <= self._nbt_cap:
            return
        new = min(_next_pow2(need), blocks_for(self.max_seq, self.block_size))
        assert new >= need
        self._dev_bt = jnp.pad(self._dev_bt,
                               ((0, 0), (0, new - self._nbt_cap)),
                               constant_values=self.garbage_block)
        self._nbt_cap = new

    def _dev_set_table(self, slot: int, ids: List[int]) -> None:
        row = np.full((self._nbt_cap,), self.garbage_block, np.int32)
        row[:len(ids)] = ids
        self._dev_bt = self._dev_bt.at[slot].set(jnp.asarray(row))

    def _dev_clear_slot(self, slot: int) -> None:
        self._dev_bt = self._dev_bt.at[slot].set(self.garbage_block)
        self._dev_len = self._dev_len.at[slot].set(0)

    def _prefill_into_slot(self, req: ServeRequest, slot: int) -> None:
        if self.paged and self.device_resident:
            self._prefill_into_slot_device(req, slot)
            return
        tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
        self._reserve(req, slot)
        if self.paged:
            # prompt-length cache piece [L, 1, T, ...] scattered into
            # freshly allocated blocks — no max_seq padding anywhere
            logits, piece = attn_call(self._prefill, self.params,
                                      {"tokens": tokens}, cache_len=None)
            ids = self.allocator.allocate(
                blocks_for(len(req.prompt), self.block_size))
            self.block_tables[slot] = ids
            self.cache = _write_prompt_blocks(self.cache, piece, ids,
                                              self.block_size)
            self.prefill_work_blocks += len(ids)
            self.prefill_tokens_done += len(req.prompt)
        else:
            logits, piece = attn_call(self._prefill, self.params,
                                      {"tokens": tokens},
                                      cache_len=self.max_seq)
            self.cache = _write_slot(self.cache, piece, slot)
        vec = logits if logits.ndim == 1 else logits[0]
        tok = int(d2h(jnp.argmax(vec)))
        req.generated.append(tok)
        req.ctx_done = len(req.prompt)
        req.first_token_step = self.steps
        req.state = State.RUNNING
        req.engine_id = self.id
        req.slot = slot
        req.tokens_by_engine[self.id] = req.tokens_by_engine.get(self.id, 0) + 1
        self.slots[slot] = req
        self.slot_len[slot] = req.length
        self.tokens_out += 1

    def _prefill_into_slot_device(self, req: ServeRequest, slot: int) -> None:
        """Bucketed prefill with DEFERRED first-token sync: the prompt is
        padded to a pow2 length (one compile per bucket), the sampled
        first token stays on device (in ``_dev_tok`` and
        ``_pending_first``) and reaches ``generated`` at the step's single
        ``d2h``. All bookkeeping here is count-based, so nothing needs
        the token's value."""
        self._reserve(req, slot)
        T = len(req.prompt)
        P = min(_next_pow2(T), _next_pow2(self.max_seq))
        toks = np.zeros((1, P), np.int32)
        toks[0, :T] = req.prompt
        logits, piece = attn_call(
            self._prefill_bucketed, self.params,
            {"tokens": jnp.asarray(toks)}, jnp.int32(T))
        piece = jax.tree.map(lambda a: a[:, :, :T], piece)
        ids = self.allocator.allocate(blocks_for(T, self.block_size))
        self.block_tables[slot] = ids
        self.cache = _write_prompt_blocks(self.cache, piece, ids,
                                          self.block_size)
        self.prefill_work_blocks += len(ids)
        self.prefill_tokens_done += T
        tok_dev = jnp.argmax(logits[0]).astype(jnp.int32)
        self._ensure_nbt_cap(len(ids))
        self._dev_set_table(slot, ids)
        self._dev_len = self._dev_len.at[slot].set(T + 1)
        self._dev_tok = self._dev_tok.at[slot].set(tok_dev)
        self._pending_first.append((req, tok_dev))
        req.ctx_done = T
        req.first_token_step = self.steps
        req.state = State.RUNNING
        req.engine_id = self.id
        req.slot = slot
        req.tokens_by_engine[self.id] = req.tokens_by_engine.get(self.id, 0) + 1
        self.slots[slot] = req
        self.slot_len[slot] = T + 1
        self.tokens_out += 1

    # ---- chunked prefill: the mixed-iteration prompt side --------------------
    # (DESIGN.md §Chunked prefill.) Each step packs up to
    # ``prefill_token_budget`` prompt-chunk tokens — resuming in-progress
    # prefills first (oldest admitted first), then admitting from the FCFS
    # queue while budget and capacity last. Chunk K/V goes straight into
    # freshly allocated pool blocks, so a partial prompt is ordinary pool
    # state: it migrates, it is accounted, and the decode batch runs
    # beside it every single iteration — no head-of-line blocking.
    def _run_chunked_prefill(self) -> Tuple[List[ServeRequest],
                                            List[ServeRequest]]:
        """Returns (rejected, completed): requests failed for never
        fitting, and requests whose LAST chunk landed this step (their
        first token is sampled; device loops defer it to the step sync).
        This is the two-call reference path; the fused device loop plans
        with :meth:`_plan_chunks` and executes the chunks inside the ONE
        mixed device call instead."""
        rejected, plan = self._plan_chunks()
        completed: List[ServeRequest] = []
        if plan:
            arrays = self._prepare_chunk_arrays(plan)
            logits, self.cache = attn_call(self._prefill_chunk,
                                           self.params, self.cache, *arrays)
            self._finish_chunks(
                plan, jnp.argmax(logits, axis=-1).astype(jnp.int32),
                completed)
        return rejected, completed

    def _plan_chunks(self) -> Tuple[List[ServeRequest],
                                    List[Tuple[int, int]]]:
        """Admission + chunk planning of the mixed iteration — pure host
        bookkeeping, no device work. Returns (rejected, plan) where plan
        is [(slot, chunk_len)] under the prefill token budget."""
        rejected: List[ServeRequest] = []
        if self.slo_sched:
            self._resume_ready()
        budget = self.prefill_token_budget
        plan: List[Tuple[int, int]] = []            # (slot, chunk_len)
        for slot in list(self._prefill_order):      # oldest admitted first
            if budget <= 0:
                break
            req = self.slots[slot]
            clen = min(req.prefill_target_len - req.ctx_done, budget)
            plan.append((slot, clen))
            budget -= clen
        while self.waiting and budget > 0:
            req = self.waiting[0]
            if len(req.prompt) + 1 > self.max_seq:  # can NEVER fit: fail
                self.waiting.popleft()
                req.rejected = True
                req.state = State.FINISHED
                req.first_token_step = self.steps
                req.finish_step = self.steps
                rejected.append(req)
                continue
            if not self.can_accept(req):
                if self.slo_sched and self._preempt_for(req):
                    continue
                break
            slot = self._free_slot()
            self.waiting.popleft()
            # longest cached chain across both tiers: device blocks are
            # shared (refcount++, zero copies), host-tier continuations
            # PROMOTE — fresh owned blocks under this request's
            # reservation, one async h2d scatter overlapping the mixed
            # iteration — and chunking starts at ctx_done = cached_tokens,
            # so only the truly-uncached tail's prefill work ever runs
            # (DESIGN.md §Prefix cache, §Multi-tier KV)
            shared, promo = self._tiered_chain(req)
            self._reserve(req, slot, cached_blocks=len(shared))
            self._slot_shared[slot] = len(shared)
            if shared:
                self.allocator.share(shared)
            promoted = (self._promote_blocks(req, shared, promo)
                        if promo else [])
            req.cached_tokens = (len(shared) + len(promoted)) \
                * self.block_size
            self.cached_prompt_tokens_total += req.cached_tokens
            req.state = State.RUNNING
            req.engine_id = self.id
            req.slot = slot
            req.ctx_done = req.cached_tokens
            self.block_tables[slot] = list(shared) + promoted
            self.slots[slot] = req
            self.slot_len[slot] = req.ctx_done
            self._prefill_order.append(slot)
            clen = min(req.prefill_target_len - req.ctx_done, budget)
            plan.append((slot, clen))
            budget -= clen
        if self.slo_sched:
            self._resume_ready()
        return rejected, plan

    def _prepare_chunk_arrays(self, plan: List[Tuple[int, int]]):
        """Device arrays for ALL of the step's planned chunks — the prompt
        half of the mixed iteration, consumed either by the separate
        ``prefill_chunk`` call or by the fused mixed call. Chunks are
        padded to a common pow2 bucket and a common pow2 table width
        (compiles stay O(slots · log budget · log max_seq)); each chunk's
        blocks are allocated here, always covered by its admission
        reservation, so allocation cannot fail. Table tails are the
        garbage block, so the padding rows of short chunks never touch
        live data. Returns ``(tokens [B, C], tables [B, nbt], ctx [B],
        clen [B])``."""
        B = len(plan)
        C = _next_pow2(max(clen for _, clen in plan))
        nbt = 1
        for slot, clen in plan:
            req = self.slots[slot]
            need = blocks_for(req.ctx_done + clen, self.block_size)
            table = self.block_tables[slot]
            if need > len(table):
                table.extend(self.allocator.allocate(need - len(table)))
            nbt = max(nbt, blocks_for(req.ctx_done + C, self.block_size))
            self.prefill_work_blocks += need    # grid-step mirror
            self.prefill_tokens_done += clen
        nbt = _next_pow2(nbt)
        toks = np.zeros((B, C), np.int32)
        bt = np.full((B, nbt), self.garbage_block, np.int32)
        ctxs = np.zeros((B,), np.int32)
        clens = np.zeros((B,), np.int32)
        for j, (slot, clen) in enumerate(plan):
            req = self.slots[slot]
            ctx = req.ctx_done
            # recompute-preempted requests rebuild KV for the resume
            # prefix (prompt + generated-so-far) instead of the prompt
            src = (req.resume_tokens if req.resume_tokens is not None
                   else req.prompt)
            toks[j, :clen] = src[ctx:ctx + clen]
            table = self.block_tables[slot]
            bt[j, :len(table)] = table
            ctxs[j] = ctx
            clens[j] = clen
        return (jnp.asarray(toks), jnp.asarray(bt), jnp.asarray(ctxs),
                jnp.asarray(clens))

    def _finish_chunks(self, plan: List[Tuple[int, int]], first_toks,
                       completed: List[ServeRequest]) -> None:
        """Post-chunk bookkeeping: advance ``ctx_done``, and for requests
        whose LAST chunk just landed, record the (still on-device) first
        token — ``first_toks`` is the int32 [B] argmax over each chunk's
        final-position logits. On the fused path the completing slot was
        dead during the device call (its device table row all-garbage, its
        length 0), so publishing its table/length here — after the call —
        means a request never decodes in the same step its prefill
        finishes; token VALUES are unaffected."""
        for j, (slot, clen) in enumerate(plan):
            req = self.slots[slot]
            T = req.prefill_target_len
            req.ctx_done += clen
            self.slot_len[slot] = req.ctx_done
            if req.ctx_done < T:
                continue
            if req.prefill_target is not None:
                # recompute resume complete: rows 0..T-1 rebuilt, decoding
                # continues from generated[-1] at position T next decode.
                # The chunk's final-position logits reproduce that token's
                # argmax — discarded, no new sample, no re-publish.
                self._finish_resume(req, slot, T)
                continue
            # final chunk: the first token exists; the finished prompt's
            # full blocks become shareable for every later arrival
            if self.prefix_cache:
                self._publish_prompt(req, slot)
            self._prefill_order.remove(slot)
            tok_dev = first_toks[j]
            req.first_token_step = self.steps
            req.tokens_by_engine[self.id] = \
                req.tokens_by_engine.get(self.id, 0) + 1
            self.tokens_out += 1
            self.slot_len[slot] = T + 1
            if self.device_resident:
                # token stays on device; it reaches the host (and
                # req.generated) at the step's single d2h
                table = self.block_tables[slot]
                self._ensure_nbt_cap(len(table))
                self._dev_set_table(slot, table)
                self._dev_len = self._dev_len.at[slot].set(T + 1)
                self._dev_tok = self._dev_tok.at[slot].set(tok_dev)
                self._pending_first.append((req, tok_dev))
            else:
                req.generated.append(int(d2h(tok_dev)))
            completed.append(req)

    # ---- SLO preemption (DESIGN.md §SLO scheduling & preemption) -------------
    def _victim_slots(self, pr: int) -> List[int]:
        """Preemptable slots for a priority-``pr`` preemptor: strictly
        lower class (so uniform-class traffic never preempts and cannot
        thrash), fully prefilled, with >= 1 synced generated token (a
        device-path request whose first token is still in-flight has no
        host-visible continuation point yet)."""
        return [i for i, r in enumerate(self.slots)
                if r is not None and not r.prefilling and r.generated
                and priority_of(r.slo_class) > pr]

    def _mem_shortfall(self, req: ServeRequest) -> int:
        """Blocks the allocator is short of admitting ``req`` (<= 0 means
        the blocker is a slot, not memory)."""
        if not self.paged:
            return 0
        need = blocks_for(self._worst_tokens(req), self.block_size)
        if req.state is not State.RUNNING:
            chain = self._cached_chain(req)
            need += self.allocator.revival_cost(chain) - len(chain)
        return need - self.allocator.headroom_blocks

    def _preempt_for(self, req: ServeRequest) -> bool:
        """Make room for a blocked higher-class request by preempting the
        lowest-class, largest resident victim: park it (slot shortage —
        blocks and reservation stay put) or drop-and-recompute its KV
        (memory shortage — parking frees nothing). Returns True if a
        victim was preempted; the caller re-checks admission."""
        if not self.paged:
            return False        # a monolithic slot IS its memory: no park
        if (req.generated and req.first_token_step is not None
                and tpot_hopeless(req.slo_class, req.first_token_step,
                                  self.steps, req.max_new_tokens,
                                  time_scale=self.slo_time_scale)):
            # TPOT-deadline admission: this resumed decode has already
            # blown its per-token deadline beyond recovery — preempting
            # healthy traffic for it buys no attainment. It waits for
            # organic capacity and is counted against attainment.
            if req.req_id not in self._tpot_hopeless_ids:
                self._tpot_hopeless_ids.add(req.req_id)
                self.tpot_skipped += 1
            return False
        pr = priority_of(req.slo_class)
        short = self._mem_shortfall(req)
        cands = self._victim_slots(pr)
        if not cands:
            # memory may be pinned only by parked lower-class requests:
            # recompute-preempt the largest of those instead
            return short > 0 and self._preempt_parked(pr)
        slot = max(cands, key=lambda i: (
            priority_of(self.slots[i].slo_class), len(self.block_tables[i])))
        mode = park_or_recompute(must_free_blocks=max(short, 0),
                                 kv_tokens=int(self.slot_len[slot]) - 1)
        if mode == "recompute":
            if not self.chunked_prefill:
                return False    # nowhere to rebuild the KV from
            self._preempt_recompute(slot)
        else:
            self._preempt_park(slot)
        return True

    def _preempt_park(self, slot: int) -> None:
        """Pause a resident decode keeping its KV: blocks pin via
        ``BlockAllocator.park`` and the reservation stays, so resume is a
        pure bookkeeping restore — bit-identical continuation."""
        req = self.slots[slot]
        table = self.block_tables[slot]
        self.allocator.park(table)
        self._seq += 1
        # size 0: a parked request outranks an equal-deadline waiting one
        # (its restore is free; re-admitting the other is not)
        req.sched_key = queue_key(req.slo_class, req.arrival_step, 0.0,
                                  self._seq, time_scale=self.slo_time_scale)
        self.parked.append(_Parked(req, table, self._slot_shared[slot],
                                   self._slot_rblocks[slot],
                                   int(self.slot_len[slot])))
        self._slot_shared[slot] = 0
        self._slot_rblocks[slot] = 0
        self.block_tables[slot] = []
        if self.device_resident:
            self._dev_clear_slot(slot)
        self.slots[slot] = None
        self.slot_len[slot] = 0
        self.slot_reserved[slot] = 0
        req.slot = None
        req.state = State.PREEMPTED
        req.preemptions += 1
        self.preemptions += 1

    def _preempt_recompute(self, slot: int) -> None:
        """Drop a resident decode's KV entirely (blocks + reservation) and
        re-enqueue it to rebuild via chunked prefill over its resume
        prefix — the memory-pressure exit."""
        req = self.slots[slot]
        written = int(self.slot_len[slot]) - 1
        self._release(slot)
        self._requeue_recompute(req, written)

    def _preempt_parked(self, pr: int) -> bool:
        """Recompute-preempt the largest parked request of a class below
        ``pr``: the only way to free memory held by parked victims."""
        if not self.chunked_prefill:
            return False
        cands = [p for p in self.parked if priority_of(p.req.slo_class) > pr]
        if not cands:
            return False
        rec = max(cands, key=lambda p: (priority_of(p.req.slo_class),
                                        len(p.table)))
        self.parked.remove(rec)
        self.allocator.unpark(rec.table)
        if rec.shared:
            self.allocator.release(rec.table[:rec.shared], owned=False)
            self.allocator.release(rec.table[rec.shared:], owned=True)
        else:
            self.allocator.release(rec.table, owned=True)
        self.allocator.unreserve(rec.rblocks)
        self._requeue_recompute(rec.req, rec.slot_len - 1)
        return True

    def _requeue_recompute(self, req: ServeRequest, written: int) -> None:
        """Re-enqueue a preempted decode as a resume job: prefill must
        rebuild ``written`` rows (= prompt + generated[:-1]); the last
        sampled token then decodes at position ``written`` exactly as it
        would have unpreempted."""
        req.prefill_target = written
        req.resume_tokens = np.concatenate(
            [np.asarray(req.prompt, np.int32),
             np.asarray(req.generated[:-1], np.int32)])
        assert len(req.resume_tokens) == written
        req.ctx_done = 0
        req.cached_tokens = 0
        req.slot = None
        req.state = State.WAITING
        req.preemptions += 1
        req.preempted_step = self.steps      # aging clock starts now
        self.preemptions += 1
        self.preempt_recomputes += 1
        self._seq += 1
        req.sched_key = queue_key(req.slo_class, req.arrival_step,
                                  self._worst_tokens(req), self._seq,
                                  time_scale=self.slo_time_scale)
        insert_sorted(self.waiting, req)

    def _age_waiting(self) -> None:
        """Starvation/aging guard (DESIGN.md §SLO scheduling): a
        recompute-preempted request still waiting climbs one priority
        class per TTFT budget elapsed since its preemption
        (sched.slo.aging_promotion), so saturated higher-class traffic
        cannot starve it forever. Keys keep their original deadline/size/
        seq components — within a promoted class the victim competes on
        its true deadline."""
        changed = False
        for req in self.waiting:
            if req.preempted_step is None:
                continue
            promote = aging_promotion(req.slo_class, req.preempted_step,
                                      self.steps,
                                      time_scale=self.slo_time_scale)
            if promote <= 0:
                continue
            key = queue_key(req.slo_class, req.arrival_step,
                            self._worst_tokens(req), req.sched_key[3],
                            time_scale=self.slo_time_scale, promote=promote)
            if key != req.sched_key:
                req.sched_key = key
                changed = True
        if changed:
            ordered = sorted(self.waiting, key=lambda r: r.sched_key)
            self.waiting.clear()
            self.waiting.extend(ordered)

    def _resume_ready(self) -> None:
        """Restore parked requests into free slots — unless a waiting
        request outranks the best parked one (preemption must not invert
        the queue order it enforced)."""
        while self.parked:
            slot = self._free_slot()
            if slot is None:
                return
            rec = min(self.parked, key=lambda p: p.req.sched_key)
            if self.waiting and self.waiting[0].sched_key < rec.req.sched_key:
                return
            self.parked.remove(rec)
            self._unpark(rec, slot)

    def _unpark(self, rec: _Parked, slot: int) -> None:
        req = rec.req
        self.allocator.unpark(rec.table)
        self.block_tables[slot] = rec.table
        self._slot_shared[slot] = rec.shared
        self._slot_rblocks[slot] = rec.rblocks
        self.slots[slot] = req
        self.slot_len[slot] = rec.slot_len
        self.slot_reserved[slot] = self._worst_tokens(req)
        req.slot = slot
        req.state = State.RUNNING
        self.resumes += 1
        if self.device_resident:
            self._ensure_nbt_cap(len(rec.table))
            self._dev_set_table(slot, rec.table)
            self._dev_len = self._dev_len.at[slot].set(rec.slot_len)
            self._dev_tok = self._dev_tok.at[slot].set(int(req.generated[-1]))

    def _finish_resume(self, req: ServeRequest, slot: int, T: int) -> None:
        """A recompute resume's last chunk landed: rows 0..T-1 are back;
        re-arm decode so ``generated[-1]`` writes row T next step. No
        token is sampled and nothing is re-published — the continuation
        is the original request's, bit for bit."""
        self._prefill_order.remove(slot)
        req.prefill_target = None
        req.resume_tokens = None
        req.ctx_done = len(req.prompt)
        self.slot_len[slot] = T + 1
        self.resumes += 1
        if self.device_resident:
            table = self.block_tables[slot]
            self._ensure_nbt_cap(len(table))
            self._dev_set_table(slot, table)
            self._dev_len = self._dev_len.at[slot].set(T + 1)
            self._dev_tok = self._dev_tok.at[slot].set(int(req.generated[-1]))

    # ---- one continuous-batching iteration ----------------------------------
    def step(self, burst: int = 1) -> List[ServeRequest]:
        """Advance the engine and return requests that finished.

        ``burst > 1`` (device-resident paged engines only) fuses up to
        that many consecutive decode iterations into one ``lax.scan``
        micro-batch with a single device→host transfer; the fusion is
        clamped so no request can hit its token-count or max_seq finish
        boundary before the last fused iteration, hence admission is
        never starved (capacity only frees at a finish)."""
        if self.paged and self.device_resident:
            return self._step_device(burst)
        return self._step_host()

    def _step_host(self) -> List[ServeRequest]:
        """The original host-driven loop (monolithic engines, and paged
        with ``device_resident=False`` — the bit-parity reference)."""
        self.steps += 1
        finished: List[ServeRequest] = []
        if self.chunked_prefill:
            rejected, prefilled = self._run_chunked_prefill()
            finished.extend(rejected)
            for r in prefilled:
                if r.done:      # max_new_tokens == 1 / eos first token
                    r.state = State.FINISHED
                    r.finish_step = self.steps
                    finished.append(r)
                    self._release(r.slot)
        else:
            for r in self._admit():
                if r.rejected:                  # prompt can never fit
                    finished.append(r)
                elif r.done:    # max_new_tokens == 1: prefill already
                    r.state = State.FINISHED    # produced the only token
                    r.finish_step = self.steps
                    finished.append(r)
                    self._release(r.slot)
        # requests still mid-prefill hold their slot but do NOT decode
        live = [(i, r) for i, r in enumerate(self.slots)
                if r is not None and not r.prefilling]
        if live:
            last_tok = jnp.asarray(
                [r.generated[-1] if r.generated else r.prompt[-1]
                 for _, r in live], jnp.int32)
            pos = jnp.asarray([self.slot_len[i] - 1 for i, _ in live],
                              jnp.int32)
            if self.paged:
                logits = self._decode_paged_live(live, last_tok, pos)
            else:
                logits = self._decode_mono_live(live, last_tok, pos)
            toks = d2h(jnp.argmax(logits, axis=-1))   # one transfer, fused
            for j, (i, r) in enumerate(live):
                tok = int(toks[j])
                r.generated.append(tok)
                r.tokens_by_engine[self.id] = \
                    r.tokens_by_engine.get(self.id, 0) + 1
                self.tokens_out += 1
                self.slot_len[i] += 1
                if r.done or self.slot_len[i] >= self.max_seq:
                    r.state = State.FINISHED
                    r.finish_step = self.steps
                    finished.append(r)
                    self._release(i)
        self._flush_demotes()
        self.peak_kv_bytes = max(self.peak_kv_bytes, self.kv_bytes_pinned())
        assert self.free_tokens() >= 0, "admission let the budget go negative"
        return finished

    # ---- device-resident step (paged default) --------------------------------
    def _burst_fn(self, num_work: int, horizon: int):
        """Jitted ``horizon``-iteration decode micro-batch, cached per
        (num_work, horizon) — both pow2-bucketed, so the cache stays
        O(log² ·). Shape changes (table width growth) retrace via jit."""
        key = (num_work, horizon)
        fn = self._burst_fns.get(key)
        if fn is not None:
            return fn
        decode = functools.partial(self.model.decode_step_paged,
                                   attn_backend=self.attn_backend,
                                   attn_interpret=self.attn_interpret,
                                   attn_num_work=num_work)

        def burst(params, cache, bt, tok, length):
            def one(carry, _):
                cache, tok, length = carry
                live = length > 0
                pos = length - 1            # dead slots: -1 -> 0 attn length
                logits, cache = decode(params, cache, tok, bt, pos)
                new_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                tok = jnp.where(live, new_tok, tok)
                length = jnp.where(live, length + 1, length)
                return (cache, tok, length), new_tok

            if horizon == 1:    # plain call — no scan carry round-trip
                (cache, tok, length), toks = one((cache, tok, length), None)
                return cache, tok, length, toks[None]
            (cache, tok, length), toks = jax.lax.scan(
                one, (cache, tok, length), None, length=horizon)
            return cache, tok, length, toks    # toks [horizon, max_slots]

        if self.tp > 1:
            burst = self._smap(burst,
                               (self._pspec, self._pool_spec, P(), P(), P()),
                               (self._pool_spec, P(), P(), P()))
        fn = jax.jit(burst)
        self._burst_fns[key] = fn
        return fn

    def _mixed_fn(self, num_work: int):
        """Jitted FUSED mixed iteration: the whole decode batch and the
        step's prompt chunks advance through the stack in this single
        attention-bearing call — one tagged work-list kernel launch per
        layer (DESIGN.md §Fused mixed-iteration attention). Cached per
        pow2 ``num_work``; shape changes (table width, chunk bucket,
        chunk count) retrace via jit."""
        fn = self._mixed_fns.get(num_work)
        if fn is not None:
            return fn
        mixed = functools.partial(self.model.mixed_step,
                                  attn_backend=self.attn_backend,
                                  attn_interpret=self.attn_interpret,
                                  attn_num_work=num_work)

        def step(params, cache, bt, tok, length, ck_tokens, bt_ck, ctx, clen):
            live = length > 0
            pos = length - 1            # dead slots: -1 -> 0 attn length
            dec_logits, ck_logits, cache = mixed(
                params, cache, tok, ck_tokens, bt, bt_ck, pos, ctx, clen)
            new_tok = jnp.argmax(dec_logits, axis=-1).astype(jnp.int32)
            tok = jnp.where(live, new_tok, tok)
            length = jnp.where(live, length + 1, length)
            ck_tok = jnp.argmax(ck_logits, axis=-1).astype(jnp.int32)
            return cache, tok, length, new_tok, ck_tok

        if self.tp > 1:
            step = self._smap(step, (self._pspec, self._pool_spec,
                                     P(), P(), P(), P(), P(), P(), P()),
                              (self._pool_spec, P(), P(), P(), P()))
        fn = jax.jit(step)
        self._mixed_fns[num_work] = fn
        return fn

    def _step_device(self, burst: int) -> List[ServeRequest]:
        self.steps += 1
        base = self.steps                  # engine step of the 1st iteration
        finished: List[ServeRequest] = []
        self._pending_first = []
        prefill_done: List[ServeRequest] = []
        chunk_plan: List[Tuple[int, int]] = []
        if self.chunked_prefill:
            if self.fused_mixed:
                # plan + admit only — the chunks execute INSIDE the fused
                # mixed call below, not as a separate device call
                rejected, chunk_plan = self._plan_chunks()
                finished.extend(rejected)
            else:
                rejected, prefilled = self._run_chunked_prefill()
                finished.extend(rejected)
                for r in prefilled:
                    if r.max_new_tokens <= 1:   # finishes at prefill; its
                        prefill_done.append(r)  # token lands after the sync
                        self._release(r.slot)
        else:
            for r in self._admit():
                if r.rejected:                  # prompt can never fit
                    finished.append(r)
                elif r.max_new_tokens <= 1:     # finishes at prefill; its
                    prefill_done.append(r)      # token lands after the sync
                    self._release(r.slot)
        # requests still mid-prefill hold their slot but do NOT decode:
        # their device table row stays all-garbage and their length 0, so
        # the fixed-shape batch treats them as dead slots
        live = [(i, r) for i, r in enumerate(self.slots)
                if r is not None and not r.prefilling]
        h = 0
        toks = None
        if self.fused_mixed and chunk_plan:
            # ---- ONE fused device call: decode batch + prompt chunks ----
            # (DESIGN.md §Fused mixed-iteration attention.) h = 1 always —
            # a step with chunk work is an admission opportunity, so it
            # never bursts (same rule as the separate path's cap)
            h = 1
            # pre-grow decode tables for this step's write (pos slot_len-1)
            for i, _ in live:
                need = blocks_for(int(self.slot_len[i]), self.block_size)
                table = self.block_tables[i]
                if need > len(table):
                    table.extend(self.allocator.allocate(need - len(table)))
                    self._ensure_nbt_cap(need)
                    self._dev_set_table(i, table)
            ck_toks, bt_ck, ctxs, clens = \
                self._prepare_chunk_arrays(chunk_plan)
            dec_blocks = [blocks_for(int(self.slot_len[i]), self.block_size)
                          for i, _ in live]
            ck_blocks = [blocks_for(self.slots[s].ctx_done + c,
                                    self.block_size) for s, c in chunk_plan]
            real = sum(dec_blocks) + sum(ck_blocks)
            # bucket = pow2(decode items) + pow2(chunk items), NOT
            # pow2(sum): the padding tail then never exceeds what the two
            # separate kernels would pad (pow2(a+b) can overshoot
            # pow2(a)+pow2(b)), so fusing strictly saves the launch; the
            # jit cache stays O(log²) keys
            num_work = ((_next_pow2(sum(dec_blocks)) if live else 0)
                        + _next_pow2(sum(ck_blocks)))
            self.last_grid = {
                "backend": "fused",
                "flat_items": num_work,
                "real_items": real,
                "padded_items": (len(dec_blocks) + len(ck_blocks))
                * max(dec_blocks + ck_blocks),
            }
            fn = self._mixed_fn(num_work)
            (self.cache, self._dev_tok, self._dev_len, new_tok,
             ck_tok) = attn_call(fn, self.params, self.cache, self._dev_bt,
                                 self._dev_tok, self._dev_len, ck_toks,
                                 bt_ck, ctxs, clens)
            if live:
                toks = new_tok[None]    # one horizon row for the step sync
            else:
                h = 0
            chunk_completed: List[ServeRequest] = []
            self._finish_chunks(chunk_plan, ck_tok, chunk_completed)
            for r in chunk_completed:
                if r.max_new_tokens <= 1:       # finishes at prefill; its
                    prefill_done.append(r)      # token lands after the sync
                    self._release(r.slot)
        elif live:
            pend_reqs = {id(r) for r, _ in self._pending_first}
            # fusion horizon: nobody may cross a count/capacity finish
            # boundary before the last fused iteration (eos finishes are
            # data-dependent and handled by truncation after the sync)
            def _until_finish(i, r):
                gen = len(r.generated) + (1 if id(r) in pend_reqs else 0)
                return min(r.max_new_tokens - gen,
                           self.max_seq - int(self.slot_len[i]))
            # only NO-admission steps fuse: with a non-empty queue (or a
            # prompt mid-chunked-prefill) every step is an admission /
            # chunk opportunity, so stay at h=1 — this is also what caps a
            # decode request's inter-token gap at ONE mixed iteration
            cap = 1 if (self.waiting or self._prefill_order
                        or self.parked) else burst
            h = max(1, min([cap] + [_until_finish(i, r) for i, r in live]))
            h = _pow2_floor(h)
            # pre-grow block tables to cover every write of the burst
            # (positions slot_len-1 .. slot_len+h-2) — covered by the
            # admission reservations, so allocation cannot fail
            for i, _ in live:
                need = blocks_for(int(self.slot_len[i]) + h - 1,
                                  self.block_size)
                table = self.block_tables[i]
                if need > len(table):
                    table.extend(self.allocator.allocate(need - len(table)))
                    self._ensure_nbt_cap(need)
                    self._dev_set_table(i, table)   # one write per grown row
            real = sum(blocks_for(int(self.slot_len[i]) + h - 1,
                                  self.block_size) for i, _ in live)
            # num_work only shapes the flat-work-list grids (flat/fused);
            # for the other backends key the jit cache on a single value so
            # pow2 growth of the live block count never forces a recompile
            num_work = (_next_pow2(real)
                        if self.attn_backend in ("flat", "fused") else 0)
            self.last_grid = {
                "backend": self.attn_backend,
                "flat_items": _next_pow2(real),
                "real_items": sum(blocks_for(int(self.slot_len[i]),
                                             self.block_size)
                                  for i, _ in live),
                "padded_items": len(live) * max(
                    blocks_for(int(self.slot_len[i]), self.block_size)
                    for i, _ in live),
            }
            fn = self._burst_fn(num_work, h)
            self.cache, self._dev_tok, self._dev_len, toks = attn_call(
                fn, self.params, self.cache, self._dev_bt, self._dev_tok,
                self._dev_len)
        # ---- the step's single device->host transfer ----
        pending = list(self._pending_first)
        parts = [jnp.stack([t for _, t in pending])] if pending else []
        if toks is not None:
            parts.append(toks.reshape(-1))
        host = d2h(jnp.concatenate(parts)) if parts else np.zeros(0, np.int32)
        first = host[:len(pending)]
        rest = host[len(pending):].reshape(h, self.max_slots) if h else None
        # prefill first tokens (deferred appends)
        for (r, _), tok in zip(pending, first):
            r.generated.append(int(tok))
        for r in prefill_done:
            r.state = State.FINISHED
            r.finish_step = base
            finished.append(r)
        # an admitted request whose FIRST token was eos is done before the
        # burst tokens; its fused decodes wrote only its own pre-grown
        # blocks, so truncating here is safe
        for i, r in live:
            if r.state is State.RUNNING and r.done:
                r.state = State.FINISHED
                r.finish_step = base
                finished.append(r)
                self._release(i)
        for s in range(h):
            for i, r in live:
                if r.state is State.FINISHED:
                    continue
                r.generated.append(int(rest[s, i]))
                r.tokens_by_engine[self.id] = \
                    r.tokens_by_engine.get(self.id, 0) + 1
                self.tokens_out += 1
                self.slot_len[i] += 1
                if r.done or self.slot_len[i] >= self.max_seq:
                    r.state = State.FINISHED
                    r.finish_step = base + s
                    finished.append(r)
                    self._release(i)
        self.steps = base + max(h - 1, 0)
        self._flush_demotes()
        self.peak_kv_bytes = max(self.peak_kv_bytes, self.kv_bytes_pinned())
        assert self.free_tokens() >= 0, "admission let the budget go negative"
        return finished

    def _decode_mono_live(self, live, last_tok, pos):
        idx = np.asarray([i for i, _ in live])
        sub_cache = jax.tree.map(lambda a: a[:, idx], self.cache)
        logits, new_sub = attn_call(self._decode, self.params, sub_cache,
                                    last_tok, pos)
        # one batched scatter over all live slots (slots never alias, so
        # there are no duplicate indices) instead of a per-slot update
        self.cache = jax.tree.map(
            lambda a, p: a.at[:, idx].set(p.astype(a.dtype)),
            self.cache, new_sub)
        return logits

    def _decode_paged_live(self, live, last_tok, pos):
        # grow block tables so every request's write position is backed
        # (covered by its admission reservation — cannot fail)
        for i, _ in live:
            need = blocks_for(int(self.slot_len[i]), self.block_size)
            table = self.block_tables[i]
            if need > len(table):
                table.extend(self.allocator.allocate(need - len(table)))
        # bucketed table width: length-adaptive (max live blocks rounded to
        # a power of two) so short batches don't pay max_seq-wide gathers
        # but jit recompiles stay O(log) in sequence length
        nbt = max(len(self.block_tables[i]) for i, _ in live)
        nbt = min(_next_pow2(nbt), blocks_for(self.max_seq, self.block_size))
        bt = np.zeros((len(live), nbt), np.int32)
        for j, (i, _) in enumerate(live):
            ids = self.block_tables[i]
            bt[j, :len(ids)] = ids
        logits, self.cache = attn_call(
            self._decode_paged, self.params, self.cache, last_tok,
            jnp.asarray(bt), pos)
        return logits

    def _release(self, slot: int) -> None:
        if slot in self._prefill_order:     # evicted mid-prefill
            self._prefill_order.remove(slot)
        if self.paged:
            # shared prefix blocks (the table's head, taken via share at
            # admission) drop a borrowed reference; the private remainder
            # releases as owner. Published blocks at refcount 0 park in
            # the reclaimable LRU instead of freeing — still warm for the
            # next identical prefix.
            s = self._slot_shared[slot]
            self._slot_shared[slot] = 0
            table = self.block_tables[slot]
            if s:
                self.allocator.release(table[:s], owned=False)
                self.allocator.release(table[s:], owned=True)
            else:
                self.allocator.release(table, owned=True)
            self.block_tables[slot] = []
            self.allocator.unreserve(self._slot_rblocks[slot])
            self._slot_rblocks[slot] = 0
            if self.device_resident:
                self._dev_clear_slot(slot)
        self.slot_reserved[slot] = 0
        self.slots[slot] = None
        self.slot_len[slot] = 0

    # ---- migration ----------------------------------------------------------
    def export_slot(self, slot: int):
        """(request, kv piece, kv bytes) for live migration.

        The piece is the wire format of DESIGN.md §Migration: contiguous
        ``[L, 1, written, ...]`` — a gather over the request's blocks on
        the paged path, a trimmed slab slice on the monolithic one — so
        bytes moved scale with the request's actual length, and paged and
        monolithic engines interoperate. ``written = slot_len - 1``: the
        latest sampled token's KV is produced by the *next* decode step
        (on whichever engine runs it), so both layouts export exactly the
        rows that exist — the paged block count always covers them. A
        request still mid-chunked-prefill has no sampled token: every one
        of its ``ctx_done`` written rows ships (``slot_len == ctx_done``),
        and the receiver resumes chunking from there (DESIGN.md §Chunked
        prefill, partial-prefill migration).
        """
        req = self.slots[slot]
        assert req is not None
        length = int(self.slot_len[slot]) - (0 if req.prefilling else 1)
        if self.paged:
            gathered = gather_kv_blocks(self.cache, self.block_tables[slot])
            # [L, nb, BS, ...] -> [L, 1, nb*BS, ...] -> trim to length
            piece = jax.tree.map(
                lambda a: a.reshape(a.shape[0], 1, -1, *a.shape[3:])[:, :, :length],
                gathered)
            if isinstance(piece, QuantKVCache):
                # wire format stays full-width: mixed bf16/int8 clusters
                # interoperate, receivers re-quantize on import
                piece = dequantize_piece(piece, self.model.cfg.dtype)
        else:
            piece = jax.tree.map(lambda a: a[:, slot:slot + 1], self.cache)
            if self.model.cfg.family != "ssm" \
                    and not self.model.cfg.sliding_window:
                piece = jax.tree.map(lambda a: a[:, :, :length], piece)
        return req, piece, kv_bytes(piece)

    def evict_slot(self, slot: int) -> None:
        self._release(slot)

    def import_request(self, req: ServeRequest, piece) -> bool:
        """Adopt a migrated request plus its KV piece — still-decoding, or
        still mid-chunked-prefill (``req.ctx_done < len(prompt)``): the
        piece then holds the ``ctx_done`` written rows and this engine
        resumes chunking where the source stopped. Rejects (via
        ``can_accept``) when no slot is free, the remaining generation
        cannot fit ``max_seq``, or the worst-case footprint exceeds the
        free budget — and partial prompts when this engine cannot chunk."""
        if req.prefilling and not self.chunked_prefill:
            return False        # nowhere to resume the prompt from
        if not self.can_accept(req):
            return False
        slot = self._free_slot()
        piece = self._localize_piece(piece)
        # a migrated shared prefix re-imports as PRIVATE (DESIGN.md
        # §Prefix cache): the wire piece is a plain contiguous gather, the
        # receiver allocates fresh blocks and reserves true length —
        # sharing is re-established only by the receiver's own index
        req.cached_tokens = 0
        self._reserve(req, slot)
        if self.paged and req.prefilling:
            written = req.ctx_done
            nb = blocks_for(written, self.block_size)
            ids = self.allocator.allocate(nb)
            self.block_tables[slot] = ids
            if nb:
                self.cache = _write_prompt_blocks(self.cache, piece, ids,
                                                  self.block_size)
            self._prefill_order.append(slot)   # resume chunking next step
            req.engine_id = self.id
            req.slot = slot
            req.state = State.RUNNING
            req.tokens_by_engine.setdefault(self.id, 0)
            self.slots[slot] = req
            self.slot_len[slot] = written
            # device mirrors stay cleared (all-garbage table, length 0):
            # the decode batch treats a mid-prefill slot as dead
            self._flush_demotes()   # import allocation may have demoted
            return True
        if self.paged:
            length = req.length
            nb = blocks_for(length, self.block_size)
            ids = self.allocator.allocate(nb)
            self.block_tables[slot] = ids
            self.cache = _write_prompt_blocks(self.cache, piece, ids,
                                              self.block_size)
            if self.device_resident:
                # adopted requests always carry >= 1 generated token, so
                # the device mirror seeds from host values (no sync)
                self._ensure_nbt_cap(nb)
                self._dev_set_table(slot, ids)
                self._dev_len = self._dev_len.at[slot].set(length)
                self._dev_tok = self._dev_tok.at[slot].set(
                    int(req.generated[-1]))
        else:
            self.cache = _write_slot(self.cache, piece, slot)
        req.engine_id = self.id
        req.slot = slot
        req.state = State.RUNNING
        # load-balance accounting (Fig. 16): the adopting engine must
        # appear in the per-engine token ledger even before its first token
        req.tokens_by_engine.setdefault(self.id, 0)
        self.slots[slot] = req
        self.slot_len[slot] = req.length
        self._flush_demotes()       # import allocation may have demoted
        return True


def _write_slot(cache, piece, slot: int):
    """Write a [L, 1, ...] piece into batch index ``slot`` of the cache.
    Leaves with a batch axis at position 1 are updated; piece S dim may be
    shorter than the cache's (trimmed migration pieces, prompt-length
    prefill pieces) — the remainder is zero-filled."""
    def put(a, p):
        p = p.astype(a.dtype)
        if p.shape[2:] != a.shape[2:]:
            pad = [(0, 0)] * p.ndim
            pad[2] = (0, a.shape[2] - p.shape[2])
            p = jnp.pad(p, pad)
        return jax.lax.dynamic_update_slice_in_dim(a, p, slot, axis=1)
    return jax.tree.map(put, cache, piece)


def _write_prompt_blocks(pool, piece, block_ids, block_size: int):
    """Scatter a contiguous KV piece (leaves [L, 1, T, ...]) into physical
    blocks ``block_ids`` of a paged pool (leaves [L, NB, BS, ...]).
    Full-precision pieces headed for an int8 pool are quantized first
    (scale leaves [L, 1, T, Hkv] pack on dim 2 like any other leaf)."""
    nb = len(block_ids)
    if isinstance(pool, QuantKVCache) and not isinstance(piece, QuantKVCache):
        piece = quantize_piece(piece)

    def pack(p):
        T = p.shape[2]
        pad = [(0, 0)] * p.ndim
        pad[2] = (0, nb * block_size - T)
        return jnp.pad(p, pad)[:, 0].reshape(
            p.shape[0], nb, block_size, *p.shape[3:])
    return scatter_kv_blocks(pool, jax.tree.map(pack, piece), block_ids)
