"""Single-instance JAX inference engine: slot-granular paged KV cache +
continuous batching (the vLLM-role component of DESIGN §3).

The cache is a preallocated pytree with leaves [L, slots, S_max, ...]; a
request owns one slot (slot-granular paging — block tables degenerate to
one block per request; token-budget admission matches vLLM semantics).
Every ``step()`` is one continuous-batching iteration: admit waiting
requests into free slots (prefill), then advance all running slots by one
token with a single batched ``decode_step``. Migration support exports /
imports a slot's KV slice plus request metadata.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.migration import kv_bytes
from repro.models.model import Model
from repro.serving.request import ServeRequest, State


class Engine:
    def __init__(self, engine_id: int, model: Model, params, *,
                 max_slots: int = 8, max_seq: int = 512,
                 token_budget: Optional[int] = None):
        assert model.cfg.family in ("dense", "moe", "vlm", "ssm"), \
            "slot engine supports decoder-only families"
        self.id = engine_id
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.token_budget = token_budget or max_slots * max_seq
        self.cache = model.init_cache(max_slots, max_seq)
        self.slot_len = np.zeros(max_slots, np.int32)       # tokens in slot
        self.slots: List[Optional[ServeRequest]] = [None] * max_slots
        self.waiting: Deque[ServeRequest] = deque()
        self.steps = 0
        self.tokens_out = 0
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill,
                                static_argnames=("cache_len",))

    # ---- load views --------------------------------------------------------
    def active(self) -> List[ServeRequest]:
        return [r for r in self.slots if r is not None]

    def used_tokens(self) -> int:
        return int(self.slot_len.sum()
                   + sum(len(r.prompt) for r in self.waiting))

    def free_tokens(self) -> int:
        return self.token_budget - self.used_tokens()

    def load(self) -> float:
        return float(self.used_tokens())

    def has_idle_slot(self) -> bool:
        return any(r is None for r in self.slots)

    def request_view(self) -> List[Tuple[float, float]]:
        return [(float(len(r.prompt)), float(r.length)) for r in self.active()]

    # ---- intake -------------------------------------------------------------
    def submit(self, req: ServeRequest) -> None:
        req.state = State.WAITING
        self.waiting.append(req)

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slots):
            if r is None:
                return i
        return None

    def _admit(self) -> List[ServeRequest]:
        admitted = []
        while self.waiting:
            req = self.waiting[0]
            slot = self._free_slot()
            if slot is None or len(req.prompt) + 1 > self.max_seq:
                break
            if self.slot_len.sum() + req.length + 1 > self.token_budget:
                break
            self.waiting.popleft()
            self._prefill_into_slot(req, slot)
            admitted.append(req)
        return admitted

    def _prefill_into_slot(self, req: ServeRequest, slot: int) -> None:
        tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
        logits, piece = self._prefill(self.params, {"tokens": tokens},
                                      cache_len=self.max_seq)
        self.cache = _write_slot(self.cache, piece, slot)
        vec = logits if logits.ndim == 1 else logits[0]
        tok = int(jnp.argmax(vec))
        req.generated.append(tok)
        req.first_token_step = self.steps
        req.state = State.RUNNING
        req.engine_id = self.id
        req.slot = slot
        req.tokens_by_engine[self.id] = req.tokens_by_engine.get(self.id, 0) + 1
        self.slots[slot] = req
        self.slot_len[slot] = req.length
        self.tokens_out += 1

    # ---- one continuous-batching iteration ----------------------------------
    def step(self) -> List[ServeRequest]:
        """Returns requests that finished this step."""
        self.steps += 1
        self._admit()
        live = [(i, r) for i, r in enumerate(self.slots) if r is not None]
        finished: List[ServeRequest] = []
        if live:
            last_tok = jnp.asarray(
                [r.generated[-1] if r.generated else r.prompt[-1]
                 for _, r in live], jnp.int32)
            pos = jnp.asarray([self.slot_len[i] - 1 for i, _ in live],
                              jnp.int32)
            sub_cache = jax.tree.map(
                lambda a: a[:, np.asarray([i for i, _ in live])], self.cache)
            logits, new_sub = self._decode(self.params, sub_cache, last_tok,
                                           pos)
            for j, (i, r) in enumerate(live):
                self.cache = _write_slot(
                    self.cache, jax.tree.map(lambda a: a[:, j:j + 1], new_sub),
                    i)
                tok = int(jnp.argmax(logits[j]))
                r.generated.append(tok)
                r.tokens_by_engine[self.id] = \
                    r.tokens_by_engine.get(self.id, 0) + 1
                self.tokens_out += 1
                self.slot_len[i] += 1
                if r.done or self.slot_len[i] >= self.max_seq:
                    r.state = State.FINISHED
                    r.finish_step = self.steps
                    finished.append(r)
                    self._release(i)
        return finished

    def _release(self, slot: int) -> None:
        self.slots[slot] = None
        self.slot_len[slot] = 0

    # ---- migration ----------------------------------------------------------
    def export_slot(self, slot: int):
        """(request, kv piece, kv bytes) for live migration."""
        req = self.slots[slot]
        assert req is not None
        piece = jax.tree.map(lambda a: a[:, slot:slot + 1], self.cache)
        return req, piece, kv_bytes(piece)

    def evict_slot(self, slot: int) -> None:
        self._release(slot)

    def import_request(self, req: ServeRequest, piece) -> bool:
        slot = self._free_slot()
        if slot is None:
            return False
        self.cache = _write_slot(self.cache, piece, slot)
        req.engine_id = self.id
        req.slot = slot
        req.state = State.RUNNING
        self.slots[slot] = req
        self.slot_len[slot] = req.length
        return True


def _write_slot(cache, piece, slot: int):
    """Write a [L, 1, ...] piece into batch index ``slot`` of the cache.
    Leaves with a batch axis at position 1 are updated; piece S dim may be
    shorter than the cache's (prefill pieces are sized to max_seq already
    by Model.prefill)."""
    def put(a, p):
        p = p.astype(a.dtype)
        if p.shape[2:] != a.shape[2:]:
            pad = [(0, 0)] * p.ndim
            pad[2] = (0, a.shape[2] - p.shape[2])
            p = jnp.pad(p, pad)
        return jax.lax.dynamic_update_slice_in_dim(a, p, slot, axis=1)
    return jax.tree.map(put, cache, piece)
