"""Multi-instance serving cluster over real JAX engines.

This is the control plane of DESIGN §3 running against actual model
compute: N in-process Engine instances serving one model, grouped into
length-specialized stages (PipelinePlan), with

  * length-aware arrival routing (earliest covering stage, bid-ask pick),
  * growth-triggered inter-stage handover with REAL KV-slice migration,
  * intra-stage bid-ask rebalancing on overload,
  * periodic adaptive boundary refinement,
  * round-robin / least-loaded baselines for comparison.

Time is step-synchronous (every engine advances one continuous-batching
iteration per tick) — the discrete-event simulator covers asynchronous
timing; this server proves the control plane works on real state.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.bidask import Bid, is_overloaded, select_receiver
from repro.core.partition import PipelinePlan
from repro.core.qoe import QoEModel
from repro.core.refinement import BoundaryRefiner
from repro.models.model import Model
from repro.serving.engine import Engine
from repro.serving.request import ServeRequest, State


@dataclasses.dataclass
class ServerConfig:
    policy: str = "cascade"            # cascade | round-robin | least-loaded
    refine_every: int = 16             # steps
    balance_every: int = 8
    max_migrations_per_step: int = 3   # §5 concurrency cap
    seed: int = 0


class MILSServer:
    def __init__(self, model: Model, params, plan: PipelinePlan,
                 qoe: Optional[QoEModel], cfg: ServerConfig, *,
                 max_slots: int = 4, max_seq: int = 256,
                 paged: Optional[bool] = None, block_size: int = 16):
        self.model = model
        self.cfg = cfg
        self.plan = plan
        self.rng = np.random.default_rng(cfg.seed)
        E = plan.num_instances
        self.engines = [Engine(i, model, params, max_slots=max_slots,
                               max_seq=max_seq, paged=paged,
                               block_size=block_size) for i in range(E)]
        # stage bookkeeping
        self.stage_bounds: List[Tuple[float, float]] = [
            (s.lo, s.hi) for s in plan.stages]
        self.stage_engines: List[List[int]] = []
        nxt = 0
        for s in plan.stages:
            self.stage_engines.append(list(range(nxt, nxt + s.num_instances)))
            nxt += s.num_instances
        self.stage_of_engine = {e: si for si, ids in
                                enumerate(self.stage_engines) for e in ids}
        self.refiners = ([BoundaryRefiner(qoe, boundary=s.hi)
                          for s in plan.stages[:-1]] if qoe else [])
        self._rr = 0
        self.steps = 0
        self.finished: List[ServeRequest] = []
        self.migrations = 0

    # ---- routing -------------------------------------------------------------
    def _stage_for(self, length: float) -> int:
        for i, (_, hi) in enumerate(self.stage_bounds):
            if length < hi:
                return i
        return len(self.stage_bounds) - 1

    def submit(self, req: ServeRequest) -> None:
        req.arrival_step = self.steps
        if self.cfg.policy == "round-robin":
            eng = self.engines[self._rr % len(self.engines)]
            self._rr += 1
        elif self.cfg.policy == "least-loaded":
            # load() = pinned cache + queued prompts; free_tokens() alone
            # is blind to a queue that hasn't been admitted yet
            eng = min(self.engines, key=lambda e: e.load())
        else:
            si = self._stage_for(len(req.prompt))
            cands = [self.engines[i] for i in self.stage_engines[si]]
            bids = [Bid(e.id, e.load(), e.used_tokens() / 1e4,
                        int(self.rng.integers(0, 1 << 30))) for e in cands]
            eng = self.engines[select_receiver(bids)]
        eng.submit(req)

    # ---- main loop -------------------------------------------------------------
    def step(self) -> List[ServeRequest]:
        self.steps += 1
        done: List[ServeRequest] = []
        for eng in self.engines:
            done.extend(eng.step())
        self.finished.extend(done)
        if self.cfg.policy == "cascade":
            self._handover()
            if self.steps % self.cfg.balance_every == 0:
                self._balance()
            if self.refiners and self.steps % self.cfg.refine_every == 0:
                self._refine()
        return done

    def run(self, requests: Sequence[ServeRequest],
            max_steps: int = 2000) -> List[ServeRequest]:
        for r in requests:
            self.submit(r)
        n = len(requests)
        while len(self.finished) < n and self.steps < max_steps:
            self.step()
        return self.finished

    # ---- CascadeInfer mechanisms -------------------------------------------------
    def _pick_receiver(self, cand_ids: Sequence[int],
                       req: ServeRequest) -> Optional[Engine]:
        """Receivers must pass the engine's own admission check (block/slot
        reservation headroom) so bid-ask never selects an engine that would
        reject the import."""
        cands = [self.engines[i] for i in cand_ids
                 if self.engines[i].can_accept(req)]
        if not cands:
            return None
        bids = [Bid(e.id, e.load(), e.used_tokens() / 1e4,
                    int(self.rng.integers(0, 1 << 30))) for e in cands]
        rid = select_receiver(bids)
        return self.engines[rid] if rid is not None else None

    def _migrate(self, src: Engine, slot: int, dst: Engine) -> bool:
        req, piece, _ = src.export_slot(slot)
        if not dst.import_request(req, piece):
            return False
        src.evict_slot(slot)
        self.migrations += 1
        return True

    def _handover(self) -> None:
        """Growth-triggered inter-stage migration (§3.2)."""
        moved = 0
        for eng in self.engines:
            si = self.stage_of_engine[eng.id]
            _, hi = self.stage_bounds[si]
            if hi == float("inf"):
                continue
            for slot, req in enumerate(list(eng.slots)):
                if req is None or req.length < hi:
                    continue
                if moved >= self.cfg.max_migrations_per_step:
                    return
                nxt = min(si + 1, len(self.stage_bounds) - 1)
                dst = self._pick_receiver(self.stage_engines[nxt], req)
                if dst is None:
                    continue       # §5 flow control: stay on source
                if self._migrate(eng, slot, dst):
                    moved += 1

    def _balance(self) -> None:
        """Intra-stage bid-ask rebalancing on overload (§4.4)."""
        for si, ids in enumerate(self.stage_engines):
            if len(ids) < 2:
                continue
            loads = {i: self.engines[i].load() for i in ids}
            for i in ids:
                peers = [l for j, l in loads.items() if j != i]
                if not is_overloaded(loads[i], peers):
                    continue
                eng = self.engines[i]
                occupied = [(s, r) for s, r in enumerate(eng.slots)
                            if r is not None]
                if not occupied:
                    continue
                slot, req = max(occupied, key=lambda sr: sr[1].length)
                dst = self._pick_receiver([j for j in ids if j != i], req)
                if dst is not None:
                    self._migrate(eng, slot, dst)

    def _refine(self) -> None:
        """Adaptive range refinement (§4.3) on live request lengths."""
        for bi in range(len(self.stage_bounds) - 1):
            own = [rv for i in self.stage_engines[bi]
                   for rv in self.engines[i].request_view()]
            succ = [self.engines[i].request_view()
                    for i in self.stage_engines[bi + 1]]
            b = self.refiners[bi].refine(own, succ)
            lo, _ = self.stage_bounds[bi]
            _, hi_next = self.stage_bounds[bi + 1]
            b = max(b, lo + 1.0)
            if hi_next != float("inf"):
                b = min(b, hi_next - 1.0)
            self.stage_bounds[bi] = (lo, b)
            self.stage_bounds[bi + 1] = (b, hi_next)

    # ---- metrics -------------------------------------------------------------
    def summary(self) -> Dict[str, float]:
        fin = self.finished
        if not fin:
            return {"finished": 0}
        # rejected requests never produced a token — folding their
        # fabricated timestamps into the means would fake instant service
        served = [r for r in fin if not r.rejected]
        out = {
            "finished": len(fin),
            "rejected": sum(1 for r in fin if r.rejected),
            "steps": self.steps,
            "migrations": self.migrations,
            "tokens_out": int(sum(e.tokens_out for e in self.engines)),
        }
        if served:
            ttft = np.asarray([r.first_token_step - r.arrival_step
                               for r in served], np.float64)
            e2e = np.asarray([r.finish_step - r.arrival_step
                              for r in served], np.float64)
            out["ttft_steps_mean"] = float(ttft.mean())
            out["e2e_steps_mean"] = float(e2e.mean())
        return out
