"""Multi-instance serving cluster over real JAX engines.

This is the control plane of DESIGN §3 running against actual model
compute: N in-process Engine instances serving one model, grouped into
length-specialized stages (PipelinePlan). All scheduling decisions —
round-robin-within-stage arrival routing (§3.2), growth-triggered
handover with sender/receiver bid-ask negotiation, intra-stage
rebalancing, boundary refinement (all Fig. 15/16 ablation modes), §5
flow control — come from the shared, backend-agnostic core
(`repro.control.plane.ControlPlane`), the same code the discrete-event
simulator drives. This server only supplies the mechanisms: step-
synchronous time (every engine advances one continuous-batching
iteration per tick) and real KV-piece migration between engines.

The serving API is open-loop: `submit_at(req, step)` builds an arrival
schedule (e.g. replayed from a `sim/workload.py` trace via
`requests_from_trace`), `step()`/`run()` advance it, an optional
`on_token` callback streams every generated token, and `run(drain=True)`
keeps stepping until everything submitted has finished.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple)

import numpy as np

from repro.control import (MIG_COMPLETED, MIG_FAILED, MIG_STARTED, XFER_OK,
                           XFER_STALL, ControlConfig, ControlPlane,
                           FaultInjector, FaultSpec, ReqView)
from repro.core.partition import PipelinePlan
from repro.core.qoe import QoEModel
from repro.kernels.cost import promote_cost_tokens
from repro.serving.engine import Engine
from repro.serving.request import ServeRequest, State
from repro.sim.metrics import class_slo_summary, fault_summary
from repro.sim.workload import Request

TokenCallback = Callable[[ServeRequest, int], None]


@dataclasses.dataclass
class ServerConfig:
    policy: str = "cascade"            # cascade | round-robin | least-loaded
    refinement: str = "adaptive"       # adaptive | quantity | memory | none
    balancing: str = "full"            # full | inter-stage | rr
    refine_every: int = 16             # steps
    balance_every: int = 8
    max_migrations_per_step: int = 3   # §5 concurrency cap
    seed: int = 0
    attn_backend: Optional[str] = None  # dense | grid | flat | fused | None=auto
    kv_dtype: str = "bf16"             # bf16 | int8 (DESIGN.md §Quantized KV)
    # SLO-tiered preemptive scheduling (DESIGN.md §SLO scheduling).
    # ``preemption=False`` restores bit-identical FCFS queues. With
    # uniform-class traffic and distinct arrival steps the SLO queue
    # order equals FCFS and no preemption can fire, so the default is
    # safe for legacy traces.
    preemption: bool = True
    slo_scale: float = 1.0             # paper §6.4 SLO-scale sweep knob
    slo_time_scale: float = 1.0        # engine steps per abstract SLO second
    # Multi-tier KV (DESIGN.md §Multi-tier KV): host-RAM tier capacity in
    # tokens per engine. 0 = tiering off — reclaim drops cached chains
    # exactly as before (bit-identical to the pre-tier server); the
    # launcher defaults this ON with a conservative budget.
    host_kv_budget: int = 0
    # ---- fault tolerance (DESIGN.md §Fault tolerance) ----
    # None = fault-free: no heartbeats/liveness run, behavior is
    # bit-identical to the pre-fault server. Spec times are in STEPS.
    faults: Optional[FaultSpec] = None
    suspect_after_steps: int = 3       # heartbeat-free steps -> suspect
    dead_after_steps: int = 6          # -> dead, residents recovered
    migration_timeout_steps: int = 4   # wire deadline for one transfer
    redispatch_budget: int = 2         # dead-engine recoveries per request


class EngineView:
    """`repro.control.protocol.InstanceView` over a real engine."""

    def __init__(self, eng):
        self.eng = eng
        self.id = eng.id

    def load(self) -> float:
        return self.eng.load()

    def free_tokens(self) -> float:
        return float(self.eng.free_tokens())

    def used_tokens(self) -> float:
        return float(self.eng.used_tokens())

    def queued_tokens(self) -> float:
        return float(self.eng.queued_tokens())

    def capacity_weight(self) -> float:
        """Instance-units this engine counts for — its tensor-parallel
        ways (DESIGN.md §Sharded serving). FakeEngine harnesses without
        a ``tp`` attribute weigh 1."""
        return float(getattr(self.eng, "tp", 1) or 1)

    def requests(self) -> List[ReqView]:
        return [ReqView(r, r.req_id, float(len(r.prompt)), float(r.length),
                        ctx_done=float(r.ctx_done),
                        ctx_total=float(r.prefill_target_len),
                        cached_tokens=float(r.cached_tokens),
                        slo_class=r.slo_class)
                for r in self.eng.slots if r is not None]

    def prefix_digests(self) -> frozenset:
        fn = getattr(self.eng, "prefix_digests", None)
        return fn() if fn is not None else frozenset()

    def tiered_digests(self):
        """digest -> "device"|"host" for tier-aware warm routing. Engines
        without a host tier (or FakeEngines without the hook) advertise
        everything as device-resident."""
        fn = getattr(self.eng, "tiered_digests", None)
        if fn is not None:
            return fn()
        return {d: "device" for d in self.prefix_digests()}

    def request_view(self):
        return self.eng.request_view()

    def has_request(self, req: ServeRequest) -> bool:
        return (req.state is State.RUNNING and req.engine_id == self.id
                and any(r is req for r in self.eng.slots))

    def can_accept(self, req: ServeRequest) -> bool:
        return self.eng.can_accept(req)

    def all_requests(self) -> List[ReqView]:
        """Every resident — slotted, waiting, parked. Dead-engine recovery
        re-dispatches all of them (a queued request dies with its engine
        just as surely as a running one)."""
        reqs = [r for r in self.eng.slots if r is not None]
        reqs += list(getattr(self.eng, "waiting", ()))
        for p in getattr(self.eng, "parked", ()):
            reqs.append(getattr(p, "req", p))   # Engine parks _Parked entries
        out, seen = [], set()
        for r in reqs:
            if id(r) in seen:
                continue
            seen.add(id(r))
            out.append(ReqView(r, r.req_id, float(len(r.prompt)),
                               float(r.length), ctx_done=float(r.ctx_done),
                               ctx_total=float(r.prefill_target_len),
                               cached_tokens=float(r.cached_tokens),
                               slo_class=r.slo_class))
        return out


class _ServerOps:
    """`repro.control.protocol.ClusterOps` over the engine pool: dispatch
    is an engine submit, migration is a synchronous export → import →
    evict of the request's actual KV piece."""

    def __init__(self, server: "MILSServer"):
        self.server = server

    def dispatch(self, req: ServeRequest, instance_id: int) -> None:
        self.server.engines[instance_id].submit(req)

    def start_migration(self, req: ServeRequest, src_id: int,
                        dst_id: int) -> str:
        server = self.server
        if src_id in server.crashed or dst_id in server.crashed:
            return MIG_FAILED      # either endpoint's process is gone
        if server.injector is not None:
            fate = server.injector.transfer_event(req.req_id)
            if fate != XFER_OK:
                # lost/stalled wire: mirror the simulator's ASYNC failure
                # sequence — report MIG_STARTED now (the plane logs
                # "migrate", keeping decision parity) and deliver the
                # failure when the deadline expires; the request never
                # leaves the source
                horizon = server.cfg.migration_timeout_steps * (
                    2 if fate == XFER_STALL else 1)
                server._doomed.append((server.steps + horizon, req.req_id))
                return MIG_STARTED
        src = server.engines[src_id]
        dst = server.engines[dst_id]
        slot = req.slot
        if slot is None or src.slots[slot] is not req:
            return MIG_FAILED
        _, piece, _ = src.export_slot(slot)
        if not dst.import_request(req, piece):
            return MIG_FAILED
        src.evict_slot(slot)
        return MIG_COMPLETED

    def set_boundary(self, stage_idx: int, hi: float) -> None:
        pass                        # the core's bounds are authoritative

    # ---- fault tolerance (DESIGN.md §Fault tolerance) --------------------
    def redispatch(self, req: ServeRequest, instance_id: int) -> bool:
        """Recover a resident of a dead engine: its KV died with the
        process, so replay prompt + generated-so-far through chunked
        prefill on ``instance_id`` — the same resume machinery recompute
        preemption uses (Engine._finish_resume), so the continuation is
        bit-identical to a never-crashed run."""
        dst = self.server.engines[instance_id]
        req.redispatches += 1
        req.slot = None
        req.engine_id = None
        req.ctx_done = 0
        req.cached_tokens = 0
        if req.generated:
            if not getattr(dst, "chunked_prefill", False):
                return False       # mid-decode resume needs chunked prefill
            req.prefill_target = len(req.prompt) + len(req.generated) - 1
            req.resume_tokens = np.concatenate(
                [np.asarray(req.prompt, np.int32),
                 np.asarray(req.generated[:-1], np.int32)])
        else:
            req.prefill_target = None
            req.resume_tokens = None
        req.state = State.WAITING
        dst.submit(req)
        return True

    def fail_request(self, req: ServeRequest) -> None:
        req.failed = True
        req.state = State.FINISHED
        req.finish_step = self.server.steps
        # completion of a sort: the drain loop must terminate
        self.server.finished.append(req)

    def instance_down(self, instance_id: int) -> None:
        # replace the carcass with a fresh engine so a later rejoin
        # starts empty (the core snapshotted the residents already)
        self.server._reset_engine(instance_id)


class MILSServer:
    def __init__(self, model, params, plan: PipelinePlan,
                 qoe: Optional[QoEModel], cfg: ServerConfig, *,
                 max_slots: int = 4, max_seq: int = 256,
                 paged: Optional[bool] = None, block_size: int = 16,
                 device_resident: Optional[bool] = None,
                 attn_backend: Optional[str] = None,
                 prefill_token_budget: Optional[int] = None,
                 chunked_prefill: Optional[bool] = None,
                 prefix_cache: Optional[bool] = None,
                 kv_dtype: Optional[str] = None,
                 host_kv_budget: Optional[int] = None,
                 tp: Any = 1,
                 engine_factory: Optional[Callable[[int], Any]] = None,
                 on_token: Optional[TokenCallback] = None):
        self.cfg = cfg
        self.plan = plan
        self.on_token = on_token
        # constructor kwargs override the ServerConfig defaults
        attn_backend = attn_backend or cfg.attn_backend
        kv_dtype = kv_dtype or cfg.kv_dtype
        host_kv_budget = (cfg.host_kv_budget if host_kv_budget is None
                          else int(host_kv_budget))
        # tensor parallelism (DESIGN.md §Sharded serving): an int gives
        # every engine the same TP ways; a sequence gives engine i
        # tp[i] — a HETEROGENEOUS cluster (e.g. (2, 1, 1)) whose capacity
        # weights the control plane uses for stage claiming and load
        # normalization. Engines own disjoint device prefixes-by-mesh.
        if isinstance(tp, (list, tuple)):
            tps = [int(x) for x in tp]
            assert len(tps) == plan.num_instances, \
                f"tp has {len(tps)} entries for {plan.num_instances} engines"
        else:
            tps = [int(tp)] * plan.num_instances
        self.tps = tps
        if engine_factory is None:
            def engine_factory(i):
                return Engine(i, model, params, max_slots=max_slots,
                              max_seq=max_seq, paged=paged,
                              block_size=block_size,
                              device_resident=device_resident,
                              attn_backend=attn_backend,
                              prefill_token_budget=prefill_token_budget,
                              chunked_prefill=chunked_prefill,
                              prefix_cache=prefix_cache,
                              kv_dtype=kv_dtype,
                              host_kv_budget=host_kv_budget,
                              preemption=cfg.preemption,
                              slo_time_scale=cfg.slo_time_scale,
                              tp=tps[i])
        self._engine_factory = engine_factory
        self.engines = [engine_factory(i)
                        for i in range(plan.num_instances)]
        self.plane = ControlPlane(
            plan, qoe,
            ControlConfig(policy=cfg.policy, refinement=cfg.refinement,
                          balancing=cfg.balancing,
                          max_migrations_per_tick=cfg.max_migrations_per_step,
                          seed=cfg.seed,
                          suspect_after=float(cfg.suspect_after_steps),
                          dead_after=float(cfg.dead_after_steps),
                          redispatch_budget=cfg.redispatch_budget),
            ops=_ServerOps(self),
            instances=[EngineView(e) for e in self.engines])
        self.steps = 0
        self.finished: List[ServeRequest] = []
        self.submitted = 0
        # ---- fault state (DESIGN.md §Fault tolerance) ----
        self.injector = (FaultInjector(cfg.faults)
                         if cfg.faults is not None else None)
        self.crashed: Dict[int, int] = {}        # engine id -> crash step
        self.downtime_steps: Dict[int, int] = {}
        self._doomed: List[Tuple[int, int]] = []  # (fail_at_step, req_id)
        # open-loop arrival schedule: (step, seq, request)
        self._schedule: List[Tuple[int, int, ServeRequest]] = []
        self._seq = 0
        self._emitted: Dict[int, int] = {}   # req_id -> tokens streamed

    # ---- observability -------------------------------------------------------
    @property
    def stage_bounds(self) -> List[Tuple[float, float]]:
        return self.plane.bounds()

    @property
    def migrations(self) -> int:
        return self.plane.migrations

    # ---- intake --------------------------------------------------------------
    def _prefix_hint(self, req: ServeRequest):
        """(head_digest, best cached tokens, promote price in token units)
        across the engine pool — the dispatch hint cache-aware routing
        consumes. Engines without a prefix cache (or FakeEngines without
        the hook) contribute nothing. Tier-aware engines return a 3-tuple
        whose third element counts host-tier blocks the hit would have to
        promote; legacy 2-tuple hints price as all-device. Ties on cached
        tokens prefer the cheaper (device-warm) instance, and the SAME
        pure pricing fn (`kernels.cost.promote_cost_tokens`) runs in the
        simulator's CascadePolicy so decision logs stay comparable."""
        digest, cached, price = None, 0.0, 0.0
        for eng in self.engines:
            fn = getattr(eng, "prefix_hint", None)
            if fn is None:
                continue
            out = fn(req)
            d, c, promo = out if len(out) == 3 else (out[0], out[1], 0)
            p = promote_cost_tokens(promo, getattr(eng, "block_size", 0))
            if d is not None:
                digest = d
            if (float(c), -p) > (cached, -price):
                cached, price = float(c), p
        return digest, cached, price

    def submit(self, req: ServeRequest) -> None:
        """Closed-loop submission: the request arrives now."""
        req.arrival_step = self.steps
        self.submitted += 1
        digest, cached, price = self._prefix_hint(req)
        self.plane.submit(req, req.req_id, float(len(req.prompt)),
                          cached_tokens=cached, prefix_digest=digest,
                          promote_cost_tokens=price,
                          slo_class=req.slo_class)

    def submit_at(self, req: ServeRequest, step: int) -> None:
        """Open-loop submission: the request arrives at ``step`` (replays
        a workload trace's arrival process in server time)."""
        self.submitted += 1
        heapq.heappush(self._schedule, (int(step), self._seq, req))
        self._seq += 1

    def _release_arrivals(self) -> None:
        while self._schedule and self._schedule[0][0] <= self.steps:
            _, _, req = heapq.heappop(self._schedule)
            req.arrival_step = self.steps
            digest, cached, price = self._prefix_hint(req)
            self.plane.submit(req, req.req_id, float(len(req.prompt)),
                              cached_tokens=cached, prefix_digest=digest,
                              promote_cost_tokens=price,
                              slo_class=req.slo_class)

    # ---- token streaming -----------------------------------------------------
    def _stream(self, reqs: Sequence[ServeRequest]) -> None:
        if self.on_token is None:
            return
        for r in reqs:
            n = self._emitted.get(r.req_id, 0)
            for tok in r.generated[n:]:
                self.on_token(r, tok)
            self._emitted[r.req_id] = len(r.generated)

    # ---- faults (DESIGN.md §Fault tolerance) ---------------------------------
    def _crash(self, iid: int) -> None:
        """Scripted hard-kill: the engine stops stepping and heartbeating;
        the plane's liveness machinery discovers the death and recovers
        the residents."""
        self.crashed[iid] = self.steps
        # flag the carcass so the conftest drain-leak fixture skips it
        try:
            self.engines[iid]._faulted = True
        except AttributeError:
            pass

    def _reset_engine(self, iid: int) -> None:
        """Swap in a fresh engine (ClusterOps.instance_down / rejoin):
        the old process' state is unreachable, a rejoin starts empty."""
        try:
            self.engines[iid]._faulted = True
        except AttributeError:
            pass
        fresh = self._engine_factory(iid)
        self.engines[iid] = fresh
        self.plane.instances[iid] = EngineView(fresh)

    def _revive(self, iid: int) -> None:
        self._reset_engine(iid)
        self.crashed.pop(iid, None)
        # the plane learns of the rejoin from the next heartbeat

    def _inject_faults(self) -> None:
        if self.injector is None:
            return
        # all_crashes folds correlated rack events into the per-instance
        # schedule — several engines can die in the same step
        for iid, at in self.cfg.faults.all_crashes:
            if int(at) == self.steps and iid not in self.crashed:
                self._crash(iid)
        for iid, at in self.cfg.faults.rejoins:
            if int(at) == self.steps and iid in self.crashed:
                self._revive(iid)
        # deliver due wire deadlines (lost/stalled transfers)
        due = [r for s, r in self._doomed if s <= self.steps]
        self._doomed = [(s, r) for s, r in self._doomed if s > self.steps]
        for rid in due:
            self.plane.migration_failed(rid)

    def _engine_runs_this_step(self, eng) -> bool:
        if eng.id in self.crashed:
            self.downtime_steps[eng.id] = \
                self.downtime_steps.get(eng.id, 0) + 1
            return False
        if self.injector is not None:
            f = self.injector.slowdown(eng.id)
            if f > 1.0 and self.steps % max(int(round(f)), 1) != 0:
                return False       # slow instance: skips iterations
        return True

    # ---- main loop -----------------------------------------------------------
    def step(self) -> List[ServeRequest]:
        self._release_arrivals()
        self.steps += 1
        self._inject_faults()
        done: List[ServeRequest] = []
        for eng in self.engines:
            if not self._engine_runs_this_step(eng):
                continue
            fin = eng.step()
            done.extend(fin)
            self._stream(eng.active())
            self._stream(fin)
        self.finished.extend(done)
        for r in done:
            self._emitted.pop(r.req_id, None)
        if self.cfg.policy == "cascade":
            self.plane.begin_tick()
            if self.cfg.faults is not None:
                # liveness runs only on fault-aware servers, so legacy
                # runs stay bit-identical to the pre-fault server
                for eng in self.engines:
                    if eng.id not in self.crashed:
                        self.plane.heartbeat(eng.id, float(self.steps))
                self.plane.check_liveness(float(self.steps))
            self.plane.handover_all()
            if self.steps % self.cfg.balance_every == 0:
                self.plane.balance()
            if self.steps % self.cfg.refine_every == 0:
                self.plane.refine()
            # retry offers deferred by §5 flow control / the tick budget —
            # without this an offer put back in a receiver queue would only
            # be retried if a later offer happened to land on that receiver
            self.plane.pump_all()
        return done

    def run(self, requests: Sequence[ServeRequest] = (),
            max_steps: int = 2000, drain: bool = True) -> List[ServeRequest]:
        """Drive the arrival schedule (plus any ``requests`` submitted
        immediately). With ``drain`` (default) keep stepping until every
        submitted request finished; otherwise stop once the schedule is
        exhausted."""
        for r in requests:
            self.submit(r)
        while self.steps < max_steps:
            if not self._schedule and (not drain
                                       or len(self.finished)
                                       >= self.submitted):
                break
            self.step()
        if drain and len(self.finished) >= self.submitted:
            # drained server = leak check: every live engine must hold no
            # requests and no allocator state beyond reclaimable cache
            for eng in self.engines:
                if eng.id in self.crashed:
                    continue
                chk = getattr(eng, "check_drained", None)
                if chk is not None:
                    chk(strict=True)
        return self.finished

    # ---- metrics -------------------------------------------------------------
    def summary(self) -> Dict[str, float]:
        fin = self.finished
        if not fin:
            return {"finished": 0}
        # rejected/failed requests never finished normal service — folding
        # their fabricated timestamps into the means would fake latencies
        served = [r for r in fin if not r.rejected and not r.failed]
        out: Dict[str, float] = {
            "finished": len(fin),
            "steps": self.steps,
            "migrations": self.migrations,
            "tokens_out": int(sum(e.tokens_out for e in self.engines)),
        }
        # failure accounting through the SAME formula the simulator
        # reports (sim.metrics.fault_summary)
        out.update(fault_summary(
            ((r.rejected, r.failed, r.redispatches) for r in fin),
            retries=self.plane.retries,
            downtime={i: float(s) for i, s in self.downtime_steps.items()
                      if s}))
        # per-stage-pair migration counts (handover vs. rebalance visibility)
        for (a, b), n in sorted(self.plane.migrations_by_stage.items()):
            out[f"migrations_s{a}_to_s{b}"] = n
        if served:
            ttft = np.asarray([r.first_token_step - r.arrival_step
                               for r in served], np.float64)
            e2e = np.asarray([r.finish_step - r.arrival_step
                              for r in served], np.float64)
            # tail latency is the paper's headline claim — report the
            # distribution, not just the mean (mirrors sim/metrics.py)
            for name, arr in (("ttft_steps", ttft), ("e2e_steps", e2e)):
                out[f"{name}_mean"] = float(arr.mean())
                for p in (50, 95, 99):
                    out[f"{name}_p{p}"] = float(np.percentile(arr, p))
        # per-class SLO attainment + goodput-under-SLO, through the SAME
        # formula the simulator reports (sim.metrics.class_slo_summary) —
        # ``slo_time_scale`` converts the abstract class deadlines into
        # steps, ``slo_scale`` is the paper's SLO-scale sweep knob
        entries = []
        for r in served:
            ttft_r = float(r.first_token_step - r.arrival_step)
            tpot_r = (float(r.finish_step - r.first_token_step)
                      / max(len(r.generated) - 1, 1))
            entries.append((r.slo_class, ttft_r, tpot_r, len(r.generated)))
        per = class_slo_summary(entries, float(self.steps),
                                scale=self.cfg.slo_scale,
                                time_scale=self.cfg.slo_time_scale)
        for cls, d in sorted(per.items()):
            out[f"slo_{cls}_attainment"] = d["attainment"]
            out[f"slo_{cls}_goodput_tok_step"] = d["goodput_tok_s"]
            out[f"slo_{cls}_requests"] = d["requests"]
        # getattr: custom engine_factory backends (FakeEngine parity
        # harnesses) may predate the preemption counters
        out["preemptions"] = sum(getattr(e, "preemptions", 0)
                                 for e in self.engines)
        out["preempt_recomputes"] = sum(getattr(e, "preempt_recomputes", 0)
                                        for e in self.engines)
        out["resumes"] = sum(getattr(e, "resumes", 0) for e in self.engines)
        out["tpot_skipped"] = sum(getattr(e, "tpot_skipped", 0)
                                  for e in self.engines)
        # multi-tier KV traffic (DESIGN.md §Multi-tier KV)
        for k in ("cache_demotions", "cache_drops", "cache_promotions",
                  "promoted_blocks_total"):
            out[k] = sum(getattr(e, k, 0) for e in self.engines)
        return out


def requests_from_trace(trace: Sequence[Request], *, vocab_size: int,
                        steps_per_second: float = 1.0,
                        max_seq: Optional[int] = None,
                        seed: int = 0) -> List[Tuple[ServeRequest, int]]:
    """Convert a `sim/workload.py` trace into (ServeRequest, arrival_step)
    pairs so the server replays the exact workload the simulator consumes:
    input_len becomes a random prompt of that length, output_len the token
    budget, and Poisson arrival times map to steps at ``steps_per_second``.
    ``max_seq`` caps lengths to what a small real engine can hold (the
    sim's 128K-context tail does not fit a reduced test model).

    Traces carrying shared-prefix groups (``Request.prefix_group >= 0``,
    from ``sim.workload.shared_prefix_spec``) are replayed with LITERAL
    shared prefixes: every request in a group starts with the same token
    block, so the real engine's content-hashed prefix cache hits exactly
    where the simulator's group-granular model does."""
    rng = np.random.default_rng(seed)
    prefixes: Dict[int, np.ndarray] = {}
    out = []
    for r in trace:
        plen, new = int(r.input_len), int(r.output_len)
        pg = getattr(r, "prefix_group", -1)
        pfx_len = int(getattr(r, "prefix_len", 0)) if pg >= 0 else 0
        if max_seq is not None:
            plen = max(1, min(plen, max_seq // 2))
            new = max(1, min(new, max_seq - plen - 1))
            pfx_len = min(pfx_len, max(plen - 1, 0))
        prompt = rng.integers(0, vocab_size, plen).astype(np.int32)
        if pfx_len > 0:
            if pg not in prefixes:
                # one draw at the group's FULL prefix length: a capped
                # replay still shares the same leading tokens
                prefixes[pg] = rng.integers(
                    0, vocab_size,
                    int(getattr(r, "prefix_len", 0))).astype(np.int32)
            prompt[:pfx_len] = prefixes[pg][:pfx_len]
        req = ServeRequest(r.req_id, prompt, new)
        req.prefix_group = pg
        req.prefix_len = pfx_len
        req.slo_class = getattr(r, "slo_class", "standard")
        out.append((req, int(round(r.arrival * steps_per_second))))
    return out
