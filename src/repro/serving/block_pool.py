"""Block-granular KV-cache allocator (the vLLM PagedAttention role).

The engine owns one global KV *pool* per model — a pytree whose leaves are
``[L, num_blocks, block_size, Hkv, Dh]`` — and every running request owns an
ordered list of physical block ids (its *block table*). Logical token
position ``t`` of a request lives at ``(table[t // BS], t % BS)``.

``BlockAllocator`` hands out physical blocks and tracks two quantities:

  * **allocated** blocks — physically backing written KV (true memory
    pressure; what load/bid accounting reports), and
  * **reserved** blocks — the worst-case footprint of every admitted
    request, ``ceil(min(prompt + max_new_tokens, max_seq) / BS)``.

Admission gates on *reservations*, growth allocates *incrementally*; since
``allocated <= reserved <= num_blocks`` is an invariant, a mid-decode
allocation can never fail and ``free_tokens()`` can never go negative —
this replaces the slot engine's inconsistent token-budget check (see
DESIGN.md §Allocator invariants).
"""
from __future__ import annotations

import dataclasses
from typing import List


def blocks_for(tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``tokens`` KV rows (>=0)."""
    return max(0, -(-int(tokens) // block_size))


@dataclasses.dataclass
class BlockAllocator:
    num_blocks: int
    block_size: int

    def __post_init__(self) -> None:
        assert self.num_blocks > 0 and self.block_size > 0
        # LIFO free list: recently-freed (still-warm) blocks are reused first
        self._free: List[int] = list(range(self.num_blocks - 1, -1, -1))
        self._reserved = 0

    # ---- views -------------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def allocated_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    @property
    def reserved_blocks(self) -> int:
        return self._reserved

    def allocated_tokens(self) -> int:
        return self.allocated_blocks * self.block_size

    def free_tokens(self) -> int:
        return self.free_blocks * self.block_size

    # ---- admission reservation ----------------------------------------------
    def can_reserve(self, n_blocks: int) -> bool:
        return self._reserved + n_blocks <= self.num_blocks

    def reserve(self, n_blocks: int) -> None:
        assert self.can_reserve(n_blocks), \
            f"reserve({n_blocks}) over capacity ({self._reserved}/{self.num_blocks})"
        self._reserved += n_blocks

    def unreserve(self, n_blocks: int) -> None:
        self._reserved -= n_blocks
        assert self._reserved >= 0

    # ---- physical blocks -----------------------------------------------------
    def allocate(self, n_blocks: int) -> List[int]:
        """Pop ``n_blocks`` physical block ids. Caller must hold a covering
        reservation — under the invariant this cannot fail."""
        assert n_blocks <= len(self._free), \
            f"allocator invariant broken: want {n_blocks}, free {len(self._free)}"
        out = [self._free.pop() for _ in range(n_blocks)]
        assert self.allocated_blocks <= self._reserved, \
            "allocated blocks exceeded reservations"
        return out

    def free(self, block_ids: List[int]) -> None:
        for b in block_ids:
            assert 0 <= b < self.num_blocks and b not in self._free, \
                f"double free / bad block id {b}"
            self._free.append(b)
