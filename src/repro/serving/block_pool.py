"""Block-granular KV-cache allocator (the vLLM PagedAttention role),
now **refcounted and prefix-shared** (DESIGN.md §Prefix cache).

The engine owns one global KV *pool* per model — a pytree whose leaves are
``[L, num_blocks, block_size, Hkv, Dh]`` — and every running request owns an
ordered list of physical block ids (its *block table*). Logical token
position ``t`` of a request lives at ``(table[t // BS], t % BS)``.

``BlockAllocator`` hands out physical blocks and tracks three quantities:

  * **referenced** blocks — refcount >= 1, physically backing written KV of
    at least one live request (true memory pressure; what load/bid
    accounting reports). A *shared* prefix block counts ONCE no matter how
    many requests' tables point at it.
  * **cached** blocks — refcount 0 but still holding a published prefix
    block (reachable through :class:`PrefixIndex`). They are *reclaimable*:
    they count as free capacity and are evicted LRU when the free list
    runs dry. ``share`` revives them (0 -> 1) without any copy.
  * **reserved** blocks — the worst-case footprint of every admitted
    request, ``ceil(min(prompt + max_new_tokens, max_seq) / BS)`` minus
    the cached blocks it shares (admission reserves only the uncached
    tail — DESIGN.md §Prefix cache).

Admission gates on *reservations*, growth allocates *incrementally*. With
sharing, the non-negotiable invariant is

    reserved + cached_live <= num_blocks

where ``cached_live`` counts cached blocks that are still referenced by
sharers but whose *allocating owner* has already released them: such a
block outlived the reservation that covered it (sharers reserved only
their tails), so the allocator carries one implicit reservation unit
for it.
Every live block is then covered — by a request reservation (private
blocks) or by ``cached_live`` (shared blocks) — hence a mid-decode
allocation can never fail and ``free_tokens()`` can never go negative.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import (Any, Callable, Dict, List, Optional, Sequence, Set,
                    Tuple)

import numpy as np


def blocks_for(tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``tokens`` KV rows (>=0)."""
    return max(0, -(-int(tokens) // block_size))


def chain_hash(parent: int, tokens) -> int:
    """Radix-style content digest of one FULL block: 64-bit
    ``hash(parent_hash, block_tokens)``. Deterministic across processes
    (sha1, not Python's randomized hash); collision probability is
    negligible at pool scale — production would verify tokens on hit,
    exactly as vLLM's prefix cache does."""
    h = hashlib.sha1()
    h.update(int(parent).to_bytes(8, "little", signed=True))
    h.update(np.asarray(tokens, np.int32).tobytes())
    return int.from_bytes(h.digest()[:8], "little", signed=True)


def prompt_chain(prompt, block_size: int,
                 limit: Optional[int] = None) -> List[int]:
    """Chained digests of a prompt's FULL blocks (partial tail excluded).
    ``limit`` caps the number of blocks hashed (lookup caps at
    ``(len(prompt) - 1) // BS`` so a fully-cached identical prompt still
    prefill-computes >= 1 token — the first token needs its logits)."""
    n = len(prompt) // block_size
    if limit is not None:
        n = min(n, limit)
    out: List[int] = []
    parent = 0
    for j in range(n):
        parent = chain_hash(parent, prompt[j * block_size:(j + 1) * block_size])
        out.append(parent)
    return out


class HostBlockStore:
    """Capacity-bounded host-RAM tier behind the device pool (DESIGN.md
    §Multi-tier KV). Entries are keyed by chain digest and carry the
    block's KV payload in the migration wire layout (leaves
    ``[L, 1, BS, ...]``; int8 blocks keep their scale leaves), plus the
    parent digest and head flag needed to re-publish on promote.

    The store is LRU over *insertion* order (a demote re-inserts, a
    promote removes), bounded at ``capacity_blocks`` entries. Making room
    evicts the oldest entry AND every host-resident descendant — a child
    whose parent is gone could never be reached by the chain-ordered
    lookup anyway, so cascading keeps capacity honest instead of leaking
    unreachable entries. A digest lives in exactly ONE tier: the
    allocator drops the host entry the moment the same digest is
    re-published on device."""

    def __init__(self, capacity_blocks: int):
        assert capacity_blocks > 0
        self.capacity_blocks = int(capacity_blocks)
        # digest -> (payload, parent_digest, head); dict preserves
        # insertion order = demote order = LRU order
        self._entries: Dict[int, Tuple[Any, int, bool]] = {}
        self._children: Dict[int, Set[int]] = {}    # parent -> host children
        # payloads still pending host materialization (the engine demotes
        # with an async device-side snapshot and flushes to numpy at the
        # end of the step — see Engine._flush_demotes)
        self._pending: Set[int] = set()
        self.drops = 0          # entries destroyed by host capacity pressure

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, digest: int) -> bool:
        return digest in self._entries

    def parent(self, digest: int) -> int:
        return self._entries[digest][1]

    def digests(self) -> frozenset:
        return frozenset(self._entries)

    def head_digests(self) -> frozenset:
        return frozenset(h for h, (_, _, head) in self._entries.items()
                         if head)

    def _unlink(self, digest: int) -> Tuple[Any, int, bool]:
        payload, parent, head = self._entries.pop(digest)
        self._pending.discard(digest)
        kids = self._children.get(parent)
        if kids is not None:
            kids.discard(digest)
            if not kids:
                del self._children[parent]
        return payload, parent, head

    def _drop_subtree(self, digest: int) -> None:
        """Destroy an entry and every host-resident descendant."""
        stack = [digest]
        while stack:
            h = stack.pop()
            if h not in self._entries:
                continue
            stack.extend(self._children.get(h, ()))
            self._unlink(h)
            self.drops += 1

    def drop_children_of(self, digest: int) -> None:
        """A parent left BOTH tiers (reclaim-time drop): its host-resident
        descendants can never be reached by the chain-ordered lookup again
        — destroy them so capacity stays honest."""
        for child in list(self._children.get(digest, ())):
            self._drop_subtree(child)

    def discard(self, digest: int) -> None:
        """Remove an entry whose digest was re-published on the device
        tier (single-tier residence; the device copy supersedes, nothing
        is lost, children stay — their parent is resident again)."""
        if digest in self._entries:
            self._unlink(digest)

    def put(self, digest: int, payload: Any, parent: int, *, head: bool,
            parent_ok: Callable[[int], bool]) -> bool:
        """Admit a demoted block. Evicts LRU (+ descendants) to make
        room; if making room destroyed the incoming block's own parent,
        the demote fails (``False``) — the chain would be unreachable."""
        assert digest not in self._entries, "digest already host-resident"
        while len(self._entries) >= self.capacity_blocks:
            self._drop_subtree(next(iter(self._entries)))
        if not parent_ok(parent):
            return False
        self._entries[digest] = (payload, parent, head)
        if parent:
            self._children.setdefault(parent, set()).add(digest)
        self._pending.add(digest)
        return True

    def pop(self, digest: int) -> Any:
        """Remove an entry for promotion and return its payload. Children
        stay: the promoted parent is about to be re-published on device,
        so they remain reachable."""
        payload, _, _ = self._unlink(digest)
        return payload

    def materialize(self, fn: Callable[[Any], Any]) -> int:
        """Apply ``fn`` (device→numpy) to every payload still pending
        host materialization. Returns the number flushed."""
        n = 0
        for h in self._pending:
            if h in self._entries:
                payload, parent, head = self._entries[h]
                self._entries[h] = (fn(payload), parent, head)
                n += 1
        self._pending.clear()
        return n

    def check(self, tier_resident: Callable[[int], bool]) -> None:
        assert len(self._entries) <= self.capacity_blocks, \
            f"host tier over capacity: {len(self._entries)}" \
            f"/{self.capacity_blocks}"
        for h, (_, parent, _) in self._entries.items():
            assert tier_resident(parent), \
                f"host entry {h} has a non-resident parent {parent}"
        for parent, kids in self._children.items():
            for k in kids:
                assert k in self._entries and self._entries[k][1] == parent


@dataclasses.dataclass
class BlockAllocator:
    num_blocks: int
    block_size: int
    # host-RAM tier capacity in blocks (DESIGN.md §Multi-tier KV);
    # 0 disables tiering — reclaim drops chains exactly as before
    host_blocks: int = 0

    def __post_init__(self) -> None:
        assert self.num_blocks > 0 and self.block_size > 0
        # LIFO free list: recently-freed (still-warm) blocks are reused
        # first. The set mirror makes the double-free assert O(1) instead
        # of an O(free-list) membership scan per freed block.
        self._free: List[int] = list(range(self.num_blocks - 1, -1, -1))
        self._free_set = set(self._free)
        self._reserved = 0
        # ---- prefix sharing state (DESIGN.md §Prefix cache) ----
        self._refs = [0] * self.num_blocks          # per-block refcount
        self._hash_of: Dict[int, int] = {}          # cached block -> digest
        self._index: Dict[int, int] = {}            # digest -> block id
        self._head_digests: set = set()             # depth-1 digests (dispatch)
        # refcount-0 cached blocks, LRU order (dict preserves insertion;
        # least-recently-released first)
        self._reclaimable: Dict[int, None] = {}
        self._cached_live = 0        # cached AND referenced (implicit resv)
        # parked blocks (DESIGN.md §SLO scheduling & preemption): per-block
        # count of park-preempted requests pinning it. A parked block keeps
        # its references and its covering reservation — parking frees a
        # batch slot, never memory — so it must not be reclaimed or freed
        # while any parker holds it.
        self._parked: Dict[int, int] = {}
        # ---- host-RAM tier (DESIGN.md §Multi-tier KV) ----
        self._host: Optional[HostBlockStore] = (
            HostBlockStore(self.host_blocks) if self.host_blocks > 0
            else None)
        # digest -> parent digest for every DEVICE-indexed block (0 for
        # chain heads) — demote needs the link to keep host chains
        # promotable, publish populates it
        self._parent_of: Dict[int, int] = {}
        # engine-installed payload snapshot: block id -> device-side KV
        # slice (async; materialized off the hot loop). None = tier off.
        self._demote_fetch: Optional[Callable[[int], Any]] = None
        # telemetry: cache_evictions (the pre-tier counter) splits into
        # demotions (chain went to the host tier) and drops (tier full,
        # disabled, or the chain's head was already gone)
        self.cache_demotions = 0
        self._reclaim_drops = 0
        self.cache_promotions = 0    # host-tier blocks revived onto device

    # ---- views -------------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        """Allocatable capacity: the free list plus every reclaimable
        (cached, refcount-0) block — a cache entry never blocks admission."""
        return len(self._free) + len(self._reclaimable)

    @property
    def allocated_blocks(self) -> int:
        """Blocks referenced by at least one live request (shared blocks
        count once)."""
        return self.num_blocks - self.free_blocks

    @property
    def cached_blocks(self) -> int:
        """Published blocks currently resident (referenced or reclaimable)."""
        return len(self._hash_of)

    @property
    def reserved_blocks(self) -> int:
        return self._reserved

    def allocated_tokens(self) -> int:
        return self.allocated_blocks * self.block_size

    def free_tokens(self) -> int:
        return self.free_blocks * self.block_size

    def ref(self, block_id: int) -> int:
        return self._refs[block_id]

    @property
    def parked_blocks(self) -> int:
        """Blocks pinned by at least one park-preempted request."""
        return len(self._parked)

    @property
    def headroom_blocks(self) -> int:
        """Blocks an admission gate could still reserve."""
        return self.num_blocks - self._reserved - self._cached_live

    # ---- host-tier views (DESIGN.md §Multi-tier KV) --------------------------
    @property
    def host_tier_enabled(self) -> bool:
        return self._host is not None

    @property
    def host_blocks_used(self) -> int:
        return len(self._host) if self._host is not None else 0

    @property
    def cache_drops(self) -> int:
        """Cached chains destroyed outright: reclaim-time drops (tier
        full/disabled/orphaned chain) plus host-tier capacity evictions."""
        return self._reclaim_drops + (self._host.drops
                                      if self._host is not None else 0)

    @property
    def cache_evictions(self) -> int:
        """Back-compat view of the pre-tier counter: every cached block
        that left the device index under pressure, wherever it went."""
        return self.cache_demotions + self.cache_drops

    def set_demote_fetch(self, fn: Optional[Callable[[int], Any]]) -> None:
        """Install the engine's payload snapshot for demotes: called with
        a block id INSIDE ``allocate`` (before the block is overwritten —
        JAX program order makes the async device-side slice a consistent
        snapshot), must return the block's KV payload or None to decline."""
        self._demote_fetch = fn

    # ---- admission reservation ----------------------------------------------
    def can_reserve(self, n_blocks: int) -> bool:
        return self._reserved + self._cached_live + n_blocks <= self.num_blocks

    def reserve(self, n_blocks: int) -> None:
        assert self.can_reserve(n_blocks), \
            f"reserve({n_blocks}) over capacity " \
            f"({self._reserved}+{self._cached_live}/{self.num_blocks})"
        self._reserved += n_blocks

    def unreserve(self, n_blocks: int) -> None:
        self._reserved -= n_blocks
        assert self._reserved >= 0

    # ---- physical blocks -----------------------------------------------------
    def allocate(self, n_blocks: int) -> List[int]:
        """Pop ``n_blocks`` fresh private block ids (refcount 1). Caller
        must hold a covering reservation — under the invariant this cannot
        fail. When the free list runs dry, refcount-0 cached blocks are
        reclaimed LRU (their index entries drop; sharing them is no longer
        possible, their content is about to be overwritten)."""
        assert n_blocks <= self.free_blocks, \
            f"allocator invariant broken: want {n_blocks}, " \
            f"free {self.free_blocks}"
        out: List[int] = []
        for _ in range(n_blocks):
            if not self._free:
                self._reclaim_one()
            b = self._free.pop()
            self._free_set.discard(b)
            assert self._refs[b] == 0 and b not in self._hash_of
            self._refs[b] = 1
            out.append(b)
        assert self.allocated_blocks <= self._reserved + self._cached_live, \
            "allocated blocks exceeded reservations"
        return out

    def _reclaim_one(self) -> None:
        """Evict the least-recently-released cached block: drop its index
        entry, DEMOTE its content to the host tier when possible, and hand
        the physical block back to the free list. Never touches a
        referenced block (those are not in ``_reclaimable``). Tables
        release head-first, so chains demote in depth order — a child
        always finds its parent already host-resident (or still on
        device); a child whose parent was dropped is dropped too, so a
        partially-destroyed chain can never be promoted."""
        b = next(iter(self._reclaimable))
        del self._reclaimable[b]
        assert self._refs[b] == 0
        h = self._hash_of.pop(b)
        self._index.pop(h, None)
        was_head = h in self._head_digests
        self._head_digests.discard(h)
        parent = self._parent_of.pop(h, 0)
        if self._try_demote(b, h, parent, was_head):
            self.cache_demotions += 1
        else:
            self._reclaim_drops += 1
            if self._host is not None:
                # the digest left both tiers: host descendants (possible
                # after an earlier promote of this block) are unreachable
                self._host.drop_children_of(h)
        self._free.append(b)
        self._free_set.add(b)

    def _tier_resident(self, digest: int) -> bool:
        """A chain link is promotable only while its parent is reachable
        in SOME tier (0 = chain head, no parent)."""
        return (digest == 0 or digest in self._index
                or (self._host is not None and digest in self._host))

    def _try_demote(self, b: int, h: int, parent: int, head: bool) -> bool:
        if self._host is None or self._demote_fetch is None:
            return False
        if not self._tier_resident(parent):
            return False        # orphaned link: could never be looked up
        payload = self._demote_fetch(b)
        if payload is None:
            return False
        return self._host.put(h, payload, parent, head=head,
                              parent_ok=self._tier_resident)

    def release(self, block_ids: Sequence[int], *, owned: bool = True) -> None:
        """Drop one reference per block.

        ``owned=True`` means the caller *allocated* these blocks (they were
        covered by its admission reservation); ``owned=False`` means the
        references came from ``share``. The distinction keeps the implicit
        reservation exact: when an owner leaves a cached block behind with
        sharers still referencing it, the block is no longer covered by any
        request reservation, so one ``_cached_live`` unit takes over; the
        last sharer's release retires the unit. A block reaching refcount 0
        goes back to the free list — unless it is published in the prefix
        index, in which case it parks in the reclaimable LRU (free
        capacity, revivable by ``share``)."""
        for b in block_ids:
            assert 0 <= b < self.num_blocks and b not in self._free_set, \
                f"double free / bad block id {b}"
            assert self._refs[b] > 0, f"double free / bad block id {b}"
            assert self._refs[b] - 1 >= self._parked.get(b, 0), \
                f"release would strand parked block {b}"
            self._refs[b] -= 1
            cached = b in self._hash_of
            assert cached or self._refs[b] == 0, \
                f"uncached block {b} was shared"
            if self._refs[b] == 0:
                if cached:                  # park, don't free
                    if not owned:
                        self._cached_live -= 1
                    self._reclaimable[b] = None
                else:
                    self._free.append(b)
                    self._free_set.add(b)
            elif owned:
                # owner leaves, sharers remain: coverage moves from the
                # owner's reservation to the allocator's implicit unit
                self._cached_live += 1

    # back-compat alias (pre-refcount callers allocated everything they free)
    def free(self, block_ids: Sequence[int]) -> None:
        self.release(block_ids, owned=True)

    def share(self, block_ids: Sequence[int]) -> None:
        """Take one reference per block. Reviving a reclaimable cached
        block (0 -> 1) removes it from the LRU and adds its implicit
        reservation unit — see the module invariant."""
        for b in block_ids:
            assert b not in self._free_set, f"share of free block {b}"
            if self._refs[b] == 0:
                assert b in self._reclaimable, f"share of free block {b}"
                del self._reclaimable[b]
                self._cached_live += 1
            self._refs[b] += 1

    # ---- preemption park/unpark ---------------------------------------------
    def park(self, block_ids: Sequence[int]) -> None:
        """Pin live blocks on behalf of a park-preempted request. The
        parker KEEPS its references and its reservation — parking only
        records that the blocks must survive until ``unpark``. A shared
        block may be parked by several preempted sharers at once."""
        for b in block_ids:
            assert self._refs[b] > 0, f"park of unreferenced block {b}"
            assert b not in self._free_set
            self._parked[b] = self._parked.get(b, 0) + 1
            assert self._refs[b] >= self._parked[b], \
                f"parked count exceeds refs on block {b}"

    def unpark(self, block_ids: Sequence[int]) -> None:
        """Drop one parker from each block (resume or recompute-preempt of
        a parked request). References are untouched — the caller still
        owns them and releases them through the normal paths."""
        for b in block_ids:
            n = self._parked.get(b, 0)
            assert n > 0, f"unpark of unparked block {b}"
            if n == 1:
                del self._parked[b]
            else:
                self._parked[b] = n - 1

    # ---- prefix index --------------------------------------------------------
    def publish(self, block_id: int, digest: int, *, head: bool = False,
                parent: int = 0) -> bool:
        """Register a FULL, written block under its chain digest. First
        writer wins: if the digest is already indexed (a concurrent
        request published the same content) the block stays private and
        ``False`` is returned. The block must be live — its publisher
        still references it. ``parent`` is the chain-parent digest (0 for
        heads), recorded so a later demote keeps the chain promotable; a
        stale host-tier entry under the same digest is superseded by the
        freshly-written device copy (single-tier residence)."""
        if digest in self._index:
            return False
        assert self._refs[block_id] > 0, "publish of an unreferenced block"
        assert block_id not in self._hash_of, "block already published"
        self._index[digest] = block_id
        self._hash_of[block_id] = digest
        self._parent_of[digest] = parent
        if self._host is not None:
            self._host.discard(digest)
        # no accounting change: the block stays covered by its publisher's
        # reservation until the publisher releases it (see ``release``)
        if head:
            self._head_digests.add(digest)
        return True

    def lookup(self, digests: Sequence[int]) -> List[int]:
        """Longest cached chain: walk ``digests`` (parent-chained, depth
        order) and return the matched block ids — stops at the first miss,
        so the result is always a consistent prefix."""
        out: List[int] = []
        for h in digests:
            b = self._index.get(h)
            if b is None:
                break
            out.append(b)
        return out

    def revival_cost(self, block_ids: Sequence[int]) -> int:
        """Implicit reservation units ``share`` of these blocks would add:
        refcount-0 (reclaimable) blocks revive into ``_cached_live``.
        Admission gates must charge this alongside the tail reservation —
        otherwise sharing a parked chain could push ``reserved +
        cached_live`` past ``num_blocks`` and break the allocate-cannot-
        fail guarantee."""
        return sum(1 for b in block_ids if self._refs[b] == 0)

    def head_digests(self) -> frozenset:
        """Depth-1 digests currently indexed — the compact per-instance
        advertisement dispatch tie-breaking consumes (DESIGN.md §Prefix
        cache)."""
        return frozenset(self._head_digests)

    # ---- host tier: tiered lookup + promote (DESIGN.md §Multi-tier KV) ------
    def lookup_tiered(self, digests: Sequence[int]) -> Tuple[List[int],
                                                             List[int]]:
        """Longest chain across BOTH tiers: the device-resident prefix
        (block ids, shareable for free) followed by the contiguous
        host-resident continuation (digests, promotable at a copy cost).
        Stops at the first digest found in neither tier, so each half is
        a consistent chain run and the table layout stays
        [shared device blocks][promoted blocks][private tail]."""
        dev = self.lookup(digests)
        host: List[int] = []
        if self._host is not None:
            for h in digests[len(dev):]:
                if h not in self._host:
                    break
                host.append(h)
        return dev, host

    def host_head_digests(self) -> frozenset:
        """Depth-1 digests resident only in the host tier — advertised
        with a 'host' tier tag so routing prices the promote copy."""
        return (self._host.head_digests() if self._host is not None
                else frozenset())

    def host_pop(self, digest: int):
        """Remove a host-tier entry for promotion and return its payload.
        The caller scatters it into a freshly allocated device block and
        re-publishes the digest there (single-tier residence)."""
        assert self._host is not None
        self.cache_promotions += 1
        return self._host.pop(digest)

    def host_materialize(self, fn) -> int:
        """Flush payloads still pending host materialization (the engine
        calls this once per step, after its single d2h)."""
        return self._host.materialize(fn) if self._host is not None else 0

    # ---- integrity (tests) ---------------------------------------------------
    def check_invariants(self) -> None:
        assert len(self._free) == len(self._free_set)
        live = sum(1 for r in self._refs if r > 0)
        assert live + self.free_blocks == self.num_blocks
        for b in self._free:
            assert self._refs[b] == 0 and b not in self._hash_of
        for b in self._reclaimable:
            assert self._refs[b] == 0 and b in self._hash_of
            assert b not in self._free_set
        assert 0 <= self._cached_live <= sum(1 for b in self._hash_of
                                             if self._refs[b] > 0)
        assert self._reserved + self._cached_live <= self.num_blocks
        assert {h: b for b, h in self._hash_of.items()} == self._index
        for b, n in self._parked.items():
            assert n > 0 and self._refs[b] >= n, \
                f"parked block {b} under-referenced"
            assert b not in self._free_set and b not in self._reclaimable
        # device index carries a parent link for every digest it holds
        assert set(self._parent_of) == set(self._index)
        if self._host is not None:
            # host-tier analogue of the device invariant: bounded capacity,
            # single-tier residence, every chain link's parent reachable
            self._host.check(self._tier_resident)
            assert not (self._host.digests() & set(self._index)), \
                "digest resident in both tiers"

    def check_drained(self) -> None:
        """A drained allocator holds NOTHING on behalf of requests: no
        reservations, no parked blocks, no referenced blocks. Reclaimable
        cached chains (refcount 0, content-indexed) are fine — they are
        free capacity wearing a name (DESIGN.md §Fault tolerance: the
        drain-time leak check every server test runs)."""
        self.check_invariants()
        assert self._reserved == 0, \
            f"leaked reservations: {self._reserved} blocks"
        assert not self._parked, f"leaked parked blocks: {self._parked}"
        assert self.allocated_blocks == 0, \
            f"leaked refcounts: {self.allocated_blocks} blocks still live"
