"""Block-granular KV-cache allocator (the vLLM PagedAttention role),
now **refcounted and prefix-shared** (DESIGN.md §Prefix cache).

The engine owns one global KV *pool* per model — a pytree whose leaves are
``[L, num_blocks, block_size, Hkv, Dh]`` — and every running request owns an
ordered list of physical block ids (its *block table*). Logical token
position ``t`` of a request lives at ``(table[t // BS], t % BS)``.

``BlockAllocator`` hands out physical blocks and tracks three quantities:

  * **referenced** blocks — refcount >= 1, physically backing written KV of
    at least one live request (true memory pressure; what load/bid
    accounting reports). A *shared* prefix block counts ONCE no matter how
    many requests' tables point at it.
  * **cached** blocks — refcount 0 but still holding a published prefix
    block (reachable through :class:`PrefixIndex`). They are *reclaimable*:
    they count as free capacity and are evicted LRU when the free list
    runs dry. ``share`` revives them (0 -> 1) without any copy.
  * **reserved** blocks — the worst-case footprint of every admitted
    request, ``ceil(min(prompt + max_new_tokens, max_seq) / BS)`` minus
    the cached blocks it shares (admission reserves only the uncached
    tail — DESIGN.md §Prefix cache).

Admission gates on *reservations*, growth allocates *incrementally*. With
sharing, the non-negotiable invariant is

    reserved + cached_live <= num_blocks

where ``cached_live`` counts cached blocks that are still referenced by
sharers but whose *allocating owner* has already released them: such a
block outlived the reservation that covered it (sharers reserved only
their tails), so the allocator carries one implicit reservation unit
for it.
Every live block is then covered — by a request reservation (private
blocks) or by ``cached_live`` (shared blocks) — hence a mid-decode
allocation can never fail and ``free_tokens()`` can never go negative.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


def blocks_for(tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``tokens`` KV rows (>=0)."""
    return max(0, -(-int(tokens) // block_size))


def chain_hash(parent: int, tokens) -> int:
    """Radix-style content digest of one FULL block: 64-bit
    ``hash(parent_hash, block_tokens)``. Deterministic across processes
    (sha1, not Python's randomized hash); collision probability is
    negligible at pool scale — production would verify tokens on hit,
    exactly as vLLM's prefix cache does."""
    h = hashlib.sha1()
    h.update(int(parent).to_bytes(8, "little", signed=True))
    h.update(np.asarray(tokens, np.int32).tobytes())
    return int.from_bytes(h.digest()[:8], "little", signed=True)


def prompt_chain(prompt, block_size: int,
                 limit: Optional[int] = None) -> List[int]:
    """Chained digests of a prompt's FULL blocks (partial tail excluded).
    ``limit`` caps the number of blocks hashed (lookup caps at
    ``(len(prompt) - 1) // BS`` so a fully-cached identical prompt still
    prefill-computes >= 1 token — the first token needs its logits)."""
    n = len(prompt) // block_size
    if limit is not None:
        n = min(n, limit)
    out: List[int] = []
    parent = 0
    for j in range(n):
        parent = chain_hash(parent, prompt[j * block_size:(j + 1) * block_size])
        out.append(parent)
    return out


@dataclasses.dataclass
class BlockAllocator:
    num_blocks: int
    block_size: int

    def __post_init__(self) -> None:
        assert self.num_blocks > 0 and self.block_size > 0
        # LIFO free list: recently-freed (still-warm) blocks are reused
        # first. The set mirror makes the double-free assert O(1) instead
        # of an O(free-list) membership scan per freed block.
        self._free: List[int] = list(range(self.num_blocks - 1, -1, -1))
        self._free_set = set(self._free)
        self._reserved = 0
        # ---- prefix sharing state (DESIGN.md §Prefix cache) ----
        self._refs = [0] * self.num_blocks          # per-block refcount
        self._hash_of: Dict[int, int] = {}          # cached block -> digest
        self._index: Dict[int, int] = {}            # digest -> block id
        self._head_digests: set = set()             # depth-1 digests (dispatch)
        # refcount-0 cached blocks, LRU order (dict preserves insertion;
        # least-recently-released first)
        self._reclaimable: Dict[int, None] = {}
        self._cached_live = 0        # cached AND referenced (implicit resv)
        # parked blocks (DESIGN.md §SLO scheduling & preemption): per-block
        # count of park-preempted requests pinning it. A parked block keeps
        # its references and its covering reservation — parking frees a
        # batch slot, never memory — so it must not be reclaimed or freed
        # while any parker holds it.
        self._parked: Dict[int, int] = {}
        # telemetry
        self.cache_evictions = 0     # cached blocks reclaimed under pressure

    # ---- views -------------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        """Allocatable capacity: the free list plus every reclaimable
        (cached, refcount-0) block — a cache entry never blocks admission."""
        return len(self._free) + len(self._reclaimable)

    @property
    def allocated_blocks(self) -> int:
        """Blocks referenced by at least one live request (shared blocks
        count once)."""
        return self.num_blocks - self.free_blocks

    @property
    def cached_blocks(self) -> int:
        """Published blocks currently resident (referenced or reclaimable)."""
        return len(self._hash_of)

    @property
    def reserved_blocks(self) -> int:
        return self._reserved

    def allocated_tokens(self) -> int:
        return self.allocated_blocks * self.block_size

    def free_tokens(self) -> int:
        return self.free_blocks * self.block_size

    def ref(self, block_id: int) -> int:
        return self._refs[block_id]

    @property
    def parked_blocks(self) -> int:
        """Blocks pinned by at least one park-preempted request."""
        return len(self._parked)

    @property
    def headroom_blocks(self) -> int:
        """Blocks an admission gate could still reserve."""
        return self.num_blocks - self._reserved - self._cached_live

    # ---- admission reservation ----------------------------------------------
    def can_reserve(self, n_blocks: int) -> bool:
        return self._reserved + self._cached_live + n_blocks <= self.num_blocks

    def reserve(self, n_blocks: int) -> None:
        assert self.can_reserve(n_blocks), \
            f"reserve({n_blocks}) over capacity " \
            f"({self._reserved}+{self._cached_live}/{self.num_blocks})"
        self._reserved += n_blocks

    def unreserve(self, n_blocks: int) -> None:
        self._reserved -= n_blocks
        assert self._reserved >= 0

    # ---- physical blocks -----------------------------------------------------
    def allocate(self, n_blocks: int) -> List[int]:
        """Pop ``n_blocks`` fresh private block ids (refcount 1). Caller
        must hold a covering reservation — under the invariant this cannot
        fail. When the free list runs dry, refcount-0 cached blocks are
        reclaimed LRU (their index entries drop; sharing them is no longer
        possible, their content is about to be overwritten)."""
        assert n_blocks <= self.free_blocks, \
            f"allocator invariant broken: want {n_blocks}, " \
            f"free {self.free_blocks}"
        out: List[int] = []
        for _ in range(n_blocks):
            if not self._free:
                self._reclaim_one()
            b = self._free.pop()
            self._free_set.discard(b)
            assert self._refs[b] == 0 and b not in self._hash_of
            self._refs[b] = 1
            out.append(b)
        assert self.allocated_blocks <= self._reserved + self._cached_live, \
            "allocated blocks exceeded reservations"
        return out

    def _reclaim_one(self) -> None:
        """Evict the least-recently-released cached block: drop its index
        entry and hand the physical block back to the free list. Never
        touches a referenced block (those are not in ``_reclaimable``)."""
        b = next(iter(self._reclaimable))
        del self._reclaimable[b]
        assert self._refs[b] == 0
        h = self._hash_of.pop(b)
        self._index.pop(h, None)
        self._head_digests.discard(h)
        self._free.append(b)
        self._free_set.add(b)
        self.cache_evictions += 1

    def release(self, block_ids: Sequence[int], *, owned: bool = True) -> None:
        """Drop one reference per block.

        ``owned=True`` means the caller *allocated* these blocks (they were
        covered by its admission reservation); ``owned=False`` means the
        references came from ``share``. The distinction keeps the implicit
        reservation exact: when an owner leaves a cached block behind with
        sharers still referencing it, the block is no longer covered by any
        request reservation, so one ``_cached_live`` unit takes over; the
        last sharer's release retires the unit. A block reaching refcount 0
        goes back to the free list — unless it is published in the prefix
        index, in which case it parks in the reclaimable LRU (free
        capacity, revivable by ``share``)."""
        for b in block_ids:
            assert 0 <= b < self.num_blocks and b not in self._free_set, \
                f"double free / bad block id {b}"
            assert self._refs[b] > 0, f"double free / bad block id {b}"
            assert self._refs[b] - 1 >= self._parked.get(b, 0), \
                f"release would strand parked block {b}"
            self._refs[b] -= 1
            cached = b in self._hash_of
            assert cached or self._refs[b] == 0, \
                f"uncached block {b} was shared"
            if self._refs[b] == 0:
                if cached:                  # park, don't free
                    if not owned:
                        self._cached_live -= 1
                    self._reclaimable[b] = None
                else:
                    self._free.append(b)
                    self._free_set.add(b)
            elif owned:
                # owner leaves, sharers remain: coverage moves from the
                # owner's reservation to the allocator's implicit unit
                self._cached_live += 1

    # back-compat alias (pre-refcount callers allocated everything they free)
    def free(self, block_ids: Sequence[int]) -> None:
        self.release(block_ids, owned=True)

    def share(self, block_ids: Sequence[int]) -> None:
        """Take one reference per block. Reviving a reclaimable cached
        block (0 -> 1) removes it from the LRU and adds its implicit
        reservation unit — see the module invariant."""
        for b in block_ids:
            assert b not in self._free_set, f"share of free block {b}"
            if self._refs[b] == 0:
                assert b in self._reclaimable, f"share of free block {b}"
                del self._reclaimable[b]
                self._cached_live += 1
            self._refs[b] += 1

    # ---- preemption park/unpark ---------------------------------------------
    def park(self, block_ids: Sequence[int]) -> None:
        """Pin live blocks on behalf of a park-preempted request. The
        parker KEEPS its references and its reservation — parking only
        records that the blocks must survive until ``unpark``. A shared
        block may be parked by several preempted sharers at once."""
        for b in block_ids:
            assert self._refs[b] > 0, f"park of unreferenced block {b}"
            assert b not in self._free_set
            self._parked[b] = self._parked.get(b, 0) + 1
            assert self._refs[b] >= self._parked[b], \
                f"parked count exceeds refs on block {b}"

    def unpark(self, block_ids: Sequence[int]) -> None:
        """Drop one parker from each block (resume or recompute-preempt of
        a parked request). References are untouched — the caller still
        owns them and releases them through the normal paths."""
        for b in block_ids:
            n = self._parked.get(b, 0)
            assert n > 0, f"unpark of unparked block {b}"
            if n == 1:
                del self._parked[b]
            else:
                self._parked[b] = n - 1

    # ---- prefix index --------------------------------------------------------
    def publish(self, block_id: int, digest: int, *, head: bool = False) -> bool:
        """Register a FULL, written block under its chain digest. First
        writer wins: if the digest is already indexed (a concurrent
        request published the same content) the block stays private and
        ``False`` is returned. The block must be live — its publisher
        still references it."""
        if digest in self._index:
            return False
        assert self._refs[block_id] > 0, "publish of an unreferenced block"
        assert block_id not in self._hash_of, "block already published"
        self._index[digest] = block_id
        self._hash_of[block_id] = digest
        # no accounting change: the block stays covered by its publisher's
        # reservation until the publisher releases it (see ``release``)
        if head:
            self._head_digests.add(digest)
        return True

    def lookup(self, digests: Sequence[int]) -> List[int]:
        """Longest cached chain: walk ``digests`` (parent-chained, depth
        order) and return the matched block ids — stops at the first miss,
        so the result is always a consistent prefix."""
        out: List[int] = []
        for h in digests:
            b = self._index.get(h)
            if b is None:
                break
            out.append(b)
        return out

    def revival_cost(self, block_ids: Sequence[int]) -> int:
        """Implicit reservation units ``share`` of these blocks would add:
        refcount-0 (reclaimable) blocks revive into ``_cached_live``.
        Admission gates must charge this alongside the tail reservation —
        otherwise sharing a parked chain could push ``reserved +
        cached_live`` past ``num_blocks`` and break the allocate-cannot-
        fail guarantee."""
        return sum(1 for b in block_ids if self._refs[b] == 0)

    def head_digests(self) -> frozenset:
        """Depth-1 digests currently indexed — the compact per-instance
        advertisement dispatch tie-breaking consumes (DESIGN.md §Prefix
        cache)."""
        return frozenset(self._head_digests)

    # ---- integrity (tests) ---------------------------------------------------
    def check_invariants(self) -> None:
        assert len(self._free) == len(self._free_set)
        live = sum(1 for r in self._refs if r > 0)
        assert live + self.free_blocks == self.num_blocks
        for b in self._free:
            assert self._refs[b] == 0 and b not in self._hash_of
        for b in self._reclaimable:
            assert self._refs[b] == 0 and b in self._hash_of
            assert b not in self._free_set
        assert 0 <= self._cached_live <= sum(1 for b in self._hash_of
                                             if self._refs[b] > 0)
        assert self._reserved + self._cached_live <= self.num_blocks
        assert {h: b for b, h in self._hash_of.items()} == self._index
        for b, n in self._parked.items():
            assert n > 0 and self._refs[b] >= n, \
                f"parked block {b} under-referenced"
            assert b not in self._free_set and b not in self._reclaimable

    def check_drained(self) -> None:
        """A drained allocator holds NOTHING on behalf of requests: no
        reservations, no parked blocks, no referenced blocks. Reclaimable
        cached chains (refcount 0, content-indexed) are fine — they are
        free capacity wearing a name (DESIGN.md §Fault tolerance: the
        drain-time leak check every server test runs)."""
        self.check_invariants()
        assert self._reserved == 0, \
            f"leaked reservations: {self._reserved} blocks"
        assert not self._parked, f"leaked parked blocks: {self._parked}"
        assert self.allocated_blocks == 0, \
            f"leaked refcounts: {self.allocated_blocks} blocks still live"
