"""Serving request lifecycle."""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional

import numpy as np


class State(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    MIGRATING = "migrating"
    PREEMPTED = "preempted"
    FINISHED = "finished"


@dataclasses.dataclass
class ServeRequest:
    req_id: int
    prompt: np.ndarray                # int32 [T]
    max_new_tokens: int
    arrival_step: int = 0
    state: State = State.WAITING
    generated: List[int] = dataclasses.field(default_factory=list)
    first_token_step: Optional[int] = None
    finish_step: Optional[int] = None
    engine_id: Optional[int] = None
    slot: Optional[int] = None
    eos_token: Optional[int] = None
    rejected: bool = False            # prompt can never fit the engine
    # fault tolerance (DESIGN.md §Fault tolerance): failed = recovery
    # budget exhausted after its engine died (excluded from served
    # metrics like rejected); redispatches = dead-engine recoveries this
    # request survived (each replays prompt + generated-so-far elsewhere)
    failed: bool = False
    redispatches: int = 0
    # prefill progress (chunked engines): prompt tokens whose KV is
    # written. Whole-prompt paths set it to len(prompt) at prefill; a
    # migrated half-prefilled request carries it to the receiver, which
    # resumes chunking from here.
    ctx_done: int = 0
    # prefix-cache state (DESIGN.md §Prefix cache): prompt tokens served
    # from this engine's shared block index — always block-aligned, <=
    # ctx_done once running. A migrated shared prefix re-imports as
    # private, so import_request resets this to 0.
    cached_tokens: int = 0
    # workload identity of a shared prefix (set by requests_from_trace for
    # traces carrying prefix groups). The REAL engine never reads these —
    # it matches on token content — but the FakeEngine parity harness and
    # dispatch-digest tests key on them.
    prefix_group: int = -1
    prefix_len: int = 0
    # (block_size, chain digests) memo — the prompt is immutable, so its
    # digest chain is computed once, not per hint probe/admission check
    prefix_digests_memo: Optional[tuple] = dataclasses.field(
        default=None, repr=False, compare=False)
    # per-engine token counts (load-balance accounting, Fig. 16)
    tokens_by_engine: Dict[int, int] = dataclasses.field(default_factory=dict)
    # --- SLO scheduling & preemption (DESIGN.md §SLO scheduling) ---
    # service class (repro.sched.slo.SLO_CLASSES; unknown -> standard)
    slo_class: str = "standard"
    # recompute-preemption resume state: when set, prefill rebuilds KV for
    # resume_tokens[:prefill_target] (= prompt + generated[:-1]) instead of
    # the bare prompt, then decoding continues from generated[-1].
    resume_tokens: Optional[np.ndarray] = dataclasses.field(
        default=None, repr=False, compare=False)
    prefill_target: Optional[int] = None
    # waiting-queue sort key (repro.sched.slo.queue_key), stamped at submit
    sched_key: Optional[tuple] = dataclasses.field(
        default=None, repr=False, compare=False)
    preemptions: int = 0
    # starvation/aging guard (DESIGN.md §SLO scheduling): step at which a
    # recompute preemption re-enqueued this request; while it waits its
    # queue key is promoted one class per elapsed TTFT budget
    # (sched.slo.aging_promotion). None = never recompute-preempted.
    preempted_step: Optional[int] = None

    @property
    def length(self) -> int:
        return len(self.prompt) + len(self.generated)

    @property
    def prefill_target_len(self) -> int:
        """Rows prefill must write before decode (re)starts: the prompt
        for fresh requests, the resume prefix for recompute-preempted."""
        return (self.prefill_target if self.prefill_target is not None
                else len(self.prompt))

    @property
    def prefilling(self) -> bool:
        return self.ctx_done < self.prefill_target_len

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.max_new_tokens:
            return True
        return bool(self.generated and self.eos_token is not None
                    and self.generated[-1] == self.eos_token)
