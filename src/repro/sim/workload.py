"""Workload generation: ShareGPT-shaped length distributions + Poisson
arrivals (paper §6.1).

The real ShareGPT trace is offline-unavailable here; the generator
reproduces its documented shape — a log-normal body of short/medium
dialogue turns with a Pareto long-context tail (paper Fig. 1 skew),
truncated at the 128K context window. Drop in a real trace via
``trace_requests`` if one is available.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

MAX_CONTEXT = 131_072


@dataclasses.dataclass(frozen=True)
class Request:
    req_id: int
    arrival: float
    input_len: int
    output_len: int

    @property
    def final_len(self) -> int:
        return self.input_len + self.output_len


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    rate: float                    # Poisson arrivals/s
    duration: float                # seconds of arrivals
    seed: int = 0
    # log-normal body (ShareGPT-ish medians ~ 700 in / 250 out)
    in_mu: float = 6.3
    in_sigma: float = 1.3
    out_mu: float = 5.3
    out_sigma: float = 1.0
    # Pareto long-context tail
    tail_frac: float = 0.06
    tail_alpha: float = 1.1
    tail_scale: float = 8000.0
    # distribution drift (paper §4.3 motivation): in_mu shifts by drift_mu
    # over the run -> the offline plan goes stale, refinement must adapt
    drift_mu: float = 0.0
    max_context: int = MAX_CONTEXT


def sample_lengths(spec: WorkloadSpec, n: int,
                   rng: np.random.Generator,
                   phase: Optional[np.ndarray] = None
                   ) -> Tuple[np.ndarray, np.ndarray]:
    mu = spec.in_mu + (spec.drift_mu * phase if phase is not None else 0.0)
    ins = rng.lognormal(mu, spec.in_sigma, n)
    tail = rng.random(n) < spec.tail_frac
    pareto = spec.tail_scale * (1 + rng.pareto(spec.tail_alpha, n))
    ins = np.where(tail, pareto, ins)
    outs = rng.lognormal(spec.out_mu, spec.out_sigma, n)
    ins = np.clip(ins, 16, spec.max_context - 64).astype(np.int64)
    outs = np.clip(outs, 8, None).astype(np.int64)
    outs = np.minimum(outs, spec.max_context - ins)
    return ins, outs


def generate(spec: WorkloadSpec) -> List[Request]:
    rng = np.random.default_rng(spec.seed)
    n = max(1, rng.poisson(spec.rate * spec.duration))
    arrivals = np.sort(rng.uniform(0.0, spec.duration, n))
    ins, outs = sample_lengths(spec, n, rng,
                               phase=arrivals / max(spec.duration, 1e-9))
    return [Request(i, float(arrivals[i]), int(ins[i]), int(outs[i]))
            for i in range(n)]


def longtail_spec(rate: float, duration: float, *, seed: int = 0,
                  tail_frac: float = 0.08,
                  max_context: int = MAX_CONTEXT) -> WorkloadSpec:
    """The scenario chunked prefill exists for (paper §2.1 / Fig. 1): a
    log-normal body of ordinary dialogue turns with a heavy 32K–128K
    *prompt* tail — long-context requests whose monolithic prefill would
    freeze a whole instance for seconds. The Pareto tail is scaled so the
    bulk of tail prompts lands in [32K, 128K] (alpha 1.05 ⇒ a 128K-capped
    median around 60K)."""
    return WorkloadSpec(rate=rate, duration=duration, seed=seed,
                        tail_frac=tail_frac, tail_alpha=1.05,
                        tail_scale=32_000.0, max_context=max_context)


def generate_longtail(rate: float, duration: float, *, seed: int = 0,
                      max_context: int = MAX_CONTEXT) -> List[Request]:
    """`generate` over `longtail_spec` — the benchmark entry point
    (`benchmarks/bench_chunked_prefill.py`, fig-6/7 long-context runs)."""
    return generate(longtail_spec(rate, duration, seed=seed,
                                  max_context=max_context))


def trace_requests(path: str, rate: float, seed: int = 0) -> List[Request]:
    """Load (input_len, output_len) pairs from a CSV trace file and attach
    Poisson arrivals — the hook for a real ShareGPT trace."""
    pairs = np.loadtxt(path, delimiter=",", dtype=np.int64).reshape(-1, 2)
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, len(pairs))
    t = np.cumsum(gaps)
    return [Request(i, float(t[i]), int(a), int(b))
            for i, (a, b) in enumerate(pairs)]
