"""Workload generation: ShareGPT-shaped length distributions + Poisson
arrivals (paper §6.1).

The real ShareGPT trace is offline-unavailable here; the generator
reproduces its documented shape — a log-normal body of short/medium
dialogue turns with a Pareto long-context tail (paper Fig. 1 skew),
truncated at the 128K context window. Drop in a real trace via
``trace_requests`` if one is available.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

MAX_CONTEXT = 131_072


@dataclasses.dataclass(frozen=True)
class Request:
    req_id: int
    arrival: float
    input_len: int
    output_len: int
    # shared-prefix identity (DESIGN.md §Prefix cache): requests with the
    # same non-negative ``prefix_group`` share their first ``prefix_len``
    # prompt tokens (a system prompt / earlier conversation turns). -1 =
    # no shared prefix. The simulator's group-granular cache model and the
    # server replay (literal shared tokens) both key on these.
    prefix_group: int = -1
    prefix_len: int = 0
    # SLO service class (repro.sched.slo.SLO_CLASSES): drives queue
    # ordering and preemption eligibility in both sim and real engines.
    slo_class: str = "standard"

    @property
    def final_len(self) -> int:
        return self.input_len + self.output_len


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    rate: float                    # Poisson arrivals/s
    duration: float                # seconds of arrivals
    seed: int = 0
    # log-normal body (ShareGPT-ish medians ~ 700 in / 250 out)
    in_mu: float = 6.3
    in_sigma: float = 1.3
    out_mu: float = 5.3
    out_sigma: float = 1.0
    # Pareto long-context tail
    tail_frac: float = 0.06
    tail_alpha: float = 1.1
    tail_scale: float = 8000.0
    # distribution drift (paper §4.3 motivation): in_mu shifts by drift_mu
    # over the run -> the offline plan goes stale, refinement must adapt
    drift_mu: float = 0.0
    max_context: int = MAX_CONTEXT


def sample_lengths(spec: WorkloadSpec, n: int,
                   rng: np.random.Generator,
                   phase: Optional[np.ndarray] = None
                   ) -> Tuple[np.ndarray, np.ndarray]:
    mu = spec.in_mu + (spec.drift_mu * phase if phase is not None else 0.0)
    ins = rng.lognormal(mu, spec.in_sigma, n)
    tail = rng.random(n) < spec.tail_frac
    pareto = spec.tail_scale * (1 + rng.pareto(spec.tail_alpha, n))
    ins = np.where(tail, pareto, ins)
    outs = rng.lognormal(spec.out_mu, spec.out_sigma, n)
    ins = np.clip(ins, 16, spec.max_context - 64).astype(np.int64)
    outs = np.clip(outs, 8, None).astype(np.int64)
    outs = np.minimum(outs, spec.max_context - ins)
    return ins, outs


def generate(spec: WorkloadSpec) -> List[Request]:
    rng = np.random.default_rng(spec.seed)
    n = max(1, rng.poisson(spec.rate * spec.duration))
    arrivals = np.sort(rng.uniform(0.0, spec.duration, n))
    ins, outs = sample_lengths(spec, n, rng,
                               phase=arrivals / max(spec.duration, 1e-9))
    return [Request(i, float(arrivals[i]), int(ins[i]), int(outs[i]))
            for i in range(n)]


def longtail_spec(rate: float, duration: float, *, seed: int = 0,
                  tail_frac: float = 0.08,
                  max_context: int = MAX_CONTEXT) -> WorkloadSpec:
    """The scenario chunked prefill exists for (paper §2.1 / Fig. 1): a
    log-normal body of ordinary dialogue turns with a heavy 32K–128K
    *prompt* tail — long-context requests whose monolithic prefill would
    freeze a whole instance for seconds. The Pareto tail is scaled so the
    bulk of tail prompts lands in [32K, 128K] (alpha 1.05 ⇒ a 128K-capped
    median around 60K)."""
    return WorkloadSpec(rate=rate, duration=duration, seed=seed,
                        tail_frac=tail_frac, tail_alpha=1.05,
                        tail_scale=32_000.0, max_context=max_context)


def generate_longtail(rate: float, duration: float, *, seed: int = 0,
                      max_context: int = MAX_CONTEXT) -> List[Request]:
    """`generate` over `longtail_spec` — the benchmark entry point
    (`benchmarks/bench_chunked_prefill.py`, fig-6/7 long-context runs)."""
    return generate(longtail_spec(rate, duration, seed=seed,
                                  max_context=max_context))


@dataclasses.dataclass(frozen=True)
class SharedPrefixSpec:
    """Shared-prefix workload (DESIGN.md §Prefix cache): the production
    shape prefix caching exists for — many users hitting a handful of
    long system prompts, plus multi-turn sessions that resend their whole
    history. ``num_groups`` prefix groups with Zipf-ish popularity; each
    request is ``prefix + fresh suffix``. Turn depth models multi-turn
    growth: turn t of a session extends the group prefix by (t-1) *
    ``turn_len`` tokens — later turns share everything the earlier turns
    sent, which is exactly what a radix prefix index exploits."""
    rate: float
    duration: float
    seed: int = 0
    num_groups: int = 4
    prefix_len: int = 1024         # system-prompt tokens per group
    zipf_a: float = 1.5            # group popularity skew
    suffix_mu: float = 5.0         # log-normal fresh-suffix body
    suffix_sigma: float = 0.8
    out_mu: float = 5.3
    out_sigma: float = 1.0
    turns: int = 1                 # max conversation depth per group
    turn_len: int = 256            # tokens a full earlier turn adds
    max_context: int = MAX_CONTEXT


def shared_prefix_spec(rate: float, duration: float, *, seed: int = 0,
                       num_groups: int = 4, prefix_len: int = 1024,
                       turns: int = 1,
                       max_context: int = MAX_CONTEXT) -> SharedPrefixSpec:
    """The scenario the refcounted prefix cache targets (benchmark entry
    point — `benchmarks/bench_prefix_cache.py`, `compare_policies
    (workload="shared_prefix")`)."""
    return SharedPrefixSpec(rate=rate, duration=duration, seed=seed,
                            num_groups=num_groups, prefix_len=prefix_len,
                            turns=turns, max_context=max_context)


def generate_shared_prefix(spec: SharedPrefixSpec) -> List[Request]:
    rng = np.random.default_rng(spec.seed)
    n = max(1, rng.poisson(spec.rate * spec.duration))
    arrivals = np.sort(rng.uniform(0.0, spec.duration, n))
    groups = np.minimum(rng.zipf(spec.zipf_a, n) - 1,
                        spec.num_groups - 1).astype(np.int64)
    depth = rng.integers(1, spec.turns + 1, n)
    prefix = spec.prefix_len + (depth - 1) * spec.turn_len
    suffix = np.clip(rng.lognormal(spec.suffix_mu, spec.suffix_sigma, n),
                     16, None).astype(np.int64)
    ins = np.minimum(prefix + suffix, spec.max_context - 64)
    prefix = np.minimum(prefix, ins - 16)     # >= 16 fresh tokens always
    outs = np.clip(rng.lognormal(spec.out_mu, spec.out_sigma, n),
                   8, None).astype(np.int64)
    outs = np.minimum(outs, spec.max_context - ins)
    # multi-turn prefixes nest: group g at depth d is its own sub-group
    # (g, d) — depth-d requests share prefix_len + (d-1)*turn_len tokens
    # with each other AND the shallower turns' prefix, which the sim's
    # group-granular model approximates by the per-(g, d) group
    return [Request(i, float(arrivals[i]), int(ins[i]), int(outs[i]),
                    prefix_group=int(groups[i] * spec.turns + depth[i] - 1),
                    prefix_len=int(prefix[i]))
            for i in range(n)]


def trace_requests(path: str, rate: float, seed: int = 0) -> List[Request]:
    """Load (input_len, output_len) pairs from a CSV trace file and attach
    Poisson arrivals — the hook for a real ShareGPT trace."""
    pairs = np.loadtxt(path, delimiter=",", dtype=np.int64).reshape(-1, 2)
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, len(pairs))
    t = np.cumsum(gaps)
    return [Request(i, float(t[i]), int(a), int(b))
            for i, (a, b) in enumerate(pairs)]


# --------------------------------------------------------------------------
# Open-loop arrival curves (ROADMAP item 4): diurnal + bursty modulation.
# The production shape FCFS folds under — a sinusoidal daily cycle with
# exponential on/off burst windows stacked on top, sampled open-loop (the
# offered load never waits for the system), via Poisson thinning.
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ArrivalCurve:
    """Time-varying arrival intensity λ(t) = base · diurnal(t) · burst(t).

    ``diurnal_amp`` modulates a sinusoid with period ``diurnal_period``
    (amplitude 0 = flat); bursts multiply the rate by ``burst_factor``
    inside on/off windows drawn from exponential gap/length clocks.
    """
    base_rate: float               # mean arrivals/s outside bursts
    diurnal_amp: float = 0.5       # in [0, 1): peak/trough swing
    diurnal_period: float = 60.0   # seconds per "day"
    burst_factor: float = 4.0      # rate multiplier inside a burst
    burst_every: float = 20.0      # mean gap between burst starts
    burst_len: float = 2.0         # mean burst duration


def burst_windows(curve: ArrivalCurve, duration: float,
                  rng: np.random.Generator) -> List[Tuple[float, float]]:
    """Sample the on/off burst windows [(start, end), ...] over a run."""
    windows: List[Tuple[float, float]] = []
    if curve.burst_factor <= 1.0 or curve.burst_every <= 0.0:
        return windows
    t = float(rng.exponential(curve.burst_every))
    while t < duration:
        end = t + float(rng.exponential(curve.burst_len))
        windows.append((t, min(end, duration)))
        t = end + float(rng.exponential(curve.burst_every))
    return windows


def rate_at(curve: ArrivalCurve, t: np.ndarray,
            windows: Sequence[Tuple[float, float]]) -> np.ndarray:
    """Vectorized λ(t) over sampled burst windows."""
    t = np.asarray(t, dtype=np.float64)
    lam = curve.base_rate * (
        1.0 + curve.diurnal_amp
        * np.sin(2.0 * np.pi * t / max(curve.diurnal_period, 1e-9)))
    boost = np.zeros_like(t)
    for s, e in windows:
        boost = np.where((t >= s) & (t < e), 1.0, boost)
    return lam * (1.0 + (curve.burst_factor - 1.0) * boost)


def arrival_times(curve: ArrivalCurve, duration: float,
                  rng: np.random.Generator) -> Tuple[np.ndarray, List[Tuple[float, float]]]:
    """Open-loop arrivals from the non-homogeneous Poisson process λ(t),
    via thinning: draw a homogeneous λ_max candidate stream, keep each
    candidate with probability λ(t)/λ_max."""
    windows = burst_windows(curve, duration, rng)
    lam_max = (curve.base_rate * (1.0 + curve.diurnal_amp)
               * max(curve.burst_factor, 1.0))
    n_cand = rng.poisson(lam_max * duration)
    cand = np.sort(rng.uniform(0.0, duration, n_cand))
    keep = rng.random(n_cand) < rate_at(curve, cand, windows) / max(lam_max, 1e-12)
    return cand[keep], windows


@dataclasses.dataclass(frozen=True)
class SLOWorkloadSpec:
    """The million-user-shaped harness trace: open-loop diurnal+bursty
    arrivals, a multi-tenant Zipf shared-prefix population, an SLO class
    mix, and per-class length profiles (interactive = short chat turns;
    batch = long analytic prompts with a 32K–128K Pareto tail)."""
    curve: ArrivalCurve
    duration: float
    seed: int = 0
    # (class, weight) mix; tuple-of-tuples so the spec stays hashable
    class_mix: Tuple[Tuple[str, float], ...] = (
        ("interactive", 0.5), ("standard", 0.3), ("batch", 0.2))
    # multi-tenant shared prefixes (system prompts), Zipf popularity
    num_tenants: int = 8
    prefix_len: int = 512
    zipf_a: float = 1.4
    prefix_frac: float = 0.7       # fraction of requests with a tenant prefix
    # per-class (in_mu, in_sigma, out_mu, out_sigma) length profiles
    profiles: Tuple[Tuple[str, float, float, float, float], ...] = (
        ("interactive", 4.5, 0.7, 4.0, 0.7),
        ("standard", 6.0, 1.0, 5.3, 0.9),
        ("batch", 7.5, 1.2, 5.8, 1.0))
    # long-context Pareto tail on batch prompts
    tail_frac: float = 0.10
    tail_alpha: float = 1.05
    tail_scale: float = 32_000.0
    max_context: int = MAX_CONTEXT


def slo_spec(rate: float, duration: float, *, seed: int = 0,
             class_mix: Optional[Tuple[Tuple[str, float], ...]] = None,
             num_tenants: int = 8, prefix_len: int = 512,
             max_context: int = MAX_CONTEXT,
             **curve_kw) -> SLOWorkloadSpec:
    """Convenience constructor (benchmark/harness entry point)."""
    kw = {}
    if class_mix is not None:
        kw["class_mix"] = tuple(class_mix)
    return SLOWorkloadSpec(curve=ArrivalCurve(base_rate=rate, **curve_kw),
                           duration=duration, seed=seed,
                           num_tenants=num_tenants, prefix_len=prefix_len,
                           max_context=max_context, **kw)


def generate_slo(spec: SLOWorkloadSpec) -> List[Request]:
    """Sample the open-loop SLO harness trace."""
    rng = np.random.default_rng(spec.seed)
    arrivals, _ = arrival_times(spec.curve, spec.duration, rng)
    n = len(arrivals)
    if n == 0:
        return []
    mixes = [m[0] for m in spec.class_mix]
    probs = np.array([m[1] for m in spec.class_mix], dtype=np.float64)
    probs /= probs.sum()
    cls_idx = rng.choice(len(mixes), size=n, p=probs)
    profiles = {p[0]: p[1:] for p in spec.profiles}
    ins = np.empty(n, dtype=np.float64)
    outs = np.empty(n, dtype=np.float64)
    for ci, name in enumerate(mixes):
        mask = cls_idx == ci
        m = int(mask.sum())
        if not m:
            continue
        in_mu, in_sig, out_mu, out_sig = profiles.get(
            name, (6.0, 1.0, 5.3, 0.9))
        ins[mask] = rng.lognormal(in_mu, in_sig, m)
        outs[mask] = rng.lognormal(out_mu, out_sig, m)
        if name == "batch" and spec.tail_frac > 0:
            tail = rng.random(m) < spec.tail_frac
            pareto = spec.tail_scale * (1 + rng.pareto(spec.tail_alpha, m))
            sub = ins[mask]
            sub[tail] = pareto[tail]
            ins[mask] = sub
    # multi-tenant Zipf prefixes on a fraction of requests
    tenants = np.minimum(rng.zipf(spec.zipf_a, n) - 1,
                         spec.num_tenants - 1).astype(np.int64)
    has_prefix = rng.random(n) < spec.prefix_frac
    plen = np.where(has_prefix, spec.prefix_len, 0).astype(np.int64)
    ins = np.clip(ins + plen, 16, spec.max_context - 64).astype(np.int64)
    plen = np.minimum(plen, ins - 16)
    outs = np.clip(outs, 4, None).astype(np.int64)
    outs = np.minimum(outs, spec.max_context - ins)
    return [Request(i, float(arrivals[i]), int(ins[i]), int(outs[i]),
                    prefix_group=int(tenants[i]) if plen[i] > 0 else -1,
                    prefix_len=int(plen[i]),
                    slo_class=mixes[int(cls_idx[i])])
            for i in range(n)]
