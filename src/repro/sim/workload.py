"""Workload generation: ShareGPT-shaped length distributions + Poisson
arrivals (paper §6.1).

The real ShareGPT trace is offline-unavailable here; the generator
reproduces its documented shape — a log-normal body of short/medium
dialogue turns with a Pareto long-context tail (paper Fig. 1 skew),
truncated at the 128K context window. Drop in a real trace via
``trace_requests`` if one is available.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

MAX_CONTEXT = 131_072


@dataclasses.dataclass(frozen=True)
class Request:
    req_id: int
    arrival: float
    input_len: int
    output_len: int
    # shared-prefix identity (DESIGN.md §Prefix cache): requests with the
    # same non-negative ``prefix_group`` share their first ``prefix_len``
    # prompt tokens (a system prompt / earlier conversation turns). -1 =
    # no shared prefix. The simulator's group-granular cache model and the
    # server replay (literal shared tokens) both key on these.
    prefix_group: int = -1
    prefix_len: int = 0

    @property
    def final_len(self) -> int:
        return self.input_len + self.output_len


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    rate: float                    # Poisson arrivals/s
    duration: float                # seconds of arrivals
    seed: int = 0
    # log-normal body (ShareGPT-ish medians ~ 700 in / 250 out)
    in_mu: float = 6.3
    in_sigma: float = 1.3
    out_mu: float = 5.3
    out_sigma: float = 1.0
    # Pareto long-context tail
    tail_frac: float = 0.06
    tail_alpha: float = 1.1
    tail_scale: float = 8000.0
    # distribution drift (paper §4.3 motivation): in_mu shifts by drift_mu
    # over the run -> the offline plan goes stale, refinement must adapt
    drift_mu: float = 0.0
    max_context: int = MAX_CONTEXT


def sample_lengths(spec: WorkloadSpec, n: int,
                   rng: np.random.Generator,
                   phase: Optional[np.ndarray] = None
                   ) -> Tuple[np.ndarray, np.ndarray]:
    mu = spec.in_mu + (spec.drift_mu * phase if phase is not None else 0.0)
    ins = rng.lognormal(mu, spec.in_sigma, n)
    tail = rng.random(n) < spec.tail_frac
    pareto = spec.tail_scale * (1 + rng.pareto(spec.tail_alpha, n))
    ins = np.where(tail, pareto, ins)
    outs = rng.lognormal(spec.out_mu, spec.out_sigma, n)
    ins = np.clip(ins, 16, spec.max_context - 64).astype(np.int64)
    outs = np.clip(outs, 8, None).astype(np.int64)
    outs = np.minimum(outs, spec.max_context - ins)
    return ins, outs


def generate(spec: WorkloadSpec) -> List[Request]:
    rng = np.random.default_rng(spec.seed)
    n = max(1, rng.poisson(spec.rate * spec.duration))
    arrivals = np.sort(rng.uniform(0.0, spec.duration, n))
    ins, outs = sample_lengths(spec, n, rng,
                               phase=arrivals / max(spec.duration, 1e-9))
    return [Request(i, float(arrivals[i]), int(ins[i]), int(outs[i]))
            for i in range(n)]


def longtail_spec(rate: float, duration: float, *, seed: int = 0,
                  tail_frac: float = 0.08,
                  max_context: int = MAX_CONTEXT) -> WorkloadSpec:
    """The scenario chunked prefill exists for (paper §2.1 / Fig. 1): a
    log-normal body of ordinary dialogue turns with a heavy 32K–128K
    *prompt* tail — long-context requests whose monolithic prefill would
    freeze a whole instance for seconds. The Pareto tail is scaled so the
    bulk of tail prompts lands in [32K, 128K] (alpha 1.05 ⇒ a 128K-capped
    median around 60K)."""
    return WorkloadSpec(rate=rate, duration=duration, seed=seed,
                        tail_frac=tail_frac, tail_alpha=1.05,
                        tail_scale=32_000.0, max_context=max_context)


def generate_longtail(rate: float, duration: float, *, seed: int = 0,
                      max_context: int = MAX_CONTEXT) -> List[Request]:
    """`generate` over `longtail_spec` — the benchmark entry point
    (`benchmarks/bench_chunked_prefill.py`, fig-6/7 long-context runs)."""
    return generate(longtail_spec(rate, duration, seed=seed,
                                  max_context=max_context))


@dataclasses.dataclass(frozen=True)
class SharedPrefixSpec:
    """Shared-prefix workload (DESIGN.md §Prefix cache): the production
    shape prefix caching exists for — many users hitting a handful of
    long system prompts, plus multi-turn sessions that resend their whole
    history. ``num_groups`` prefix groups with Zipf-ish popularity; each
    request is ``prefix + fresh suffix``. Turn depth models multi-turn
    growth: turn t of a session extends the group prefix by (t-1) *
    ``turn_len`` tokens — later turns share everything the earlier turns
    sent, which is exactly what a radix prefix index exploits."""
    rate: float
    duration: float
    seed: int = 0
    num_groups: int = 4
    prefix_len: int = 1024         # system-prompt tokens per group
    zipf_a: float = 1.5            # group popularity skew
    suffix_mu: float = 5.0         # log-normal fresh-suffix body
    suffix_sigma: float = 0.8
    out_mu: float = 5.3
    out_sigma: float = 1.0
    turns: int = 1                 # max conversation depth per group
    turn_len: int = 256            # tokens a full earlier turn adds
    max_context: int = MAX_CONTEXT


def shared_prefix_spec(rate: float, duration: float, *, seed: int = 0,
                       num_groups: int = 4, prefix_len: int = 1024,
                       turns: int = 1,
                       max_context: int = MAX_CONTEXT) -> SharedPrefixSpec:
    """The scenario the refcounted prefix cache targets (benchmark entry
    point — `benchmarks/bench_prefix_cache.py`, `compare_policies
    (workload="shared_prefix")`)."""
    return SharedPrefixSpec(rate=rate, duration=duration, seed=seed,
                            num_groups=num_groups, prefix_len=prefix_len,
                            turns=turns, max_context=max_context)


def generate_shared_prefix(spec: SharedPrefixSpec) -> List[Request]:
    rng = np.random.default_rng(spec.seed)
    n = max(1, rng.poisson(spec.rate * spec.duration))
    arrivals = np.sort(rng.uniform(0.0, spec.duration, n))
    groups = np.minimum(rng.zipf(spec.zipf_a, n) - 1,
                        spec.num_groups - 1).astype(np.int64)
    depth = rng.integers(1, spec.turns + 1, n)
    prefix = spec.prefix_len + (depth - 1) * spec.turn_len
    suffix = np.clip(rng.lognormal(spec.suffix_mu, spec.suffix_sigma, n),
                     16, None).astype(np.int64)
    ins = np.minimum(prefix + suffix, spec.max_context - 64)
    prefix = np.minimum(prefix, ins - 16)     # >= 16 fresh tokens always
    outs = np.clip(rng.lognormal(spec.out_mu, spec.out_sigma, n),
                   8, None).astype(np.int64)
    outs = np.minimum(outs, spec.max_context - ins)
    # multi-turn prefixes nest: group g at depth d is its own sub-group
    # (g, d) — depth-d requests share prefix_len + (d-1)*turn_len tokens
    # with each other AND the shallower turns' prefix, which the sim's
    # group-granular model approximates by the per-(g, d) group
    return [Request(i, float(arrivals[i]), int(ins[i]), int(outs[i]),
                    prefix_group=int(groups[i] * spec.turns + depth[i] - 1),
                    prefix_len=int(prefix[i]))
            for i in range(n)]


def trace_requests(path: str, rate: float, seed: int = 0) -> List[Request]:
    """Load (input_len, output_len) pairs from a CSV trace file and attach
    Poisson arrivals — the hook for a real ShareGPT trace."""
    pairs = np.loadtxt(path, delimiter=",", dtype=np.int64).reshape(-1, 2)
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, len(pairs))
    t = np.cumsum(gaps)
    return [Request(i, float(t[i]), int(a), int(b))
            for i, (a, b) in enumerate(pairs)]
