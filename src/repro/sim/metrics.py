"""Metrics over a simulation run (paper §6.1: TTFT, TPOT, throughput,
SLO attainment; Fig. 16: per-stage output-token CV)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.sim.instance import Instance, SimRequest


@dataclasses.dataclass
class SimResult:
    completed: List[SimRequest]
    duration: float
    num_submitted: int
    instances: List[Instance]
    policy_name: str
    stage_of_instance: Optional[List[int]] = None

    # ---- latency ----------------------------------------------------------
    @property
    def served(self):
        return [r for r in self.completed if not r.rejected]

    def _arr(self, fn) -> np.ndarray:
        return np.asarray([fn(r) for r in self.served], np.float64)

    def ttft(self) -> np.ndarray:
        return self._arr(lambda r: r.ttft)

    def tpot(self) -> np.ndarray:
        return self._arr(lambda r: r.tpot)

    def normalized_latency(self) -> np.ndarray:
        return self._arr(lambda r: r.normalized_latency)

    def summary(self) -> Dict[str, float]:
        ttft, tpot = self.ttft(), self.tpot()
        nl = self.normalized_latency()
        return {
            "policy": self.policy_name,
            "completed": len(self.served),
            "rejected": len(self.completed) - len(self.served),
            "submitted": self.num_submitted,
            "ttft_mean": float(ttft.mean()) if len(ttft) else float("nan"),
            "ttft_p95": float(np.percentile(ttft, 95)) if len(ttft) else float("nan"),
            "tpot_mean": float(tpot.mean()) if len(tpot) else float("nan"),
            "tpot_p95": float(np.percentile(tpot, 95)) if len(tpot) else float("nan"),
            "norm_latency_mean": float(nl.mean()) if len(nl) else float("nan"),
            "throughput_tok_s": self.throughput(),
        }

    # ---- throughput -------------------------------------------------------
    def throughput(self) -> float:
        toks = sum(r.req.output_len for r in self.served)
        return toks / max(self.duration, 1e-9)

    # ---- SLO (paper §6.4) --------------------------------------------------
    def slo_attainment(self, ttft_slo: float, tpot_slo: float,
                       scale: float = 1.0) -> float:
        if not self.served:
            return 0.0
        ok = sum(1 for r in self.served
                 if r.ttft <= scale * ttft_slo and r.tpot <= scale * tpot_slo)
        return ok / len(self.served)

    # ---- load balance (paper Fig. 16) ---------------------------------------
    def output_tokens_by_instance(self) -> np.ndarray:
        n = len(self.instances)
        out = np.zeros(n)
        for r in self.completed:
            for iid, cnt in r.tokens_by_instance.items():
                out[iid] += cnt
        return out

    def stage_cv(self) -> List[float]:
        """Coefficient of variation of per-instance output tokens, per stage
        (lower = better balanced). Falls back to one global stage."""
        toks = self.output_tokens_by_instance()
        if self.stage_of_instance is None:
            groups = {0: list(range(len(self.instances)))}
        else:
            groups = {}
            for iid, si in enumerate(self.stage_of_instance):
                groups.setdefault(si, []).append(iid)
        cvs = []
        for si in sorted(groups):
            vals = toks[groups[si]]
            mu = vals.mean()
            cvs.append(float(vals.std() / mu) if mu > 0 else 0.0)
        return cvs
