"""Metrics over a simulation run (paper §6.1: TTFT, TPOT, throughput,
SLO attainment; Fig. 16: per-stage output-token CV)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.sched.slo import slo_of
from repro.sim.instance import Instance, SimRequest


# --------------------------------------------------------------------------
# SLO attainment & goodput-under-SLO (paper §6.4) — module-level single
# source of truth: `SimResult.slo_attainment`/`slo_summary` AND
# `serving.MILSServer.summary` both call these, so there is exactly ONE
# SLO formula in the codebase. Entries are (slo_class, ttft, tpot,
# output_tokens) in abstract time units; ``time_scale`` converts the spec
# deadlines into the caller's clock (1.0 for the sim, steps-per-unit for
# the server) and ``scale`` is the paper's SLO-scale sweep knob.
# --------------------------------------------------------------------------
def _slo_ok(ttft: float, tpot: float, ttft_slo: float, tpot_slo: float,
            scale: float = 1.0) -> bool:
    return ttft <= scale * ttft_slo and tpot <= scale * tpot_slo


def slo_attainment(entries: Iterable[Tuple[float, float]],
                   ttft_slo: float, tpot_slo: float,
                   scale: float = 1.0) -> float:
    """Fraction of (ttft, tpot) pairs meeting a fixed SLO pair."""
    entries = list(entries)
    if not entries:
        return 0.0
    ok = sum(1 for ttft, tpot in entries
             if _slo_ok(ttft, tpot, ttft_slo, tpot_slo, scale))
    return ok / len(entries)


def class_slo_summary(entries: Iterable[Tuple[str, float, float, int]],
                      duration: float, *, scale: float = 1.0,
                      time_scale: float = 1.0) -> Dict[str, Dict[str, float]]:
    """Per-SLO-class attainment and goodput-under-SLO.

    ``entries`` are (slo_class, ttft, tpot, output_tokens) per served
    request; each class is judged against ITS OWN deadlines
    (repro.sched.slo.SLO_CLASSES, times ``time_scale`` then ``scale``).
    Goodput counts only tokens of requests that met their class SLO —
    the metric the preemptive scheduler is accepted on.
    """
    per: Dict[str, Dict[str, float]] = {}
    for cls, ttft, tpot, out_tokens in entries:
        spec = slo_of(cls)
        d = per.setdefault(spec.name, {"requests": 0, "slo_ok": 0,
                                       "tokens": 0, "goodput_tokens": 0})
        ok = _slo_ok(ttft, tpot, spec.ttft_slo * time_scale,
                     spec.tpot_slo * time_scale, scale)
        d["requests"] += 1
        d["slo_ok"] += int(ok)
        d["tokens"] += int(out_tokens)
        if ok:
            d["goodput_tokens"] += int(out_tokens)
    dur = max(float(duration), 1e-9)
    for d in per.values():
        d["attainment"] = d["slo_ok"] / max(d["requests"], 1)
        d["goodput_tok_s"] = d["goodput_tokens"] / dur
    return per


# --------------------------------------------------------------------------
# Failure accounting (DESIGN.md §Fault tolerance) — module-level single
# source of truth, like class_slo_summary above: `SimResult.fault_summary`
# AND `serving.MILSServer.summary` both call this, so sim and server
# report chaos runs through exactly ONE formula. ``flags`` is one
# (rejected, failed, redispatches) triple per terminal request;
# ``retries`` is the plane's count of backoff'd migration failures;
# ``downtime`` maps instance id -> accumulated down time in the caller's
# clock (sim seconds / server steps).
# --------------------------------------------------------------------------
def fault_summary(flags: Iterable[Tuple[bool, bool, int]], *,
                  retries: int = 0,
                  downtime: Optional[Dict[int, float]] = None
                  ) -> Dict[str, float]:
    rejected = failed = redispatched = 0
    for rej, fail, redisp in flags:
        rejected += int(bool(rej))
        failed += int(bool(fail))
        redispatched += int(redisp > 0)
    out: Dict[str, float] = {
        "rejected": rejected,
        "failed": failed,
        "redispatched": redispatched,
        "retries": int(retries),
    }
    downtime = downtime or {}
    out["downtime_total"] = float(sum(downtime.values()))
    for iid in sorted(downtime):
        out[f"downtime_i{iid}"] = float(downtime[iid])
    return out


@dataclasses.dataclass
class SimResult:
    completed: List[SimRequest]
    duration: float
    num_submitted: int
    instances: List[Instance]
    policy_name: str
    stage_of_instance: Optional[List[int]] = None
    retries: int = 0                 # plane-counted migration retries

    # ---- latency ----------------------------------------------------------
    @property
    def served(self):
        return [r for r in self.completed
                if not r.rejected and not r.failed]

    def _arr(self, fn) -> np.ndarray:
        return np.asarray([fn(r) for r in self.served], np.float64)

    def ttft(self) -> np.ndarray:
        return self._arr(lambda r: r.ttft)

    def tpot(self) -> np.ndarray:
        return self._arr(lambda r: r.tpot)

    def normalized_latency(self) -> np.ndarray:
        return self._arr(lambda r: r.normalized_latency)

    def fault_summary(self) -> Dict[str, float]:
        """Failure accounting for the run (shared formula with the real
        server — see module-level ``fault_summary``)."""
        return fault_summary(
            ((r.rejected, r.failed, r.redispatches) for r in self.completed),
            retries=self.retries,
            downtime={i.id: i.downtime_s(self.duration)
                      for i in self.instances if i.downtime_s(self.duration)})

    def summary(self) -> Dict[str, float]:
        ttft, tpot = self.ttft(), self.tpot()
        nl = self.normalized_latency()
        out = {
            "policy": self.policy_name,
            "completed": len(self.served),
            "submitted": self.num_submitted,
            "ttft_mean": float(ttft.mean()) if len(ttft) else float("nan"),
            "ttft_p95": float(np.percentile(ttft, 95)) if len(ttft) else float("nan"),
            "tpot_mean": float(tpot.mean()) if len(tpot) else float("nan"),
            "tpot_p95": float(np.percentile(tpot, 95)) if len(tpot) else float("nan"),
            "norm_latency_mean": float(nl.mean()) if len(nl) else float("nan"),
            "throughput_tok_s": self.throughput(),
        }
        out.update(self.fault_summary())
        # multi-tier KV traffic (DESIGN.md §Multi-tier KV); getattr keeps
        # pre-tier Instance stand-ins (test doubles) summarizable
        for k in ("cache_demotions", "cache_drops", "cache_promotions",
                  "promoted_blocks_total"):
            out[k] = sum(getattr(i, k, 0) for i in self.instances)
        return out

    # ---- throughput -------------------------------------------------------
    def throughput(self) -> float:
        toks = sum(r.req.output_len for r in self.served)
        return toks / max(self.duration, 1e-9)

    # ---- SLO (paper §6.4) --------------------------------------------------
    def slo_attainment(self, ttft_slo: float, tpot_slo: float,
                       scale: float = 1.0) -> float:
        return slo_attainment(((r.ttft, r.tpot) for r in self.served),
                              ttft_slo, tpot_slo, scale)

    def slo_summary(self, scale: float = 1.0) -> Dict[str, Dict[str, float]]:
        """Per-class SLO attainment + goodput-under-SLO over the run
        (classes judged against their own SLO_CLASSES deadlines)."""
        return class_slo_summary(
            ((r.req.slo_class, r.ttft, r.tpot, r.req.output_len)
             for r in self.served),
            self.duration, scale=scale)

    def preemption_stats(self) -> Dict[str, int]:
        return {
            "preemptions": sum(i.preemptions for i in self.instances),
            "preempt_recomputes": sum(i.preempt_recomputes
                                      for i in self.instances),
            "resumes": sum(i.resumes for i in self.instances),
            "tpot_skipped": sum(getattr(i, "tpot_skipped", 0)
                                for i in self.instances),
        }

    # ---- load balance (paper Fig. 16) ---------------------------------------
    def output_tokens_by_instance(self) -> np.ndarray:
        n = len(self.instances)
        out = np.zeros(n)
        for r in self.completed:
            for iid, cnt in r.tokens_by_instance.items():
                out[iid] += cnt
        return out

    def stage_cv(self) -> List[float]:
        """Coefficient of variation of per-instance output tokens, per stage
        (lower = better balanced). Falls back to one global stage."""
        toks = self.output_tokens_by_instance()
        if self.stage_of_instance is None:
            groups = {0: list(range(len(self.instances)))}
        else:
            groups = {}
            for iid, si in enumerate(self.stage_of_instance):
                groups.setdefault(si, []).append(iid)
        cvs = []
        for si in sorted(groups):
            vals = toks[groups[si]]
            mu = vals.mean()
            cvs.append(float(vals.std() / mu) if mu > 0 else 0.0)
        return cvs
