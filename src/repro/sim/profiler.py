"""QoE-model profiling (paper §4.1 fitting procedure).

Partition lengths into exponential buckets, and for each (bucket, batch
size B) keep exactly B requests in flight on one instance for a fixed
horizon — whenever one completes, another is enqueued. From the trace,
each request yields its normalized latency Q and its average batch loads
F_k over its lifetime; least squares on (F, Q) gives D.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from repro.core.qoe import QoEModel, fit_qoe
from repro.sim.costmodel import HardwareProfile
from repro.sim.events import EventQueue
from repro.sim.instance import Instance, SimRequest
from repro.sim.workload import Request


def profile_point(profile: HardwareProfile, length_range: Tuple[int, int],
                  batch_size: int, *,
                  output_len: Tuple[int, int] = (128, 320),
                  horizon_s: float = 60.0, capacity: float = 2e6,
                  seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Keep ``batch_size`` in flight with inputs from ``length_range``.
    Output lengths vary across ``output_len`` so ΣL decorrelates from ΣI
    (identifiability of D4 vs D2); the floor follows the paper's
    "discarding those that are too short" (§4.1) — tiny outputs divide
    fixed waits by a small O and blow up normalized-latency variance.
    Returns (F [N,5], Q [N])."""
    rng = np.random.default_rng(seed)
    events = EventQueue()
    inst = Instance(0, profile, capacity, events)
    counter = [0]
    done: List[SimRequest] = []

    def new_request(t: float) -> SimRequest:
        I = int(rng.integers(length_range[0], max(length_range[1],
                                                  length_range[0] + 1)))
        O = int(rng.integers(output_len[0], output_len[1]))
        counter[0] += 1
        return SimRequest(req=Request(counter[0], t, I, O), length=I)

    def on_done(_inst, sr, t):
        done.append(sr)
        if t < horizon_s:
            _inst.enqueue(new_request(t), t)   # keep B in flight

    inst.on_request_done = on_done
    for _ in range(batch_size):
        inst.enqueue(new_request(0.0), 0.0)
    events.run_until(horizon_s * 2)
    while inst.running or inst.waiting:
        if not len(events):
            break
        events.run_until(events.now + horizon_s)

    F = np.asarray([np.asarray(r.feat_sum) / max(r.feat_iters, 1)
                    for r in done])
    Q = np.asarray([r.normalized_latency for r in done])
    return F.reshape(-1, 5), Q


def profile_and_fit(profile: HardwareProfile, *,
                    buckets: Sequence[Tuple[int, int]] = (
                        (128, 256), (256, 512), (512, 1024), (1024, 2048),
                        (2048, 4096), (4096, 8192), (8192, 16384),
                        (16384, 32768)),
                    batch_sizes: Sequence[int] = (1, 2, 4, 8, 16, 32, 64),
                    horizon_s: float = 20.0,
                    seed: int = 0,
                    return_samples: bool = False):
    """Full §4.1 sweep -> fitted QoEModel (optionally with samples)."""
    Fs, Qs = [], []
    for bi, bucket in enumerate(buckets):
        for B in batch_sizes:
            F, Q = profile_point(profile, bucket, B, horizon_s=horizon_s,
                                 seed=seed + 997 * bi + B)
            if len(Q):
                Fs.append(F)
                Qs.append(Q)
    F_all = np.concatenate(Fs, axis=0)
    Q_all = np.concatenate(Qs, axis=0)
    model = fit_qoe(F_all, Q_all)
    if return_samples:
        return model, F_all, Q_all
    return model
